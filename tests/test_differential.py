"""Differential harness for the whole predicate stack: THREE independent
evaluation paths of the same ``Expr`` must agree bit-for-bit on every table —

  1. **naive**: per-node ``Expr.evaluate`` chained one predicate at a time
     (the reference semantics, ``expr.py``);
  2. **fused jnp**: the optimizer fuses the predicate chain into one
     ``fused_mask`` node executed as a single jnp conjunction;
  3. **pallas**: the same fused node stamped ``engine="pallas"`` and executed
     through the Expr->bitset kernel (interpret mode off-TPU), including the
     packed-word round-trip (``Bitset.from_mask``/``to_mask``).

Hypothesis generates random Expr trees over random ColumnarTables (mixed
int32/float32 dtypes, NULL sentinels, NaNs, random validity, ragged
non-block-multiple lengths); the deterministic battery keeps the same
coverage alive on bare containers where hypothesis degrades to skips
(tests/_hyp.py).
"""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax.numpy as jnp

from repro.core.cohort import Bitset
from repro.core.columnar import ColumnarTable, NULL_INT
from repro.kernels.predicate import compilable, predicate_bitset
from repro.study import PlanBuilder, assign_engines, col, execute, optimize
from repro.study.expr import all_of

BLOCK = 64   # small block -> multi-block grids even on tiny tables


def _table(valid=None, **cols) -> ColumnarTable:
    arrs = {}
    for k, v in cols.items():
        a = np.asarray(v)
        arrs[k] = a.astype(np.float32 if a.dtype.kind == "f" else np.int32)
    v = None if valid is None else jnp.asarray(np.asarray(valid, bool))
    return ColumnarTable.from_columns(arrs, valid=v)


def _rand_table(rng, n: int) -> ColumnarTable:
    a = rng.integers(-5, 15, n)
    a[rng.random(n) < 0.25] = int(NULL_INT)
    x = rng.normal(size=n).astype(np.float32)
    x[rng.random(n) < 0.2] = np.nan
    return _table(valid=rng.random(n) < 0.85, id=np.arange(n),
                  a=a, b=rng.integers(-5, 15, n), x=x)


# ---------------------------------------------------------------------------
# the three paths
# ---------------------------------------------------------------------------
def _naive_ids(t: ColumnarTable, exprs) -> list:
    """Reference: chain per-node evaluation, one predicate at a time."""
    cur = t
    for e in exprs:
        cur = cur.filter(e.mask(cur))
    return np.asarray(cur.columns["id"])[cur.valid_numpy()].tolist()


def _engine_ids(t: ColumnarTable, exprs, engine: str) -> list:
    """Build predicate-chain plan, fuse to ONE fused_mask, stamp ``engine``,
    execute, return the surviving row ids (in order — the optimizer appends
    one compaction to the named output, identical for both engines)."""
    b = PlanBuilder()
    nid = b.scan("T")
    for e in exprs:
        nid = b.predicate(nid, e)
    b.set_output("out", nid)
    opt = optimize(b.build(), predicate_engine="jnp")
    assert opt.count_ops().get("fused_mask", 0) == 1
    opt = assign_engines(opt, predicate_engine=engine, block=BLOCK)
    out = execute(opt, {"T": t})[opt.output_ids["out"]]
    return out.to_numpy()["id"].tolist()


def _assert_three_way(t: ColumnarTable, exprs) -> None:
    want = _naive_ids(t, exprs)
    got_jnp = _engine_ids(t, exprs, "jnp")
    got_pal = _engine_ids(t, exprs, "pallas")
    assert got_jnp == want, "fused jnp != naive"
    assert got_pal == want, "pallas kernel != naive"

    # kernel-level + packed-word round-trips on the fused conjunction
    fused = all_of(*exprs)
    param = fused.to_param()
    if compilable(param):
        n = t.capacity
        want_mask = np.asarray(fused.mask(t))
        words, cnt = predicate_bitset(t.columns, t.valid, expr_param=param,
                                      block=BLOCK, interpret=True)
        assert int(cnt) == int(want_mask.sum())
        unpacked = np.asarray(Bitset.to_mask(words, n))
        assert unpacked.tolist() == want_mask.tolist(), "bitset unpack"
        repacked = np.asarray(Bitset.from_mask(jnp.asarray(want_mask)))
        assert np.array_equal(repacked, np.asarray(words)), "bitset repack"


# ---------------------------------------------------------------------------
# deterministic battery (runs without hypothesis)
# ---------------------------------------------------------------------------
CASES = [
    # each leaf op; ragged + block-boundary lengths; NULL/NaN interplay
    ("cmp_int", 63, lambda: [col("a") >= 3]),
    ("cmp_chain", 64, lambda: [col("a") >= 3, col("b") < 10]),
    ("isin", 65, lambda: [col("a").isin([1, 2, 9])]),
    ("isin_empty", 40, lambda: [col("a").isin([])]),
    ("isin_float_probe", 100, lambda: [col("x").isin([0, 1])]),
    ("null_tests", 130, lambda: [col("a").not_null(), col("x").not_null()]),
    ("arith", 129, lambda: [(col("a") + 2) % 3 == 1, col("b") * 2 >= col("a")]),
    ("float_cmp", 128, lambda: [col("x") > 0.25, ~(col("x") <= 0.75)]),
    ("bool_mix", 200, lambda: [(col("a").is_null() | (col("a") > 4))
                               & (col("b") != 7)]),
    ("between", 47, lambda: [col("b").between(-1, 9)]),
    ("deep", 333, lambda: [~((col("a") < 0) | col("x").is_null())
                           & (col("a").isin([3, 4, 5]) | (col("b") % 2 == 0))]),
]


@pytest.mark.parametrize("name,n,mk", CASES, ids=[c[0] for c in CASES])
def test_three_way_battery(name, n, mk):
    rng = np.random.default_rng(hash(name) % 2**31)
    _assert_three_way(_rand_table(rng, n), mk())


def test_three_way_single_row_and_all_invalid():
    rng = np.random.default_rng(7)
    _assert_three_way(_rand_table(rng, 1), [col("a") >= 0])
    t = _table(valid=np.zeros(50, bool), id=np.arange(50), a=np.arange(50),
               b=np.arange(50), x=np.arange(50).astype(np.float32))
    _assert_three_way(t, [col("a") >= 0])


def test_kernel_empty_table():
    words, cnt = predicate_bitset({"a": jnp.zeros((0,), jnp.int32)},
                                  jnp.zeros((0,), bool),
                                  expr_param=(col("a") >= 0).to_param(),
                                  block=BLOCK, interpret=True)
    assert words.shape == (0,) and int(cnt) == 0


def test_oversized_isin_falls_back_to_jnp():
    """Whitelists past the VMEM membership budget are not kernel-compilable;
    assign_engines stamps them back to jnp and execution still agrees."""
    from repro.kernels.predicate import MAX_ISIN_VALUES

    big = col("a").isin(range(MAX_ISIN_VALUES + 1))
    small = col("a").isin(range(8))
    assert not compilable(big.to_param())
    assert compilable(small.to_param())

    rng = np.random.default_rng(3)
    t = _rand_table(rng, 100)
    b = PlanBuilder()
    b.set_output("out", b.predicate(b.scan("T"), big))
    opt = assign_engines(optimize(b.build()), predicate_engine="pallas",
                         block=BLOCK)
    masks = [n for n in opt.nodes if n.op == "fused_mask"]
    assert masks and all(n.get("engine") == "jnp" for n in masks)
    got = execute(opt, {"T": t})[opt.output_ids["out"]].to_numpy()["id"]
    assert got.tolist() == _naive_ids(t, [big])


def test_kernel_rejects_non_boolean_root():
    with pytest.raises(ValueError):
        predicate_bitset({"a": jnp.zeros((4,), jnp.int32)},
                         jnp.ones((4,), bool),
                         expr_param=(col("a") + 1).to_param(),
                         block=BLOCK, interpret=True)
    assert not compilable((col("a") + 1).to_param())
    assert compilable((col("a") >= 1).to_param())


def test_engine_pallas_routes_predicates_through_kernel():
    """Acceptance: under the global ``engine="pallas"`` the optimizer stamps
    every fused_mask with the bitset kernel engine (auto resolves through the
    global engine even off-TPU), and execution stays bit-identical."""
    rng = np.random.default_rng(21)
    t = _rand_table(rng, 150)
    b = PlanBuilder()
    nid = b.predicate(b.predicate(b.scan("T"), col("a") >= 2),
                      col("b") < 9)
    b.set_output("out", nid)
    opt = optimize(b.build(), predicate_engine="auto", engine="pallas")
    masks = [n for n in opt.nodes if n.op == "fused_mask"]
    assert masks and all(n.get("engine") == "pallas" for n in masks)
    assert all(n.get("bitset_word") == "uint32" for n in masks)
    got = execute(opt, {"T": t}, engine="xla")[opt.output_ids["out"]]
    want = _naive_ids(t, [col("a") >= 2, col("b") < 9])
    assert got.to_numpy()["id"].tolist() == want


# ---------------------------------------------------------------------------
# hypothesis: random Expr trees x random tables
# ---------------------------------------------------------------------------
_COLS = ("a", "b", "x")


def _random_pred(draw, depth: int):
    c = col(_COLS[draw(st.integers(0, 2))])
    if depth <= 0 or draw(st.integers(0, 2)) == 0:
        kind = draw(st.integers(0, 4))
        if kind == 0:
            op = draw(st.sampled_from(["==", "!=", "<", "<=", ">", ">="]))
            rhs = (draw(st.integers(-5, 15)) if draw(st.booleans())
                   else draw(st.floats(-2, 2, allow_nan=False, width=32)))
            return {"==": c.__eq__, "!=": c.__ne__, "<": c.__lt__,
                    "<=": c.__le__, ">": c.__gt__, ">=": c.__ge__}[op](rhs)
        if kind == 1:
            vals = draw(st.lists(st.integers(-5, 15), max_size=6))
            return c.isin(vals)
        if kind == 2:
            return c.is_null() if draw(st.booleans()) else c.not_null()
        if kind == 3:
            lo = draw(st.integers(-5, 5))
            return c.between(lo, lo + draw(st.integers(0, 10)))
        # nonzero literal divisor: int division by zero is backend-defined
        return (c + draw(st.integers(0, 3))) % draw(st.integers(1, 4)) \
            == draw(st.integers(0, 3))
    k = draw(st.integers(0, 2))
    l = _random_pred(draw, depth - 1)
    if k == 0:
        return ~l
    r = _random_pred(draw, depth - 1)
    return (l & r) if k == 1 else (l | r)


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_property_three_way_parity(data):
    """naive per-node == fused jnp conjunction == pallas bitset kernel, on
    random trees over random tables (mixed dtypes, sentinels, ragged n)."""
    draw = data.draw
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    n = draw(st.integers(1, 3 * BLOCK + 5))
    exprs = [_random_pred(draw, draw(st.integers(0, 2)))
             for _ in range(draw(st.integers(1, 3)))]
    _assert_three_way(_rand_table(rng, n), exprs)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_bitset_roundtrip(data):
    """Packing is lossless at every length: from_mask ∘ to_mask == id on the
    kernel's words, and popcounts equal mask sums."""
    draw = data.draw
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    n = draw(st.integers(1, 200))
    t = _rand_table(rng, n)
    e = _random_pred(draw, 1)
    param = e.to_param()
    words, cnt = predicate_bitset(t.columns, t.valid, expr_param=param,
                                  block=BLOCK, interpret=True)
    mask = np.asarray(Bitset.to_mask(words, n))
    assert int(cnt) == int(mask.sum())
    assert np.array_equal(np.asarray(Bitset.from_mask(jnp.asarray(mask))),
                          np.asarray(words))
    assert mask.tolist() == np.asarray(e.mask(t)).tolist()
