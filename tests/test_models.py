"""Per-arch smoke tests (reduced configs, CPU): one forward/train step with
shape + finiteness assertions, decode-vs-parallel consistency, and the
config invariants of the full-size (dry-run-only) configs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS, LONG_CONTEXT_OK
from repro.models import get_bundle, all_archs
from repro.models import lm as LM

# Seed-debt triage: the model/mesh stack targets a newer jax than the
# container ships — jax.sharding.AxisType / get_abstract_mesh are absent, so
# every forward pass dies in layers.py/mesh.py.  strict=False + the hasattr
# condition: the day the jax toolchain catches up these run (and must pass)
# again, while *new* regressions elsewhere stay loud.  Tracked in CHANGES.md
# (PR 4) and ROADMAP "Seed state: seed tests failing".
jax_version_xfail = pytest.mark.xfail(
    not hasattr(jax.sharding, "AxisType"), strict=False,
    reason="seed debt: installed jax lacks jax.sharding.AxisType/"
           "get_abstract_mesh required by the model stack")

KEY = jax.random.key(0)


def _mesh_dependent_archs():
    # seamless-m4t-medium (encoder-decoder frontend) never reaches the
    # mesh-dependent sdpa path and passes on the container jax — keep it a
    # HARD test so regressions there stay loud; every other arch needs the
    # missing jax.sharding API and carries the conditional xfail.
    return [a if a == "seamless-m4t-medium"
            else pytest.param(a, marks=jax_version_xfail)
            for a in all_archs()]


def make_batch(cfg, B=2, S=32):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 3, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            KEY, (B, max(64, S // 4), cfg.frontend_dim), jnp.bfloat16)
    if cfg.frontend == "vision_patches":
        batch["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", _mesh_dependent_archs())
def test_arch_smoke_train_step(arch):
    b = get_bundle(arch, reduced=True)
    params = b.init(KEY)
    batch = make_batch(b.cfg)
    loss, grads = jax.value_and_grad(b.train_loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", _mesh_dependent_archs())
def test_arch_smoke_prefill_and_decode(arch):
    b = get_bundle(arch, reduced=True)
    params = b.init(KEY)
    B = 2
    batch = make_batch(b.cfg, B=B)
    pre = b.prefill(params, batch)
    assert pre.shape[0] == B and pre.shape[1] == 1
    assert not np.isnan(np.asarray(pre, np.float32)).any(), arch
    cache = b.init_cache(B, 64)
    logits, new_cache = b.decode(
        params, cache, {"tokens": batch["tokens"][:, :1], "pos": jnp.int32(3)})
    assert logits.shape[:2] == (B, 1)
    assert not np.isnan(np.asarray(logits, np.float32)).any(), arch
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", [
    "llama3.2-3b", "h2o-danube-1.8b", "gemma3-12b", "recurrentgemma-2b",
    "xlstm-125m",
])
@jax_version_xfail
def test_decode_matches_parallel(arch):
    """Token-by-token decode with cache == parallel forward (ring buffers,
    recurrent states, GQA, mLSTM recurrent form)."""
    b = get_bundle(arch, reduced=True)
    cfg = b.cfg
    params = b.init(jax.random.key(1))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.key(2), (B, S), 3, cfg.vocab_size)
    full_logits, _ = LM.forward(params, cfg, toks)
    cache = b.init_cache(B, 32)
    dec = jax.jit(b.decode)
    maxerr = 0.0
    for t in range(S):
        logits, cache = dec(params, cache,
                            {"tokens": toks[:, t:t + 1], "pos": jnp.int32(t)})
        e = float(jnp.abs(logits[:, 0].astype(jnp.float32)
                          - full_logits[:, t].astype(jnp.float32)).max())
        maxerr = max(maxerr, e)
    assert maxerr < 0.05, (arch, maxerr)


@jax_version_xfail
def test_moe_routing_mass_conserved():
    """Top-k gate weights sum to 1 per token; padded experts get no mass."""
    from repro.models import layers as L

    b = get_bundle("qwen2-moe-a2.7b", reduced=True)
    cfg = b.cfg
    p = L.moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
    logits = x.reshape(-1, cfg.d_model) @ p["router"]
    pad_mask = jnp.arange(cfg.padded_experts) >= cfg.n_experts
    logits = jnp.where(pad_mask[None], -1e30, logits)
    gates, experts = jax.lax.top_k(logits, cfg.top_k)
    assert int(experts.max()) < cfg.n_experts  # never routes to pad experts
    y = L.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()


@jax_version_xfail
def test_vlm_image_positions_masked_in_loss():
    b = get_bundle("phi-3-vision-4.2b", reduced=True)
    cfg = b.cfg
    params = b.init(KEY)
    batch = make_batch(cfg, B=2, S=32)
    # corrupting image-position TOKENS must not change the loss (they are
    # replaced by projected patches and masked out of CE)
    l1 = b.train_loss(params, batch)
    toks2 = batch["tokens"].at[:, : cfg.n_frontend_tokens].set(7)
    l2 = b.train_loss(params, {**batch, "tokens": toks2})
    assert abs(float(l1) - float(l2)) < 1e-5


# ---- full-size config invariants (dry-run-only sizes; no allocation) --------
@pytest.mark.parametrize("arch", all_archs())
def test_full_config_param_counts(arch):
    cfg = ARCHS[arch]
    total = cfg.total_params()
    expected = {
        "deepseek-moe-16b": 16.4e9, "qwen2-moe-a2.7b": 14.3e9,
        "recurrentgemma-2b": 2.7e9, "h2o-danube-1.8b": 1.8e9,
        "llama3.2-3b": 3.2e9, "gemma3-12b": 12e9, "qwen2-1.5b": 1.5e9,
        "xlstm-125m": 0.125e9, "phi-3-vision-4.2b": 3.8e9,
        "seamless-m4t-medium": 1.2e9,
    }[arch]
    assert 0.5 * expected < total < 1.8 * expected, (arch, total, expected)


@pytest.mark.parametrize("arch", all_archs())
def test_full_config_divisibility(arch):
    """Static dims must divide the 16-way model axis (after padding)."""
    cfg = ARCHS[arch]
    assert cfg.padded_vocab % 16 == 0
    if cfg.n_experts:
        assert cfg.padded_experts % 16 == 0
    assert (cfg.n_heads * cfg.head_dim_) % 16 == 0
    assert cfg.d_ff % 16 == 0 or cfg.d_ff == 0
    assert cfg.n_layers - cfg.first_dense_layers >= len(cfg.pattern)


def test_long_context_applicability_table():
    assert LONG_CONTEXT_OK == {
        "recurrentgemma-2b", "h2o-danube-1.8b", "gemma3-12b", "xlstm-125m"}
    for arch in all_archs():
        b = get_bundle(arch)
        from repro.configs.base import SHAPES
        assert b.supports(SHAPES["train_4k"])
        assert b.supports(SHAPES["long_500k"]) == (arch in LONG_CONTEXT_OK)
