"""Distributed tests: shard_map flattening + feature drivers on a forced
multi-device CPU mesh (subprocess — the main process must keep 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

# Seed-debt triage (see tests/test_models.py for the full note): the mesh
# helpers these subprocesses import need jax.sharding.AxisType, absent from
# the container's jax.  strict=False — they reactivate on a newer jax.
jax_version_xfail = pytest.mark.xfail(
    not hasattr(jax.sharding, "AxisType"), strict=False,
    reason="seed debt: installed jax lacks jax.sharding.AxisType/"
           "get_abstract_mesh required by the mesh stack")


def run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@jax_version_xfail
def test_distributed_flatten_matches_local():
    code = textwrap.dedent("""
        import json
        import jax, numpy as np
        from repro.data.synthetic import SyntheticConfig, generate_dcir
        from repro.core.flattening import flatten_star, distributed_flatten
        from repro.core.schema import DCIR_SCHEMA

        cfg = SyntheticConfig(n_patients=200, seed=3)
        dcir = generate_dcir(cfg)
        flat, _ = flatten_star(DCIR_SCHEMA, dcir)
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        dflat, ovf = distributed_flatten(DCIR_SCHEMA, dcir, mesh)
        a = flat.to_numpy(); b = dflat.to_numpy()
        print(json.dumps({
            "local_rows": int(flat.count), "dist_rows": int(dflat.count),
            "overflow": int(ovf),
            "key_sum_local": int(np.sort(a["flow_id"]).sum()),
            "key_sum_dist": int(np.sort(b["flow_id"]).sum()),
            "pid_sum_local": int(a["patient_id"].sum()),
            "pid_sum_dist": int(b["patient_id"].sum()),
        }))
    """)
    r = run_subprocess(code)
    assert r["overflow"] == 0
    assert r["local_rows"] == r["dist_rows"]
    assert r["key_sum_local"] == r["key_sum_dist"]
    assert r["pid_sum_local"] == r["pid_sum_dist"]


@jax_version_xfail
def test_exchange_partitions_by_key():
    """After exchange, every shard holds only keys that hash to it."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.columnar import ColumnarTable
        from repro.core.flattening import exchange

        n = 4
        mesh = jax.make_mesh((n,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        keys = np.arange(4096, dtype=np.int32)
        t = ColumnarTable.from_columns({"k": keys})

        def body(cols, valid):
            # valid arrives as the packed bitset, word-sharded on "data"
            tt = ColumnarTable.from_columns(cols, valid=valid)
            out, ovf = exchange(tt, "k", "data", n, 4096)
            me = jax.lax.axis_index("data")
            kk = out.columns["k"].astype(jnp.uint32)
            h = kk * jnp.uint32(0x9E3779B1); h = h ^ (h >> 16)
            bad = out.valid_bool() & ((h % n).astype(jnp.int32) != me)
            # rank-1 per-shard outputs (scalars cannot carry a 'data' spec)
            return bad.sum()[None], ovf[None], out.count[None]

        fn = jax.shard_map(body, mesh=mesh, in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data"), P("data")),
                           check_vma=False)
        bad, ovf, cnt = fn(dict(t.columns), t.valid)
        print(json.dumps({"bad": int(np.asarray(bad).sum()),
                          "overflow": int(np.asarray(ovf).sum()),
                          "total_rows": int(np.asarray(cnt).sum())}))
    """)
    r = run_subprocess(code)
    assert r["bad"] == 0
    assert r["overflow"] == 0
    assert r["total_rows"] == 4096


@jax_version_xfail
def test_sharded_train_step_runs():
    """Reduced model, (2 data, 2 model) mesh: one sharded train step."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.models import get_bundle
        from repro.train.train_step import init_train_state, make_train_step
        from repro.train.optimizer import AdamWConfig
        from repro.distributed.sharding import param_shardings, batch_shardings
        from repro.configs.base import SHAPES

        b = get_bundle("qwen2-1.5b", reduced=True)
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            state = init_train_state(b, jax.random.key(0))
            p_sh = param_shardings(b.cfg, mesh, state["params"])
            state = {"params": jax.device_put(state["params"], p_sh),
                     "opt": state["opt"]}
            step = jax.jit(make_train_step(b, AdamWConfig()))
            batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 32),
                                                  3, b.cfg.vocab_size)}
            state, m = step(state, batch)
            print(json.dumps({"loss": float(m["loss"])}))
    """)
    r = run_subprocess(code)
    assert 0 < r["loss"] < 20


def test_dryrun_artifacts_if_present():
    """Integration gate: if the dry-run matrix ran, every cell must be ok."""
    out_dir = os.path.join(os.path.dirname(__file__), "..",
                           "benchmarks", "results", "dryrun")
    if not os.path.isdir(out_dir) or not os.listdir(out_dir):
        pytest.skip("dry-run matrix not generated yet")
    bad = []
    for f in os.listdir(out_dir):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(out_dir, f)) as fh:
            rec = json.load(fh)
        if not (rec.get("ok") or rec.get("skipped")):
            bad.append((f, rec.get("error")))
    assert not bad, bad


@jax_version_xfail
def test_sharded_moe_matches_unsharded():
    """EP shard_map path == dense path numerically (same params, same batch).

    Capacity semantics differ (per-group vs global) only when tokens drop;
    the reduced config has generous capacity so outputs must match closely.
    """
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import get_bundle

        b = get_bundle("deepseek-moe-16b", reduced=True)
        params = b.init(jax.random.key(0))
        batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 32),
                                              3, b.cfg.vocab_size)}
        l_dense = float(b.train_loss(params, batch))   # no mesh: dense path
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            l_ep = float(jax.jit(b.train_loss)(params, batch))
        print(json.dumps({"dense": l_dense, "ep": l_ep}))
    """)
    r = run_subprocess(code)
    assert abs(r["dense"] - r["ep"]) < 0.05, r


@jax_version_xfail
def test_sharded_forward_matches_unsharded_dense_arch():
    """SP constraints must not change numerics for a dense arch."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import get_bundle

        b = get_bundle("gemma3-12b", reduced=True)
        params = b.init(jax.random.key(0))
        batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 32),
                                              3, b.cfg.vocab_size)}
        l1 = float(b.train_loss(params, batch))
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        with jax.set_mesh(mesh):
            l2 = float(jax.jit(b.train_loss)(params, batch))
        print(json.dumps({"unsharded": l1, "sharded": l2}))
    """)
    r = run_subprocess(code)
    assert abs(r["unsharded"] - r["sharded"]) < 0.02, r


@jax_version_xfail
def test_exposures_sharded_matches_local():
    """Patient-partitioned shard-local exposures == global exposures."""
    code = textwrap.dedent("""
        import json
        import jax, numpy as np
        from repro.core import (DCIR_SCHEMA, distributed_flatten, exposures,
                                exposures_sharded, drug_dispenses, flatten_star)
        from repro.data.synthetic import SyntheticConfig, generate_dcir

        cfg = SyntheticConfig(n_patients=300, seed=9)
        dcir = generate_dcir(cfg)
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        # patient-partitioned flat table (the layout the launcher guarantees)
        dflat, ovf = distributed_flatten(DCIR_SCHEMA, dcir, mesh)
        drugs = drug_dispenses()(dflat, compact=False)
        sharded = exposures_sharded(drugs, cfg.n_patients, mesh,
                                    purview_days=45)

        flat, _ = flatten_star(DCIR_SCHEMA, dcir)
        ref = exposures(drug_dispenses()(flat), cfg.n_patients,
                        purview_days=45)

        a = sharded.to_numpy(); b = ref.to_numpy()
        key = lambda d: sorted(zip(d["patient_id"].tolist(),
                                   d["value"].tolist(),
                                   d["start"].tolist(), d["end"].tolist()))
        print(json.dumps({"overflow": int(ovf), "match": key(a) == key(b),
                          "n": len(key(a))}))
    """)
    r = run_subprocess(code)
    assert r["overflow"] == 0
    assert r["match"] and r["n"] > 0, r
