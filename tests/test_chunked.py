"""Out-of-core chunked execution: store round-trip, mmap loads, chunked-vs-
resident parity (deterministic battery + hypothesis property over random
plans × chunk sizes), ONE-compile pinning, kill-and-resume, the chunk-unsafe
op guard, SP015, and the shared sharded jit cache."""
import json
import warnings
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import DCIR_SCHEMA, drug_dispenses
from repro.core.columnar import ColumnarTable
from repro.core.extraction import Extractor
from repro.data import (ChunkStore, SyntheticConfig, generate_dcir,
                        load_star, partition_star, save_star)
from repro.data.io import load_columnar_arrays, save_columnar
from repro.study import (Study, clear_jit_cache, col, jit_cache_info)
from repro.study.analyze import analyze
from repro.study.chunked import (ChunkedExecutor, _InjectedCrash,
                                 chunk_unsafe_ops)

N_PAT = 120


@pytest.fixture(scope="module")
def star():
    return generate_dcir(SyntheticConfig(n_patients=N_PAT,
                                         flows_per_patient=5.0, seed=3))


def _study():
    return (Study(n_patients=N_PAT)
            .flatten(DCIR_SCHEMA)
            .extract(drug_dispenses(), name="drugs")
            .patients("IR_BEN")
            .cohort("base", "extract_patients")
            .cohort("drugged", "drugs")
            .cohort("final", "drugged & base")
            .featurize("X", cohort="final", kind="dense",
                       n_buckets=12, bucket_days=31, n_features=64))


def _assert_bit_identical(res, chk, features=True):
    assert set(res.cohorts) == set(chk.cohorts)
    for k, c in res.cohorts.items():
        np.testing.assert_array_equal(np.asarray(c.subjects),
                                      np.asarray(chk.cohorts[k].subjects),
                                      err_msg=f"cohort {k}")
        assert c.subject_count() == chk.cohorts[k].subject_count()
    assert set(res.events) == set(chk.events)
    for k, t in res.events.items():
        a, b = t.to_numpy(), chk.events[k].to_numpy()
        assert set(a) == set(b), k
        for c in a:
            np.testing.assert_array_equal(a[c], b[c],
                                          err_msg=f"events {k}.{c}")
    if features:
        fa, fb = jax.tree.leaves(res.features), jax.tree.leaves(chk.features)
        assert len(fa) == len(fb)
        for u, v in zip(fa, fb):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


# ---------------------------------------------------------------------------
# ChunkStore
# ---------------------------------------------------------------------------
def test_partition_roundtrip(star, tmp_path):
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=96)
    src = star["ER_PRS"]
    assert store.source == "ER_PRS"
    assert store.manifest.total_rows == int(src.count)
    assert store.n_chunks == -(-src.capacity // 96)
    assert set(store.manifest.resident) == {"ER_PHA", "ER_CAM", "IR_BEN"}
    store.validate()
    # chunk payloads are exactly the source's row slices (32-aligned words)
    full = src.to_numpy()
    got = {c: [] for c in full}
    for ci in range(store.n_chunks):
        t = store.chunk_table(ci, verify=True)
        assert t.capacity == 96
        part = t.to_numpy()
        for c in full:
            got[c].append(part[c])
    for c in full:
        np.testing.assert_array_equal(np.concatenate(got[c]), full[c])
    # key ranges cover valid rows
    for m in store.manifest.chunks:
        assert m.rows <= 96
        if m.rows:
            assert m.key_lo is not None and m.key_lo <= m.key_hi


def test_partition_rejects_misaligned_capacity(star, tmp_path):
    with pytest.raises(ValueError, match="multiple of 32"):
        partition_star(star, str(tmp_path / "s"), source="ER_PRS",
                       chunk_capacity=100)
    with pytest.raises(ValueError, match="multiple of 32"):
        partition_star(star, str(tmp_path / "s"), source="ER_PRS",
                       chunk_capacity=0)


def test_chunk_hash_detects_corruption(star, tmp_path):
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=96)
    cols, valid = store.load_chunk_arrays(0, verify=True)   # clean
    doctored = {k: np.array(v) for k, v in cols.items()}
    doctored["patient_id"] = doctored["patient_id"] + 1
    from repro.data.io import save_columnar_arrays

    save_columnar_arrays(doctored, valid, store.chunk_path(0),
                         compressed=False)
    with pytest.raises(IOError, match="hash mismatch"):
        store.load_chunk_arrays(0, verify=True)


def test_partition_from_saved_star_dir_mmap(star, tmp_path):
    sd = str(tmp_path / "star")
    save_star(star, sd, compressed=False)
    a = partition_star(star, str(tmp_path / "a"), source="ER_PRS",
                       chunk_capacity=96)
    b = partition_star(sd, str(tmp_path / "b"), source="ER_PRS",
                       chunk_capacity=96)
    # streaming the saved star through mmap produces the identical store
    assert a.fingerprint() == b.fingerprint()


# ---------------------------------------------------------------------------
# data/io.py mmap pass-through (the satellite bugfix)
# ---------------------------------------------------------------------------
def test_mmap_mode_pass_through(star, tmp_path):
    t = star["IR_BEN"]
    p = str(tmp_path / "t.npz")
    save_columnar(t, p, compressed=False)
    cols, valid = load_columnar_arrays(p, mmap_mode="r")
    # uncompressed members come back memory-mapped, not materialized
    assert all(isinstance(v, np.memmap) for v in cols.values())
    assert isinstance(valid, np.memmap)
    eager_cols, eager_valid = load_columnar_arrays(p)
    assert not any(isinstance(v, np.memmap) for v in eager_cols.values())
    for k in eager_cols:
        np.testing.assert_array_equal(np.asarray(cols[k]), eager_cols[k])
    np.testing.assert_array_equal(np.asarray(valid), eager_valid)


def test_mmap_mode_compressed_fallback(star, tmp_path):
    t = star["IR_BEN"]
    p = str(tmp_path / "t.npz")
    save_columnar(t, p, compressed=True)
    with pytest.warns(RuntimeWarning, match="cannot be memory-mapped"):
        cols, valid = load_columnar_arrays(p, mmap_mode="r")  # degrades eagerly
    assert not any(isinstance(v, np.memmap) for v in cols.values())
    np.testing.assert_array_equal(cols["patient_id"],
                                  np.asarray(t.columns["patient_id"]))


def test_load_star_mmap(star, tmp_path):
    sd = str(tmp_path / "star")
    save_star(star, sd, compressed=False)
    loaded = load_star(sd, mmap_mode="r")
    assert set(loaded) == set(star)
    for k, t in star.items():
        a, b = t.to_numpy(), loaded[k].to_numpy()
        assert set(a) == set(b), k
        for c in a:
            np.testing.assert_array_equal(a[c], b[c], err_msg=f"{k}.{c}")


# ---------------------------------------------------------------------------
# chunked-vs-resident parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk_capacity", [64, 96, 512])
def test_chunked_matches_resident(star, tmp_path, chunk_capacity):
    res = _study().run(star)
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=chunk_capacity)
    chk = _study().run_chunked(store)
    _assert_bit_identical(res, chk)


def test_chunked_concat_preserves_branch_order(star, tmp_path):
    # resident concat lays rows out branch-major ([drugs; acts]) while each
    # chunk emits its own [drugs_ci; acts_ci] — the merge must slice the
    # branches back apart (nested: concat-of-concat flattens the same way)
    def build():
        from repro.core import medical_acts_dcir
        return (Study(n_patients=N_PAT)
                .flatten(DCIR_SCHEMA)
                .extract(drug_dispenses(), name="drugs")
                .extract(medical_acts_dcir(), name="acts")
                .filter("acts", col("value") >= 100, name="acts_hi")
                .concat("pair", "drugs", "acts")
                .concat("triple", "pair", "acts_hi")
                .patients("IR_BEN")
                .cohort("base", "extract_patients")
                .cohort("hit", "pair")
                .flow("hit", "base"))
    res = build().run(star)
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=64)
    assert store.n_chunks > 1
    chk = build().run_chunked(store)
    # _assert_bit_identical compares valid rows IN ORDER per column — the
    # interleaved naive merge fails exactly here on "pair"/"triple"
    _assert_bit_identical(res, chk, features=False)


def test_one_compile_across_all_chunks(star, tmp_path):
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=96)
    assert store.n_chunks > 3
    clear_jit_cache()
    rep = {}
    _study().run_chunked(store, report_sink=rep)
    assert rep["executed"] == store.n_chunks
    # fixed chunk capacities => pytree-identical specs => the jit cache
    # serves every chunk after the first from ONE compiled executable
    assert rep["compiles"] == 1
    info = jit_cache_info()
    assert info["compiles"] == 1
    assert info["hits"] == store.n_chunks - 1


def test_kill_and_resume(star, tmp_path):
    res = _study().run(star)
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=96)
    ck = str(tmp_path / "ckpt")

    ex = ChunkedExecutor(store, checkpoint_dir=ck, crash_after=2)
    with pytest.raises(_InjectedCrash):
        ex.run(_study())
    assert ex.report.executed == 2
    lines = [json.loads(ln) for ln in open(os.path.join(ck, "journal.jsonl"))]
    assert lines[0]["kind"] == "header"
    assert [ln["index"] for ln in lines[1:]] == [0, 1]

    # crash again mid-resume: completed chunks are NOT re-executed
    ex2 = ChunkedExecutor(store, checkpoint_dir=ck, crash_after=3)
    with pytest.raises(_InjectedCrash):
        ex2.run(_study())
    assert ex2.report.resumed == 2
    assert ex2.report.executed == 3

    ex3 = ChunkedExecutor(store, checkpoint_dir=ck)
    out = ex3.run(_study())
    assert ex3.report.resumed == 5
    assert ex3.report.executed == store.n_chunks - 5
    _assert_bit_identical(res, out)


def test_resume_ignores_foreign_journal(star, tmp_path):
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=96)
    ck = str(tmp_path / "ckpt")
    _study().run_chunked(store, checkpoint_dir=ck)
    # a different plan (different predicate) must not adopt the old journal
    other = (Study(n_patients=N_PAT)
             .flatten(DCIR_SCHEMA)
             .extract(drug_dispenses().filtered(col("cip13") >= 3),
                      name="drugs")
             .cohort("drugged", "drugs"))
    rep = {}
    out = other.run_chunked(store, checkpoint_dir=ck, report_sink=rep)
    assert rep["resumed"] == 0
    assert rep["executed"] == store.n_chunks
    ref = other.run(star)
    _assert_bit_identical(ref, out, features=False)


def test_chunk_unsafe_ops_rejected(star, tmp_path):
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=96)
    unsafe = (Study(n_patients=N_PAT)
              .flatten(DCIR_SCHEMA)
              .extract(drug_dispenses(), name="drugs")
              .transform("exposures", "drugs", name="exposed",
                         purview_days=60)
              .cohort("exp", "exposed"))
    with pytest.raises(ValueError, match="chunk-unsafe"):
        unsafe.run_chunked(store)
    plan = unsafe.plan()
    assert any(op == "transform" for _, op in
               chunk_unsafe_ops(plan, "ER_PRS"))
    # the escape hatch runs (approximate semantics, documented)
    ChunkedExecutor(store, allow_unsafe=True).run(unsafe)


def test_misaligned_manifest_rejected_statically(star, tmp_path):
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=96)
    mpath = os.path.join(store.dirpath, "manifest.json")
    doc = json.load(open(mpath))
    doc["chunk_capacity"] = 100                  # simulate a bad manifest
    json.dump(doc, open(mpath, "w"))
    bad = ChunkStore(store.dirpath)
    with pytest.raises(ValueError, match="multiple of 32"):
        ChunkedExecutor(bad).run(_study())


def test_sp015_diagnostic():
    s = (Study(n_patients=16)
         .patients("IR_BEN")
         .cohort("base", "extract_patients"))
    plan = s.optimized_plan()
    bad = [d for d in analyze(plan, chunk_capacity=100) if d.code == "SP015"]
    assert bad and bad[0].severity == "error"
    assert not [d for d in analyze(plan, chunk_capacity=96)
                if d.code == "SP015"]
    # sharded: the quantum tightens to 32*n_shards
    assert [d for d in analyze(plan, n_shards=2, chunk_capacity=96)
            if d.code == "SP015"]
    assert not [d for d in analyze(plan, n_shards=2, chunk_capacity=128)
                if d.code == "SP015"]


# ---------------------------------------------------------------------------
# shared jit cache: execute_plan_sharded (satellite regression test)
# ---------------------------------------------------------------------------
def test_sharded_executables_share_jit_cache(star):
    from jax.sharding import Mesh

    from repro.distributed.pipeline import execute_plan_sharded

    s = (Study(n_patients=N_PAT)
         .extract(Extractor(name="ev", source="FLAT", category=1,
                            value_col="cip13", start_col="execution_date"),
                  name="ev")
         .cohort("got", "ev"))
    env = {"FLAT": ColumnarTable.from_columns({
        "patient_id": star["ER_PRS"].columns["patient_id"],
        "cip13": star["ER_PRS"].columns["flow_id"],
        "execution_date": star["ER_PRS"].columns["execution_date"]})}
    plan = s.optimized_plan(tables=env)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    clear_jit_cache()
    execute_plan_sharded(plan, env, N_PAT, mesh)
    info = jit_cache_info()
    assert info == {"plans": 1, "compiles": 1, "hits": 0}
    execute_plan_sharded(plan, env, N_PAT, mesh)
    info = jit_cache_info()
    assert info == {"plans": 1, "compiles": 1, "hits": 1}
    clear_jit_cache()
    assert jit_cache_info() == {"plans": 0, "compiles": 0, "hits": 0}


# ---------------------------------------------------------------------------
# hypothesis property: random plans × random chunk sizes
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), cap_words=st.integers(1, 6),
       op=st.sampled_from(["&", "|", "-"]))
def test_property_chunked_parity(tmp_path_factory, seed, cap_words, op):
    rng = np.random.default_rng(seed)
    n_pat = int(rng.integers(8, 40))
    n_rows = int(rng.integers(10, 200))
    # random event table: patients deliberately interleaved so chunk
    # boundaries split a patient's events
    ev = ColumnarTable.from_columns({
        "patient_id": jnp.asarray(rng.integers(0, n_pat, n_rows), jnp.int32),
        "code": jnp.asarray(rng.integers(0, 12, n_rows), jnp.int32),
        "date": jnp.asarray(rng.integers(0, 1000, n_rows), jnp.int32),
    })
    pats = ColumnarTable.from_columns({
        "patient_id": jnp.arange(n_pat, dtype=jnp.int32),
        "gender": jnp.asarray(rng.integers(1, 3, n_pat), jnp.int32),
        "birth_date": jnp.zeros(n_pat, jnp.int32),
        "death_date": jnp.zeros(n_pat, jnp.int32),
    })
    thr = int(rng.integers(0, 13))
    ex = Extractor(name="ev", source="EV", category=1, value_col="code",
                   start_col="date").filtered(col("code") >= thr)

    def build():
        return (Study(n_patients=n_pat)
                .extract(ex, name="ev")
                .patients("PATS")
                .cohort("base", "extract_patients")
                .cohort("got", "ev")
                .cohort("final", f"got {op} base"))

    tables = {"EV": ev, "PATS": pats}
    res = build().run(tables)
    d = tmp_path_factory.mktemp("chunkstore")
    store = partition_star(tables, str(d / "store"), source="EV",
                           chunk_capacity=32 * cap_words)
    chk = build().run_chunked(store)
    _assert_bit_identical(res, chk)


def test_resume_tolerates_torn_journal_tail(star, tmp_path):
    """A kill mid-append leaves a torn final journal line; resume must keep
    every completed line before it (one-chunk cost, not a full restart)."""
    store = partition_star(star, str(tmp_path / "store"), source="ER_PRS",
                           chunk_capacity=96)
    ck = str(tmp_path / "ckpt")
    res = _study().run_chunked(store, checkpoint_dir=ck)
    jp = os.path.join(ck, "journal.jsonl")
    n_done = sum(1 for ln in open(jp) if '"chunk"' in ln)
    assert n_done == store.n_chunks

    # tear the last line mid-record (no trailing newline, invalid JSON)
    with open(jp, "rb") as f:
        raw = f.read()
    torn = raw.rstrip(b"\n")[:-7]
    with open(jp, "wb") as f:
        f.write(torn)
    rep = {}
    out = _study().run_chunked(store, checkpoint_dir=ck, report_sink=rep)
    assert rep["resumed"] == store.n_chunks - 1, \
        "a torn tail must cost exactly the one uncommitted chunk"
    assert rep["executed"] == 1
    _assert_bit_identical(res, out)

    # garbage appended after valid lines: the valid prefix still resumes
    with open(jp, "ab") as f:
        f.write(b'{"kind": "chu')
    rep2 = {}
    out2 = _study().run_chunked(store, checkpoint_dir=ck, report_sink=rep2)
    assert rep2["resumed"] == store.n_chunks
    assert rep2["executed"] == 0
    _assert_bit_identical(res, out2)


def test_mmap_degrade_is_surfaced(star, tmp_path):
    """Compressed members silently degraded to eager reads before; now the
    per-member ``mapped_sink`` flags and a once-per-file RuntimeWarning
    surface it."""
    t = star["IR_BEN"]
    raw = str(tmp_path / "raw.npz")
    packed = str(tmp_path / "packed.npz")
    save_columnar(t, raw, compressed=False)
    save_columnar(t, packed, compressed=True)

    flags = {}
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # mapped loads must not warn
        load_columnar_arrays(raw, mmap_mode="r", mapped_sink=flags)
    assert flags and all(flags.values())
    assert "__valid__" in flags and "patient_id" in flags

    flags = {}
    with pytest.warns(RuntimeWarning, match="cannot be memory-mapped"):
        load_columnar_arrays(packed, mmap_mode="r", mapped_sink=flags)
    assert flags and not any(flags.values())

    # eager loads (no mmap requested): no warning, flags all False
    flags = {}
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        load_columnar_arrays(packed, mapped_sink=flags)
    assert flags and not any(flags.values())
