"""Extended sub-databases + extractors + transformers (paper suppl. Tables
2-4: SSR, HAD, IR_IMB; biology/practitioner/CSARR/takeover/ALD extractors;
prescription/interaction/outcome transformers; >25 statistics)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Category, Cohort, DCIR_SCHEMA, HAD_SCHEMA, IR_IMB_SCHEMA, SSR_SCHEMA,
    biology_acts, bladder_cancer, csarr_acts, diagnoses, drug_dispenses,
    drug_interactions, drug_prescriptions, flatten_star, heart_failure,
    infarctus, long_term_diseases, medical_acts_dcir, practitioner_encounters,
    ssr_stays, stats, takeover_reasons,
)
from repro.core.columnar import ColumnarTable, NULL_INT
from repro.data.synthetic import (
    SyntheticConfig, generate_dcir, generate_had, generate_ir_imb,
    generate_pmsi, generate_ssr,
)

CFG = SyntheticConfig(n_patients=300, seed=21)


@pytest.fixture(scope="module")
def flats():
    dcir = generate_dcir(CFG)
    ssr = generate_ssr(CFG)
    had = generate_had(CFG)
    imb = generate_ir_imb(CFG)
    return {
        "dcir_tables": dcir,
        "DCIR": flatten_star(DCIR_SCHEMA, dcir)[0],
        "SSR": flatten_star(SSR_SCHEMA, ssr)[0],
        "HAD": flatten_star(HAD_SCHEMA, had)[0],
        "IR_IMB": flatten_star(IR_IMB_SCHEMA, imb)[0],
        "ssr_tables": ssr,
        "had_tables": had,
    }


def test_ssr_flatten_blowup(flats):
    assert int(flats["SSR"].count) >= int(flats["ssr_tables"]["SSR_B"].count)


def test_csarr_and_ssr_stays(flats):
    acts = csarr_acts()(flats["SSR"])
    assert int(acts.count) > 0
    a = acts.to_numpy()
    assert (a["category"] == Category.MEDICAL_ACT).all()
    stays = ssr_stays()(flats["SSR"])
    assert int(stays.count) == int(flats["ssr_tables"]["SSR_B"].count)
    s = stays.to_numpy()
    assert (s["end"] >= s["start"]).all()


def test_takeover_reasons(flats):
    main = takeover_reasons(main=True)(flats["HAD"])
    assoc = takeover_reasons(main=False)(flats["HAD"])
    assert int(main.count) == int(flats["had_tables"]["HAD_B"].count)
    assert int(assoc.count) < int(main.count)  # ~50% null associated


def test_long_term_diseases(flats):
    ald = long_term_diseases()(flats["IR_IMB"])
    assert int(ald.count) > 0
    a = ald.to_numpy()
    assert (a["end"] > a["start"]).all()  # longitudinal


def test_biology_and_practitioner(flats):
    bio = biology_acts()(flats["DCIR"])
    med = practitioner_encounters(medical=True)(flats["DCIR"])
    non = practitioner_encounters(medical=False)(flats["DCIR"])
    b, m, n = bio.to_numpy(), med.to_numpy(), non.to_numpy()
    assert (b["value"] >= 1080).all()
    assert ((m["value"] >= 1000) & (m["value"] < 1040)).all()
    assert ((n["value"] >= 1040) & (n["value"] < 1080)).all()
    # bands partition the prestation space: no double counting
    total = int(bio.count) + int(med.count) + int(non.count)
    assert total == int(flats["dcir_tables"]["ER_PRS"].count)


def test_drug_prescriptions(flats):
    drugs = drug_dispenses()(flats["DCIR"])
    rx = drug_prescriptions(drugs, CFG.n_patients, refill_days=30)
    r = rx.to_numpy()
    assert (r["end"] >= r["start"]).all()
    assert int(rx.count) <= int(drugs.count)


def test_drug_interactions_window():
    from repro.core import make_events

    ev = make_events(
        patient_id=jnp.asarray([0, 0, 0, 1], jnp.int32),
        category=Category.DRUG_DISPENSE,
        value=jnp.asarray([5, 7, 7, 5], jnp.int32),
        start=jnp.asarray([0, 10, 200, 0], jnp.int32),
    )
    out = drug_interactions(ev, 2, window_days=30)
    o = out.to_numpy()
    # only (5,7) at day 10 interacts; day 200 is outside the window,
    # patient 1 has a single drug
    assert len(o["patient_id"]) == 1 and o["patient_id"][0] == 0
    assert o["group_id"][0] == 5


def test_outcome_transformers(flats):
    pmsi = generate_pmsi(CFG)
    from repro.core import PMSI_MCO_SCHEMA

    flat_pmsi = flatten_star(PMSI_MCO_SCHEMA, pmsi)[0]
    diag = diagnoses()(flat_pmsi)
    acts = medical_acts_dcir()(flats["DCIR"])
    bc = bladder_cancer(acts, diag, act_codes=(1, 2), diag_codes=(3, 4))
    mi = infarctus(diag, diag_codes=(10, 11, 12))
    hf = heart_failure(diag, diag_codes=(20, 21))
    for out in (bc, mi, hf):
        o = out.to_numpy()
        assert (o["category"] == Category.OUTCOME_FRACTURE).all() or len(o["category"]) == 0


def test_statistics_battery(flats):
    """paper §3.5: 'more than 25 Patient-centric or Event-centric statistics'."""
    assert len(stats.STATISTICS) >= 25
    drugs = drug_dispenses()(flats["DCIR"])
    cohort = Cohort.from_events("drugs", drugs, CFG.n_patients)
    cohort.window = (14_600, 14_600 + 3 * 365)
    pats = flats["dcir_tables"]["IR_BEN"]
    out = stats.compute(cohort, pats)
    assert len(out) >= 25
    assert out["subject_count"]["subjects"] == cohort.subject_count()
    assert out["events_total"]["events"] == int(drugs.count)


def test_pipeline_config():
    from repro.configs.scalpel3 import FULL_SNDS, PAPER_STUDY

    assert len(FULL_SNDS.flatten) == 5  # all Table-2 sub-databases
    assert "long_term_diseases" in FULL_SNDS.extractors
    assert PAPER_STUDY.exposure_purview_days == 60
