"""ColumnarTable unit + property tests (the Parquet-analogue invariants)."""
from _hyp import given, settings, st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.columnar import ColumnarTable, NULL_INT, is_null


def make_table(vals, valid=None):
    return ColumnarTable.from_columns(
        {"a": np.asarray(vals, np.int32),
         "b": np.asarray(vals, np.int32) * 2},
        valid=None if valid is None else np.asarray(valid, bool),
    )


def test_select_is_metadata_only():
    t = make_table([1, 2, 3])
    s = t.select(["a"])
    assert s.column_names == ("a",)
    assert int(s.count) == 3


def test_filter_narrows_validity_without_movement():
    t = make_table([1, 2, 3, 4])
    f = t.filter(jnp.asarray([True, False, True, False]))
    assert int(f.count) == 2
    # data unmoved
    assert (np.asarray(f.columns["a"]) == [1, 2, 3, 4]).all()


def test_compact_preserves_order():
    t = make_table([5, 6, 7, 8], valid=[False, True, False, True])
    c = t.compact()
    assert int(c.count) == 2
    assert np.asarray(c.columns["a"])[:2].tolist() == [6, 8]
    vb = c.valid_numpy()
    assert vb[:2].all() and not vb[2:].any()
    assert c.valid.dtype == jnp.uint32          # packed-bitset representation


def test_drop_nulls():
    vals = np.asarray([1, int(NULL_INT), 3], np.int32)
    t = ColumnarTable.from_columns({"a": vals})
    d = t.drop_nulls(["a"])
    assert int(d.count) == 2


def test_sort_by_sinks_invalid():
    t = make_table([3, 1, 2, 9], valid=[True, True, True, False])
    s = t.sort_by(["a"])
    assert np.asarray(s.columns["a"])[:3].tolist() == [1, 2, 3]
    assert not s.valid_numpy()[3]


def test_concat_and_pad():
    t1, t2 = make_table([1]), make_table([2, 3])
    c = ColumnarTable.concat([t1, t2])
    assert int(c.count) == 3 and c.capacity == 3
    p = c.pad_to(8)
    assert p.capacity == 8 and int(p.count) == 3


@settings(max_examples=50, deadline=None)
@given(
    vals=st.lists(st.integers(-2**31 + 2, 2**31 - 1), min_size=1, max_size=64),
    data=st.data(),
)
def test_property_filter_compact_roundtrip(vals, data):
    """compact(filter(m)) holds exactly the masked values, in order."""
    mask = data.draw(st.lists(st.booleans(), min_size=len(vals), max_size=len(vals)))
    t = make_table(vals)
    c = t.filter(jnp.asarray(mask)).compact()
    expected = [v for v, m in zip(vals, mask) if m]
    assert int(c.count) == len(expected)
    assert np.asarray(c.columns["a"])[: len(expected)].tolist() == expected


@settings(max_examples=50, deadline=None)
@given(vals=st.lists(st.integers(-10**6, 10**6), min_size=1, max_size=64))
def test_property_sort_matches_numpy(vals):
    t = make_table(vals)
    s = t.sort_by(["a"])
    assert np.asarray(s.columns["a"]).tolist() == sorted(vals)


@settings(max_examples=30, deadline=None)
@given(
    vals=st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=64),
    data=st.data(),
)
def test_property_monitoring_checksum_invariant_under_permutation(vals, data):
    """key_sum/key_xor are order-independent (the no-loss audit relies on it)."""
    perm = data.draw(st.permutations(list(range(len(vals)))))
    t1 = make_table(vals)
    t2 = make_table([vals[i] for i in perm])
    s1 = t1.monitoring_stats("a")
    s2 = t2.monitoring_stats("a")
    assert int(s1["key_sum"]) == int(s2["key_sum"])
    assert int(s1["key_xor"]) == int(s2["key_xor"])
