"""Typed column-expression IR: the ``col()``/``Expr`` DSL, the recursive-
descent cohort-algebra parser, predicate fusion parity (fused single-pass
mask vs naive per-node evaluation), and join-aware column pruning (the
acceptance criterion: dimension columns no extractor reads are dropped from
the star scans before the first join, with identical end-to-end results)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import DCIR_SCHEMA, PMSI_MCO_SCHEMA, drug_dispenses, \
    flatten_star, medical_acts_dcir
from repro.core.columnar import ColumnarTable, NULL_INT
from repro.core.extraction import Extractor
from repro.data.synthetic import SyntheticConfig, generate_dcir, generate_pmsi
from repro.study import (
    PlanBuilder, Study, col, execute, expr_from_param, lit, optimize,
    parse_cohort_expr, column_audit_from_log,
)
from repro.study.expr import CohortCombine, CohortRef, node_predicate

CFG = SyntheticConfig(n_patients=200, seed=11)


@pytest.fixture(scope="module")
def dcir():
    return generate_dcir(CFG)


@pytest.fixture(scope="module")
def pmsi():
    return generate_pmsi(CFG)


def _table(**cols):
    arrs = {}
    for k, v in cols.items():
        a = np.asarray(v)
        arrs[k] = a.astype(np.float32 if a.dtype.kind == "f" else np.int32)
    return ColumnarTable.from_columns(arrs)


# ---------------------------------------------------------------------------
# Expr DSL basics
# ---------------------------------------------------------------------------
def test_expr_required_columns_and_roundtrip():
    e = ((col("a") + 1 >= col("b") * 2) & col("c").isin([1, 2, 3])
         | ~col("d").is_null())
    assert e.required_columns() == {"a", "b", "c", "d"}
    p = e.to_param()
    assert expr_from_param(p).to_param() == p      # stable serialization
    hash(p)                                        # plan params must hash


def test_expr_evaluate_matches_numpy():
    t = _table(a=[1, 5, int(NULL_INT), 7], b=[2, 2, 2, 2])
    m = np.asarray(((col("a") >= 3) & (col("a") % 2 == 1)
                    & col("a").not_null()).mask(t))
    assert m.tolist() == [False, True, False, True]
    m2 = np.asarray((col("a").between(1, 6) | (col("b") == 7)).mask(t))
    # NULL sentinel compares raw (document: use is_null for sentinel tests)
    assert m2.tolist() == [True, True, False, False]
    assert np.asarray(col("a").isin([]).mask(t)).tolist() == [False] * 4


def test_expr_rejects_python_bool_context():
    with pytest.raises(TypeError):
        bool(col("a") == 1)
    with pytest.raises(TypeError):
        col("a") == "strings-are-not-literals"


def test_predicate_node_in_plan_matches_naive(dcir):
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    e = (col("cip13").not_null() & (col("execution_date") >= 14_700)
         & ~col("prestation_code").isin([1000, 1001]))
    b = PlanBuilder()
    t = b.predicate(b.scan("DCIR"), e)
    out = b.set_output("out", b.compact(t))
    got = execute(b.build(), {"DCIR": flat})[out].to_numpy()
    want = flat.filter(e.mask(flat)).compact().to_numpy()
    for k in want:
        assert (got[k] == want[k]).all(), k


def test_extractor_where_predicate(dcir):
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    base = drug_dispenses()
    filt = base.filtered(col("execution_date") >= 14_800)
    assert "execution_date" in filt.projection()
    ev, ev_all = filt(flat).to_numpy(), base(flat).to_numpy()
    assert len(ev["start"]) < len(ev_all["start"])
    assert (ev["start"] >= 14_800).all()


def test_study_filter_output(dcir):
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    res = (Study(n_patients=CFG.n_patients)
           .extract(drug_dispenses(), name="drugs")
           .filter("drugs", col("start") >= 14_800, name="recent")
           .run({"DCIR": flat}))
    all_ev, recent = res.events["drugs"].to_numpy(), res.events["recent"].to_numpy()
    want = all_ev["start"][all_ev["start"] >= 14_800]
    assert sorted(recent["start"].tolist()) == sorted(want.tolist())


def test_node_predicate_reexpresses_legacy_ops():
    b = PlanBuilder()
    t = b.add("drop_nulls", (b.scan("T"),), cols=("x", "y"))
    v = b.add("value_filter", (t,), col="x", codes=(1, 2))
    s = b.slice_time(v, "d", 10, 20)
    plan_b = b
    nodes = plan_b.build().nodes
    assert node_predicate(nodes[t]).required_columns() == {"x", "y"}
    assert node_predicate(nodes[v]).required_columns() == {"x"}
    assert node_predicate(nodes[s]).required_columns() == {"d"}
    tbl = _table(x=[1, 3, int(NULL_INT)], y=[1, 1, 1], d=[12, 5, 15])
    assert np.asarray(node_predicate(nodes[v]).mask(tbl)).tolist() == \
        [True, False, False]
    assert np.asarray(node_predicate(nodes[s]).mask(tbl)).tolist() == \
        [True, False, True]


# ---------------------------------------------------------------------------
# fused path vs naive per-node evaluation (property)
# ---------------------------------------------------------------------------
def _random_pred(draw, depth: int):
    cols = ("a", "b", "c")
    if depth <= 0 or draw(st.integers(0, 2)) == 0:
        c = col(cols[draw(st.integers(0, 2))])
        kind = draw(st.integers(0, 3))
        if kind == 0:
            op = draw(st.sampled_from(["==", "!=", "<", "<=", ">", ">="]))
            rhs = lit(draw(st.integers(-5, 15)))
            return {"==": c.__eq__, "!=": c.__ne__, "<": c.__lt__,
                    "<=": c.__le__, ">": c.__gt__, ">=": c.__ge__}[op](rhs)
        if kind == 1:
            vals = draw(st.lists(st.integers(-5, 15), max_size=5))
            return c.isin(vals)
        if kind == 2:
            return c.is_null() if draw(st.booleans()) else c.not_null()
        return (c + draw(st.integers(0, 3))) % 4 == draw(st.integers(0, 3))
    k = draw(st.integers(0, 2))
    l = _random_pred(draw, depth - 1)
    if k == 0:
        return ~l
    r = _random_pred(draw, depth - 1)
    return (l & r) if k == 1 else (l | r)


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_property_fused_equals_naive(data):
    """A chain of random predicates executed through the optimizer (fused
    into ONE fused_mask, single-pass conjunction) must keep exactly the rows
    the naive per-node Expr evaluation keeps."""
    draw = data.draw
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    n = draw(st.integers(1, 64))
    vals = rng.integers(-5, 15, n)
    vals[rng.random(n) < 0.2] = int(NULL_INT)
    t = _table(id=np.arange(n), a=vals, b=rng.integers(-5, 15, n),
               c=rng.integers(-5, 15, n))
    exprs = [_random_pred(draw, draw(st.integers(0, 2)))
             for _ in range(draw(st.integers(1, 3)))]

    b = PlanBuilder()
    nid = b.scan("T")
    for e in exprs:
        nid = b.predicate(nid, e)
    out = b.set_output("out", b.compact(nid))
    opt = optimize(b.build())
    assert opt.count_ops().get("fused_mask", 0) == 1   # chain fused to one
    got = execute(opt, {"T": t})[opt.output_ids["out"]].to_numpy()["id"]

    naive = t
    for e in exprs:
        naive = naive.filter(e.mask(naive))
    want = naive.compact().to_numpy()["id"]
    assert got.tolist() == want.tolist()


# ---------------------------------------------------------------------------
# cohort-algebra parser
# ---------------------------------------------------------------------------
def test_parser_precedence_and_parens():
    assert parse_cohort_expr("a | b & c") == CohortCombine(
        "|", CohortRef("a"), CohortCombine("&", CohortRef("b"), CohortRef("c")))
    assert parse_cohort_expr("(a | b) - c") == CohortCombine(
        "-", CohortCombine("|", CohortRef("a"), CohortRef("b")), CohortRef("c"))
    # legacy flat expressions keep their left-fold meaning
    assert parse_cohort_expr("a & b - c") == CohortCombine(
        "-", CohortCombine("&", CohortRef("a"), CohortRef("b")), CohortRef("c"))
    assert parse_cohort_expr("a - b - c") == CohortCombine(
        "-", CohortCombine("-", CohortRef("a"), CohortRef("b")), CohortRef("c"))
    # operand names keep non-paren characters (legacy bracketed names)
    assert parse_cohort_expr("( drug_purchases[cip13] )") == \
        CohortRef("drug_purchases[cip13]")


@pytest.mark.parametrize("bad", ["", "a b", "a &", "& a", "(a | b", "a ) b",
                                 "a & ( )", "a | | b"])
def test_parser_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_cohort_expr(bad)


def _algebra_study(flat):
    a = Extractor(name="ea", source="T", category=1, value_col="v",
                  start_col="s", codes=(1, 2, 3))
    b = Extractor(name="eb", source="T", category=1, value_col="v",
                  start_col="s", codes=(2, 3, 4))
    c = Extractor(name="ec", source="T", category=1, value_col="v",
                  start_col="s", codes=(3, 4, 5))
    s = Study(n_patients=32)
    for name, ex in (("a", a), ("b", b), ("c", c)):
        s.extract(ex, name=name)
    return s


@pytest.fixture(scope="module")
def algebra_flat():
    rng = np.random.default_rng(5)
    n = 200
    return _table(patient_id=rng.integers(0, 32, n),
                  v=rng.integers(0, 8, n), s=rng.integers(0, 100, n))


def test_cohort_precedence_semantics(algebra_flat):
    res = (_algebra_study(algebra_flat)
           .cohort("mixed", "a | b & c")
           .cohort("grouped", "(a | b) & c")
           .cohort("ca", "a").cohort("cb", "b").cohort("cc", "c")
           .run({"T": algebra_flat}))
    A, B, C = (res.cohorts[k] for k in ("ca", "cb", "cc"))
    want_mixed = A.union(B.intersection(C))
    want_grouped = A.union(B).intersection(C)
    assert (np.asarray(res.cohorts["mixed"].subjects)
            == np.asarray(want_mixed.subjects)).all()
    assert (np.asarray(res.cohorts["grouped"].subjects)
            == np.asarray(want_grouped.subjects)).all()
    # the two really differ on this data — the old left-fold bug was silent
    assert (np.asarray(want_mixed.subjects)
            != np.asarray(want_grouped.subjects)).any()


def test_cohort_paren_difference(algebra_flat):
    res = (_algebra_study(algebra_flat)
           .cohort("x", "(a | b) - c")
           .cohort("ca", "a").cohort("cb", "b").cohort("cc", "c")
           .run({"T": algebra_flat}))
    want = res.cohorts["ca"].union(res.cohorts["cb"]).difference(
        res.cohorts["cc"])
    assert (np.asarray(res.cohorts["x"].subjects)
            == np.asarray(want.subjects)).all()


def test_legacy_flat_expression_bit_for_bit(algebra_flat):
    """Legacy flat expressions whose old left fold agreed with standard
    precedence (every & before |/-) keep their exact meaning through the new
    parser; mixes like "a | b & c" intentionally change — that silent
    left-fold reading was the bug (covered above)."""
    res = (_algebra_study(algebra_flat)
           .cohort("old", "a & b - c")
           .cohort("ca", "a").cohort("cb", "b").cohort("cc", "c")
           .run({"T": algebra_flat}))
    want = res.cohorts["ca"].intersection(res.cohorts["cb"]).difference(
        res.cohorts["cc"])
    assert (np.asarray(res.cohorts["old"].subjects)
            == np.asarray(want.subjects)).all()


# ---------------------------------------------------------------------------
# join-aware column pruning (the acceptance criterion)
# ---------------------------------------------------------------------------
def _scan_projections(plan):
    """{source: effective projected column set} for every star scan."""
    out = {}
    for i, n in enumerate(plan.nodes):
        if n.op != "scan_star":
            continue
        cols = set(n.get("columns") or ())
        for j in plan.consumers()[i]:
            if plan.nodes[j].op == "select":
                cols = set(plan.nodes[j].get("cols"))
        out[n.get("source")] = cols
    return out


def test_pruning_drops_unreferenced_dimension_columns(dcir):
    s = (Study(n_patients=CFG.n_patients)
         .flatten(DCIR_SCHEMA)
         .extract(drug_dispenses(), name="drugs")
         .extract(medical_acts_dcir(), name="acts"))
    opt = s.optimized_plan()
    proj = _scan_projections(opt)
    # referenced: union extractor projection + join keys; everything else in
    # each star table must be gone before the first join
    assert proj["IR_BEN"] == {"patient_id"}            # pure join key
    assert proj["ER_PHA"] == {"flow_id", "cip13"}      # drops atc, quantity
    assert proj["ER_CAM"] == {"flow_id", "ccam_code"}
    assert proj["ER_PRS"] == {"flow_id", "patient_id", "execution_date"}
    # end-to-end: pruned results identical to the unpruned plan
    res = s.run(dict(dcir))
    unpruned = optimize(s.plan(), tables=dict(dcir), prune_cols=False)
    vals = execute(unpruned, dict(dcir))
    for name in ("drugs", "acts"):
        a = res.events[name].to_numpy()
        b = vals[unpruned.output_ids[name]].to_numpy()
        assert set(a) == set(b)
        for k in a:
            assert (a[k] == b[k]).all(), (name, k)
    # and the pruned plan scans strictly fewer columns
    n_pruned = sum(len(c) for c in proj.values())
    n_full = sum(len(c) for c in _scan_projections(unpruned).values())
    assert n_pruned < n_full


def test_pruning_expand_join_parity(pmsi):
    """1:N star (PMSI): pruning through expand_join keeps results identical
    while narrowing the scans."""
    def build():
        return (Study(n_patients=CFG.n_patients)
                .flatten(PMSI_MCO_SCHEMA, name="PMSI")
                .extract(Extractor(
                    name="hospital_acts", source="PMSI", category=2,
                    value_col="ccam_code", start_col="act_date",
                    null_cols=("ccam_code",),
                    distinct=("stay_id", "ccam_code", "act_date")),
                    name="hacts"))
    pruned = build().run(dict(pmsi))
    pruned.assert_no_loss()
    s2 = build()
    unpruned_plan = optimize(s2.plan(), tables=dict(pmsi), prune_cols=False)
    vals = execute(unpruned_plan, dict(pmsi))
    a = pruned.events["hacts"].to_numpy()
    b = vals[unpruned_plan.output_ids["hacts"]].to_numpy()
    for k in a:
        assert (a[k] == b[k]).all(), k
    proj = _scan_projections(pruned.plan)
    assert "icd_code" not in proj["MCO_D"] or proj["MCO_D"] == {"stay_id"}
    assert proj["MCO_D"] == {"stay_id"}                # unused 1:N child
    assert proj["MCO_A"] == {"stay_id", "ccam_code", "act_date"}


def test_keep_true_pins_full_flat_schema(dcir):
    s = (Study(n_patients=CFG.n_patients)
         .flatten(DCIR_SCHEMA, keep=True)
         .extract(drug_dispenses(), name="drugs"))
    opt = s.optimized_plan()
    proj = _scan_projections(opt)
    # the materialized flat output demands every star column: no pruning
    assert proj["IR_BEN"] == {"patient_id", "gender", "birth_date",
                              "death_date"}
    res = s.run(dict(dcir))
    assert "DCIR" in res.events
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    assert set(res.events["DCIR"].column_names) == set(flat.column_names)


def test_auto_demote_only_when_chained(dcir):
    kept = Study(n_patients=CFG.n_patients).flatten(DCIR_SCHEMA)
    assert "DCIR" in dict(kept.plan().outputs)        # nothing chained: kept
    chained = (Study(n_patients=CFG.n_patients)
               .flatten(DCIR_SCHEMA)
               .extract(drug_dispenses(), name="drugs"))
    assert "DCIR" not in dict(chained.plan().outputs)
    res = chained.run(dict(dcir))
    assert "DCIR" not in res.events and "drugs" in res.events


def test_pruned_study_sharded_matches_local(dcir):
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    build = lambda: (Study(n_patients=CFG.n_patients)
                     .flatten(DCIR_SCHEMA)
                     .extract(drug_dispenses(), name="drugs"))
    local = build().run(dict(dcir))
    sharded = build().run(dict(dcir), mesh=mesh)
    a, b = local.events["drugs"].to_numpy(), sharded.events["drugs"].to_numpy()
    for k in a:
        assert (a[k] == b[k]).all(), k


def test_column_audit_recorded_in_log(dcir):
    res = (Study(n_patients=CFG.n_patients)
           .flatten(DCIR_SCHEMA)
           .extract(drug_dispenses(), name="drugs")
           .run(dict(dcir)))
    rows = column_audit_from_log(res.log)
    assert rows                                        # audit rows exist
    by_stage = {r["stage"]: r for r in rows}
    pruned = [r for r in rows if r.get("pruned_columns")]
    dropped = {c for r in pruned for c in r["pruned_columns"]}
    assert {"gender", "birth_date", "death_date"} <= dropped
    join_rows = [r for r in by_stage if "lookup_join" in r]
    assert join_rows and all(by_stage[r]["required_columns"]
                             for r in join_rows)
