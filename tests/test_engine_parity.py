"""Engine parity: ``engine='pallas'`` (fused filter_compact kernel, interpret
mode on CPU) must produce identical events to ``engine='xla'`` (argsort-free
searchsorted compaction) for every Table-3 extractor factory, including the
``distinct=`` dedupe paths."""
import numpy as np
import pytest

from repro.core import (
    DCIR_SCHEMA, HAD_SCHEMA, IR_IMB_SCHEMA, PMSI_MCO_SCHEMA, SSR_SCHEMA,
    biology_acts, csarr_acts, diagnoses, drug_dispenses, flatten_star,
    hospital_stays, long_term_diseases, medical_acts_dcir, medical_acts_pmsi,
    practitioner_encounters, ssr_stays, takeover_reasons,
)
from repro.data.synthetic import (
    SyntheticConfig, generate_dcir, generate_had, generate_ir_imb,
    generate_pmsi, generate_ssr,
)

CFG = SyntheticConfig(n_patients=250, seed=17)


@pytest.fixture(scope="module")
def flats():
    return {
        "DCIR": flatten_star(DCIR_SCHEMA, generate_dcir(CFG))[0],
        "PMSI_MCO": flatten_star(PMSI_MCO_SCHEMA, generate_pmsi(CFG))[0],
        "SSR": flatten_star(SSR_SCHEMA, generate_ssr(CFG))[0],
        "HAD": flatten_star(HAD_SCHEMA, generate_had(CFG))[0],
        "IR_IMB": flatten_star(IR_IMB_SCHEMA, generate_ir_imb(CFG))[0],
    }


TABLE3 = [
    pytest.param(drug_dispenses(), id="drug_dispenses[cip13]"),
    pytest.param(drug_dispenses(granularity="atc"), id="drug_dispenses[atc]"),
    pytest.param(drug_dispenses(codes=list(range(40))), id="drug_dispenses[codes]"),
    pytest.param(medical_acts_dcir(), id="medical_acts_dcir"),
    pytest.param(medical_acts_pmsi(), id="medical_acts_pmsi[distinct]"),
    pytest.param(diagnoses(), id="diagnoses[distinct]"),
    pytest.param(diagnoses(codes=list(range(50))), id="diagnoses[codes+distinct]"),
    pytest.param(hospital_stays(), id="hospital_stays[distinct]"),
    pytest.param(biology_acts(), id="biology_acts"),
    pytest.param(practitioner_encounters(medical=True), id="encounters[medical]"),
    pytest.param(practitioner_encounters(medical=False), id="encounters[other]"),
    pytest.param(csarr_acts(), id="csarr_acts[distinct]"),
    pytest.param(ssr_stays(), id="ssr_stays[distinct]"),
    pytest.param(takeover_reasons(main=True), id="takeover[main]"),
    pytest.param(takeover_reasons(main=False), id="takeover[assoc]"),
    pytest.param(long_term_diseases(), id="long_term_diseases"),
]


@pytest.mark.parametrize("extractor", TABLE3)
def test_pallas_xla_compaction_parity(flats, extractor):
    flat = flats[extractor.source]
    xla = extractor(flat, engine="xla")
    pallas = extractor(flat, engine="pallas")
    assert int(xla.count) == int(pallas.count)
    a, b = xla.to_numpy(), pallas.to_numpy()
    assert set(a) == set(b)
    for k in a:
        assert (a[k] == b[k]).all(), k


@pytest.mark.parametrize("extractor", TABLE3[:4])
def test_study_engine_parity(flats, extractor):
    """The plan executor's per-node engine selection matches too."""
    from repro.study import Study

    def run(engine):
        return (Study(n_patients=CFG.n_patients)
                .extract(extractor, name="x")
                .run({extractor.source: flats[extractor.source]},
                     engine=engine).events["x"])

    a, b = run("xla").to_numpy(), run("pallas").to_numpy()
    for k in a:
        assert (a[k] == b[k]).all(), k
