"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp refs."""
from _hyp import given, settings, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


# -- filter_compact ------------------------------------------------------------
@pytest.mark.parametrize("n,block", [(256, 256), (1000, 256), (130, 64),
                                     (4096, 512), (64, 64)])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_filter_compact_sweep(n, block, dtype):
    if dtype == jnp.int32:
        vals = jnp.asarray(RNG.integers(-10**9, 10**9, n), dtype)
    else:
        vals = jnp.asarray(RNG.normal(size=n), dtype)
    mask = jnp.asarray(RNG.random(n) < 0.37)
    out, cnt = ops.filter_compact(vals, mask, block=block, interpret=True)
    rout, rcnt = ref.filter_compact_ref(vals, mask)
    assert int(cnt) == int(rcnt)
    assert_allclose(np.asarray(out), np.asarray(rout))


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_filter_compact_property(data):
    n = data.draw(st.integers(1, 300))
    vals = jnp.asarray(RNG.integers(0, 10**6, n), jnp.int32)
    mask = jnp.asarray(data.draw(st.lists(st.booleans(), min_size=n, max_size=n)))
    out, cnt = ops.filter_compact(vals, mask, block=64, interpret=True)
    expected = np.asarray(vals)[np.asarray(mask)]
    assert int(cnt) == len(expected)
    assert (np.asarray(out)[: len(expected)] == expected).all()


# -- segmented scan ------------------------------------------------------------
@pytest.mark.parametrize("n,block", [(512, 512), (2048, 512), (700, 128),
                                     (128, 128), (96, 32)])
def test_segment_scan_sweep(n, block):
    flags = jnp.asarray(RNG.random(n) < 0.08).at[0].set(True)
    vals = jnp.asarray(RNG.integers(0, 10**6, n), jnp.int32)
    mn, mx, ct = ops.segmented_scan(flags, vals, block=block, interpret=True)
    rmn, rmx, rct = ref.segmented_scan_ref(flags, vals)
    assert (np.asarray(mn) == np.asarray(rmn)).all()
    assert (np.asarray(mx) == np.asarray(rmx)).all()
    assert (np.asarray(ct) == np.asarray(rct)).all()


def test_segment_scan_single_run_spanning_blocks():
    """One run across many blocks exercises the SMEM carry chain."""
    n, block = 1024, 128
    flags = jnp.zeros(n, bool).at[0].set(True)
    vals = jnp.asarray(RNG.integers(0, 100, n), jnp.int32)
    mn, mx, ct = ops.segmented_scan(flags, vals, block=block, interpret=True)
    assert int(ct[-1]) == n
    assert int(mn[-1]) == int(np.asarray(vals).min())
    assert int(mx[-1]) == int(np.asarray(vals).max())


# -- bitset ---------------------------------------------------------------------
@pytest.mark.parametrize("n", [1024, 4096, 1000, 32])
@pytest.mark.parametrize("op", ["and", "or", "andnot", "xor"])
def test_bitset_sweep(n, op):
    a = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    w, c = ops.bitset_op(a, b, op, interpret=True)
    rw, rc = ref.bitset_op_ref(a, b, op)
    assert (np.asarray(w) == np.asarray(rw)).all()
    assert int(c) == int(rc)


# -- ragged / degenerate edge cases (wrapper + kernel-level padding) ----------
@pytest.mark.parametrize("n", [0, 63, 64, 65, 255, 256, 257])
@pytest.mark.parametrize("kind", ["empty", "all_kept", "all_dropped", "mixed"])
def test_filter_compact_edges(n, kind):
    vals = jnp.asarray(RNG.integers(-10**6, 10**6, n), jnp.int32)
    mask = {"empty": jnp.zeros(n, bool),
            "all_kept": jnp.ones(n, bool),
            "all_dropped": jnp.zeros(n, bool),
            "mixed": jnp.asarray(RNG.random(n) < 0.5)}[kind]
    out, cnt = ops.filter_compact(vals, mask, block=64, interpret=True)
    expected = np.asarray(vals)[np.asarray(mask)]
    assert int(cnt) == len(expected)
    assert (np.asarray(out)[: len(expected)] == expected).all()


@pytest.mark.parametrize("n", [0, 1, 31, 1023, 1024, 1025])
def test_bitset_op_ragged_and_degenerate(n):
    """Kernel-level ragged-tail padding: no block-multiple assert, popcounts
    unpolluted by the zero-padded tail."""
    from repro.kernels import bitset_ops as bo

    a = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    b = jnp.asarray(RNG.integers(0, 2**32, n, dtype=np.uint32))
    for op in ("and", "or", "andnot", "xor"):
        w, c = ops.bitset_op(a, b, op, interpret=True)
        rw, rc = ref.bitset_op_ref(a, b, op)
        assert w.shape == (n,)
        assert (np.asarray(w) == np.asarray(rw)).all()
        assert int(c) == int(rc)
        if n:  # kernel entry point directly (padded tail returned)
            wk, pk = bo.bitset_op_popcount(a, b, op, interpret=True)
            assert (np.asarray(wk)[:n] == np.asarray(rw)).all()
            assert int(np.asarray(pk).sum()) == int(rc)


def test_kernel_interpret_defaults_follow_backend():
    """interpret=None resolves by backend in every kernel module (no more
    hardcoded interpret=True entry points), through the ONE shared helper."""
    import repro.kernels as K
    from repro.kernels import bitset_ops as bo
    from repro.kernels import filter_compact as fc
    from repro.kernels import predicate as pk

    on_cpu = jax.default_backend() != "tpu"
    assert K.default_interpret() == on_cpu
    assert ops.default_interpret is K.default_interpret
    assert pk.default_interpret is K.default_interpret
    # callable without interpret= on any backend
    v = jnp.arange(64, dtype=jnp.int32)
    m = jnp.ones(64, bool)
    out, cnt = fc.filter_compact_blocks(v, m, block=64)
    assert int(cnt[0]) == 64 and (np.asarray(out) == np.asarray(v)).all()
    w, p = bo.bitset_op_popcount(v.astype(jnp.uint32),
                                 v.astype(jnp.uint32), "and", block=64)
    assert (np.asarray(w) == np.asarray(v)).all()


# -- hash partition ---------------------------------------------------------------
@pytest.mark.parametrize("n,block,n_dest", [(2048, 512, 8), (512, 128, 16),
                                            (1000, 256, 4)])
def test_hash_partition_sweep(n, block, n_dest):
    keys = jnp.asarray(RNG.integers(0, 10**6, n), jnp.int32)
    valid = jnp.asarray(RNG.random(n) < 0.9)
    d, r, h = ops.hash_partition_plan(keys, valid, n_dest, block=block,
                                      interpret=True)
    rd, rr, rh = ref.hash_partition_plan_ref(
        jnp.pad(keys, (0, (-n) % block)), jnp.pad(valid, (0, (-n) % block)),
        n_dest, block)
    assert (np.asarray(d) == np.asarray(rd)[:n]).all()
    assert (np.asarray(r) == np.asarray(rr)[:n]).all()
    assert (np.asarray(h) == np.asarray(rh)).all()


def test_hash_partition_histogram_consistency():
    n, block, n_dest = 1024, 256, 8
    keys = jnp.asarray(RNG.integers(0, 10**6, n), jnp.int32)
    valid = jnp.ones(n, bool)
    d, r, h = ops.hash_partition_plan(keys, valid, n_dest, block=block,
                                      interpret=True)
    # histogram matches destination counts
    dn = np.asarray(d)
    for dest in range(n_dest):
        assert np.asarray(h)[:, dest].sum() == (dn == dest).sum()


# -- flash attention --------------------------------------------------------------
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,D,causal,window",
    [
        (2, 4, 2, 128, 128, 64, True, 0),
        (1, 8, 2, 256, 256, 64, True, 64),
        (2, 4, 4, 1, 384, 64, True, 0),        # decode
        (1, 4, 1, 1, 512, 128, True, 128),     # decode + window
        (2, 2, 2, 96, 96, 32, False, 0),       # bidirectional + padding
        (1, 2, 1, 80, 160, 32, True, 0),       # Sq != Skv (chunked prefill)
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, causal, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Skv, D)), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=64, bk=64, interpret=True)
    rout = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert_allclose(np.asarray(out, np.float32), np.asarray(rout, np.float32),
                    rtol=tol, atol=tol)


def test_flash_attention_matches_model_sdpa():
    """Kernel vs the model's XLA attention path (serving parity)."""
    from repro.models import layers as L

    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    xla = L.sdpa(q, k, v, causal=True, window=32, q_positions=pos)
    pallas = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, window=32, bq=64, bk=64,
        interpret=True,
    ).transpose(0, 2, 1, 3).reshape(B, S, Hq * D)
    assert_allclose(np.asarray(pallas), np.asarray(xla), rtol=3e-5, atol=3e-5)
