"""Training-runtime tests: optimization progress, checkpoint/restart
determinism, microbatch-accumulation equivalence, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_bundle
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.train.train_step import init_train_state, make_train_step
from repro.train.checkpointing import (
    AsyncCheckpointer, latest_step, restore_checkpoint, save_checkpoint,
)
from repro.train.grad_compression import (
    dequantize_int8, ef_compress_step, quantize_int8,
)

ARCH = "xlstm-125m"  # smallest reduced config

# Seed-debt triage (see tests/test_models.py for the full note): the model
# stack needs jax.sharding.AxisType/get_abstract_mesh, absent from the
# container's jax.  Reactivates on a newer jax.
jax_version_xfail = pytest.mark.xfail(
    not hasattr(jax.sharding, "AxisType"), strict=False,
    reason="seed debt: installed jax lacks jax.sharding.AxisType/"
           "get_abstract_mesh required by the model stack")


def small_batch(cfg, key, B=4, S=32):
    return {"tokens": jax.random.randint(key, (B, S), 3, cfg.vocab_size)}


@jax_version_xfail
def test_loss_decreases():
    b = get_bundle(ARCH, reduced=True)
    step = jax.jit(make_train_step(
        b, AdamWConfig(lr_peak=3e-3, warmup_steps=5, total_steps=40)),
        donate_argnums=(0,))
    state = init_train_state(b, jax.random.key(0))
    key = jax.random.key(1)
    batch = small_batch(b.cfg, key)  # overfit one batch
    losses = []
    for t in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_grad_clip_and_lr_schedule():
    cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(cfg, jnp.int32(0))) == 0.0
    assert abs(float(cosine_lr(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(cosine_lr(cfg, jnp.int32(100))) < 1e-4


@jax_version_xfail
def test_checkpoint_restart_is_bit_deterministic(tmp_path):
    """Train 6 steps; vs train 3, checkpoint, restore, train 3 — identical."""
    b = get_bundle(ARCH, reduced=True)
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=10)
    step = jax.jit(make_train_step(b, opt))
    key = jax.random.key(0)
    batches = [small_batch(b.cfg, jax.random.key(100 + t)) for t in range(6)]

    state_a = init_train_state(b, key)
    for t in range(6):
        state_a, _ = step(state_a, batches[t])

    state_b = init_train_state(b, key)
    for t in range(3):
        state_b, _ = step(state_b, batches[t])
    save_checkpoint(str(tmp_path), 3, state_b, meta={"arch": ARCH})
    assert latest_step(str(tmp_path)) == 3
    restored, manifest = restore_checkpoint(str(tmp_path), 3, state_b)
    assert manifest["arch"] == ARCH
    for t in range(3, 6):
        restored, _ = step(restored, batches[t])

    for a, r in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_async_checkpointer(tmp_path):
    b = get_bundle(ARCH, reduced=True)
    state = init_train_state(b, jax.random.key(0))
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.save(s, state)
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [20, 30]  # keep=2 GC'd step 10


@jax_version_xfail
def test_microbatch_accumulation_matches_full_batch():
    b = get_bundle(ARCH, reduced=True)
    opt = AdamWConfig(lr_peak=1e-3, warmup_steps=1, total_steps=10)
    full = jax.jit(make_train_step(b, opt, microbatches=1))
    accum = jax.jit(make_train_step(b, opt, microbatches=2))
    state1 = init_train_state(b, jax.random.key(0))
    state2 = jax.tree.map(jnp.copy, state1)
    batch = small_batch(b.cfg, jax.random.key(5), B=4)
    s1, m1 = full(state1, batch)
    s2, m2 = accum(state2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2
    # parameters should agree to accumulation-order tolerance
    diffs = [float(jnp.abs(a.astype(jnp.float32) - c.astype(jnp.float32)).max())
             for a, c in zip(jax.tree.leaves(s1["params"]),
                             jax.tree.leaves(s2["params"]))]
    assert max(diffs) < 5e-2, max(diffs)


def test_quantize_roundtrip_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=4096), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-7


def test_error_feedback_contracts():
    """With error feedback, the accumulated error stays bounded while the
    compressed stream's running sum tracks the true gradient sum."""
    rng = np.random.default_rng(1)
    err = jnp.zeros(1024, jnp.float32)
    true_sum = jnp.zeros(1024, jnp.float32)
    sent_sum = jnp.zeros(1024, jnp.float32)
    for t in range(50):
        g = jnp.asarray(rng.normal(size=1024), jnp.float32)
        sent, err = ef_compress_step(g, err)
        true_sum = true_sum + g
        sent_sum = sent_sum + sent
    # residual equals the remaining error buffer exactly
    np.testing.assert_allclose(np.asarray(true_sum - sent_sum),
                               np.asarray(err), rtol=1e-4, atol=1e-4)
    assert float(jnp.abs(err).max()) < 0.1  # bounded by one quantization bin


def test_elastic_restore_reshapes_nothing_but_layout(tmp_path):
    """Restore with explicit shardings (single device: layout no-op) checks
    the reshard code path."""
    b = get_bundle(ARCH, reduced=True)
    state = init_train_state(b, jax.random.key(0))
    save_checkpoint(str(tmp_path), 1, state)
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
        state)
    restored, _ = restore_checkpoint(str(tmp_path), 1, state, shardings=sh)
    for a, r in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))
