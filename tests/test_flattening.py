"""SCALPEL-Flattening tests: joins vs numpy oracles, temporal slicing
equivalence, monitoring (no-loss) statistics."""
from _hyp import given, settings, st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.columnar import ColumnarTable, NULL_INT, is_null
from repro.core.flattening import expand_join, flatten_sliced, flatten_star, lookup_join
from repro.core.schema import DCIR_SCHEMA, PMSI_MCO_SCHEMA
from repro.data.synthetic import SyntheticConfig, generate_dcir, generate_pmsi


@pytest.fixture(scope="module")
def dcir():
    return generate_dcir(SyntheticConfig(n_patients=150, seed=7))


@pytest.fixture(scope="module")
def pmsi():
    return generate_pmsi(SyntheticConfig(n_patients=150, seed=7))


def test_lookup_join_matches_numpy(dcir):
    flat, st_ = lookup_join(dcir["ER_PRS"], dcir["ER_PHA"], "flow_id", "flow_id")
    f = flat.to_numpy()
    prs = dcir["ER_PRS"].to_numpy()
    pha = dcir["ER_PHA"].to_numpy()
    lut = dict(zip(pha["flow_id"].tolist(), pha["cip13"].tolist()))
    for i in range(0, len(f["flow_id"]), 97):
        fid = f["flow_id"][i]
        want = lut.get(fid, int(NULL_INT))
        assert f["cip13"][i] == want
    assert int(st_.rows_in) == int(st_.rows_out)
    st_.assert_no_loss()


def test_expand_join_cross_product(pmsi):
    flat, st_ = expand_join(pmsi["MCO_B"], pmsi["MCO_D"], "stay_id", "stay_id",
                            out_capacity=4096)
    f = flat.to_numpy()
    d = pmsi["MCO_D"].to_numpy()
    b = pmsi["MCO_B"].to_numpy()
    # every stay with diagnoses appears exactly count(diags) times;
    # stays without diagnoses appear once with null icd
    import collections
    diag_counts = collections.Counter(d["stay_id"].tolist())
    out_counts = collections.Counter(f["stay_id"].tolist())
    for sid in b["stay_id"].tolist():
        assert out_counts[sid] == max(diag_counts.get(sid, 0), 1)
    st_.assert_no_loss()


def test_expand_join_overflow_detected(pmsi):
    _, st_ = expand_join(pmsi["MCO_B"], pmsi["MCO_D"], "stay_id", "stay_id",
                         out_capacity=8)
    assert int(st_.overflow) > 0
    with pytest.raises(AssertionError):
        st_.assert_no_loss()


def test_flatten_star_row_conservation(dcir):
    flat, stats = flatten_star(DCIR_SCHEMA, dcir)
    # DCIR is block-sparse: N:1 joins preserve the central row count
    assert int(flat.count) == int(dcir["ER_PRS"].count)
    for s in stats:
        s.assert_no_loss()


def test_flatten_pmsi_blowup(pmsi):
    flat, _ = flatten_star(PMSI_MCO_SCHEMA, pmsi)
    # 1:N children blow the row count up (Table 1's phenomenon)
    assert int(flat.count) > int(pmsi["MCO_B"].count)


def test_temporal_slicing_equivalence(dcir):
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    t0, t1 = 14_600, 14_600 + 3 * 365
    sliced, _ = flatten_sliced(DCIR_SCHEMA, dcir, "execution_date", 5, t0, t1)
    assert int(sliced.count) == int(flat.count)
    # same multiset of (flow_id) keys
    a = np.sort(flat.to_numpy()["flow_id"])
    b = np.sort(sliced.to_numpy()["flow_id"])
    assert (a == b).all()


@settings(max_examples=25, deadline=None)
@given(
    n_left=st.integers(1, 40),
    n_right=st.integers(0, 40),
    key_range=st.integers(1, 10),
    data=st.data(),
)
def test_property_lookup_join_oracle(n_left, n_right, key_range, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    lk = rng.integers(0, key_range, n_left).astype(np.int32)
    rk = rng.permutation(key_range)[: min(n_right, key_range)].astype(np.int32)
    rv = rng.integers(0, 1000, rk.shape[0]).astype(np.int32)
    left = ColumnarTable.from_columns({"k": lk})
    right = ColumnarTable.from_columns({"k": rk, "v": rv})
    out, _ = lookup_join(left, right, "k", "k")
    lut = dict(zip(rk.tolist(), rv.tolist()))
    o = out.to_numpy()
    for i in range(n_left):
        assert o["v"][i] == lut.get(int(o["k"][i]), int(NULL_INT))
