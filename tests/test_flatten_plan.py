"""Plan-level flattening: join/exchange nodes in the Study IR.

Covers the join edge cases (NULL keys on both sides, duplicate right keys,
overflow accounting), the optimizer's join rewrites (capacity planning,
exchange pruning), the bounded ``flatten_sliced`` capacity, and the parity of
``Study.flatten`` with the eager ``flatten_star`` — single-device and under
``shard_map``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DCIR_SCHEMA, PMSI_MCO_SCHEMA, drug_dispenses, medical_acts_dcir
from repro.core.columnar import ColumnarTable, NULL_INT, is_null
from repro.core.flattening import (
    distributed_flatten, expand_join, flatten_sliced, flatten_star, lookup_join,
)
from repro.data.synthetic import SyntheticConfig, generate_dcir, generate_pmsi
from repro.study import Study, optimize, plan_capacities, prune_exchanges

CFG = SyntheticConfig(n_patients=200, seed=3)


@pytest.fixture(scope="module")
def dcir():
    return generate_dcir(CFG)


@pytest.fixture(scope="module")
def pmsi():
    return generate_pmsi(CFG)


def _table(**cols):
    return ColumnarTable.from_columns(
        {k: np.asarray(v, np.int32) for k, v in cols.items()})


# ---------------------------------------------------------------------------
# join edge cases
# ---------------------------------------------------------------------------
def test_lookup_join_null_keys_never_match():
    # SQL semantics: a NULL left key must not match a NULL right key
    left = _table(k=[1, int(NULL_INT), 3])
    right = _table(k=[int(NULL_INT), 1], v=[111, 222])
    out, st = lookup_join(left, right, "k", "k")
    o = out.to_numpy()
    assert o["v"].tolist() == [222, int(NULL_INT), int(NULL_INT)]
    assert int(st.matched) == 1
    assert int(st.null_keys) == 2  # one per side
    st.assert_no_loss()


def test_lookup_join_duplicate_right_keys_take_first_sorted():
    # N:1 contract violated by the data: the join still yields one row per
    # left row, gathering the first matching right row in sort order
    left = _table(k=[7, 8])
    right = _table(k=[7, 7, 8], v=[10, 20, 30])
    out, st = lookup_join(left, right, "k", "k")
    o = out.to_numpy()
    assert int(o["v"][1]) == 30
    assert int(o["v"][0]) in (10, 20)  # one of the duplicates, deterministic
    assert int(st.rows_out) == 2


def test_expand_join_null_keys_emit_single_null_row():
    left = _table(k=[int(NULL_INT), 5])
    right = _table(k=[int(NULL_INT), int(NULL_INT), 5], v=[1, 2, 3])
    out, st = expand_join(left, right, "k", "k", out_capacity=8)
    o = out.to_numpy()
    # null-key left row -> exactly one output row with null right attributes
    rows = sorted(zip(o["k"].tolist(), o["v"].tolist()))
    assert rows == [(int(NULL_INT), int(NULL_INT)), (5, 3)]
    assert int(st.matched) == 1
    assert int(st.null_keys) == 3
    st.assert_no_loss()


def test_expand_join_overflow_accounting_is_exact():
    # left key 1 matches 4 right rows, key 2 matches 2: true total = 6
    left = _table(k=[1, 2])
    right = _table(k=[1, 1, 1, 1, 2, 2], v=[0, 1, 2, 3, 4, 5])
    full, st_full = expand_join(left, right, "k", "k", out_capacity=6)
    assert int(st_full.overflow) == 0 and int(full.count) == 6
    clipped, st_clip = expand_join(left, right, "k", "k", out_capacity=4)
    assert int(st_clip.overflow) == 2          # exactly total - capacity
    assert int(clipped.count) == 4
    with pytest.raises(AssertionError):
        st_clip.assert_no_loss()


def test_expand_join_duplicate_left_keys_cross_product():
    left = _table(k=[4, 4])
    right = _table(k=[4, 4, 4], v=[1, 2, 3])
    out, st = expand_join(left, right, "k", "k", out_capacity=16)
    assert int(out.count) == 6                 # 2 x 3 pairs
    st.assert_no_loss()


# ---------------------------------------------------------------------------
# flatten_sliced capacity bound
# ---------------------------------------------------------------------------
def test_flatten_sliced_capacity_bounded(dcir):
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    n_slices = 6
    sliced, stats = flatten_sliced(DCIR_SCHEMA, dcir, "execution_date",
                                   n_slices, 14_600, 14_600 + 3 * 365)
    assert int(sliced.count) == int(flat.count)
    # each slice allocates ~its own row count, not the full central capacity
    assert sliced.capacity < flat.capacity * 2
    assert sliced.capacity < flat.capacity * n_slices  # the old blow-up
    for s in stats:
        s.assert_no_loss()


# ---------------------------------------------------------------------------
# optimizer join rewrites
# ---------------------------------------------------------------------------
def test_capacity_planner_sets_exact_expand_capacities(pmsi):
    s = Study(n_patients=CFG.n_patients).flatten(PMSI_MCO_SCHEMA, name="PMSI")
    opt = s.optimized_plan(tables=dict(pmsi))
    caps = [n.get("capacity") for n in opt.nodes if n.op == "expand_join"]
    assert all(c is not None for c in caps)
    # planner capacity: exact row count rounded up to 64 — at most one
    # quantum above the true output size, and tighter than the (L+R)*1.5
    # trace-time guess
    res = s.run(dict(pmsi))
    res.assert_no_loss()
    out_rows = [d["rows_out"] for _, d in sorted(res.flatten_stats.items())
                if d["stage"].startswith("expand_join")]
    for cap, rows in zip(caps, out_rows):
        assert rows <= cap < rows + 64 + 1


def test_prune_exchanges_drops_redundant_and_local():
    from repro.study import PlanBuilder

    s = Study(n_patients=8)
    s.flatten(DCIR_SCHEMA)                       # exchange=True by default
    raw = s.plan()
    # the builder tracks the left side's partitioning, so the raw plan
    # already has only the needed exchanges: one left + one right per
    # distinct join key (flow_id joined twice), no final patient exchange
    # after the patient_id join (2 left + 3 right = 5)
    assert raw.count_ops()["exchange"] == 5
    # on-mesh those are all load-bearing; off-mesh every exchange drops
    assert prune_exchanges(raw, n_shards=4).count_ops()["exchange"] == 5
    assert prune_exchanges(raw, n_shards=1).count_ops().get("exchange", 0) == 0
    # a declared pre-partitioning makes the matching exchange redundant
    b = PlanBuilder()
    t = b.scan_star("T", partitioned_on="k")
    b.set_output("out", b.exchange(t, "k"))
    assert prune_exchanges(b.build(),
                           n_shards=4).count_ops().get("exchange", 0) == 0


def test_replanned_capacities_follow_data_distribution():
    # same-shaped inputs, different join-key distributions: the second run
    # must RE-plan capacities, not reuse the first run's exact sizes (a
    # stale capacity would silently truncate rows)
    import numpy as _np

    from repro.core.schema import JoinEdge, StarSchema, TableSchema
    i32 = _np.dtype(_np.int32)
    schema = StarSchema(
        name="S",
        central=TableSchema("C", {"k": i32, "patient_id": i32}, key="k"),
        dims=(TableSchema("D", {"k": i32, "v": i32}, key="k"),),
        joins=(JoinEdge("C", "D", "k", "k", one_to_many=True),),
    )
    central = _table(k=[0, 1, 2, 3], patient_id=[0, 1, 2, 3])
    uniform = {"C": central, "D": _table(k=[0, 1, 2, 3] * 2, v=list(range(8)))}
    skewed = {"C": central, "D": _table(k=[0] * 8, v=list(range(8)))}
    study = Study(n_patients=4).flatten(schema, name="f")
    ra = study.run(dict(uniform))
    ra.assert_no_loss()
    assert int(ra.events["f"].count) == 8       # every key matches twice
    rb = study.run(dict(skewed))                # k=0: 8 matches, others miss
    rb.assert_no_loss()
    assert int(rb.events["f"].count) == 8 + 3


def test_capacity_planner_handles_time_slices(dcir):
    s = (Study(n_patients=CFG.n_patients)
         .flatten(DCIR_SCHEMA, time_slices=3, time_column="execution_date",
                  t0=14_600, t1=14_600 + 3 * 365))
    opt = s.optimized_plan(tables=dict(dcir))
    caps = [n.get("capacity") for n in opt.nodes if n.op == "slice_time"]
    assert caps and all(c is not None for c in caps)
    res = s.run(dict(dcir))
    res.assert_no_loss()
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    assert int(res.events["DCIR"].count) == int(flat.count)


# ---------------------------------------------------------------------------
# plan-level Study.flatten vs eager flatten_star parity
# ---------------------------------------------------------------------------
def _assert_tables_equal(a: ColumnarTable, b: ColumnarTable):
    x, y = a.to_numpy(), b.to_numpy()
    assert set(x) == set(y)
    for k in x:
        assert (x[k] == y[k]).all(), k


@pytest.mark.parametrize("schema,gen", [(DCIR_SCHEMA, generate_dcir),
                                        (PMSI_MCO_SCHEMA, generate_pmsi)])
def test_study_flatten_matches_eager(schema, gen):
    tables = gen(CFG)
    eager, _ = flatten_star(schema, tables)
    res = (Study(n_patients=CFG.n_patients)
           .flatten(schema, name="flat")
           .run(dict(tables)))
    res.assert_no_loss()
    _assert_tables_equal(eager, res.events["flat"])


def test_study_flatten_matches_eager_sharded(dcir):
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    eager, _ = flatten_star(DCIR_SCHEMA, dcir)
    res = (Study(n_patients=CFG.n_patients)
           .flatten(DCIR_SCHEMA, name="flat")
           .run(dict(dcir), mesh=mesh))
    res.assert_no_loss()
    _assert_tables_equal(eager, res.events["flat"])


def test_flatten_extract_one_plan(dcir):
    """Raw star tables -> flat -> events -> cohort, one optimized plan."""
    s = (Study(n_patients=CFG.n_patients)
         .flatten(DCIR_SCHEMA)
         .extract(drug_dispenses(), name="drugs")
         .extract(medical_acts_dcir(), name="acts")
         .cohort("drugged", "drugs"))
    res = s.run(dict(dcir))
    # flattening and extraction share ONE plan: extract chains onto the
    # flatten node instead of scanning a pre-flattened env table
    ops = res.plan.count_ops()
    # the IR_BEN dimension join is column-pruned to its bare key and then
    # ELIMINATED (optimizer.eliminate_joins): it survives only as an
    # audit-only key_count node; the two detail joins carry real columns
    assert ops.get("lookup_join", 0) == 2 and "scan" not in ops
    assert ops.get("key_count", 0) == 1
    # one merged union projection downstream of the joins, plus the pruning
    # selects the optimizer inserts above the star scans (the flat table is
    # auto-demoted from the outputs once extractors chain onto it)
    union = [n for n in res.plan.nodes if n.op == "select"
             and not n.get("pruned_columns")]
    assert len(union) == 1
    prunes = [n for n in res.plan.nodes if n.op == "select"
              and n.get("pruned_columns")]
    assert prunes and any("gender" in n.get("pruned_columns")
                          for n in prunes)   # IR_BEN narrows to its join key
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    for name, ex in [("drugs", drug_dispenses()), ("acts", medical_acts_dcir())]:
        _assert_tables_equal(ex(flat), res.events[name])
    # per-join stats land in the OperationLog automatically — the
    # eliminated join's audit survives as its key_count entry
    join_entries = [e for e in res.log.entries
                    if e["op"].startswith("plan:lookup_join")]
    assert len(join_entries) == 2
    kc_entries = [e for e in res.log.entries
                  if e["op"].startswith("plan:key_count")]
    assert len(kc_entries) == 1
    for e in join_entries + kc_entries:
        assert e["params"]["overflow"] == 0
        assert e["params"]["key_sum_in"] == e["params"]["key_sum_out"]


def test_distributed_flatten_wrapper_single_device(dcir):
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    flat_d, overflow = distributed_flatten(DCIR_SCHEMA, dcir, mesh)
    assert int(overflow) == 0
    eager, _ = flatten_star(DCIR_SCHEMA, dcir)
    a, b = eager.to_numpy(), flat_d.to_numpy()
    ia, ib = np.argsort(a["flow_id"]), np.argsort(b["flow_id"])
    for k in a:
        assert (a[k][ia] == b[k][ib]).all(), k
