"""Serving tests: decode parity with prefill, continuous batcher liveness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_bundle
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.serve_step import greedy_sample, make_serve_step

ARCH = "qwen2-1.5b"

# Seed-debt triage (see tests/test_models.py for the full note): the model
# stack needs jax.sharding.AxisType/get_abstract_mesh, absent from the
# container's jax.  Reactivates on a newer jax.
jax_version_xfail = pytest.mark.xfail(
    not hasattr(jax.sharding, "AxisType"), strict=False,
    reason="seed debt: installed jax lacks jax.sharding.AxisType/"
           "get_abstract_mesh required by the model stack")


@jax_version_xfail
def test_greedy_decode_matches_prefill_argmax():
    b = get_bundle(ARCH, reduced=True)
    params = b.init(jax.random.key(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(1), (B, S), 3, b.cfg.vocab_size)
    pre = b.prefill(params, {"tokens": toks})
    want = np.asarray(jnp.argmax(pre[:, -1], axis=-1))

    cache = b.init_cache(B, 32)
    step = jax.jit(make_serve_step(b))
    for t in range(S):
        logits, cache = step(params, cache,
                             {"tokens": toks[:, t:t + 1], "pos": jnp.int32(t)})
    got = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
    np.testing.assert_array_equal(got, want)


@jax_version_xfail
def test_continuous_batcher_completes_requests():
    b = get_bundle(ARCH, reduced=True)
    params = b.init(jax.random.key(0))
    engine = ContinuousBatcher(b, params, n_slots=2, kv_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=[1] + rng.integers(8, 100, 5).tolist(),
                    max_new=4) for i in range(4)]
    for r in reqs:
        engine.submit(r)
    engine.run(max_steps=200)
    assert all(r.done for r in reqs)
    assert all(1 <= len(r.out) <= 4 for r in reqs)


@jax_version_xfail
def test_cache_donation_shape_stability():
    """Repeated decode steps keep one cache allocation (donated buffers)."""
    b = get_bundle(ARCH, reduced=True)
    params = b.init(jax.random.key(0))
    cache = b.init_cache(2, 32)
    step = jax.jit(make_serve_step(b), donate_argnums=(1,))
    toks = jnp.ones((2, 1), jnp.int32) * 5
    for t in range(8):
        _, cache = step(params, cache, {"tokens": toks, "pos": jnp.int32(t)})
    leaves = jax.tree.leaves(cache)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)
