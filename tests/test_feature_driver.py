"""FeatureDriver tests: dense scatter tensors + LM token streams."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Category, Cohort, FeatureDriver, TokenizerSpec, make_events
from repro.core.feature_driver import BOS, EOS, PAD


def make_cohort(n_patients=8):
    ev = make_events(
        patient_id=jnp.asarray([0, 0, 1, 3, 3, 3], jnp.int32),
        category=Category.DRUG_DISPENSE,
        value=jnp.asarray([5, 7, 5, 1, 2, 3], jnp.int32),
        start=jnp.asarray([10, 40, 20, 5, 6, 7], jnp.int32),
    )
    return Cohort.from_events("drugs", ev, n_patients)


def test_dense_features_counts():
    c = make_cohort()
    c.window = (0, 100)
    fd = FeatureDriver(c)
    X = fd.dense_features(n_buckets=10, bucket_days=10, n_features=16)
    assert X.shape == (8, 10, 16)
    assert float(X.sum()) == 6.0  # every event lands once
    assert float(X[0, 1, 5]) == 1.0  # patient 0, day 10, drug 5
    assert float(X[3].sum()) == 3.0


def test_dense_features_window_check():
    c = make_cohort()
    c.window = (0, 30)  # events at 40 fall outside
    fd = FeatureDriver(c)
    X = fd.dense_features(n_buckets=3, bucket_days=10, n_features=16)
    assert fd.checks["events_out_of_window"] == 1
    assert float(X.sum()) == 5.0


def test_token_sequences_structure():
    c = make_cohort()
    c.window = (0, 100)
    fd = FeatureDriver(c)
    toks, mask = fd.token_sequences(seq_len=16)
    t = np.asarray(toks)
    assert (t[:, 0] == BOS).all()
    # patient 3 has 3 events -> BOS e e e EOS PAD...
    assert t[3, 4] == EOS
    assert (t[3, 5:] == PAD).all()
    assert np.asarray(mask)[3].sum() == 5
    # patient with no events: BOS EOS
    assert t[2, 1] == EOS
    spec = TokenizerSpec.default()
    off = spec.category_offsets[Category.DRUG_DISPENSE]
    assert t[0, 1] == off + 5 and t[0, 2] == off + 7  # time-ordered


def test_token_sequences_truncation_counted():
    c = make_cohort()
    c.window = (0, 100)
    fd = FeatureDriver(c)
    toks, _ = fd.token_sequences(seq_len=4)  # room for only 2 events
    assert fd.checks["events_truncated"] > 0


def test_tokenizer_vocab_layout():
    spec = TokenizerSpec.default()
    offs = sorted(spec.category_offsets.values())
    assert offs[0] >= 8  # specials reserved
    # non-overlapping category ranges
    for (c1, o1) in spec.category_offsets.items():
        for (c2, o2) in spec.category_offsets.items():
            if c1 < c2:
                assert (o1 + spec.category_sizes[c1] <= o2) or \
                       (o2 + spec.category_sizes[c2] <= o1)
