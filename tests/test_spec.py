"""Declarative spec front end: exact round-trips onto the golden plans,
golden spec files, the SPEC-nnn rejection matrix (one mutation per code),
parser position info, and service ``submit_spec`` parity — the wire path
must produce bit-identical results, identical cache behavior, and
structured (never traceback) failures.

    REGEN_GOLDENS=1 PYTHONPATH=src python -m pytest tests/test_spec.py
"""
import json
import os
import random

import numpy as np
import pytest

from repro.data import SyntheticConfig, generate_dcir
from repro.study import (CohortQueryService, ServiceConfig, Study, col,
                         compile_spec, spec_from_study, validate_spec)
from repro.study.defects import golden_studies
from repro.study.expr import CohortParseError, as_param, parse_cohort_expr
from repro.study.fuzz import (MUTATIONS, gen_valid_spec, mutate_spec,
                              results_equal)
from repro.study.spec import (SPEC_CODES, SpecValidationError, error_payload,
                              expr_dict_to_param, expr_to_dict)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")

CFG = SyntheticConfig(n_patients=200, seed=7)


@pytest.fixture(scope="module")
def dcir():
    return generate_dcir(CFG)


def _wire_study():
    """A spec-expressible study exercising every concept kind the fuzzer
    generates: flatten, whitelist extract, filter, algebra, flow."""
    from repro.core import DCIR_SCHEMA, drug_dispenses, medical_acts_dcir
    return (Study(n_patients=CFG.n_patients)
            .flatten(DCIR_SCHEMA)
            .extract(drug_dispenses(codes=list(range(80))), name="drugs")
            .extract(medical_acts_dcir(), name="acts")
            .filter("acts", col("value") >= 120, name="acts_hi")
            .patients("IR_BEN")
            .cohort("base", "extract_patients")
            .cohort("drugged", "drugs")
            .cohort("final", "(drugged & base) - acts_hi")
            .flow("base", "drugged", "final"))


# ---------------------------------------------------------------------------
# round-trip: Study -> spec -> Study rebuilds the identical plan
# ---------------------------------------------------------------------------
def test_round_trip_golden_plans():
    for name, study in golden_studies().items():
        spec = spec_from_study(study)
        rebuilt = compile_spec(json.loads(json.dumps(spec)))  # via the wire
        for eng in ("jnp", "pallas"):
            assert (rebuilt.optimized_plan(predicate_engine=eng).key()
                    == study.optimized_plan(predicate_engine=eng).key()), \
                f"{name}/{eng}: spec round-trip changed the plan"
        # the inverse is a fixpoint: re-exporting the rebuilt study is a
        # no-op, so specs are stable artifacts, not drifting snapshots
        assert spec_from_study(rebuilt) == spec


def test_round_trip_property_fuzzed_specs():
    rng = random.Random(42)
    for _ in range(25):
        spec = gen_valid_spec(rng)
        assert validate_spec(spec) == []
        study = compile_spec(spec)
        spec2 = spec_from_study(study)
        assert compile_spec(spec2).plan().key() == study.plan().key()
        assert spec_from_study(compile_spec(spec2)) == spec2


def test_spec_from_study_refuses_bound_tables(dcir):
    s = Study(n_patients=10).source("T", dcir["IR_BEN"])
    with pytest.raises(ValueError, match="data, not declarations"):
        spec_from_study(s)


def test_expr_wire_round_trip():
    exprs = [
        (col("a") + 1 < col("b") * 2) & ~col("c").isin([1, 2, 3]),
        col("x").is_null() | (col("y") != 0),
    ]
    for e in exprs:
        p = as_param(e)
        d = json.loads(json.dumps(expr_to_dict(p)))
        assert expr_dict_to_param(d) == p


# ---------------------------------------------------------------------------
# golden spec files: the two example studies as public wire artifacts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["quickstart", "cohort_study"])
def test_golden_spec_files(name):
    spec = spec_from_study(golden_studies()[name])
    path = os.path.join(GOLDEN_DIR, f"{name}_spec.json")
    if os.environ.get("REGEN_GOLDENS"):
        with open(path, "w") as f:
            # NOT sort_keys: cohorts is an *ordered* mapping (declaration
            # order is reference order); sorting would corrupt the artifact
            json.dump(spec, f, indent=1)
        return
    if not os.path.exists(path):
        pytest.fail(f"golden {name}_spec.json missing — regenerate with "
                    f"REGEN_GOLDENS=1")
    with open(path) as f:
        golden = json.load(f)
    assert json.loads(json.dumps(spec)) == golden, (
        f"wire spec of {name} drifted from its golden; regenerate with "
        f"REGEN_GOLDENS=1 and review the diff.")
    # the golden file itself must stay compilable and clean
    assert validate_spec(golden) == []


# ---------------------------------------------------------------------------
# rejection matrix: every SPEC code fires via its catalog mutation
# ---------------------------------------------------------------------------
def test_mutation_catalog_covers_every_validation_code():
    validation_codes = {c for c in SPEC_CODES
                        if c not in ("SPEC-429", "SPEC-900")}
    assert {code for code, _ in MUTATIONS} == validation_codes


@pytest.mark.parametrize("idx", range(len(MUTATIONS)))
def test_rejection_matrix(idx):
    rng = random.Random(idx)
    code, mutated = mutate_spec(gen_valid_spec(rng), idx, rng)
    issues = validate_spec(mutated)
    assert any(i.code == code for i in issues), \
        f"expected {code}, got {sorted({i.code for i in issues})}"
    with pytest.raises(SpecValidationError) as ei:
        compile_spec(mutated)
    errs = error_payload(ei.value)
    assert all(set(e) == {"code", "path", "message", "hint"} for e in errs)
    json.dumps(errs)                               # wire-serializable


def test_cohort_parse_error_position():
    with pytest.raises(CohortParseError) as ei:
        parse_cohort_expr("base & ( other")
    e = ei.value
    assert e.offset == len("base & ( other")       # where ')' was expected
    assert "^" in str(e)
    with pytest.raises(CohortParseError) as ei:
        parse_cohort_expr("a & & b")
    assert ei.value.offset == 4

    spec = gen_valid_spec(random.Random(3))
    spec["cohorts"]["bad"] = "base & & base"
    (issue,) = [i for i in validate_spec(spec) if i.code == "SPEC-012"]
    assert issue.path == "cohorts.bad"
    assert "offset 7" in issue.message and "^" in issue.message


def test_error_payload_never_leaks_internals():
    errs = error_payload(RuntimeError("secret /etc/shadow state"))
    assert [e["code"] for e in errs] == ["SPEC-900"]
    assert "secret" not in json.dumps(errs)
    assert "RuntimeError" in errs[0]["message"]    # the type is public


# ---------------------------------------------------------------------------
# service wire path: submit_spec parity + structured rejection
# ---------------------------------------------------------------------------
def test_submit_spec_parity_with_python_study(dcir):
    study = _wire_study()
    spec = json.loads(json.dumps(spec_from_study(study)))

    py_svc = CohortQueryService(dict(dcir), config=ServiceConfig())
    t_py = py_svc.submit(_wire_study())
    py_svc.drain()
    wire_svc = CohortQueryService(dict(dcir), config=ServiceConfig())
    t_wire = wire_svc.submit_spec(spec)
    wire_svc.drain()

    assert t_py.status == "done" and t_wire.status == "done", \
        (t_py.error, t_wire.error)
    assert results_equal(t_py.result, t_wire.result) is None
    # identical plans => identical cache/compile behavior on fresh services
    assert (t_wire.cache_hits, t_wire.cache_misses) == \
        (t_py.cache_hits, t_py.cache_misses)
    assert wire_svc.stats.compile_count == py_svc.stats.compile_count

    payload = t_wire.wire_payload()
    assert payload["status"] == "done"
    assert payload["cohorts"]["final"] == \
        t_py.result.cohorts["final"].subject_count()
    assert payload["flow"] == [r["subjects"]
                               for r in t_py.result.flow.flowchart()]
    json.dumps(payload)


def test_submit_spec_rejects_with_structured_errors(dcir):
    svc = CohortQueryService(dict(dcir))
    spec = gen_valid_spec(random.Random(5))
    spec["cohorts"]["bad"] = "base & ("
    ticket = svc.submit_spec(spec, tenant="t1")
    assert ticket.status == "invalid"
    assert svc.stats.plans_rejected == 1
    payload = ticket.wire_payload()
    assert payload["status"] == "invalid"
    assert any(e["code"] == "SPEC-012" for e in payload["errors"])
    assert all("Traceback" not in json.dumps(e) for e in payload["errors"])
    assert any(e["op"] == "service:invalid:t1" for e in svc.log.entries)
    # an invalid spec consumes no queue slot and never reaches the planner
    assert svc.step() == 0


def test_validate_spec_never_raises_on_unhashable_values(dcir):
    # a filter source may be any JSON value; unhashable ones (list/object)
    # must surface as findings, never a TypeError out of validate_spec
    where = {"op": "cmp", "cmp": "<",
             "lhs": {"op": "col", "name": "start"},
             "rhs": {"op": "lit", "value": 1}}
    for src in (["a"], {"a": 1}):
        spec = {"spec_version": 1, "n_patients": 4,
                "concepts": [{"kind": "filter", "source": src,
                              "where": where}]}
        issues = validate_spec(spec)
        assert any(i.code == "SPEC-005" for i in issues)
    svc = CohortQueryService(dict(dcir))
    ticket = svc.submit_spec(
        {"spec_version": 1, "n_patients": 4,
         "concepts": [{"kind": "filter", "source": ["a"],
                       "where": where}]}, tenant="t9")
    assert ticket.status == "invalid"
    json.dumps(ticket.wire_payload())


def test_reserved_kwargs_are_validation_findings():
    # kwargs keys that collide with builder parameters would raise
    # TypeError inside compile; the validator must catch them first
    spec = {"spec_version": 1, "n_patients": 8,
            "concepts": [
                {"kind": "patients", "name": "p"},
                {"kind": "transform", "fn": "exposures", "inputs": ["p"],
                 "kwargs": {"name": "boom", "fn": "x"}}],
            "cohorts": {"base": "p"},
            "outputs": [{"kind": "featurize", "name": "f", "cohort": "base",
                         "kwargs": {"cohort": "base", "kind": "tokens"}}]}
    hits = {(i.code, i.path) for i in validate_spec(spec)}
    assert ("SPEC-005", "concepts[1].kwargs") in hits
    assert ("SPEC-005", "outputs[0].kwargs") in hits
    with pytest.raises(SpecValidationError):
        compile_spec(spec)


def test_submit_spec_never_leaks_unexpected_exceptions(dcir, monkeypatch):
    # even a non-SpecValidationError out of compile_spec must resolve as a
    # structured SPEC-900 ticket, not escape the wire entry point
    import repro.study.spec as specmod

    def kaboom(_spec):
        raise RuntimeError("secret internals")

    monkeypatch.setattr(specmod, "compile_spec", kaboom)
    svc = CohortQueryService(dict(dcir))
    ticket = svc.submit_spec({"spec_version": 1, "n_patients": 4},
                             tenant="t3")
    assert ticket.status == "invalid"
    assert svc.stats.plans_rejected == 1
    payload = ticket.wire_payload()
    assert [e["code"] for e in payload["errors"]] == ["SPEC-900"]
    assert "secret" not in json.dumps(payload)
    assert any(e["op"] == "service:invalid:t3" for e in svc.log.entries)


def test_submit_spec_analyzer_rejection_is_structured(dcir):
    svc = CohortQueryService(dict(dcir))
    spec = gen_valid_spec(random.Random(6))
    ex = spec["concepts"][0]["extractor"]
    ex["where"] = {"op": "and",                     # provably always-false
                   "lhs": {"op": "cmp", "cmp": "<",
                           "lhs": {"op": "col", "name": "quantity"},
                           "rhs": {"op": "lit", "value": 2}},
                   "rhs": {"op": "cmp", "cmp": ">",
                           "lhs": {"op": "col", "name": "quantity"},
                           "rhs": {"op": "lit", "value": 30}}}
    assert validate_spec(spec) == []               # structurally fine
    ticket = svc.submit_spec(spec, tenant="t2")
    svc.drain()
    assert ticket.status == "invalid"
    assert svc.stats.plans_rejected == 1
    payload = ticket.wire_payload()
    assert any(e["code"] == "SP003" for e in payload["errors"])
    json.dumps(payload)


def test_submit_spec_full_queue_is_wire_structured(dcir):
    svc = CohortQueryService(dict(dcir),
                             config=ServiceConfig(max_queue=0))
    ticket = svc.submit_spec(spec_from_study(_wire_study()))
    assert ticket.status == "rejected"
    payload = ticket.wire_payload()
    assert payload["status"] == "rejected"
    assert [e["code"] for e in payload["errors"]] == ["SPEC-429"]
