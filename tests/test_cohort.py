"""Cohort algebra tests: bitset <-> set homomorphism (hypothesis), flow
flowcharts, description composition (paper Supplementary Out[6])."""
from _hyp import given, settings, st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Bitset, Category, Cohort, CohortCollection, CohortFlow, make_events


def cohort_from_set(name, s, n):
    idx = jnp.asarray(sorted(s) or [0], jnp.int32)
    valid = jnp.asarray([True] * len(s) + ([False] if not s else []))[: max(len(s), 1)]
    bits = Bitset.from_indices(idx, valid, n)
    return Cohort(name=name, description=name, subjects=bits, n_patients=n)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 200),
    data=st.data(),
)
def test_property_bitset_set_homomorphism(n, data):
    a = set(data.draw(st.lists(st.integers(0, n - 1), max_size=n)))
    b = set(data.draw(st.lists(st.integers(0, n - 1), max_size=n)))
    ca = cohort_from_set("a", a, n)
    cb = cohort_from_set("b", b, n)
    assert ca.subject_count() == len(a)
    assert ca.intersection(cb).subject_count() == len(a & b)
    assert ca.union(cb).subject_count() == len(a | b)
    assert ca.difference(cb).subject_count() == len(a - b)
    # mask round-trip
    mask = np.asarray(ca.subjects_mask())
    assert set(np.nonzero(mask)[0].tolist()) == a


def test_descriptions_compose():
    n = 16
    base = cohort_from_set("extract_patients", {0, 1, 2, 3}, n)
    expo = cohort_from_set("exposures", {1, 2, 3, 4}, n)
    frac = cohort_from_set("fractures", {2}, n)
    final = expo.intersection(base).difference(frac)
    assert "without" in final.describe()
    assert final.subject_count() == 2  # {1,3}


def test_cohort_events_filtered_on_combine():
    n = 8
    ev = make_events(
        patient_id=jnp.asarray([0, 1, 2], jnp.int32), category=Category.EXPOSURE,
        value=jnp.asarray([1, 1, 1], jnp.int32),
        start=jnp.asarray([0, 0, 0], jnp.int32),
    )
    ca = Cohort.from_events("a", ev, n)
    cb = cohort_from_set("b", {0, 2}, n)
    inter = ca.intersection(cb)
    assert inter.subject_count() == 2
    kept = inter.events_of()
    assert int(kept.count) == 2


def test_cohort_flow_monotone_and_flowchart():
    n = 32
    c1 = cohort_from_set("s1", set(range(20)), n)
    c2 = cohort_from_set("s2", set(range(5, 32)), n)
    c3 = cohort_from_set("s3", set(range(0, 32, 2)), n)
    flow = CohortFlow([c1, c2, c3])
    counts = [r["subjects"] for r in flow.flowchart()]
    assert counts == sorted(counts, reverse=True)  # fold(∩) can only shrink
    assert flow.flowchart()[1]["removed"] == counts[0] - counts[1]
    assert flow.final.subject_count() == counts[-1]
    assert "stage" in flow.render()


def test_cohort_collection():
    n = 8
    cc = CohortCollection({})
    cc.add(cohort_from_set("x", {1, 2}, n))
    assert cc.cohorts_names == {"x"}
    assert cc.get("x").subject_count() == 2


def test_bitset_kernel_parity():
    """Cohort algebra kernel (Pallas) agrees with the jnp path."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 2**32, 2048, dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, 2048, dtype=np.uint32))
    for op in ("and", "or", "andnot"):
        w, c = ops.bitset_op(a, b, op, interpret=True)
        rw, rc = ref.bitset_op_ref(a, b, op)
        assert (np.asarray(w) == np.asarray(rw)).all()
        assert int(c) == int(rc)


# ---------------------------------------------------------------------------
# empty-cohort statistics: every aggregation must be total and NaN-free,
# returning the documented sentinels when a denominator count is zero
# ---------------------------------------------------------------------------
def _empty_cohort(n=16):
    ev = make_events(
        patient_id=jnp.zeros((4,), jnp.int32),
        category=Category.DRUG_DISPENSE,
        value=jnp.zeros((4,), jnp.int32),
        start=jnp.zeros((4,), jnp.int32),
        valid=jnp.zeros((4,), bool),           # zero valid events
    )
    return Cohort(name="empty", description="empty", events=ev,
                  subjects=jnp.zeros((Bitset.n_words(n),), jnp.uint32),
                  n_patients=n)


def _empty_patients():
    from repro.core.columnar import ColumnarTable

    return ColumnarTable.from_columns(
        {"patient_id": np.zeros(4, np.int32),
         "gender": np.zeros(4, np.int32),
         "birth_date": np.zeros(4, np.int32),
         "death_date": np.zeros(4, np.int32)},
        valid=np.zeros(4, bool))


def _assert_finite(v, path):
    if isinstance(v, dict):
        for k, x in v.items():
            _assert_finite(x, f"{path}.{k}")
    elif isinstance(v, (list, tuple)):
        for i, x in enumerate(v):
            _assert_finite(x, f"{path}[{i}]")
    elif isinstance(v, float):
        assert np.isfinite(v), f"{path} is not finite: {v}"


def test_empty_cohort_sentinels():
    from repro.core import stats

    c, p = _empty_cohort(), _empty_patients()
    assert stats.age_mean(c, p) == {"mean": 0.0, "std": 0.0, "n": 0}
    assert stats.gender_ratio(c, p) == {"male_fraction": 0.0, "n": 0}
    assert stats.mean_gap_days(c) == {"mean_gap": 0.0, "pairs": 0}
    assert stats.events_per_patient_percentiles(c) == \
        {"p50": 0, "p90": 0, "p99": 0, "n": 0}


def test_empty_cohort_full_battery_nan_free():
    """The whole registered battery runs over an empty cohort without a
    single NaN/inf anywhere in the output."""
    from repro.core import stats

    c, p = _empty_cohort(), _empty_patients()
    out = stats.compute(c, p)
    assert out                                  # battery did run
    _assert_finite(out, "stats")
    report = stats.report(c, p)
    assert "nan" not in report.lower()


def test_nonempty_stats_keep_values():
    """The guards must not disturb populated cohorts."""
    from repro.core import stats

    n = 16
    ev = make_events(
        patient_id=jnp.asarray([1, 1, 2, 3], jnp.int32),
        category=Category.DRUG_DISPENSE,
        value=jnp.asarray([5, 6, 5, 7], jnp.int32),
        start=jnp.asarray([10, 40, 20, 30], jnp.int32),
        valid=jnp.ones((4,), bool),
    )
    c = Cohort.from_events("pop", ev, n)
    g = stats.mean_gap_days(c)
    assert g["pairs"] == 1 and g["mean_gap"] == 30.0   # patient 1: 10 -> 40
    pct = stats.events_per_patient_percentiles(c)
    assert pct["n"] == 3 and pct["p50"] == 1
