"""Import-or-stub hypothesis.

CI installs hypothesis and runs the property tests for real; bare containers
(no hypothesis) must still *collect* every test module and run the non-property
tests, so property tests degrade to clean per-test skips instead of killing
the module at import.  Usage in test modules::

    from _hyp import given, settings, st
"""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: decorator-time strategy
        expressions like ``st.integers(0, 9)`` must evaluate, but their
        values are never consumed (the stubbed ``given`` skips the test)."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # zero-arg stub: pytest must not try to resolve the property
            # arguments as fixtures
            def skipped():
                pytest.skip("hypothesis not installed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco
