"""Lazy query-plan layer (``repro.study``): IR, optimizer rewrites, executor
parity with the eager API, automatic provenance, and the satellite regressions
(dedupe dead-code path, Cohort.union window semantics)."""
import jax
import numpy as np
import pytest

from repro.core import (
    Category, Cohort, DCIR_SCHEMA, OperationLog, biology_acts, dedupe_by,
    drug_dispenses, exposures, flatten_star, medical_acts_dcir,
    practitioner_encounters,
)
from repro.core.columnar import ColumnarTable
from repro.core.events import make_events
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.study import (
    PlanBuilder, Study, execute, flow_rows_from_log, fuse_masks,
    merge_projections, optimize,
)

CFG = SyntheticConfig(n_patients=300, seed=13)


@pytest.fixture(scope="module")
def dcir():
    return generate_dcir(CFG)


@pytest.fixture(scope="module")
def flat(dcir):
    return flatten_star(DCIR_SCHEMA, dcir)[0]


def _study(extractors):
    s = Study(n_patients=CFG.n_patients)
    for name, ex in extractors:
        s.extract(ex, name=name)
    return s


FOUR = [("drugs", drug_dispenses()), ("acts", medical_acts_dcir()),
        ("bio", biology_acts()), ("enc", practitioner_encounters())]


# ---------------------------------------------------------------------------
# optimizer structure (the tentpole acceptance: shared scan, one compaction
# per output, fused masks)
# ---------------------------------------------------------------------------
def test_shared_scan_single_projection():
    opt = _study(FOUR).optimized_plan()
    ops = opt.count_ops()
    assert ops["scan"] == 1                     # one pass over DCIR
    assert ops["select"] == 1                   # union projection
    assert ops["compact"] == 4                  # exactly one per output
    assert "drop_nulls" not in ops and "value_filter" not in ops
    # union projection covers every extractor's column set
    sel = next(n for n in opt.nodes if n.op == "select")
    for _, ex in FOUR:
        assert set(ex.projection()) <= set(sel.get("cols"))


def test_mask_fusion_collapses_chains():
    # drug_dispenses(codes=...) is a private not-null -> isin predicate
    # chain: it must fuse into ONE node carrying both conjuncts.  bio/enc
    # share one null-mask node (two consumers), which must stay shared —
    # computed once — not be duplicated into both branches.
    exts = [("drugs", drug_dispenses(codes=list(range(20)))),
            ("bio", biology_acts()), ("enc", practitioner_encounters())]
    raw = _study(exts).plan()
    n_masks_raw = raw.count_ops().get("predicate", 0)
    opt = optimize(raw)
    assert n_masks_raw == 5      # drugs: 2; bio/enc: shared null + 2 filters
    assert opt.count_ops()["fused_mask"] == 4
    assert not any(n.op in ("predicate", "drop_nulls", "value_filter")
                   for n in opt.nodes)
    both = [n for n in opt.nodes if n.op == "fused_mask"
            and len(n.get("exprs")) == 2]
    assert len(both) == 1        # the fused drugs chain (not-null & isin)
    tags = sorted(e[0] for e in both[0].get("exprs"))
    assert tags == ["isin", "notnull"]
    shared = [i for i, n in enumerate(opt.nodes) if n.op == "fused_mask"
              and len(opt.consumers()[i]) == 2]
    assert len(shared) == 1      # bio/enc's common null mask


def test_compaction_deferred_to_outputs():
    b = PlanBuilder()
    t = b.select(b.scan("DCIR"), ["patient_id", "cip13", "execution_date"])
    t = b.compact(t)                            # interior compact: bypassed
    t = b.drop_nulls(t, ["cip13"])
    c = b.conform_events(t, name="x", category=1, value_col="cip13",
                         start_col="execution_date")
    b.set_output("x", c)
    opt = optimize(b.build())
    assert opt.count_ops()["compact"] == 1
    out_node = opt.nodes[opt.output_ids["x"]]
    assert out_node.op == "compact"


def test_hash_consing_shares_identical_subplans():
    b = PlanBuilder()
    a = drug_dispenses().contribute(b)
    c = drug_dispenses().contribute(b)
    assert a == c                               # identical extractor: one branch


# ---------------------------------------------------------------------------
# executor parity with the eager API
# ---------------------------------------------------------------------------
def test_study_matches_eager_per_extractor(flat):
    res = _study(FOUR).run({"DCIR": flat})
    for name, ex in FOUR:
        eager = ex(flat).to_numpy()
        lazy = res.events[name].to_numpy()
        for k in eager:
            assert (eager[k] == lazy[k]).all(), (name, k)


def test_study_unoptimized_matches_optimized(flat):
    a = _study(FOUR).run({"DCIR": flat}, optimize=False)
    b = _study(FOUR).run({"DCIR": flat}, optimize=True)
    for name in a.events:
        x, y = a.events[name].to_numpy(), b.events[name].to_numpy()
        for k in x:
            assert (x[k] == y[k]).all(), (name, k)


def test_transform_node_matches_free_function(flat):
    res = (Study(n_patients=CFG.n_patients)
           .extract(drug_dispenses(), name="drugs")
           .transform("exposures", "drugs", name="expo", purview_days=60)
           .run({"DCIR": flat}))
    drugs = drug_dispenses()(flat)
    eager = exposures(drugs, CFG.n_patients, purview_days=60).to_numpy()
    lazy = res.events["expo"].to_numpy()
    for k in eager:
        assert (eager[k] == lazy[k]).all(), k


def test_cohort_algebra_and_flow(flat, dcir):
    res = (Study(n_patients=CFG.n_patients)
           .extract(drug_dispenses(), name="drugs")
           .extract(medical_acts_dcir(), name="acts")
           .patients("IR_BEN")
           .cohort("base", "extract_patients")
           .cohort("drugged", "drugs")
           .cohort("final", "drugged & base - acts")
           .flow("base", "drugged", "final")
           .run({"DCIR": flat, "IR_BEN": dcir["IR_BEN"]}))
    drugs = drug_dispenses()(flat)
    acts = medical_acts_dcir()(flat)
    dr = Cohort.from_events("drugs", drugs, CFG.n_patients)
    ac = Cohort.from_events("acts", acts, CFG.n_patients)
    from repro.core import patients

    base = Cohort.from_patient_table("base", patients(dcir["IR_BEN"]),
                                     CFG.n_patients)
    want = dr.intersection(base).difference(ac)
    assert res.cohorts["final"].subject_count() == want.subject_count()
    assert (np.asarray(res.cohorts["final"].subjects)
            == np.asarray(want.subjects)).all()
    stages = [r["subjects"] for r in res.flow.flowchart()]
    assert stages[0] >= stages[1] >= stages[2]


def test_cohort_aliases_both_realized(flat):
    # two cohort declarations hash-consing to the same plan node must BOTH
    # appear in the result, each under its own name
    res = (Study(n_patients=CFG.n_patients)
           .extract(drug_dispenses(), name="drugs")
           .cohort("a", "drugs")
           .cohort("b", "drugs")
           .run({"DCIR": flat}))
    assert set(res.cohorts) == {"a", "b"}
    assert res.cohorts["a"].name == "a" and res.cohorts["b"].name == "b"
    assert (res.cohorts["a"].subject_count()
            == res.cohorts["b"].subject_count())


def test_jit_cache_reused_across_identical_studies(flat):
    from repro.study import clear_jit_cache, jit_cache_info

    clear_jit_cache()
    _study(FOUR).run({"DCIR": flat})
    assert jit_cache_info()["plans"] == 1
    _study(FOUR).run({"DCIR": flat})            # same structure: cache hit
    assert jit_cache_info()["plans"] == 1
    _study(FOUR[:2]).run({"DCIR": flat})        # new structure: new entry
    assert jit_cache_info()["plans"] == 2


# ---------------------------------------------------------------------------
# automatic provenance
# ---------------------------------------------------------------------------
def test_provenance_automatic_and_flow_reconstructs(flat, dcir):
    res = (Study(n_patients=CFG.n_patients)
           .extract(drug_dispenses(), name="drugs")
           .patients("IR_BEN")
           .cohort("base", "extract_patients")
           .cohort("drugged", "drugs")
           .cohort("final", "drugged & base")
           .flow("base", "drugged", "final")
           .run({"DCIR": flat, "IR_BEN": dcir["IR_BEN"]}))
    # no manual log.record call anywhere above; every plan node is logged
    assert len(res.log.entries) >= len([n for n in res.plan.nodes
                                        if n.op not in ("featurize", "flow")])
    removed = [e for e in res.log.entries if e["op"].startswith("plan:fused_mask")]
    assert removed and all(e["in"] >= e["out"]
                           for e in OperationLog.from_json(
                               res.log.to_json()).flowchart()
                           if e["stage"].startswith("plan:fused_mask"))
    # flowchart reconstructs from the log alone (paper §3.4 promise)
    got = flow_rows_from_log(res.log)
    want = [{k: r[k] for k in ("stage", "subjects", "removed")}
            for r in res.flow.flowchart()]
    assert got == want


def test_eager_wrapper_still_logs_single_record(flat):
    log = OperationLog()
    drug_dispenses()(flat, log)
    assert len(log.entries) == 1
    assert log.entries[0]["op"] == "extract:drug_purchases[cip13]"


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------
def test_dedupe_with_invalid_rows_between_equal_key_runs():
    # rows 1 and 3 are invalid and carry keys that would split/extend runs if
    # dedupe consulted them; sort_by sinks them, dedupe must ignore them.
    t = ColumnarTable.from_columns(
        {"k": np.asarray([2, 2, 2, 7, 7, 7], np.int32),
         "v": np.asarray([0, 1, 2, 3, 4, 5], np.int32)},
        valid=np.asarray([True, False, True, False, True, True]),
    )
    d = dedupe_by(t, ["k"]).compact()
    o = d.to_numpy()
    assert sorted(o["k"].tolist()) == [2, 7]
    assert int(d.count) == 2
    # first row of each *valid* run wins
    assert set(o["v"].tolist()) == {0, 4}


def test_union_window_spans_both():
    bits = np.zeros(2, np.uint32)
    a = Cohort("a", "a", jax.numpy.asarray(bits), 64, window=(100, 200))
    b = Cohort("b", "b", jax.numpy.asarray(bits), 64, window=(150, 400))
    assert a.union(b).window == (100, 400)          # spans both
    assert a.intersection(b).window == (150, 200)   # overlap only
    assert a.difference(b).window == (100, 200)     # self's coverage


def test_union_keeps_subjects_superset(flat):
    drugs = drug_dispenses()(flat)
    acts = medical_acts_dcir()(flat)
    a = Cohort.from_events("drugs", drugs, CFG.n_patients)
    b = Cohort.from_events("acts", acts, CFG.n_patients)
    u = a.union(b)
    assert u.subject_count() >= max(a.subject_count(), b.subject_count())


# ---------------------------------------------------------------------------
# sharded plan execution (1-device mesh; multi-device covered by
# tests/test_distributed.py-style subprocess runs on capable jax versions)
# ---------------------------------------------------------------------------
def test_sharded_execution_matches_local(flat, dcir):
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    build = lambda: (Study(n_patients=CFG.n_patients)
                     .extract(drug_dispenses(), name="drugs")
                     .extract(medical_acts_dcir(), name="acts")
                     .cohort("drugged", "drugs"))
    local = build().run({"DCIR": flat})
    sharded = build().run({"DCIR": flat}, mesh=mesh)
    for name in local.events:
        x, y = local.events[name].to_numpy(), sharded.events[name].to_numpy()
        for k in x:
            assert (x[k] == y[k]).all(), (name, k)
    assert (local.cohorts["drugged"].subject_count()
            == sharded.cohorts["drugged"].subject_count())
