"""Multi-tenant cohort-query service: plan normalization, shared-executable
compilation, the cross-tenant subgraph cache, admission policy — and the
acceptance bar: every served query is bit-identical to a solo ``Study.run``.

Deterministic (no hypothesis): fixed synthetic DCIR, fixed study shapes.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import DCIR_SCHEMA, drug_dispenses, medical_acts_dcir
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.serving.batching import SlotScheduler
from repro.study import (
    CohortQueryService, ServiceConfig, Study, clear_jit_cache, col,
    device_params, jit_cache_info, normalize,
)
from repro.study import executor as _executor

CFG = SyntheticConfig(n_patients=300, seed=13)
CODES_A = list(range(100, 140))
CODES_B = list(range(60, 100))


@pytest.fixture(scope="module")
def dcir():
    return generate_dcir(CFG)


def _study(threshold, codes):
    """The shared study shape: flatten -> whitelist extract -> threshold
    filter -> cohort algebra.  ``threshold``/``codes`` are the literals
    normalization hoists out of the compiled program."""
    s = Study(n_patients=CFG.n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(drug_dispenses(codes=codes), name="drugs")
    s.extract(medical_acts_dcir(), name="acts")
    s.filter("acts", col("value") >= threshold, name="acts_hi")
    s.cohort("base", "drugs")
    s.cohort("final", "base & acts_hi")
    return s


def _other_shape(codes):
    s = Study(n_patients=CFG.n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(drug_dispenses(codes=codes), name="drugs")
    s.cohort("exposed", "drugs")
    return s


def _assert_same_result(a, b):
    assert set(a.events) == set(b.events)
    for k in a.events:
        ta, tb = a.events[k], b.events[k]
        assert int(ta.count) == int(tb.count), k
        assert np.array_equal(np.asarray(ta.valid), np.asarray(tb.valid)), k
        for c in ta.columns:
            assert np.array_equal(np.asarray(ta.columns[c]),
                                  np.asarray(tb.columns[c])), (k, c)
    assert set(a.cohorts) == set(b.cohorts)
    for k in a.cohorts:
        assert np.array_equal(np.asarray(a.cohorts[k].subjects),
                              np.asarray(b.cohorts[k].subjects)), k
    assert a.flatten_stats == b.flatten_stats


# ---------------------------------------------------------------------------
# normalization: equal structure, different literals -> one canonical plan
# ---------------------------------------------------------------------------
def test_normalize_equal_structure_shares_plan(dcir):
    pa = _study(100, CODES_A).optimized_plan(tables=dict(dcir))
    pb = _study(500, CODES_B).optimized_plan(tables=dict(dcir))
    na, nb = normalize(pa), normalize(pb)
    assert na.plan.key() == nb.plan.key()
    assert na.lits != nb.lits or na.vecs != nb.vecs
    # labels are alpha-renamed: tenant-chosen names never leak into the key
    assert all(not n.get("name") for n in na.plan.nodes)
    # a different shape does NOT collide
    nc = normalize(_other_shape(CODES_A).optimized_plan(tables=dict(dcir)))
    assert nc.plan.key() != na.plan.key()


def test_normalized_execution_parity_and_shared_compile(dcir):
    """Satellite regression: two equal-structure/different-literal plans
    compile ONE executor executable, and both runs stay bit-identical to
    their baked-literal executions."""
    env = dict(dcir)
    studies = [_study(100, CODES_A), _study(500, CODES_B)]
    solos = [s.run(env) for s in studies]

    clear_jit_cache()
    for s, solo in zip(studies, solos):
        plan = s.optimized_plan(tables=env)
        nplan = normalize(plan)
        vals = _executor.execute(nplan.plan, env,
                                 n_patients=CFG.n_patients,
                                 expr_params=device_params(nplan))
        canon_of = nplan.orig_to_canon()
        for name, oi in plan.output_ids.items():
            if name not in solo.events:
                continue
            got, want = vals[canon_of[oi]], solo.events[name]
            assert int(got.count) == int(want.count), name
            assert np.array_equal(np.asarray(got.valid),
                                  np.asarray(want.valid)), name
            for c in want.columns:
                assert np.array_equal(np.asarray(got.columns[c]),
                                      np.asarray(want.columns[c])), (name, c)
    info = jit_cache_info()
    assert info["compiles"] == 1, info    # literals are traced args
    assert info["hits"] == 1, info


# ---------------------------------------------------------------------------
# the service: parity, executable sharing, subgraph cache
# ---------------------------------------------------------------------------
def test_service_multi_tenant_parity(dcir):
    env = dict(dcir)
    svc = CohortQueryService(env, config=ServiceConfig())
    jobs = [("alice", _study(100, CODES_A)), ("bob", _study(500, CODES_B)),
            ("carol", _study(250, CODES_A)), ("alice", _other_shape(CODES_B))]
    tickets = [svc.submit(s, tenant=t) for t, s in jobs]
    svc.drain()
    for (tenant, study), ticket in zip(jobs, tickets):
        assert ticket.status == "done", ticket.error
        _assert_same_result(study.run(env), ticket.result)
    # 2 shapes -> 2 executables for 4 queries; shared prefixes hit
    assert svc.stats.compile_count == 2
    assert svc.stats.cache_hits > 0
    assert svc.stats.hit_rate() >= 0.5
    ops = [e["op"] for e in svc.log.entries]
    assert ops.count("service:compile") == 2
    assert sum(op.startswith("service:query:") for op in ops) == 4


def test_service_repeat_query_hits_everywhere(dcir):
    svc = CohortQueryService(dict(dcir))
    t1 = svc.submit(_study(100, CODES_A), tenant="a")
    svc.drain()
    t2 = svc.submit(_study(100, CODES_A), tenant="b")  # other tenant, same q
    svc.drain()
    assert t1.cache_misses > 0 and t1.cache_hits == 0
    assert t2.cache_misses == 0 and t2.cache_hits == t1.cache_misses
    assert not t2.compiled
    _assert_same_result(t1.result, t2.result)


def test_service_cache_eviction_under_budget(dcir):
    env = dict(dcir)
    # budget sized to hold only part of one query's cut set: inserts evict
    # older entries LRU-first, correctness must not depend on the cache
    svc = CohortQueryService(env, config=ServiceConfig(
        cache_budget_bytes=200_000))
    r1 = svc.query(_study(100, CODES_A), tenant="a")
    r2 = svc.query(_study(500, CODES_B), tenant="b")
    assert svc.stats.cache_evictions > 0
    assert svc.stats.cache_bytes <= 200_000
    assert svc.stats.cache_entries == len(svc._cache)
    _assert_same_result(_study(100, CODES_A).run(env), r1)
    _assert_same_result(_study(500, CODES_B).run(env), r2)


def test_service_table_version_invalidation(dcir):
    env_v2 = generate_dcir(SyntheticConfig(n_patients=CFG.n_patients, seed=99))
    svc = CohortQueryService(dict(dcir))
    svc.query(_study(100, CODES_A), tenant="a")
    assert svc.stats.cache_entries > 0
    svc.update_tables(env_v2)
    assert svc.stats.table_version == 1
    assert svc.stats.cache_entries == 0 and svc.stats.cache_bytes == 0
    # the same query against v2 tables must reflect v2 content, not v1 cache
    r = svc.query(_study(100, CODES_A), tenant="a")
    _assert_same_result(_study(100, CODES_A).run(dict(env_v2)), r)


# ---------------------------------------------------------------------------
# admission: priority order, per-tenant quotas, bounded queue
# ---------------------------------------------------------------------------
def test_slot_scheduler_priority_then_fifo():
    sched = SlotScheduler(2)
    sched.submit("low1", key="a", priority=0)
    sched.submit("hi", key="b", priority=5)
    sched.submit("low2", key="a", priority=0)
    assert [x for x, _ in sched.admit()] == ["hi", "low1"]
    sched.release("b")
    assert [x for x, _ in sched.admit()] == ["low2"]


def test_slot_scheduler_per_key_quota_keeps_fifo_within_key():
    sched = SlotScheduler(4, per_key_quota=1)
    for i in range(3):
        sched.submit(f"a{i}", key="a")
    sched.submit("b0", key="b")
    assert [x for x, _ in sched.admit()] == ["a0", "b0"]  # a1/a2 over quota
    assert sched.queued() == 2
    sched.release("a")
    assert [x for x, _ in sched.admit()] == ["a1"]        # FIFO within key
    sched.release("a")
    assert [x for x, _ in sched.admit()] == ["a2"]


def test_slot_scheduler_bounded_queue():
    sched = SlotScheduler(1, max_queue=2)
    assert sched.submit("x") and sched.submit("y")
    assert not sched.submit("z")
    assert sched.queued() == 2


def test_service_queue_rejection_and_stats(dcir):
    svc = CohortQueryService(dict(dcir), config=ServiceConfig(max_queue=1))
    s = _study(100, CODES_A)
    t1 = svc.submit(s, tenant="a")
    t2 = svc.submit(s, tenant="b")
    assert t1.status == "queued" and t2.status == "rejected"
    svc.drain()
    assert t1.status == "done" and t2.result is None
    assert svc.stats.tenant("b").rejected == 1
    assert svc.stats.tenant("a").completed == 1
    # queue drained: admission opens up again
    t3 = svc.submit(s, tenant="c")
    svc.drain()
    assert t3.status == "done"


def test_slot_scheduler_fifo_with_non_comparable_items():
    """Heap entries must never fall through to comparing the items
    themselves: dicts are not orderable, so equal-priority ties break on
    the sequence counter alone (FIFO within a priority band)."""
    sched = SlotScheduler(4)
    items = [{"q": i} for i in range(4)]          # dict: no __lt__
    for it in items:
        sched.submit(it, key="a", priority=3)     # all ties
    assert [x for x, _ in sched.admit()] == items


# ---------------------------------------------------------------------------
# async pipeline: overlap, slot release on realization, hit parity
# ---------------------------------------------------------------------------
def test_service_async_pipeline_multi_tenant_stress(dcir):
    """N tenants x mixed shapes through the pipelined service: every
    ticket resolves bit-identical to a solo ``Study.run``, and the stage
    accounting shows realization actually overlapped device submission."""
    env = dict(dcir)
    svc = CohortQueryService(env, config=ServiceConfig(pipeline=True,
                                                       n_slots=4))
    jobs = []
    for q in range(9):
        tenant = f"t{q % 3}"
        if q % 3 == 2:
            study = _other_shape(list(range(60 + q, 100 + q)))
        else:
            study = _study(40 + q, list(range(100 + q, 140 + q)))
        jobs.append((tenant, study))
    tickets = [svc.submit(s, tenant=t) for t, s in jobs]
    svc.drain()
    assert svc._sched.inflight() == 0, \
        "slots must release when realization finishes"
    assert not svc._pending and not svc._inflight_cuts
    for (tenant, study), ticket in zip(jobs, tickets):
        assert ticket.status == "done", (tenant, ticket.error)
        assert ticket.submit_s > 0 and ticket.realize_s > 0
        _assert_same_result(study.run(env), ticket.result)
    assert svc.stats.compile_count == 2           # 2 shapes, 9 queries
    snap = svc.stats.snapshot()
    assert snap["queries"] == 9
    assert snap["wall_s"] > 0
    assert snap["overlap_s"] > 0, \
        "pipelined drain must overlap realize with the next submit"


def test_service_pipelined_repeat_hits_within_one_drain(dcir):
    """A repeat query admitted while the first copy is still realizing must
    wait for its cache insert and then hit — pipelined hit/miss accounting
    matches the synchronous mode exactly."""
    svc = CohortQueryService(dict(dcir),
                             config=ServiceConfig(pipeline=True))
    t1 = svc.submit(_study(100, CODES_A), tenant="a")
    t2 = svc.submit(_study(100, CODES_A), tenant="b")
    svc.drain()
    assert t1.status == "done" and t2.status == "done"
    assert t1.cache_misses > 0 and t1.cache_hits == 0
    assert t2.cache_misses == 0 and t2.cache_hits == t1.cache_misses
    assert not t2.compiled
    _assert_same_result(t1.result, t2.result)


def test_service_sync_mode_parity_with_pipeline(dcir):
    env = dict(dcir)
    results = {}
    for pipeline in (False, True):
        svc = CohortQueryService(env, config=ServiceConfig(
            pipeline=pipeline))
        tickets = [svc.submit(_study(100, CODES_A), tenant="a"),
                   svc.submit(_study(500, CODES_B), tenant="b")]
        svc.drain()
        assert all(t.status == "done" for t in tickets)
        results[pipeline] = [t.result for t in tickets]
        assert svc.stats.cache_misses > 0
    for a, b in zip(results[False], results[True]):
        _assert_same_result(a, b)


# ---------------------------------------------------------------------------
# sharded path: normalization sharing + subgraph cache under shard_map
# ---------------------------------------------------------------------------
def test_service_sharded_normalized_cache_parity(dcir):
    import jax
    from jax.sharding import Mesh

    env = dict(dcir)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    svc = CohortQueryService(env, mesh=mesh, config=ServiceConfig())
    jobs = [("a", _study(100, CODES_A)), ("b", _study(500, CODES_B)),
            ("c", _study(100, CODES_A)),          # repeat -> full hit
            ("a", _other_shape(CODES_B))]
    tickets = [svc.submit(s, tenant=t) for t, s in jobs]
    svc.drain()
    for (tenant, study), ticket in zip(jobs, tickets):
        assert ticket.status == "done", (tenant, ticket.error)
        _assert_same_result(study.run(env), ticket.result)
    # sharded path compiles once per normalized shape, like the local path
    assert svc.stats.compile_count == 2
    assert svc.stats.cache_hits > 0
    assert tickets[2].cache_misses == 0 \
        and tickets[2].cache_hits == tickets[0].cache_misses
    assert svc.stats.demotions == 0
