"""GPipe pipeline parallelism: pipelined == sequential (fwd + grads)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

# Seed-debt triage (see tests/test_models.py for the full note): the
# subprocess imports the mesh helpers which need jax.sharding.AxisType,
# absent from the container's jax.  Reactivates on a newer jax.
jax_version_xfail = pytest.mark.xfail(
    not hasattr(jax.sharding, "AxisType"), strict=False,
    reason="seed debt: installed jax lacks jax.sharding.AxisType/"
           "get_abstract_mesh required by the mesh stack")


def run_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@jax_version_xfail
def test_gpipe_matches_sequential():
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_transformer

        P_STAGES, LPS, M, MB, D = 4, 2, 8, 4, 16
        mesh = jax.make_mesh((P_STAGES,), ("pipe",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        key = jax.random.key(0)
        Ws = jax.random.normal(key, (P_STAGES, LPS, D, D), jnp.float32) * 0.1

        def layer(W, x):
            return jnp.tanh(x @ W)

        mbs = jax.random.normal(jax.random.key(1), (M, MB, D), jnp.float32)

        # sequential reference
        ref = mbs
        for s in range(P_STAGES):
            for l in range(LPS):
                ref = jax.vmap(lambda x: layer(Ws[s, l], x))(ref)

        piped = pipeline_transformer(layer, mesh, P_STAGES)(Ws, mbs)
        err = float(jnp.abs(piped - ref).max())

        # grads through the pipeline
        def loss_piped(Ws):
            return pipeline_transformer(layer, mesh, P_STAGES)(Ws, mbs).sum()
        def loss_ref(Ws):
            y = mbs
            for s in range(P_STAGES):
                for l in range(LPS):
                    y = jnp.tanh(y @ Ws[s, l])
            return y.sum()
        g1 = jax.grad(loss_piped)(Ws)
        g2 = jax.grad(loss_ref)(Ws)
        gerr = float(jnp.abs(g1 - g2).max())
        print(json.dumps({"fwd_err": err, "grad_err": gerr}))
    """)
    r = run_subprocess(code)
    assert r["fwd_err"] < 1e-5, r
    assert r["grad_err"] < 1e-4, r
