"""Bitset-native validity: parity, layout and no-unpack guarantees.

The table/cohort data model carries row validity as a packed uint32 bitset
(``core.bitset`` layout) end-to-end.  This module pins the redesign:

  * ``from_columns`` accepts bool-valid and bitset-valid forms, validates
    their length, and both produce bit-identical tables (property test +
    deterministic battery over every columnar op);
  * every *plan* op (mask, compact, join, slice_time, flow, stats battery)
    is bit-identical under bool-valid vs bitset-valid input tables, locally
    and under ``compat_shard_map``;
  * the optimizer's ``eliminate_joins`` degrades a pruned-to-key lookup_join
    to an audit-only ``key_count`` without changing results;
  * executor-level no-unpack assertion: on the Pallas engines the
    predicate -> cohort -> compaction path never expands validity back to a
    bool column (``bitset.unpack`` is instrumented and must not fire);
  * the ">25 statistics" battery expands each cohort/table bitset ONCE per
    ``stats.compute`` (memoized unpack).
"""
from _hyp import given, settings, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.bitset as bitset
from repro.core.bitset import pack, unpack_np
from repro.core.cohort import Bitset, Cohort
from repro.core.columnar import ColumnarTable, NULL_INT
from repro.core import stats
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.study import Study, col, execute
from repro.study.optimizer import eliminate_joins, optimize, prune_columns
from repro.study.plan import PlanBuilder


def _mk(vals, valid=None, extra=None):
    cols = {"a": np.asarray(vals, np.int32),
            "b": np.asarray(vals, np.int32) * 3}
    if extra:
        cols.update(extra)
    return ColumnarTable.from_columns(
        cols, valid=None if valid is None else valid)


def _same(t1: ColumnarTable, t2: ColumnarTable):
    assert t1.capacity == t2.capacity
    assert int(t1.count) == int(t2.count)
    assert np.array_equal(np.asarray(t1.valid), np.asarray(t2.valid))
    assert t1.column_names == t2.column_names
    a, b = t1.to_numpy(), t2.to_numpy()
    for k in a:
        assert np.array_equal(a[k], b[k]), k


# ---------------------------------------------------------------------------
# from_columns compatibility surface + validation (satellite: length checks)
# ---------------------------------------------------------------------------
def test_from_columns_accepts_bool_and_bitset():
    mask = np.asarray([True, False, True, True, False], bool)
    t_bool = _mk(range(5), valid=mask)
    t_bits = _mk(range(5), valid=pack(jnp.asarray(mask)))
    assert t_bool.valid.dtype == jnp.uint32 and t_bits.valid.dtype == jnp.uint32
    _same(t_bool, t_bits)


def test_from_columns_validates_bool_mask_length():
    with pytest.raises(ValueError, match="valid mask length"):
        _mk(range(5), valid=np.ones(4, bool))


def test_from_columns_validates_packed_word_length():
    # 5 rows need 1 word; handing 2 words must fail loudly, not corrupt count
    with pytest.raises(ValueError, match="packed valid"):
        _mk(range(5), valid=jnp.ones((2,), jnp.uint32))


def test_from_columns_clears_packed_tail_bits():
    # caller-supplied words with garbage past the capacity: count stays exact
    words = jnp.asarray([0xFFFFFFFF], jnp.uint32)
    t = _mk(range(5), valid=words)
    assert int(t.count) == 5
    assert int(np.asarray(t.valid)[0]) == 0b11111


def test_valid_bool_roundtrip():
    mask = np.asarray([True, False] * 17, bool)          # ragged (34 rows)
    t = _mk(range(34), valid=mask)
    assert np.array_equal(np.asarray(t.valid_bool()), mask)
    assert np.array_equal(t.valid_numpy(), mask)


# ---------------------------------------------------------------------------
# columnar-op parity: bool-valid vs bitset-valid tables
# ---------------------------------------------------------------------------
def _op_battery(t: ColumnarTable, mask2: np.ndarray):
    yield t.filter(jnp.asarray(mask2))
    yield t.filter(pack(jnp.asarray(mask2)))             # packed filter mask
    yield t.drop_nulls(["a"])
    yield t.compact()
    yield t.sort_by(["a"])
    yield t.take(jnp.arange(t.capacity)[::-1])
    yield t.pad_to(t.capacity + 7)
    yield t.shrink_to(max(t.capacity - 3, 1))
    yield ColumnarTable.concat([t, t])
    yield t.select(["a"])


def _run_battery(vals, mask, mask2):
    vals = np.asarray(vals, np.int32)
    mask = np.asarray(mask, bool)
    t_bool = _mk(vals, valid=mask)
    t_bits = _mk(vals, valid=pack(jnp.asarray(mask)))
    for o1, o2 in zip(_op_battery(t_bool, mask2), _op_battery(t_bits, mask2)):
        _same(o1, o2)
    m1 = t_bool.monitoring_stats("a")
    m2 = t_bits.monitoring_stats("a")
    for k in m1:
        assert int(m1[k]) == int(m2[k]), k


def test_op_battery_deterministic():
    rng = np.random.RandomState(7)
    for n in (1, 5, 31, 32, 33, 64, 100):
        vals = rng.randint(-50, 50, size=n)
        vals[rng.rand(n) < 0.2] = int(NULL_INT)
        _run_battery(vals, rng.rand(n) < 0.6, rng.rand(n) < 0.5)


@settings(max_examples=40, deadline=None)
@given(vals=st.lists(st.integers(-100, 100), min_size=1, max_size=80),
       data=st.data())
def test_op_battery_property(vals, data):
    n = len(vals)
    mask = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    mask2 = data.draw(st.lists(st.booleans(), min_size=n, max_size=n))
    _run_battery(vals, mask, mask2)


# ---------------------------------------------------------------------------
# plan-op parity: a full study (mask, compact, join, slice_time, flow,
# stats battery) under bool-valid vs bitset-valid env tables, local + sharded
# ---------------------------------------------------------------------------
CFG = SyntheticConfig(n_patients=120, seed=11)


@pytest.fixture(scope="module")
def dcir():
    return generate_dcir(CFG)


def _retype_valid(tables, form: str):
    out = {}
    for k, t in tables.items():
        v = t.valid_bool() if form == "bool" else t.valid
        out[k] = ColumnarTable.from_columns(dict(t.columns), valid=v)
    return out


def _study():
    from repro.core import DCIR_SCHEMA, drug_dispenses, medical_acts_dcir

    return (Study(n_patients=CFG.n_patients)
            .flatten(DCIR_SCHEMA, time_slices=2,
                     time_column="execution_date", t0=14_000, t1=16_000)
            .extract(drug_dispenses(), name="drugs")
            .extract(medical_acts_dcir()
                     .filtered(col("execution_date") >= 14_000), name="acts")
            .patients("IR_BEN")
            .cohort("base", "extract_patients")
            .cohort("drugged", "drugs")
            .cohort("final", "drugged & base - acts")
            .flow("base", "drugged", "final"))


def _assert_results_equal(r1, r2):
    assert set(r1.events) == set(r2.events)
    for k in r1.events:
        a, b = r1.events[k].to_numpy(), r2.events[k].to_numpy()
        for c in a:
            assert np.array_equal(a[c], b[c]), (k, c)
    for k in r1.cohorts:
        assert np.array_equal(np.asarray(r1.cohorts[k].subjects),
                              np.asarray(r2.cohorts[k].subjects)), k
    assert [row["subjects"] for row in r1.flow.flowchart()] == \
           [row["subjects"] for row in r2.flow.flowchart()]


@pytest.mark.parametrize("mesh_mode", ["local", "shard_map"])
def test_plan_parity_bool_vs_bitset_valid(dcir, mesh_mode):
    mesh = None
    if mesh_mode == "shard_map":
        from jax.sharding import Mesh

        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    r_bool = _study().run(_retype_valid(dict(dcir), "bool"), mesh=mesh)
    r_bits = _study().run(_retype_valid(dict(dcir), "bits"), mesh=mesh)
    r_bool.assert_no_loss()
    _assert_results_equal(r_bool, r_bits)
    # the stats battery on top must agree too (memoized masks included)
    pats = r_bool.events["extract_patients"]
    s1 = stats.compute(r_bool.cohorts["final"], pats)
    s2 = stats.compute(r_bits.cohorts["final"],
                       r_bits.events["extract_patients"])
    assert s1 == s2


# ---------------------------------------------------------------------------
# eliminate_joins: pruned N:1 join -> audit-only key_count, same results
# ---------------------------------------------------------------------------
def test_eliminate_joins_key_count_audit():
    left = ColumnarTable.from_columns({
        "flow_id": np.asarray([1, 2, 3, 4, int(NULL_INT)], np.int32),
        "patient_id": np.asarray([0, 1, 2, 3, 4], np.int32),
        "val": np.asarray([10, 20, 30, 40, 50], np.int32),
        "execution_date": np.asarray([5, 6, 7, 8, 9], np.int32),
    })
    right = ColumnarTable.from_columns({
        "flow_id": np.asarray([2, 4, 9], np.int32),
        "extra": np.asarray([7, 8, 9], np.int32),
    })

    def build():
        b = PlanBuilder()
        l = b.scan_star("L", columns=("flow_id", "patient_id", "val",
                                      "execution_date"))
        r = b.scan_star("R", columns=("flow_id", "extra"))
        j = b.lookup_join(l, r, "flow_id", "flow_id")
        p = b.predicate(j, col("val") >= 20)
        e = b.conform_events(p, name="ev", category=2, value_col="val",
                             start_col="execution_date")
        b.set_output("ev", b.compact(e))
        return b.build()

    raw = build()
    opt = optimize(raw)
    ops = opt.count_ops()
    assert ops.get("lookup_join", 0) == 0 and ops.get("key_count", 0) == 1

    env = {"L": left, "R": right}
    sink = {}
    v_raw = execute(raw, env, jit=False)
    v_opt = execute(opt, env, stats_sink=sink)
    a = v_raw[raw.output_ids["ev"]].to_numpy()
    b_ = v_opt[opt.output_ids["ev"]].to_numpy()
    for k in a:
        assert np.array_equal(a[k], b_[k]), k
    (kc_stats,) = [d for i, d in sink.items()
                   if opt.nodes[i].op == "key_count"]
    # membership audit: keys 2 and 4 hit; the NULL left key is counted
    assert kc_stats["matched"] == 2
    assert kc_stats["null_keys"] == 1
    assert kc_stats["rows_in"] == kc_stats["rows_out"] == 5
    assert kc_stats["overflow"] == 0


def test_key_count_empty_right_table():
    # lookup_join guards cap_r == 0; its key_count remnant must too
    left = ColumnarTable.from_columns({
        "flow_id": np.asarray([1, 2], np.int32),
        "patient_id": np.asarray([0, 1], np.int32),
        "val": np.asarray([10, 20], np.int32),
        "d": np.asarray([5, 6], np.int32),
    })
    right = ColumnarTable.empty({"flow_id": np.int32, "extra": np.int32}, 0)
    b = PlanBuilder()
    l = b.scan_star("L", columns=("flow_id", "patient_id", "val", "d"))
    r = b.scan_star("R", columns=("flow_id", "extra"))
    j = b.lookup_join(l, r, "flow_id", "flow_id")
    p = b.predicate(j, col("val") >= 0)
    # conform is the schema boundary that un-pins the output's full schema,
    # letting required_columns prove the right side contributes nothing
    e = b.conform_events(p, name="ev", category=1, value_col="val",
                         start_col="d")
    b.set_output("out", b.compact(e))
    opt = optimize(b.build())
    assert opt.count_ops().get("key_count", 0) == 1
    sink = {}
    vals = execute(opt, {"L": left, "R": right}, stats_sink=sink)
    assert int(vals[opt.output_ids["out"]].count) == 2
    (kc,) = [d for i, d in sink.items() if opt.nodes[i].op == "key_count"]
    assert kc["matched"] == 0 and kc["rows_out"] == 2


def test_eliminate_joins_keeps_needed_joins():
    # if a consumer reads a right-side column the join must survive
    b = PlanBuilder()
    l = b.scan_star("L", columns=("flow_id", "val"))
    r = b.scan_star("R", columns=("flow_id", "extra"))
    j = b.lookup_join(l, r, "flow_id", "flow_id")
    p = b.predicate(j, col("extra") >= 0)
    b.set_output("out", b.compact(p))
    opt = eliminate_joins(prune_columns(b.build()))
    assert opt.count_ops().get("lookup_join", 0) == 1


# ---------------------------------------------------------------------------
# executor-level no-unpack assertion on the pallas predicate->cohort->compact
# path (the acceptance criterion of the bitset-native redesign)
# ---------------------------------------------------------------------------
class _UnpackCounter:
    def __init__(self, monkeypatch):
        self.calls = 0
        orig = bitset.unpack

        def counting(words, n_bits):
            self.calls += 1
            return orig(words, n_bits)

        monkeypatch.setattr(bitset, "unpack", counting)


def _hot_path_plan():
    b = PlanBuilder()
    t = b.scan("EV")
    m = b.predicate(t, (col("value") >= 3) & col("value").not_null())
    c1 = b.cohort_from_events(m, name="hi")
    m2 = b.predicate(t, col("start") < 50)
    c2 = b.cohort_from_events(m2, name="early")
    both = b.cohort_op("&", c1, c2, name="both")
    b.set_output("both", both)
    b.set_output("hi_events", b.compact(m))
    return b.build()


def test_pallas_path_never_unpacks(monkeypatch):
    rng = np.random.RandomState(3)
    ev = ColumnarTable.from_columns({
        "patient_id": rng.randint(0, 40, 200).astype(np.int32),
        "value": rng.randint(0, 9, 200).astype(np.int32),
        "start": rng.randint(0, 100, 200).astype(np.int32),
    }, valid=rng.rand(200) < 0.8)
    plan = _hot_path_plan()
    ctr = _UnpackCounter(monkeypatch)
    vals = execute(plan, {"EV": ev}, n_patients=40, engine="pallas",
                   predicate_engine="pallas", jit=False)
    assert ctr.calls == 0, (
        f"pallas predicate->cohort->compaction path expanded validity to a "
        f"bool column {ctr.calls} time(s)")
    # layout check: every exported table carries packed uint32 validity
    out = vals[plan.output_ids["hi_events"]]
    assert out.valid.dtype == jnp.uint32
    assert out.valid.shape[0] == -(-out.capacity // 32)
    # sanity: the instrumentation does fire on the jnp fallback path
    ctr2 = _UnpackCounter(monkeypatch)
    execute(plan, {"EV": ev}, n_patients=40, engine="xla",
            predicate_engine="jnp", jit=False)
    assert ctr2.calls > 0


def test_pallas_and_jnp_engines_bit_identical(dcir):
    r_j = _study().run(dict(dcir), predicate_engine="jnp")
    r_p = _study().run(dict(dcir), predicate_engine="pallas")
    _assert_results_equal(r_j, r_p)


# ---------------------------------------------------------------------------
# stats: one bitset expansion per compute() battery (memoization satellite)
# ---------------------------------------------------------------------------
_PATIENT_STATS = ["gender_distribution", "mortality", "age_buckets",
                  "age_mean", "mortality_rate", "gender_ratio"]


def test_stats_unpack_memoized(monkeypatch):
    rng = np.random.RandomState(5)
    n = 64
    patients = ColumnarTable.from_columns({
        "patient_id": np.arange(n, dtype=np.int32),
        "gender": rng.randint(1, 3, n).astype(np.int32),
        "birth_date": rng.randint(0, 10_000, n).astype(np.int32),
        "death_date": np.full(n, int(NULL_INT), np.int32),
    })
    cohort = Cohort(name="c", description="c",
                    subjects=pack(jnp.asarray(rng.rand(n) < 0.5)),
                    n_patients=n)
    ctr = _UnpackCounter(monkeypatch)
    out = stats.compute(cohort, patients, names=list(_PATIENT_STATS))
    assert set(out) == set(_PATIENT_STATS)
    # exactly two expansions: the subject bitset + the patients validity;
    # all six statistics share them through the memoized masks
    assert ctr.calls == 2, ctr.calls
    stats.compute(cohort, patients, names=list(_PATIENT_STATS))
    assert ctr.calls == 2  # second battery: fully cached


def test_subjects_mask_memoized():
    n = 50
    c = Cohort(name="c", description="c",
               subjects=pack(jnp.ones((n,), bool)), n_patients=n)
    m1 = c.subjects_mask()
    assert c.subjects_mask() is m1


# ---------------------------------------------------------------------------
# sort/dedupe stay word-wise (satellite of the cohort-service PR): sorting
# gathers bits straight from the packed words and re-emits first_n words;
# dedupe's row validity is an iota compare on the sorted table
# ---------------------------------------------------------------------------
def test_sort_and_dedupe_never_unpack(monkeypatch):
    from repro.core.extraction import dedupe_by

    rng = np.random.RandomState(5)
    t = _mk(rng.randint(0, 7, 97), valid=rng.rand(97) < 0.7,
            extra={"k": rng.randint(0, 5, 97).astype(np.int32)})
    ctr = _UnpackCounter(monkeypatch)
    s = t.sort_by(["k", "a"])
    d = dedupe_by(t, ["k", "a"])
    jax.block_until_ready((s.valid, d.valid))
    assert ctr.calls == 0, (
        f"sort/dedupe expanded packed validity {ctr.calls} time(s)")
    # layout: packed words out; the sort's valid rows are exactly the first
    # `count` (dedupe keeps a masked table — run heads — by design)
    assert s.valid.dtype == jnp.uint32 and d.valid.dtype == jnp.uint32
    assert np.array_equal(np.asarray(s.valid),
                          np.asarray(bitset.first_n(s.count, s.capacity)))
    # semantics vs a plain numpy reference
    mask = unpack_np(np.asarray(t.valid), t.capacity)
    ks, as_ = np.asarray(t.columns["k"])[mask], np.asarray(t.columns["a"])[mask]
    order = np.lexsort((as_, ks))
    assert np.array_equal(np.asarray(s.columns["k"])[:int(s.count)], ks[order])
    assert np.array_equal(np.asarray(s.columns["a"])[:int(s.count)], as_[order])
    dmask = unpack_np(np.asarray(d.valid), d.capacity)
    got = set(zip(np.asarray(d.columns["k"])[dmask].tolist(),
                  np.asarray(d.columns["a"])[dmask].tolist()))
    assert got == set(zip(ks.tolist(), as_.tolist()))
    assert int(d.count) == len(got)


def test_join_fills_never_unpack(monkeypatch):
    # joins stay word-wise too (satellite of the static-analysis PR): the
    # key fills and the found-mask gather read bits via ``bitset.bit_at``,
    # never expanding validity to a bool column
    from repro.core.flattening import expand_join, lookup_join

    rng = np.random.RandomState(11)
    left = ColumnarTable.from_columns({
        "pid": jnp.asarray(rng.randint(0, 20, 97).astype(np.int32)),
        "v": jnp.asarray(rng.randint(0, 9, 97).astype(np.int32)),
    }, valid=jnp.asarray(rng.rand(97) < 0.8))
    right = ColumnarTable.from_columns({
        "pid": jnp.asarray(np.arange(20, dtype=np.int32)),
        "w": jnp.asarray(rng.randint(0, 5, 20).astype(np.int32)),
    }, valid=jnp.asarray(rng.rand(20) < 0.9))
    child = ColumnarTable.from_columns({
        "pid": jnp.asarray(rng.randint(0, 20, 64).astype(np.int32)),
        "x": jnp.asarray(rng.randint(0, 5, 64).astype(np.int32)),
    }, valid=jnp.asarray(rng.rand(64) < 0.9))
    ctr = _UnpackCounter(monkeypatch)
    j, _ = lookup_join(left, right, "pid", "pid", prefix="r_")
    e, _ = expand_join(left, child, "pid", "pid", 512, prefix="c_")
    jax.block_until_ready((j.valid, e.valid))
    assert ctr.calls == 0, (
        f"join key fills expanded packed validity {ctr.calls} time(s)")
    # layout: packed uint32 words out of both join flavours
    assert j.valid.dtype == jnp.uint32 and e.valid.dtype == jnp.uint32
    assert j.valid.shape[0] == -(-j.capacity // 32)
    assert e.valid.shape[0] == -(-e.capacity // 32)
    # semantics vs a numpy reference: every valid left row survives the
    # lookup join, and its right attribute is the match or the null sentinel
    lmask = unpack_np(np.asarray(left.valid), left.capacity)
    rmask = unpack_np(np.asarray(right.valid), right.capacity)
    jmask = unpack_np(np.asarray(j.valid), j.capacity)
    assert np.array_equal(jmask, lmask)
    rmap = {int(k): int(w) for k, w, ok in zip(
        np.asarray(right.columns["pid"]), np.asarray(right.columns["w"]),
        rmask) if ok}
    for i in np.nonzero(lmask)[0]:
        k = int(np.asarray(left.columns["pid"])[i])
        want = rmap.get(k, NULL_INT)
        assert int(np.asarray(j.columns["r_w"])[i]) == want
    # expand join: one output row per (valid left, valid child) key pair,
    # plus one null-filled row per unmatched valid left row
    cmask = unpack_np(np.asarray(child.valid), child.capacity)
    ckeys = np.asarray(child.columns["pid"])[cmask]
    n_pairs = sum(
        max(int((ckeys == int(np.asarray(left.columns["pid"])[i])).sum()), 1)
        for i in np.nonzero(lmask)[0])
    assert int(e.count) == n_pairs
