"""Transformer tests: exposures / follow-up / fractures / trackloss against
sequential python oracles (including a hypothesis sweep for exposures)."""
from _hyp import given, settings, st
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Category, DCIR_SCHEMA, exposures, flatten_star, follow_up, fractures,
    make_events, observation_period, sort_events, trackloss,
)
from repro.core.columnar import ColumnarTable, NULL_INT
from repro.data.synthetic import SyntheticConfig, generate_dcir


def events_from(pids, vals, starts, cat=Category.DRUG_DISPENSE):
    n = len(pids)
    return make_events(
        patient_id=jnp.asarray(pids, jnp.int32),
        category=cat,
        value=jnp.asarray(vals, jnp.int32),
        start=jnp.asarray(starts, jnp.int32),
    )


def exposure_oracle(pids, vals, starts, purview):
    """Greedy merge per (patient, drug): the paper's exposure semantics."""
    from collections import defaultdict

    groups = defaultdict(list)
    for p, v, s in zip(pids, vals, starts):
        groups[(p, v)].append(s)
    out = []
    for (p, v), dates in groups.items():
        dates = sorted(dates)
        start = dates[0]
        last = dates[0]
        n = 1
        for d in dates[1:]:
            if d - last <= purview:
                last = d
                n += 1
            else:
                out.append((p, v, start, last + purview, n))
                start = last = d
                n = 1
        out.append((p, v, start, last + purview, n))
    return sorted(out)


def test_exposures_simple():
    ev = events_from([0, 0, 0, 1], [5, 5, 5, 5], [0, 30, 200, 10])
    ex = exposures(ev, n_patients=2, purview_days=60)
    o = ex.to_numpy()
    got = sorted(zip(o["patient_id"], o["value"], o["start"], o["end"],
                     o["weight"].astype(int)))
    want = exposure_oracle([0, 0, 0, 1], [5, 5, 5, 5], [0, 30, 200, 10], 60)
    assert got == want


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 60),
    purview=st.integers(1, 50),
    data=st.data(),
)
def test_property_exposures_oracle(n, purview, data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    pids = rng.integers(0, 5, n).tolist()
    vals = rng.integers(0, 4, n).tolist()
    starts = rng.integers(0, 300, n).tolist()
    ex = exposures(events_from(pids, vals, starts), n_patients=5,
                   purview_days=purview)
    o = ex.to_numpy()
    got = sorted(zip(o["patient_id"], o["value"], o["start"], o["end"],
                     o["weight"].astype(int)))
    assert got == exposure_oracle(pids, vals, starts, purview)


def test_observation_period():
    ev = events_from([0, 0, 1], [1, 2, 3], [100, 50, 70])
    obs = observation_period(ev, n_patients=3)
    o = obs.to_numpy()
    assert o["start"][0] == 50 and o["end"][0] == 100
    assert o["start"][1] == 70
    assert len(o["patient_id"]) == 2  # patient 2 has no events


def test_follow_up_death_clips():
    pats = ColumnarTable.from_columns({
        "patient_id": np.asarray([0, 1], np.int32),
        "gender": np.asarray([1, 2], np.int32),
        "birth_date": np.asarray([0, 0], np.int32),
        "death_date": np.asarray([150, int(NULL_INT)], np.int32),
    })
    ev = events_from([0, 1], [1, 1], [100, 100])
    fu = follow_up(pats, ev, n_patients=2, study_end=1000)
    o = fu.to_numpy()
    assert o["end"][0] == 150      # clipped at death
    assert o["end"][1] == 1000     # study end


def test_fractures_washout():
    acts = events_from([0, 0, 0], [2, 2, 2], [0, 30, 200], cat=Category.MEDICAL_ACT)
    diags = events_from([], [], [], cat=Category.DIAGNOSIS)
    fr = fractures(acts, diags, fracture_act_codes=[2], fracture_diag_codes=[],
                   washout_days=90)
    o = fr.to_numpy()
    # events at 0 and 200 kept; 30 is inside the washout of 0
    assert sorted(o["start"].tolist()) == [0, 200]


def test_fractures_per_site_washout_independent():
    # same patient, two body sites (site = value % n_sites)
    acts = events_from([0, 0], [1, 2], [0, 10], cat=Category.MEDICAL_ACT)
    diags = events_from([], [], [], cat=Category.DIAGNOSIS)
    fr = fractures(acts, diags, [1, 2], [], n_sites=8, washout_days=90)
    assert int(fr.count) == 2  # different sites: both kept


def test_trackloss():
    ev = events_from([0, 0, 1, 1], [1, 1, 1, 1], [0, 500, 0, 30])
    tl = trackloss(ev, n_patients=2, gap_days=120)
    o = tl.to_numpy()
    assert o["patient_id"].tolist() == [0]
    assert o["start"][0] == 120


def test_end_to_end_dcir_pipeline():
    from repro.core import drug_dispenses

    dcir = generate_dcir(SyntheticConfig(n_patients=100, seed=3))
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    drugs = drug_dispenses()(flat)
    ex = exposures(drugs, n_patients=100, purview_days=45)
    assert 0 < int(ex.count) <= int(drugs.count)
    o = ex.to_numpy()
    assert (o["end"] - o["start"] >= 45).all()
