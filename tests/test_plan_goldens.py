"""Plan-snapshot golden tests: the optimized plans of the two example
pipelines (quickstart, cohort_study) are serialized — ops, wiring, predicate
engines + bitset layout, fused exprs, pruned/required columns — and diffed
against ``tests/goldens/*.json``.

Optimizer changes then surface as *reviewable golden updates* instead of
silent plan drift: a pass reordering, a lost fusion, a widened scan or a
dropped engine stamp shows up as a JSON diff in the PR.  Content-dependent
params (capacities, slack heuristics) are excluded — the goldens pin plan
*structure*, not synthetic-data statistics.

Regenerate intentionally with::

    REGEN_GOLDENS=1 PYTHONPATH=src python -m pytest tests/test_plan_goldens.py
"""
import json
import os

import pytest

from repro.core import DCIR_SCHEMA, PMSI_MCO_SCHEMA, diagnoses, \
    drug_dispenses, hospital_stays, medical_acts_dcir, medical_acts_pmsi
from repro.study import Study, col, cut_points, normalize
from repro.study.expr import render_param

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")

# structural params worth pinning; capacities/slacks stay out (they depend on
# synthetic table statistics, not on optimizer behavior)
_KEEP = (
    "source", "star", "partitioned_on", "cols", "pruned_columns",
    "required_columns", "engine", "bitset_block", "bitset_word", "left_key",
    "right_key", "prefix", "key", "col", "keys", "name", "fn", "category",
    "value_col", "start_col", "end_col", "group_col", "weight_col", "kind",
    "null_cols", "lo", "hi", "columns", "valid_layout",
)


def plan_snapshot(plan) -> dict:
    """JSON-stable structural view of an optimized plan."""
    nodes = []
    for n in plan.nodes:
        p = {}
        for k, v in n.params:
            if k == "expr":
                p[k] = render_param(v)
            elif k == "exprs":
                p[k] = [render_param(e) for e in v]
            elif k == "filters":
                p[k] = [[c, list(codes)] for c, codes in v]
            elif k in _KEEP and v is not None:
                p[k] = list(v) if isinstance(v, tuple) else v
        nodes.append({"op": n.op, "inputs": list(n.inputs), "params": p})
    return {"nodes": nodes, "outputs": dict(plan.outputs)}


def normal_snapshot(plan) -> dict:
    """Structural view of the *canonical* (service-shared) form of a plan:
    the alpha-renamed node graph with hoisted-literal slots rendered as
    ``?N``/``?setN`` placeholders, the extracted literal/vector params, and
    the subgraph-cache cut points.  Pins what the cohort-query service keys
    executables and cache entries on — normalization drift (a slot
    reordering, a lost hoist, a shifted cut) surfaces as a golden diff."""
    nplan = normalize(plan)
    snap = plan_snapshot(nplan.plan)
    snap["lits"] = [float(v) if isinstance(v, float) else v
                    for v in nplan.lits]
    snap["vecs"] = [list(v) for v in nplan.vecs]
    snap["cut_points"] = [[i, nplan.plan.nodes[i].op]
                          for i in cut_points(nplan.plan)]
    return snap


def _check(name: str, plan, snapshot=plan_snapshot) -> None:
    snap = snapshot(plan)
    path = os.path.join(GOLDEN_DIR, name)
    if os.environ.get("REGEN_GOLDENS"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        return
    if not os.path.exists(path):
        pytest.fail(f"golden {name} missing — regenerate with REGEN_GOLDENS=1")
    with open(path) as f:
        want = json.load(f)
    # json round-trip normalization (tuples -> lists) for the comparison
    snap = json.loads(json.dumps(snap, sort_keys=True))
    assert snap == want, (
        f"optimized plan drifted from goldens/{name}.  If the change is "
        f"intentional, regenerate with REGEN_GOLDENS=1 and review the diff.")


def _quickstart_study() -> Study:
    """Mirror of examples/quickstart.py: flatten + 2 extractors + patients
    + cohort algebra + flow."""
    return (Study(n_patients=1_000)
            .flatten(DCIR_SCHEMA)
            .extract(drug_dispenses(), name="drug_purchases")
            .extract(medical_acts_dcir(codes=list(range(30))), name="acts")
            .patients("IR_BEN")
            .cohort("base", "extract_patients")
            .cohort("drugged", "drug_purchases")
            .cohort("final", "drugged & base - acts")
            .flow("base", "drugged", "final"))


def _cohort_study() -> Study:
    """Mirror of examples/cohort_study.py (flat sources, transformers,
    algebra with parens, featurize)."""
    STUDY_END = 14_600 + 3 * 365
    return (Study(n_patients=2_000, window=(14_600, STUDY_END))
            .patients("IR_BEN")
            .extract(drug_dispenses(), name="drug_purchases")
            .extract(drug_dispenses()
                     .filtered(col("cip13").isin(range(65))
                               & col("execution_date").between(14_600,
                                                               STUDY_END)),
                     name="prevalent_drugs")
            .extract(medical_acts_dcir(), name="acts")
            .extract(medical_acts_pmsi(), name="hospital_acts")
            .extract(diagnoses(), name="diagnoses")
            .extract(hospital_stays(), name="stays")
            .transform("exposures", "drug_purchases", name="exposures",
                       purview_days=60)
            .concat("all_acts", "acts", "hospital_acts")
            .transform("fractures", "all_acts", "diagnoses", name="fractures",
                       fracture_act_codes=list(range(30)),
                       fracture_diag_codes=list(range(40)))
            .transform("follow_up", "extract_patients", "drug_purchases",
                       name="follow_up", study_end=STUDY_END)
            .cohort("base", "extract_patients")
            .cohort("exposed", "exposures")
            .cohort("fractured", "fractures")
            .cohort("final", "(exposed & base) - fractured")
            .flow("base", "exposed", "final")
            .featurize("X", cohort="final", kind="dense",
                       n_buckets=36, bucket_days=31, n_features=128)
            .featurize("tokens", cohort="final", kind="tokens", seq_len=256))


# predicate_engine is pinned explicitly ("auto" would make goldens
# backend-dependent); "pallas" also pins the engine + bitset-layout stamps.
def test_quickstart_plan_golden():
    _check("quickstart_plan.json",
           _quickstart_study().optimized_plan(predicate_engine="pallas"))


def test_quickstart_plan_golden_jnp_engine():
    _check("quickstart_plan_jnp.json",
           _quickstart_study().optimized_plan(predicate_engine="jnp"))


def test_cohort_study_plan_golden():
    _check("cohort_study_plan.json",
           _cohort_study().optimized_plan(predicate_engine="pallas"))


def test_quickstart_normal_golden():
    _check("quickstart_normal.json",
           _quickstart_study().optimized_plan(predicate_engine="jnp"),
           snapshot=normal_snapshot)


def test_cohort_study_normal_golden():
    _check("cohort_study_normal.json",
           _cohort_study().optimized_plan(predicate_engine="jnp"),
           snapshot=normal_snapshot)


def test_normal_snapshot_hoists_and_renames():
    """The canonical form must be literal-free and label-free: every literal
    lives in the params vectors (rendered as ?N slots), tenant-chosen names
    are alpha-renamed, and two literal-variants share one snapshot."""
    mk = lambda codes: (Study(n_patients=1_000)
                        .flatten(DCIR_SCHEMA)
                        .extract(medical_acts_dcir(codes=codes), name="acts"))
    a = normal_snapshot(mk(list(range(30))).optimized_plan(
        predicate_engine="jnp"))
    b = normal_snapshot(mk(list(range(100, 130))).optimized_plan(
        predicate_engine="jnp"))
    assert a["vecs"] == [list(range(30))]
    # same-length code lists share one structure (the vector is a traced
    # argument; its *length* is shape, hence structural)
    a.pop("vecs"), b.pop("vecs")
    assert a == b
    rendered = json.dumps(a)
    assert "?set0" in rendered          # hoisted isin slot, not inline codes
    assert "acts" not in rendered       # label stripped
    assert a["cut_points"], "canonical plan should expose cache cut points"


def test_snapshot_captures_engines_and_pruning():
    """The snapshot itself must carry the audit fields the goldens exist to
    pin: predicate engines + bitset layout and pruned scan projections."""
    snap = plan_snapshot(
        _quickstart_study().optimized_plan(predicate_engine="pallas"))
    ops = [n["op"] for n in snap["nodes"]]
    assert "fused_mask" in ops and "scan_star" in ops
    masks = [n for n in snap["nodes"] if n["op"] == "fused_mask"]
    assert all(m["params"].get("engine") == "pallas" for m in masks)
    assert all(m["params"].get("bitset_block") == 1024 for m in masks)
    # bitset-native validity: predicate + compact nodes carry the layout
    # stamp, and the pruned-to-key IR_BEN join is eliminated to a key_count
    layered = [n for n in snap["nodes"] if n["op"] in ("fused_mask", "compact")]
    assert layered and all(
        n["params"].get("valid_layout") == "bitset_u32" for n in layered)
    assert "key_count" in ops
    pruned = [n for n in snap["nodes"]
              if n["op"] == "select" and n["params"].get("pruned_columns")]
    assert pruned, "quickstart plan should prune unused dimension columns"
