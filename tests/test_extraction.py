"""SCALPEL-Extraction tests: extractor steps vs numpy oracles + provenance."""
import numpy as np
import pytest

from repro.core import (
    DCIR_SCHEMA, PMSI_MCO_SCHEMA, Category, OperationLog, dedupe_by,
    diagnoses, drug_dispenses, flatten_star, hospital_stays,
    medical_acts_dcir, medical_acts_pmsi, patients,
)
from repro.core.columnar import ColumnarTable, NULL_INT
from repro.data.synthetic import SyntheticConfig, generate_dcir, generate_pmsi

CFG = SyntheticConfig(n_patients=200, seed=11)


@pytest.fixture(scope="module")
def flat_dcir():
    dcir = generate_dcir(CFG)
    return dcir, flatten_star(DCIR_SCHEMA, dcir)[0]


@pytest.fixture(scope="module")
def flat_pmsi():
    pmsi = generate_pmsi(CFG)
    return pmsi, flatten_star(PMSI_MCO_SCHEMA, pmsi)[0]


def test_drug_extractor_counts(flat_dcir):
    dcir, flat = flat_dcir
    ev = drug_dispenses()(flat)
    pha = dcir["ER_PHA"].to_numpy()
    assert int(ev.count) == (pha["cip13"] != int(NULL_INT)).sum()
    e = ev.to_numpy()
    assert (e["category"] == Category.DRUG_DISPENSE).all()
    assert (e["end"] == int(NULL_INT)).all()  # punctual


def test_drug_extractor_value_filter(flat_dcir):
    _, flat = flat_dcir
    codes = list(range(10))
    ev = drug_dispenses(codes=codes)(flat)
    e = ev.to_numpy()
    assert set(e["value"].tolist()) <= set(codes)


def test_atc_granularity(flat_dcir):
    _, flat = flat_dcir
    ev = drug_dispenses(granularity="atc")(flat)
    e = ev.to_numpy()
    assert e["value"].max() < CFG.n_atc_classes


def test_diagnoses_distinct(flat_pmsi):
    pmsi, flat = flat_pmsi
    ev = diagnoses()(flat)
    d = pmsi["MCO_D"].to_numpy()
    uniq = len(set(zip(d["stay_id"].tolist(), d["icd_code"].tolist(),
                       d["diag_kind"].tolist())))
    assert int(ev.count) == uniq


def test_hospital_stays_longitudinal(flat_pmsi):
    pmsi, flat = flat_pmsi
    ev = hospital_stays()(flat)
    assert int(ev.count) == len(np.unique(pmsi["MCO_B"].to_numpy()["stay_id"]))
    e = ev.to_numpy()
    assert (e["end"] >= e["start"]).all()  # continuous events


def test_patients_extractor(flat_dcir):
    dcir, _ = flat_dcir
    log = OperationLog()
    p = patients(dcir["IR_BEN"], log)
    assert int(p.count) == CFG.n_patients
    assert log.entries[0]["op"] == "extract:extract_patients"


def test_dedupe_by():
    t = ColumnarTable.from_columns({
        "k": np.asarray([3, 1, 3, 1, 2], np.int32),
        "v": np.asarray([10, 11, 12, 13, 14], np.int32),
    })
    d = dedupe_by(t, ["k"]).compact()
    o = d.to_numpy()
    assert sorted(o["k"].tolist()) == [1, 2, 3]


def test_provenance_flowchart(flat_dcir):
    _, flat = flat_dcir
    log = OperationLog()
    drug_dispenses()(flat, log)
    medical_acts_dcir()(flat, log)
    rows = log.flowchart()
    assert len(rows) == 2
    assert all(r["removed"] >= 0 for r in rows)
    blob = log.to_json()
    restored = OperationLog.from_json(blob)
    assert restored.flowchart() == rows


def test_pallas_engine_parity(flat_dcir):
    """extractor(engine='pallas') == extractor(engine='xla') row-for-row."""
    _, flat = flat_dcir
    ex = drug_dispenses()
    a = ex(flat, engine="xla").to_numpy()
    b = ex(flat, engine="pallas").to_numpy()
    for k in a:
        assert (a[k] == b[k]).all(), k
