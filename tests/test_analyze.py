"""Static plan verifier: every diagnostic code fires on its seeded defect,
clean plans stay clean, analysis is sound w.r.t. execution (SP003 "provably
empty" really means zero rows), the cohort-query service rejects error plans
before compiling, and the diagnostic surface of the golden example plans is
pinned as a reviewable JSON golden.

Regenerate diag goldens intentionally with::

    REGEN_GOLDENS=1 PYTHONPATH=src python -m pytest tests/test_analyze.py
"""
import dataclasses
import json
import os

import numpy as np
import pytest

import jax.numpy as jnp

from _hyp import given, settings, st
from repro.core import DCIR_SCHEMA, drug_dispenses, medical_acts_dcir
from repro.core.columnar import ColumnarTable
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.study import (
    CohortQueryService, DIAGNOSTIC_CODES, PlanValidationError, ServiceConfig,
    Study, analyze, col, execute, normalize,
)
from repro.study.analyze import errors, format_diagnostics
from repro.study.defects import DEFECTS, all_defects, golden_studies
from repro.study.optimizer import assign_engines
from repro.study.plan import PlanBuilder

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")

CFG = SyntheticConfig(n_patients=200, seed=7)


@pytest.fixture(scope="module")
def dcir():
    return generate_dcir(CFG)


# ---------------------------------------------------------------------------
# the defect matrix: every registered code fires on its seeded fixture
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("code", sorted(DEFECTS))
def test_seeded_defect_fires(code):
    plan, kwargs = DEFECTS[code]()
    diags = analyze(plan, **kwargs)
    hit = [d for d in diags if d.code == code]
    assert hit, (f"{code} did not fire on its seeded defect; got:\n"
                 + (format_diagnostics(diags) or "(clean)"))
    want_sev, _ = DIAGNOSTIC_CODES[code]
    # severity may escalate above the registered baseline (e.g. SP007 word
    # misalignment becomes an error when it breaks the shard quantum) but
    # never soften below it
    rank = {"info": 0, "warn": 1, "error": 2}
    assert all(rank[d.severity] >= rank[want_sev] for d in hit)
    assert all(d.message for d in hit)


def test_defect_registry_covers_every_code():
    assert set(DEFECTS) == set(DIAGNOSTIC_CODES)


def test_golden_studies_are_clean():
    """The two example pipelines carry no error/warn diagnostics under
    either predicate engine — the plan-lint CI gate's contract."""
    for name, study in golden_studies().items():
        for engine in ("pallas", "jnp"):
            plan = study.optimized_plan(predicate_engine=engine)
            diags = analyze(plan, n_patients=study.n_patients)
            bad = [d for d in diags if d.severity in ("error", "warn")]
            assert not bad, (f"{name}/{engine}:\n"
                             + format_diagnostics(bad))


# ---------------------------------------------------------------------------
# soundness: analysis verdicts agree with actual execution
# ---------------------------------------------------------------------------
_CMP = {"<": lambda c, v: c < v, "<=": lambda c, v: c <= v,
        ">": lambda c, v: c > v, ">=": lambda c, v: c >= v,
        "==": lambda c, v: c == v}


def _conjunct_plan(conjs):
    b = PlanBuilder()
    t = b.scan("T")
    expr = None
    for op, v in conjs:
        c = _CMP[op](col("x"), v)
        expr = c if expr is None else (expr & c)
    m = b.predicate(t, expr)
    b.set_output("out", b.compact(m))
    return b.build()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(sorted(_CMP)),
                          st.integers(-4, 19)),
                min_size=1, max_size=4))
def test_interval_analysis_sound_vs_execution(conjs):
    """Random comparison conjuncts over a known int column: the analyzer
    must never call a satisfiable predicate empty (if SP003 fires, execution
    provably yields zero rows), and clean plans must execute."""
    plan = _conjunct_plan(conjs)
    tbl = ColumnarTable.from_columns(
        {"x": jnp.arange(16, dtype=jnp.int32),
         "patient_id": jnp.arange(16, dtype=jnp.int32)})
    diags = analyze(plan, tables={"T": tbl})
    assert not any(d.code in ("SP001", "SP002", "SP012", "SP013")
                   for d in diags)
    vals = execute(plan, {"T": tbl}, jit=False)
    out = vals[plan.output_ids["out"]]
    if any(d.code == "SP003" for d in diags):
        assert int(out.count) == 0, (
            "SP003 claimed always-false but rows survived:\n"
            + format_diagnostics(diags))


def test_contradiction_marks_output_empty():
    plan = _conjunct_plan([("<", 3), (">", 5)])
    diags = analyze(plan)
    assert {d.code for d in diags} >= {"SP003", "SP014"}


def test_errors_helper_and_formatting():
    plan, kwargs = DEFECTS["SP003"]()
    diags = analyze(plan, **kwargs)
    errs = errors(diags)
    assert errs and all(d.severity == "error" for d in errs)
    text = format_diagnostics(diags)
    assert "SP003" in text and "node" in text


# ---------------------------------------------------------------------------
# Study.check(): the user-facing entry point
# ---------------------------------------------------------------------------
def _bad_study(n_patients):
    s = Study(n_patients=n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(medical_acts_dcir(), name="acts")
    s.filter("acts", (col("value") < 3) & (col("value") > 5), name="never")
    s.cohort("bad", "never")
    return s


def _good_study(n_patients):
    s = Study(n_patients=n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(drug_dispenses(codes=list(range(40))), name="drugs")
    s.filter("drugs", col("value") >= 1, name="hi")
    s.cohort("base", "hi")
    return s


def test_study_check_flags_defect(dcir):
    diags = _bad_study(CFG.n_patients).check(tables=dict(dcir))
    codes = {d.code for d in diags if d.severity == "error"}
    assert "SP003" in codes


def test_study_check_clean(dcir):
    diags = _good_study(CFG.n_patients).check(tables=dict(dcir))
    assert not [d for d in diags if d.severity in ("error", "warn")], \
        format_diagnostics(diags)


# ---------------------------------------------------------------------------
# normalize() demotion audit: hoisted literals are kernel operands now, so
# demotion is the *exception* (kernel-infeasible stamps only)
# ---------------------------------------------------------------------------
def test_normalize_keeps_hoisted_literals_on_pallas():
    b = PlanBuilder()
    t = b.scan("T")
    m = b.predicate(t, col("x") > 5)          # inline literal -> hoisted
    b.set_output("out", b.compact(m))
    plan = assign_engines(b.build(), predicate_engine="pallas")
    nplan = normalize(plan)
    assert nplan.demoted == (), \
        "hoisted-literal pallas predicates must keep the kernel engine"
    pred = [n for n in nplan.plan.nodes if n.op == "predicate"]
    assert pred and all(n.get("engine") == "pallas" for n in pred)
    # literal-free predicates stay pallas and record nothing either
    b2 = PlanBuilder()
    t2 = b2.scan("T")
    m2 = b2.predicate(t2, col("x").not_null())
    b2.set_output("out", b2.compact(m2))
    n2 = normalize(assign_engines(b2.build(), predicate_engine="pallas"))
    assert n2.demoted == ()


def test_normalize_demotes_kernel_infeasible_stamp():
    # force-stamp pallas onto an isin past the VMEM operand budget (the
    # optimizer itself would stamp jnp) — the one case that still demotes
    from repro.kernels.predicate import MAX_ISIN_VALUES
    from repro.study.expr import as_param

    b = PlanBuilder()
    t = b.scan("T")
    m = b.add("predicate", (t,),
              expr=as_param(col("x").isin(range(MAX_ISIN_VALUES + 1))),
              engine="pallas", bitset_block=1024, bitset_word="uint32")
    b.set_output("out", b.compact(m))
    nplan = normalize(b.build())
    assert nplan.demoted, "oversized-whitelist pallas stamp must demote"
    for nid in nplan.demoted:
        assert nplan.plan.nodes[nid].get("engine") == "jnp"


# ---------------------------------------------------------------------------
# service integration: admission-time rejection + demotion accounting
# ---------------------------------------------------------------------------
def test_service_rejects_error_plan_before_compile(dcir):
    svc = CohortQueryService(dict(dcir), config=ServiceConfig())
    bad = svc.submit(_bad_study(CFG.n_patients), tenant="t1")
    svc.drain()
    assert bad.status == "invalid"
    assert isinstance(bad.error, PlanValidationError)
    assert any(d.code == "SP003" for d in bad.error.diagnostics)
    assert svc.stats.plans_rejected == 1
    assert svc.stats.tenant("t1").invalid == 1
    assert svc.stats.compile_count == 0, \
        "rejected plan must never reach the compile cache"
    assert any(e["op"] == "service:invalid:t1" for e in svc.log.entries)
    # a healthy study from another tenant still serves afterwards
    ok = svc.submit(_good_study(CFG.n_patients), tenant="t2")
    svc.drain()
    assert ok.status == "done"
    assert svc.stats.compile_count >= 1


def test_service_serves_pallas_without_demotions(dcir):
    # no-demotion regression: hoisted literals ride as kernel operands, so
    # a pallas-engine service keeps every predicate on the kernel path and
    # the demotion audit stays silent
    svc = CohortQueryService(
        dict(dcir), config=ServiceConfig(predicate_engine="pallas"))
    t = svc.submit(_good_study(CFG.n_patients), tenant="a")
    svc.drain()
    assert t.status == "done", t.error
    assert svc.stats.demotions == 0
    assert svc.stats.tenant("a").demoted == 0
    assert not [e for e in svc.log.entries
                if e["op"].startswith("service:demote:")]
    snap = svc.stats.snapshot()
    assert snap["demotions"] == 0
    assert snap["plans_rejected"] == 0


# ---------------------------------------------------------------------------
# diag goldens: the diagnostic surface of the example plans is pinned
# ---------------------------------------------------------------------------
def _diag_snapshot(study):
    plan = study.optimized_plan(predicate_engine="pallas")
    diags = analyze(plan, n_patients=study.n_patients)
    return [dataclasses.asdict(d) for d in diags]


def _check_diag_golden(name, study):
    snap = _diag_snapshot(study)
    path = os.path.join(GOLDEN_DIR, name)
    if os.environ.get("REGEN_GOLDENS"):
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True)
        return
    if not os.path.exists(path):
        pytest.fail(f"golden {name} missing — regenerate with REGEN_GOLDENS=1")
    with open(path) as f:
        want = json.load(f)
    snap = json.loads(json.dumps(snap, sort_keys=True))
    assert snap == want, (
        f"diagnostic surface drifted from goldens/{name}.  If intentional, "
        f"regenerate with REGEN_GOLDENS=1 and review the diff.")


def test_quickstart_diag_golden():
    _check_diag_golden("quickstart_diag.json", golden_studies()["quickstart"])


def test_cohort_study_diag_golden():
    _check_diag_golden("cohort_study_diag.json",
                       golden_studies()["cohort_study"])
