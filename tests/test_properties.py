"""Cross-cutting property tests (system invariants, hypothesis-driven)."""
from _hyp import given, settings, st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Bitset, Cohort
from repro.core.columnar import ColumnarTable
from repro.core.flattening import expand_join, flatten_star
from repro.core.schema import PMSI_MCO_SCHEMA
from repro.data.synthetic import SyntheticConfig, generate_pmsi
from repro.models.layers import _hierarchical_rank


# -- MoE dispatch rank ---------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 400),
    e=st.sampled_from([2, 4, 8, 16, 64]),
    block=st.sampled_from([16, 64, 256]),
    data=st.data(),
)
def test_property_hierarchical_rank_oracle(n, e, block, data):
    """rank(i) == #earlier rows routed to the same expert — for any shape."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    fe = jnp.asarray(rng.integers(0, e, n), jnp.int32)
    oh = (fe[:, None] == jnp.arange(e)[None, :]).astype(jnp.int32)
    rank = np.asarray(_hierarchical_rank(oh, fe, block=block))
    cnt = np.zeros(e, int)
    for i, x in enumerate(np.asarray(fe)):
        assert rank[i] == cnt[x], (i, int(x))
        cnt[x] += 1


# -- cohort algebra laws ---------------------------------------------------------
def _cohort(name, s, n):
    idx = jnp.asarray(sorted(s) or [0], jnp.int32)
    valid = jnp.asarray([True] * max(len(s), 1)) if s else jnp.asarray([False])
    return Cohort(name=name, description=name,
                  subjects=Bitset.from_indices(idx, valid, n), n_patients=n)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 128), data=st.data())
def test_property_de_morgan(n, data):
    """|A \\ (B ∪ C)| == |(A \\ B) \\ C| — fold-order invariance the paper's
    CohortFlow semantics rely on."""
    draw = lambda: set(data.draw(st.lists(st.integers(0, n - 1), max_size=n)))
    A, B, C = _cohort("a", draw(), n), _cohort("b", draw(), n), _cohort("c", draw(), n)
    lhs = A.difference(B.union(C))
    rhs = A.difference(B).difference(C)
    assert lhs.subject_count() == rhs.subject_count()
    assert (np.asarray(lhs.subjects) == np.asarray(rhs.subjects)).all()


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 128), data=st.data())
def test_property_intersection_bounded(n, data):
    draw = lambda: set(data.draw(st.lists(st.integers(0, n - 1), max_size=n)))
    A, B = _cohort("a", draw(), n), _cohort("b", draw(), n)
    inter = A.intersection(B)
    assert inter.subject_count() <= min(A.subject_count(), B.subject_count())
    uni = A.union(B)
    assert uni.subject_count() == (A.subject_count() + B.subject_count()
                                   - inter.subject_count())


# -- flattening conservation -------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_pat=st.integers(20, 120))
def test_property_pmsi_flatten_row_conservation(seed, n_pat):
    """Every (stay, diagnosis, act) combination appears exactly
    max(n_diag,1)·max(n_act,1) times per stay — for any synthetic draw."""
    import collections

    cfg = SyntheticConfig(n_patients=n_pat, seed=seed)
    pmsi = generate_pmsi(cfg)
    flat, stats = flatten_star(PMSI_MCO_SCHEMA, pmsi)
    for s in stats:
        s.assert_no_loss()
    f = flat.to_numpy()
    b = pmsi["MCO_B"].to_numpy()
    d = collections.Counter(pmsi["MCO_D"].to_numpy()["stay_id"].tolist())
    a = collections.Counter(pmsi["MCO_A"].to_numpy()["stay_id"].tolist())
    out = collections.Counter(f["stay_id"].tolist())
    for sid in b["stay_id"].tolist():
        want = max(d.get(sid, 0), 1) * max(a.get(sid, 0), 1)
        assert out[sid] == want, (sid, out[sid], want)


# -- tokenizer round-trip ------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_property_token_stream_event_conservation(data):
    """Every in-vocabulary event appears in the token stream exactly once
    (or is counted as truncated)."""
    from repro.core import Category, FeatureDriver, make_events

    n_pat = data.draw(st.integers(1, 16))
    n_ev = data.draw(st.integers(0, 64))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    ev = make_events(
        patient_id=jnp.asarray(rng.integers(0, n_pat, max(n_ev, 1)), jnp.int32),
        category=Category.DRUG_DISPENSE,
        value=jnp.asarray(rng.integers(0, 100, max(n_ev, 1)), jnp.int32),
        start=jnp.asarray(rng.integers(0, 1000, max(n_ev, 1)), jnp.int32),
        valid=jnp.asarray([True] * n_ev + [False] * (max(n_ev, 1) - n_ev)),
    )
    c = Cohort.from_events("e", ev, n_pat)
    c.window = (0, 2_000_000)
    fd = FeatureDriver(c)
    seq_len = data.draw(st.sampled_from([8, 32, 128]))
    toks, _ = fd.token_sequences(seq_len)
    n_emitted = int((np.asarray(toks) > 7).sum())  # non-special tokens
    assert n_emitted + fd.checks["events_truncated"] == n_ev
