"""Paper Table 1: dataset characteristics + storage ratios.

Generates the synthetic SNDS at the benchmark scale factor and reports the
same quantities as Table 1: central/denormalized row counts, patients, event
counts, distinct codes, and CSV vs columnar on-disk sizes (the paper's 11.2x
DCIR compression; ours differs with data entropy but the ratio direction and
the PMSI blow-up must reproduce).
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.core.flattening import flatten_star
from repro.core.schema import DCIR_SCHEMA, PMSI_MCO_SCHEMA
from repro.core.columnar import NULL_INT
from repro.data.io import csv_size_bytes, save_columnar
from repro.data.synthetic import SyntheticConfig, generate_dcir, generate_pmsi


def run(n_patients: int = 2_000, seed: int = 0) -> List[Dict]:
    cfg = SyntheticConfig(n_patients=n_patients, seed=seed)
    rows: List[Dict] = []
    with tempfile.TemporaryDirectory() as tmp:
        for name, schema, gen in (
            ("DCIR", DCIR_SCHEMA, generate_dcir),
            ("PMSI-MCO", PMSI_MCO_SCHEMA, generate_pmsi),
        ):
            tables = gen(cfg)
            central = tables[schema.central.name]
            t0 = time.time()
            flat, stats = flatten_star(schema, tables)
            flatten_s = time.time() - t0
            for s in stats:
                s.assert_no_loss()

            csv_b = sum(csv_size_bytes(t) for t in tables.values())
            col_b = sum(
                save_columnar(t, os.path.join(tmp, f"{name}_{tn}"))
                for tn, t in tables.items()
            )
            flat_b = save_columnar(flat, os.path.join(tmp, f"{name}_flat"))

            fnp = flat.to_numpy()
            rec = {
                "database": name,
                "rows_central": int(central.count),
                "rows_denormalized": int(flat.count),
                "patients": len(np.unique(fnp["patient_id"]))
                if "patient_id" in fnp else n_patients,
                "csv_bytes": csv_b,
                "columnar_bytes": col_b,
                "flat_columnar_bytes": flat_b,
                "csv_over_columnar": round(csv_b / max(col_b, 1), 2),
                "flatten_seconds": round(flatten_s, 2),
            }
            if name == "DCIR":
                pha = tables["ER_PHA"].to_numpy()
                drugs = pha["cip13"][pha["cip13"] != int(NULL_INT)]
                rec["drug_events"] = int(drugs.shape[0])
                rec["distinct_drug_codes"] = len(np.unique(drugs))
            else:
                d = tables["MCO_D"].to_numpy()
                rec["diagnosis_events"] = int(d["icd_code"].shape[0])
                rec["distinct_diag_codes"] = len(np.unique(d["icd_code"]))
            rows.append(rec)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
