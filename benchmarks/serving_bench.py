"""Cohort-query service vs sequential solo runs: compile sharing + subgraph
cache under a mixed multi-tenant workload.

Workload: ``n_queries`` studies from ``n_tenants`` tenants round-robined
over three plan *shapes*; every query carries tenant/query-specific literals
(follow-up thresholds, shifted code windows), so the naive baseline — a
fresh ``Study.run`` per query, literals baked into the plan — compiles one
executable per distinct query.  The service normalizes literals out, so it
compiles once per *shape*, and serves the shared flatten/whitelist prefixes
from the cross-tenant subgraph cache.

Measured: cold-compile counts (service executables vs naive jit entries),
subgraph-cache hit rate, per-query latency p50/p95 and total wall for both
paths — and the acceptance bar: every service result bit-identical to its
solo run.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import DCIR_SCHEMA, drug_dispenses, medical_acts_dcir
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.study import (
    CohortQueryService, ServiceConfig, Study, clear_jit_cache, col,
    jit_cache_info,
)


def _shape_full(n_patients: int, threshold: int, codes: List[int]) -> Study:
    s = Study(n_patients=n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(drug_dispenses(codes=codes), name="drugs")
    s.extract(medical_acts_dcir(), name="acts")
    s.filter("acts", col("value") >= threshold, name="acts_hi")
    s.cohort("base", "drugs")
    s.cohort("final", "base & acts_hi")
    return s


def _shape_drugs(n_patients: int, threshold: int, codes: List[int]) -> Study:
    s = Study(n_patients=n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(drug_dispenses(codes=codes), name="drugs")
    s.cohort("exposed", "drugs")
    return s


def _shape_acts(n_patients: int, threshold: int, codes: List[int]) -> Study:
    s = Study(n_patients=n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(medical_acts_dcir(codes=codes), name="acts")
    s.filter("acts", (col("value") >= threshold)
             & (col("value") < threshold + 400), name="band")
    s.cohort("banded", "band")
    return s


_SHAPES = (_shape_full, _shape_drugs, _shape_acts)


def _same(a, b) -> bool:
    if set(a.events) != set(b.events) or set(a.cohorts) != set(b.cohorts):
        return False
    for k in a.events:
        ta, tb = a.events[k], b.events[k]
        if int(ta.count) != int(tb.count):
            return False
        if not np.array_equal(np.asarray(ta.valid), np.asarray(tb.valid)):
            return False
        if any(not np.array_equal(np.asarray(ta.columns[c]),
                                  np.asarray(tb.columns[c]))
               for c in ta.columns):
            return False
    return all(np.array_equal(np.asarray(a.cohorts[k].subjects),
                              np.asarray(b.cohorts[k].subjects))
               for k in a.cohorts)


def run(n_patients: int = 2_000, n_queries: int = 32, n_tenants: int = 4,
        seed: int = 11) -> List[Dict]:
    tables = generate_dcir(SyntheticConfig(n_patients=n_patients, seed=seed))
    tenants = [f"tenant{i}" for i in range(n_tenants)]

    def mk(q: int) -> Study:
        # distinct literals per query: the naive path cannot reuse anything
        shape = _SHAPES[q % len(_SHAPES)]
        return shape(n_patients, threshold=40 + q,
                     codes=list(range(60 + q, 120 + q)))

    # -- naive baseline: fresh solo run per query, literals baked -------------
    clear_jit_cache()
    naive_lat: List[float] = []
    solo_results = []
    t0 = time.perf_counter()
    for q in range(n_queries):
        t = time.perf_counter()
        solo_results.append(mk(q).run(dict(tables)))
        naive_lat.append(time.perf_counter() - t)
    naive_total = time.perf_counter() - t0
    naive_compiles = jit_cache_info()["compiles"]

    # -- service: one resident table set, mixed-tenant queue ------------------
    svc = CohortQueryService(tables, config=ServiceConfig(n_slots=8))
    t0 = time.perf_counter()
    tickets = [svc.submit(mk(q), tenant=tenants[q % n_tenants])
               for q in range(n_queries)]
    svc.drain()
    service_total = time.perf_counter() - t0
    service_lat = [t.latency_s for t in tickets]

    parity = all(t.status == "done" and _same(solo, t.result)
                 for solo, t in zip(solo_results, tickets))

    def pct(xs, p):
        return float(np.percentile(np.asarray(xs), p))

    return [{
        "name": "mixed_tenant",
        "n_patients": n_patients,
        "n_queries": n_queries,
        "n_tenants": n_tenants,
        "n_shapes": len(_SHAPES),
        "naive_compiles": naive_compiles,
        "service_compiles": svc.stats.compile_count,
        "cache_hits": svc.stats.cache_hits,
        "cache_misses": svc.stats.cache_misses,
        "hit_rate": round(svc.stats.hit_rate(), 4),
        "naive_total_s": round(naive_total, 4),
        "service_total_s": round(service_total, 4),
        "speedup": round(naive_total / service_total, 2),
        "naive_p50_s": round(pct(naive_lat, 50), 4),
        "naive_p95_s": round(pct(naive_lat, 95), 4),
        "service_p50_s": round(pct(service_lat, 50), 4),
        "service_p95_s": round(pct(service_lat, 95), 4),
        "parity": "pass" if parity else "FAIL",
    }]


def main() -> None:
    import json

    print(json.dumps(run(n_patients=500), indent=2))


if __name__ == "__main__":
    main()
