"""Cohort-query service vs sequential solo runs: compile sharing + subgraph
cache + the async submit/realize pipeline under a mixed multi-tenant
workload.

Workload: ``n_queries`` studies from ``n_tenants`` tenants round-robined
over three plan *shapes*; every query carries tenant/query-specific literals
(follow-up thresholds, shifted code windows), so the naive baseline — a
fresh ``Study.run`` per query, literals baked into the plan — compiles one
executable per distinct query.  The service normalizes literals out, so it
compiles once per *shape*, and serves the shared flatten/whitelist prefixes
from the cross-tenant subgraph cache.

Measured: cold-compile counts (service executables vs naive jit entries),
subgraph-cache hit rate, per-query latency p50/p95, total wall for the
naive path and BOTH service modes.  The sync-vs-pipelined comparison is
made on *warm* serve walls — each service first pays its per-shape
compiles on untimed warmup queries, then the timed 32-query serve is the
steady-state regime where host realization overlaps the next query's
device submission.  The *gated* pipeline invariant is the run's own
no-overlap accounting — pipelined serve wall < that serve's
submit_s + realize_s, i.e. ``serve_overlap_s > 0`` — same idiom as the
chunked-execution bench: the measured synchronous wall is reported (and
usually loses) but not gated, because on a core-saturated CPU smoke host
overlapped work still contends for the same cores and the wall race is
noise.  Also measured: the sharded path's compile count
(one per normalized shape, same as local), and the normalization demotion
count for the golden pallas-stamped plans (must be 0: hoisted literals are
kernel operands now).  The acceptance bar everywhere: every served query
bit-identical to its solo run.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import DCIR_SCHEMA, drug_dispenses, medical_acts_dcir
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.study import (
    CohortQueryService, ServiceConfig, Study, clear_jit_cache, col,
    jit_cache_info, normalize,
)


def _shape_full(n_patients: int, threshold: int, codes: List[int]) -> Study:
    s = Study(n_patients=n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(drug_dispenses(codes=codes), name="drugs")
    s.extract(medical_acts_dcir(), name="acts")
    s.filter("acts", col("value") >= threshold, name="acts_hi")
    s.cohort("base", "drugs")
    s.cohort("final", "base & acts_hi")
    return s


def _shape_drugs(n_patients: int, threshold: int, codes: List[int]) -> Study:
    s = Study(n_patients=n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(drug_dispenses(codes=codes), name="drugs")
    s.cohort("exposed", "drugs")
    return s


def _shape_acts(n_patients: int, threshold: int, codes: List[int]) -> Study:
    s = Study(n_patients=n_patients)
    s.flatten(DCIR_SCHEMA)
    s.extract(medical_acts_dcir(codes=codes), name="acts")
    s.filter("acts", (col("value") >= threshold)
             & (col("value") < threshold + 400), name="band")
    s.cohort("banded", "band")
    return s


_SHAPES = (_shape_full, _shape_drugs, _shape_acts)


def _same(a, b) -> bool:
    if set(a.events) != set(b.events) or set(a.cohorts) != set(b.cohorts):
        return False
    for k in a.events:
        ta, tb = a.events[k], b.events[k]
        if int(ta.count) != int(tb.count):
            return False
        if not np.array_equal(np.asarray(ta.valid), np.asarray(tb.valid)):
            return False
        if any(not np.array_equal(np.asarray(ta.columns[c]),
                                  np.asarray(tb.columns[c]))
               for c in ta.columns):
            return False
    return all(np.array_equal(np.asarray(a.cohorts[k].subjects),
                              np.asarray(b.cohorts[k].subjects))
               for k in a.cohorts)


def _golden_demotions() -> int:
    """Normalization demotions across the golden pallas-stamped plans —
    with hoisted literals as kernel operands this must be 0."""
    from repro.study.defects import golden_studies

    total = 0
    for study in golden_studies().values():
        nplan = normalize(study.optimized_plan(predicate_engine="pallas"))
        total += len(nplan.demoted)
    return total


def run(n_patients: int = 2_000, n_queries: int = 32, n_tenants: int = 4,
        seed: int = 11, sharded_queries: int = 8) -> List[Dict]:
    tables = generate_dcir(SyntheticConfig(n_patients=n_patients, seed=seed))
    tenants = [f"tenant{i}" for i in range(n_tenants)]

    def mk(q: int) -> Study:
        # distinct literals per query: the naive path cannot reuse anything
        shape = _SHAPES[q % len(_SHAPES)]
        return shape(n_patients, threshold=40 + q,
                     codes=list(range(60 + q, 120 + q)))

    # -- naive baseline: fresh solo run per query, literals baked -------------
    clear_jit_cache()
    naive_lat: List[float] = []
    solo_results = []
    t0 = time.perf_counter()
    for q in range(n_queries):
        t = time.perf_counter()
        solo_results.append(mk(q).run(dict(tables)))
        naive_lat.append(time.perf_counter() - t)
    naive_total = time.perf_counter() - t0
    naive_compiles = jit_cache_info()["compiles"]

    def serve(pipeline: bool):
        """Warm a fresh service (one untimed query per shape pays the
        per-shape compile), then time the full workload — the steady-state
        serving regime, where the sync-vs-pipelined comparison is not
        drowned by cold-compile jitter.  Returns the timed-phase stage
        accounting too (submit/realize/overlap deltas across the serve)."""
        svc = CohortQueryService(
            tables, config=ServiceConfig(n_slots=8, pipeline=pipeline))
        t0 = time.perf_counter()
        for i in range(len(_SHAPES)):          # distinct warmup literals
            svc.submit(mk(n_queries + i), tenant="warmup")
        svc.drain()
        warm_s = time.perf_counter() - t0
        sub0, rea0 = svc.stats.submit_s, svc.stats.realize_s
        t0 = time.perf_counter()
        tickets = [svc.submit(mk(q), tenant=tenants[q % n_tenants])
                   for q in range(n_queries)]
        svc.drain()
        serve_s = time.perf_counter() - t0
        stages = {"submit_s": svc.stats.submit_s - sub0,
                  "realize_s": svc.stats.realize_s - rea0}
        stages["overlap_s"] = max(
            0.0, stages["submit_s"] + stages["realize_s"] - serve_s)
        ok = all(t.status == "done" and _same(solo, t.result)
                 for solo, t in zip(solo_results, tickets))
        return svc, tickets, warm_s, serve_s, stages, ok

    # -- service, synchronous reference: realize inline per admission ---------
    svc_sync, _, sync_warm, sync_serve, _, sync_parity = serve(pipeline=False)

    # -- service, pipelined: realize on the worker, overlap next submit -------
    svc, tickets, warm, serve_s, stages, parity = serve(pipeline=True)
    sync_total = sync_warm + sync_serve
    service_total = warm + serve_s
    service_lat = [t.latency_s for t in tickets]
    snap = svc.stats.snapshot()

    # -- sharded service: same normalization sharing + cache under shard_map --
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    svc_sh = CohortQueryService(tables, mesh=mesh,
                                config=ServiceConfig(n_slots=8))
    sh_tickets = [svc_sh.submit(mk(q), tenant=tenants[q % n_tenants])
                  for q in range(min(sharded_queries, n_queries))]
    svc_sh.drain()
    sharded_parity = all(t.status == "done" and _same(solo, t.result)
                         for solo, t in zip(solo_results, sh_tickets))

    def pct(xs, p):
        return float(np.percentile(np.asarray(xs), p))

    return [{
        "name": "mixed_tenant",
        "n_patients": n_patients,
        "n_queries": n_queries,
        "n_tenants": n_tenants,
        "n_shapes": len(_SHAPES),
        "naive_compiles": naive_compiles,
        "service_compiles": svc.stats.compile_count,
        "cache_hits": svc.stats.cache_hits,
        "cache_misses": svc.stats.cache_misses,
        "hit_rate": round(svc.stats.hit_rate(), 4),
        "naive_total_s": round(naive_total, 4),
        "service_sync_total_s": round(sync_total, 4),
        "service_total_s": round(service_total, 4),
        "service_sync_serve_s": round(sync_serve, 4),
        "service_serve_s": round(serve_s, 4),
        "speedup": round(naive_total / service_total, 2),
        "pipeline_speedup": round(sync_serve / serve_s, 2),
        "serve_submit_s": round(stages["submit_s"], 4),
        "serve_realize_s": round(stages["realize_s"], 4),
        "serve_overlap_s": round(stages["overlap_s"], 4),
        "submit_s": snap["submit_s"],
        "realize_s": snap["realize_s"],
        "overlap_s": snap["overlap_s"],
        "naive_p50_s": round(pct(naive_lat, 50), 4),
        "naive_p95_s": round(pct(naive_lat, 95), 4),
        "service_p50_s": round(pct(service_lat, 50), 4),
        "service_p95_s": round(pct(service_lat, 95), 4),
        "demotions": svc.stats.demotions + svc_sync.stats.demotions
                     + svc_sh.stats.demotions,
        "golden_demotions": _golden_demotions(),
        "sharded_queries": len(sh_tickets),
        "sharded_compiles": svc_sh.stats.compile_count,
        "sharded_cache_hits": svc_sh.stats.cache_hits,
        "parity": "pass" if parity and sync_parity else "FAIL",
        "sharded_parity": "pass" if sharded_parity else "FAIL",
    }]


def main() -> None:
    import json

    print(json.dumps(run(n_patients=500), indent=2))


if __name__ == "__main__":
    main()
