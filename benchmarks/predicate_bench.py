"""Fused-predicate benchmark: mask-pass bytes, jnp mask algebra vs the
Pallas Expr->bitset kernel.

The acceptance metric mirrors ``pruning_bench``'s byte-proxy style: for every
``fused_mask``/``predicate`` node of the optimized plan, the bytes one mask
pass moves through HBM —

  * **jnp engine**:   read each required column once + the packed validity
                      words, materialize a bool mask column (1 byte/row)
                      that the pack-at-the-boundary then consumes;
  * **pallas engine**: identical column reads (one fused pass), read the
                      packed validity words, write the packed uint32 bitset
                      (1 *bit*/row) + per-block popcounts — the bool column
                      never exists.

Column reads are equal by construction (PR 3 already fused the conjunction),
so the delta is the mask materialization itself: 8x smaller on the output
side, and what feeds the cohort bitset algebra directly.  The CI gate fails
if the kernel path does not beat the jnp path on these bytes for every case.
Wall-clock for both engines is reported too — honestly: on CPU the kernel
runs in *interpret mode* and is slower; the byte model is the TPU story.

Run:  PYTHONPATH=src python benchmarks/predicate_bench.py
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _timeit(fn) -> float:
    import jax

    t0 = time.time()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out))
    return time.time() - t0


def _mask_pass_bytes(plan, tables, block: int) -> Dict[str, Dict[str, int]]:
    """Per-predicate-node byte accounting over the actually-executed plan
    (table capacities come from an eager jnp evaluation, like the join-inflow
    proxy in pruning_bench).  ``block`` is the pallas plan's stamped bitset
    block (the jnp plan walked here carries no layout stamps)."""
    from repro.study.executor import run_plan_body
    from repro.study.expr import node_predicate
    from repro.study.plan import PREDICATE_OPS

    env = {s: tables[s] for s in plan.sources()}
    vals, _, _ = run_plan_body(plan, env, 0, "xla", predicate_engine="jnp")
    per: Dict[str, Dict[str, int]] = {}
    for i, n in enumerate(plan.nodes):
        if n.op not in PREDICATE_OPS:
            continue
        e = node_predicate(n)
        if e is None:
            continue
        t = vals[n.inputs[0]]
        cap = t.capacity
        col_bytes = sum(np.asarray(t.columns[c]).itemsize * cap
                        for c in e.required_columns() if c in t.columns)
        # + packed validity words (1 bit/row — table validity is a bitset
        # for BOTH engines since the bitset-native redesign)
        reads = col_bytes + 4 * ((cap + 31) // 32)
        grid = -(-cap // block)
        per[f"#{i}:{n.op}"] = {
            "rows": cap,
            "jnp_bytes": reads + cap,                        # bool mask out
            "pallas_bytes": reads + 4 * ((cap + 31) // 32)   # bitset out
            + 4 * grid,                                      # popcounts
        }
    return per


def run(n_patients: int = 2_000, seed: int = 13, repeats: int = 3,
        block: int = 1024) -> List[Dict]:
    from repro.core import (
        DCIR_SCHEMA, PMSI_MCO_SCHEMA, drug_dispenses, medical_acts_dcir,
        medical_acts_pmsi,
    )
    from repro.data.synthetic import SyntheticConfig, generate_dcir, \
        generate_pmsi
    from repro.study import Study, assign_engines, execute
    import dataclasses

    cfg = SyntheticConfig(n_patients=n_patients, seed=seed)
    cases = [
        ("DCIR", DCIR_SCHEMA, generate_dcir(cfg),
         [("drugs", drug_dispenses()), ("acts", medical_acts_dcir())]),
        ("PMSI-MCO", PMSI_MCO_SCHEMA, generate_pmsi(cfg),
         [("hacts", medical_acts_pmsi())]),
    ]
    rows: List[Dict] = []
    for name, schema, tables, exts in cases:
        def build():
            s = Study(n_patients=cfg.n_patients).flatten(schema,
                                                         name=schema.name)
            for out_name, ex in exts:
                s.extract(dataclasses.replace(ex, source=schema.name),
                          name=out_name)
            return s

        plans = {
            eng: build().optimized_plan(tables=dict(tables),
                                        predicate_engine=eng)
            for eng in ("jnp", "pallas")
        }
        # re-stamp the pallas plan with the requested bitset block (the
        # optimizer pipeline stamps DEFAULT_BLOCK)
        plans["pallas"] = assign_engines(plans["pallas"],
                                         predicate_engine="pallas",
                                         block=block)
        n_masks = plans["pallas"].count_ops().get("fused_mask", 0)
        # byte accounting walks the jnp-stamped plan (same fused_mask set;
        # its eager evaluation must not run the interpret-mode kernel), with
        # the pallas plan's block size for the popcount term
        per = _mask_pass_bytes(plans["jnp"], dict(tables), block=block)
        b_jnp = sum(d["jnp_bytes"] for d in per.values())
        b_pal = sum(d["pallas_bytes"] for d in per.values())

        vals = {eng: execute(p, dict(tables)) for eng, p in plans.items()}
        parity = "pass"
        for out_name, _ in exts:
            a = vals["jnp"][plans["jnp"].output_ids[out_name]].to_numpy()
            b = vals["pallas"][plans["pallas"].output_ids[out_name]].to_numpy()
            if set(a) != set(b) or any((a[k] != b[k]).any() for k in a):
                parity = "FAIL"

        def timed(eng):
            fn = lambda: execute(plans[eng], dict(tables))
            fn()                                    # warm the jit cache
            return min(_timeit(fn) for _ in range(repeats))

        rows.append({
            "database": name,
            "fused_masks": n_masks,
            "mask_bytes_jnp": b_jnp,
            "mask_bytes_pallas": b_pal,
            "reduction": round(1 - b_pal / max(b_jnp, 1), 4),
            "per_mask": per,
            "jnp_s": round(timed("jnp"), 5),
            "pallas_s": round(timed("pallas"), 5),
            "interpret_mode": __import__("jax").default_backend() != "tpu",
            "parity": parity,
        })
    return rows


def main() -> None:
    import json

    print(json.dumps(run(), indent=2))


if __name__ == "__main__":
    main()
