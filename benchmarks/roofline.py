"""§Roofline: three-term analysis per (arch × shape) from the dry-run.

Terms (seconds, per step, per chip — TPU v5e constants):
  compute    = FLOPs / 197e12           (bf16 MXU peak)
  memory     = bytes_accessed / 819e9   (HBM bandwidth)
  collective = Σ collective result bytes × op_factor / 50e9  (ICI per link)

FLOPs / bytes / collectives come from the compiled per-device program, with
two corrections (both validated empirically, see dryrun.py):
  1. while-body scaling — XLA cost analysis counts a scan body once, so
     per-cell cost is reconstructed from the depth probes:
        X_total = X(probe0) + n_periods · (X(probe1) − X(probe0));
  2. time-scan layers (sLSTM) — the inner over-sequence scan is also counted
     once; an analytic (S−1)·step term is added (×3 for train: fwd+bwd+remat).

MODEL_FLOPS = 6·N_active·tokens; the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/attention/dispatch overhead per cell.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional

from repro.configs.archs import ARCHS
from repro.configs.base import SHAPES

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

# bytes a ring algorithm moves per device, as a multiple of the parsed
# (per-device) result-shape bytes
OP_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _coll_bytes(coll: Dict[str, Dict]) -> float:
    return sum(OP_FACTOR[k] * v["bytes"] for k, v in coll.items())


def _probe_pair(rec):
    lo, hi = rec.get("probe_levels", [0, 1])
    probes = rec.get("probes") or {}
    p_lo, p_hi = probes.get(f"p{lo}", {}), probes.get(f"p{hi}", {})
    if "error" in p_lo or "error" in p_hi or not p_lo or not p_hi:
        return None
    return lo, p_lo, p_hi


def _corrected(rec: Dict[str, Any], field: str) -> Optional[float]:
    """probe-corrected per-device cost for `field` in {flops, bytes_accessed}.

    total = f(lo) + (n_periods - lo) · max(f(hi) − f(lo), 0); negative deltas
    (partitioner noise at tiny decode scales) clamp to the measured f(hi).
    """
    pair = _probe_pair(rec)
    if pair is None:
        return None
    lo, p_lo, p_hi = pair
    npd = rec.get("n_periods", 0)
    delta = max(p_hi[field] - p_lo[field], 0.0)
    return p_lo[field] + (npd - lo) * delta


def _corrected_coll(rec: Dict[str, Any]) -> Optional[float]:
    pair = _probe_pair(rec)
    if pair is None:
        return None
    lo, p_lo, p_hi = pair
    npd = rec.get("n_periods", 0)
    delta = max(_coll_bytes(p_hi["collectives"]) - _coll_bytes(p_lo["collectives"]), 0.0)
    return _coll_bytes(p_lo["collectives"]) + (npd - lo) * delta


def _slstm_correction(arch: str, shape: str, chips: int) -> float:
    """Analytic (S-1)-step flops for the sLSTM time scan (per device)."""
    cfg = ARCHS[arch]
    if "slstm" not in cfg.pattern:
        return 0.0
    cell = SHAPES[shape]
    if cell.kind == "decode":
        return 0.0  # decode steps the scan once; probe already counts it
    n_slstm = cfg.n_layers * cfg.pattern.count("slstm") // len(cfg.pattern)
    H = cfg.n_heads
    hd = cfg.d_model // H
    b_loc = max(1, cell.global_batch // 16)  # data-axis sharding
    step_flops = 4 * 2 * b_loc * H * hd * hd
    factor = 3.0 if cell.kind == "train" else 1.0  # fwd+bwd+remat
    return n_slstm * (cell.seq_len - 1) * step_flops * factor


def analyze_cell(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if not rec.get("ok"):
        return None
    arch, shape = rec["arch"], rec["shape"]
    cfg = ARCHS[arch]
    cell = SHAPES[shape]
    chips = rec["chips"]

    flops = _corrected(rec, "flops")
    bytes_acc = _corrected(rec, "bytes_accessed")
    coll = _corrected_coll(rec)
    corrected = flops is not None
    # grad-accumulation wraps the loss in one more scan level: the probes see
    # the microbatch body once -> scale the in-scan costs by the trip count
    mb = rec.get("microbatches", 1)
    if corrected and mb > 1:
        flops *= mb
        bytes_acc *= mb
        coll *= mb
    if flops is None:
        flops = rec["cost"]["flops"]
        bytes_acc = rec["cost"]["bytes_accessed"]
        coll = _coll_bytes(rec["collectives"])
    flops += _slstm_correction(arch, shape, chips)

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    n_active = cfg.params_per_token()
    mult = 3.0 if cell.kind == "train" else 1.0  # fwd only vs fwd+bwd
    if cfg.is_encdec and cell.kind != "decode":
        # encoder runs over S/4 frames, decoder over S tokens: split N
        d, hd = cfg.d_model, cfg.head_dim_
        attn = d * (cfg.n_heads * hd) * 2 + d * (cfg.n_kv_heads * hd) * 2
        n_enc = cfg.n_encoder_layers * (attn + 3 * d * cfg.d_ff)
        n_dec = n_active - n_enc
        enc_tokens = cell.global_batch * max(64, cell.seq_len // 4)
        model_flops = 2.0 * mult * (n_dec * tokens + n_enc * enc_tokens) / chips
    else:
        model_flops = 2.0 * mult * n_active * tokens / chips  # per device
    ratio = model_flops / flops if flops else 0.0

    mem = rec.get("memory", {})
    hbm_gib = (mem.get("argument_bytes", 0) + mem.get("temp_bytes", 0)
               + mem.get("output_bytes", 0) - mem.get("alias_bytes", 0)) / 2**30
    return {
        "arch": arch, "shape": shape, "mesh": rec["mesh"], "chips": chips,
        "flops_per_dev": flops, "bytes_per_dev": bytes_acc,
        "coll_bytes_per_dev": coll,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_dev": model_flops, "useful_ratio": ratio,
        "hbm_gib": hbm_gib, "fits_16g": hbm_gib <= 16.0,
        "probe_corrected": corrected,
    }


def load_all(mesh: str = "16x16", dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, f"*__{mesh}.json"))):
        rec = json.load(open(f))
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "skipped": True,
                         "reason": rec.get("reason")})
            continue
        a = analyze_cell(rec)
        if a:
            rows.append(a)
    return rows


def render_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_coll | dominant | "
           "6ND/HLO | HBM GiB | fits |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped ({r['reason']}) | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} ms "
            f"| {r['t_memory_s']*1e3:.2f} ms | {r['t_collective_s']*1e3:.3f} ms "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['hbm_gib']:.1f} | {'y' if r['fits_16g'] else 'N'} |")
    return "\n".join(lines)


def run() -> List[Dict]:
    return load_all()


if __name__ == "__main__":
    rows = run()
    print(render_markdown(rows))
