"""Bitset-native validity benchmark: end-to-end mask-path bytes, bool-valid
baseline vs the packed-bitset table layout.

The seed layout carried ``ColumnarTable.valid`` as a bool column (1 byte/row)
that every mask-path node re-read and re-wrote; the bitset-native redesign
carries packed uint32 words (1 bit/row) end-to-end.  The acceptance metric
mirrors ``predicate_bench``'s byte-proxy style, but measured over the WHOLE
mask path of an optimized study plan — every node whose input/output crosses
HBM with a validity payload:

  * predicate/fused_mask nodes: read input validity, write the mask result;
  * ``compact``/``slice_time``: read the keep-mask, write the compacted
    front-run validity;
  * ``cohort_from_events``: read the event table's validity (the subject
    bitset it emits was packed in both layouts).

For each such node the bool-valid baseline moves ``capacity`` bytes per
validity read/write; the bitset layout moves ``4 * ceil(capacity/32)`` —
an 8x (87.5%) reduction of mask-path metadata bytes, on every validity
payload of the path rather than only the predicate output.  Column reads are
identical by construction and excluded.  Parity: the same plan executed with
the jnp and pallas predicate engines must produce bit-identical extracted
events — the gate fails otherwise, or if the bitset bytes fail to shrink.

Wall-clock for both engines is reported too — honestly: on CPU the kernels
run in *interpret mode* and are slower; the byte model is the TPU story.

Run:  PYTHONPATH=src python benchmarks/bitset_bench.py
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

# the mask path: nodes whose validity payload crosses HBM between kernels
_MASK_PATH_OPS = ("predicate", "drop_nulls", "value_filter", "fused_mask",
                  "compact", "slice_time", "cohort_from_events")


def _timeit(fn) -> float:
    import jax

    t0 = time.time()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out))
    return time.time() - t0


def _word_bytes(cap: int) -> int:
    return 4 * ((cap + 31) // 32)


def _mask_path_bytes(plan, tables) -> Dict[str, Dict[str, int]]:
    """Per-node validity-byte accounting over the actually-executed plan
    (capacities from an eager jnp evaluation, like the join-inflow proxy in
    pruning_bench)."""
    from repro.study.executor import run_plan_body
    from repro.study.plan import COHORT_OPS, TABLE_OPS

    env = {s: tables[s] for s in plan.sources()}
    vals, _, _ = run_plan_body(plan, env, 0, "xla", predicate_engine="jnp")
    per: Dict[str, Dict[str, int]] = {}
    for i, n in enumerate(plan.nodes):
        if n.op not in _MASK_PATH_OPS:
            continue
        caps_in = [vals[j].capacity for j in n.inputs
                   if plan.nodes[j].op in TABLE_OPS]
        cap_out = (vals[i].capacity
                   if n.op not in COHORT_OPS and n.op != "cohort_from_events"
                   else 0)  # cohort bitsets were packed in both layouts
        rw = caps_in + ([cap_out] if cap_out else [])
        per[f"#{i}:{n.op}"] = {
            "validity_payloads": len(rw),
            "bool_bytes": sum(rw),
            "bitset_bytes": sum(_word_bytes(c) for c in rw),
        }
    return per


def run(n_patients: int = 2_000, seed: int = 13, repeats: int = 3) -> List[Dict]:
    from repro.core import (
        DCIR_SCHEMA, PMSI_MCO_SCHEMA, drug_dispenses, medical_acts_dcir,
        medical_acts_pmsi,
    )
    from repro.data.synthetic import SyntheticConfig, generate_dcir, \
        generate_pmsi
    from repro.study import Study, execute
    import dataclasses

    cfg = SyntheticConfig(n_patients=n_patients, seed=seed)
    cases = [
        ("DCIR", DCIR_SCHEMA, generate_dcir(cfg),
         [("drugs", drug_dispenses()), ("acts", medical_acts_dcir())]),
        ("PMSI-MCO", PMSI_MCO_SCHEMA, generate_pmsi(cfg),
         [("hacts", medical_acts_pmsi())]),
    ]
    rows: List[Dict] = []
    for name, schema, tables, exts in cases:
        def build():
            s = Study(n_patients=cfg.n_patients).flatten(schema,
                                                         name=schema.name)
            for out_name, ex in exts:
                s.extract(dataclasses.replace(ex, source=schema.name),
                          name=out_name)
            for out_name, _ in exts:
                s.cohort(f"c_{out_name}", out_name)
            return s

        plans = {
            eng: build().optimized_plan(tables=dict(tables),
                                        predicate_engine=eng)
            for eng in ("jnp", "pallas")
        }
        per = _mask_path_bytes(plans["pallas"], dict(tables))
        b_bool = sum(d["bool_bytes"] for d in per.values())
        b_bits = sum(d["bitset_bytes"] for d in per.values())

        vals = {eng: execute(p, dict(tables)) for eng, p in plans.items()}
        parity = "pass"
        for out_name, _ in exts:
            a = vals["jnp"][plans["jnp"].output_ids[out_name]].to_numpy()
            b = vals["pallas"][plans["pallas"].output_ids[out_name]].to_numpy()
            if set(a) != set(b) or any((a[k] != b[k]).any() for k in a):
                parity = "FAIL"

        def timed(eng):
            fn = lambda: execute(plans[eng], dict(tables))
            fn()                                    # warm the jit cache
            return min(_timeit(fn) for _ in range(repeats))

        rows.append({
            "database": name,
            "mask_path_nodes": len(per),
            "mask_bytes_bool": b_bool,
            "mask_bytes_bitset": b_bits,
            "reduction": round(1 - b_bits / max(b_bool, 1), 4),
            "per_node": per,
            "jnp_s": round(timed("jnp"), 5),
            "pallas_s": round(timed("pallas"), 5),
            "interpret_mode": __import__("jax").default_backend() != "tpu",
            "parity": parity,
        })
    return rows


def main() -> None:
    import json

    print(json.dumps(run(), indent=2))


if __name__ == "__main__":
    main()
