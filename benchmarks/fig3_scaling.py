"""Paper Figure 3: extraction tasks (a)-(g) — horizontal scaling + the
normalized-SQL (SAS-Oracle stand-in) baseline.

Two reproductions:
  1. *Baseline comparison* (the paper's dashed lines): each task is run
     against (i) the SCALPEL3 flat columnar table (one up-front flatten) and
     (ii) the normalized star schema with joins at query time — isolating
     exactly the paper's variable.  Wall-clock on this container is
     meaningful here (same device, same data).
  2. *Horizontal scaling* (the solid lines): tasks re-run with the data
     row-sharded over n ∈ {1,2,4,8} forced host devices (subprocess).  The
     container has ONE physical core, so wall-clock cannot speed up; the
     scaling evidence reported is per-shard work (rows/bytes per executor ~
     1/n) plus wall time for transparency — EXPERIMENTS.md §Fig3 explains.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Callable, Dict, List

import jax
import numpy as np

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if SRC not in sys.path:
    sys.path.insert(0, SRC)

from repro.core import (  # noqa: E402
    DCIR_SCHEMA, PMSI_MCO_SCHEMA, diagnoses, drug_dispenses, exposures,
    flatten_star, fractures, hospital_stays, lookup_join, medical_acts_dcir,
    medical_acts_pmsi, patients, sort_events,
)
from repro.core.columnar import ColumnarTable  # noqa: E402
from repro.data.synthetic import SyntheticConfig, generate_dcir, generate_pmsi  # noqa: E402

TASKS = ("a_patients", "b_drugs", "c_prevalent", "d_exposures",
         "e_acts", "f_diagnoses", "g_fractures")


def _block(x):
    jax.block_until_ready(jax.tree.leaves(x))
    return x


def _time(fn: Callable, repeat: int = 3) -> float:
    fn()  # warmup/compile
    ts = []
    for _ in range(repeat):
        t0 = time.time()
        _block(fn())
        ts.append(time.time() - t0)
    return float(np.median(ts))


def make_tasks(cfg: SyntheticConfig, dcir, pmsi, flat_dcir, flat_pmsi,
               normalized: bool) -> Dict[str, Callable]:
    """Task set (a)-(g).  normalized=True re-joins the star schema inside
    every query (the SAS-Oracle execution model)."""
    P = cfg.n_patients

    def dcir_source():
        if not normalized:
            return flat_dcir
        return flatten_star(DCIR_SCHEMA, dcir)[0]   # join at query time

    def pmsi_source():
        if not normalized:
            return flat_pmsi
        return flatten_star(PMSI_MCO_SCHEMA, pmsi)[0]

    prevalent_codes = list(range(65))

    def c_prevalent():
        drugs = drug_dispenses(codes=prevalent_codes)(dcir_source())
        from repro.core.transformers import observation_period
        first = observation_period(drugs, P)
        return first.filter(first.columns["start"] < 14_600 + 365)

    def g_fract():
        acts = medical_acts_dcir()(dcir_source())
        diag = diagnoses()(pmsi_source())
        return fractures(acts, diag, list(range(30)), list(range(40)))

    return {
        "a_patients": lambda: patients(dcir["IR_BEN"]),
        "b_drugs": lambda: drug_dispenses()(dcir_source()),
        "c_prevalent": c_prevalent,
        "d_exposures": lambda: exposures(
            drug_dispenses()(dcir_source()), P, purview_days=60),
        "e_acts": lambda: medical_acts_pmsi()(pmsi_source()),
        "f_diagnoses": lambda: diagnoses()(pmsi_source()),
        "g_fractures": g_fract,
    }


def run_baseline(n_patients: int = 2_000, seed: int = 0) -> List[Dict]:
    """Reproduction 1: flat-columnar vs normalized-join per task."""
    cfg = SyntheticConfig(n_patients=n_patients, seed=seed)
    dcir, pmsi = generate_dcir(cfg), generate_pmsi(cfg)
    flat_dcir, _ = flatten_star(DCIR_SCHEMA, dcir)
    flat_pmsi, _ = flatten_star(PMSI_MCO_SCHEMA, pmsi)
    rows = []
    scalpel = make_tasks(cfg, dcir, pmsi, flat_dcir, flat_pmsi, normalized=False)
    sqlish = make_tasks(cfg, dcir, pmsi, flat_dcir, flat_pmsi, normalized=True)
    for name in TASKS:
        t_flat = _time(scalpel[name])
        t_norm = _time(sqlish[name])
        rows.append({
            "task": name,
            "scalpel3_s": round(t_flat, 4),
            "normalized_join_s": round(t_norm, 4),
            "speedup": round(t_norm / max(t_flat, 1e-9), 2),
        })
    return rows


_WORKER = r"""
import json, os, sys, time
sys.path.insert(0, {src!r})
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import (DCIR_SCHEMA, flatten_star, drug_dispenses,
                        medical_acts_dcir, exposures)
from repro.data.synthetic import SyntheticConfig, generate_dcir

n = {n_shards}
cfg = SyntheticConfig(n_patients={n_patients}, seed=0)
dcir = generate_dcir(cfg)
flat, _ = flatten_star(DCIR_SCHEMA, dcir)
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
sh = NamedSharding(mesh, P("data"))
rep = NamedSharding(mesh, P())
cap = -(-flat.capacity // n) * n
flat = flat.pad_to(cap)
flat = jax.tree.map(
    lambda x: jax.device_put(x, sh if getattr(x, "ndim", 0) >= 1 else rep), flat)

ext = drug_dispenses()
acts = medical_acts_dcir()
def task_b(t): return ext(t, compact=False)
def task_e(t): return acts(t, compact=False)
def task_d(t): return exposures(ext(t, compact=False), cfg.n_patients, 60)

out = {{}}
for name, fn in (("b_drugs", task_b), ("e_acts", task_e), ("d_exposures", task_d)):
    jfn = jax.jit(fn)
    r = jfn(flat); jax.block_until_ready(jax.tree.leaves(r))
    ts = []
    for _ in range(3):
        t0 = time.time(); r = jfn(flat); jax.block_until_ready(jax.tree.leaves(r))
        ts.append(time.time() - t0)
    c = jfn.lower(flat).compile()
    ca = c.cost_analysis() or {{}}
    out[name] = {{
        "wall_s": float(np.median(ts)),
        "per_device_flops": float(ca.get("flops", 0.0)),
        "per_device_bytes": float(ca.get("bytes accessed", 0.0)),
    }}
print(json.dumps(out))
"""


def run_scaling(n_patients: int = 2_000,
                shard_counts=(1, 2, 4, 8)) -> List[Dict]:
    """Reproduction 2: per-executor work vs shard count (subprocess/forced
    devices; see module docstring for the 1-core caveat)."""
    rows = []
    for n in shard_counts:
        code = _WORKER.format(src=SRC, n_shards=n, n_patients=n_patients)
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = SRC
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        if out.returncode != 0:
            rows.append({"shards": n, "error": out.stderr[-500:]})
            continue
        data = json.loads(out.stdout.strip().splitlines()[-1])
        for task, d in data.items():
            rows.append({"shards": n, "task": task, **{
                k: (round(v, 4) if isinstance(v, float) else v)
                for k, v in d.items()}})
    return rows


if __name__ == "__main__":
    print("== baseline (flat vs normalized-join) ==")
    for r in run_baseline():
        print(r)
    print("== scaling ==")
    for r in run_scaling():
        print(r)
