"""Fused study-plan execution vs the eager per-extractor path.

The claim behind ``repro.study`` (ISSUE 1 tentpole): N extractors over one
flat table cost N projection→mask→compaction passes when run eagerly, but one
shared scan + fused masks + one XLA program when run as a Plan.  This bench
measures both on the synthetic DCIR table, with jit/compile warmed for BOTH
paths so the delta is execution, not tracing.

Run:  PYTHONPATH=src python benchmarks/study_plan_bench.py
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax


def _extractors():
    from repro.core import (
        biology_acts, drug_dispenses, medical_acts_dcir, practitioner_encounters,
    )

    return [
        ("drugs", drug_dispenses()),
        ("drugs_atc", drug_dispenses(granularity="atc")),
        ("acts", medical_acts_dcir()),
        ("bio", biology_acts()),
        ("enc_med", practitioner_encounters(medical=True)),
        ("enc_other", practitioner_encounters(medical=False)),
    ]


def _block(outs) -> None:
    jax.block_until_ready([t.count for t in outs])


def run(n_patients: int = 2_000, repeats: int = 10, engine: str = "xla") -> List[Dict]:
    from repro.core import DCIR_SCHEMA, flatten_star
    from repro.data.synthetic import SyntheticConfig, generate_dcir
    from repro.study import Study

    cfg = SyntheticConfig(n_patients=n_patients, seed=7)
    dcir = generate_dcir(cfg)
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    exts = _extractors()

    def eager_once():
        return [ex(flat, engine=engine) for _, ex in exts]

    def build_study() -> Study:
        s = Study(n_patients=n_patients)
        for name, ex in exts:
            s.extract(ex, name=name)
        return s

    study = build_study()
    tables = {"DCIR": flat}

    # warm both paths (jit compile excluded from timing)
    _block(eager_once())
    res = study.run(tables, engine=engine)
    _block(list(res.events.values()))

    t0 = time.perf_counter()
    for _ in range(repeats):
        _block(eager_once())
    eager_s = (time.perf_counter() - t0) / repeats

    t0 = time.perf_counter()
    for _ in range(repeats):
        r = study.run(tables, engine=engine)
        _block(list(r.events.values()))
    fused_s = (time.perf_counter() - t0) / repeats

    opt = study.optimized_plan()
    ops = opt.count_ops()
    eager_ops: Dict[str, int] = {}
    for _, ex in exts:
        from repro.study.plan import PlanBuilder

        b = PlanBuilder()
        ex.contribute(b)
        for n in b.build().nodes:
            eager_ops[n.op] = eager_ops.get(n.op, 0) + 1

    rows = [
        {
            "name": f"eager_{len(exts)}x",
            "seconds": eager_s,
            "derived": f"scans={eager_ops.get('scan', 0)} "
                       f"mask_nodes={eager_ops.get('predicate', 0)}",
        },
        {
            "name": f"fused_plan_{len(exts)}x",
            "seconds": fused_s,
            "derived": f"scans={ops.get('scan', 0)} mask_nodes={ops.get('fused_mask', 0)} "
                       f"compactions={ops.get('compact', 0)} "
                       f"speedup={eager_s / fused_s:.2f}x",
        },
    ]
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--n-patients", type=int, default=2_000)
    ap.add_argument("--repeats", type=int, default=10)
    ap.add_argument("--engine", default="xla", choices=("xla", "pallas"))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for r in run(args.n_patients, args.repeats, args.engine):
        print(f"study_plan.{r['name']},{r['seconds'] * 1e6:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
