"""Join-aware column pruning benchmark: bytes materialized into each join.

The proxy metric is the acceptance metric of the pruning pass: the sum of
column sizes (allocated bytes, capacity x itemsize) *entering* every
lookup_join/expand_join of the flattening chain.  Pruning narrows the star
scans to the columns some extractor actually reads, so on the synthetic star
schemas the pruned plan must feed strictly fewer bytes into the joins than
the unpruned baseline — the CI gate fails otherwise — while producing
bit-identical extracted events (parity-checked here too).

Run:  PYTHONPATH=src python benchmarks/pruning_bench.py
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _table_bytes(t) -> int:
    return sum(np.asarray(c).nbytes for c in t.columns.values())


def _join_inflow_bytes(plan, tables) -> Dict[str, int]:
    """Execute the plan body eagerly and sum the allocated bytes of every
    table flowing into each join node."""
    from repro.study.executor import run_plan_body
    from repro.study.plan import JOIN_OPS

    env = {s: tables[s] for s in plan.sources()}
    vals, _, _ = run_plan_body(plan, env, 0, "xla")
    per: Dict[str, int] = {}
    for i, n in enumerate(plan.nodes):
        if n.op in JOIN_OPS:
            per[f"#{i}:{n.op}{n.get('name') or ''}"] = sum(
                _table_bytes(vals[j]) for j in n.inputs)
    return per


def run(n_patients: int = 2_000, seed: int = 9, repeats: int = 3) -> List[Dict]:
    from repro.core import (
        DCIR_SCHEMA, PMSI_MCO_SCHEMA, drug_dispenses, medical_acts_dcir,
        medical_acts_pmsi,
    )
    from repro.data.synthetic import SyntheticConfig, generate_dcir, generate_pmsi
    from repro.study import Study, execute, optimize

    cfg = SyntheticConfig(n_patients=n_patients, seed=seed)
    cases = [
        ("DCIR", DCIR_SCHEMA, generate_dcir(cfg),
         [("drugs", drug_dispenses()), ("acts", medical_acts_dcir())]),
        ("PMSI-MCO", PMSI_MCO_SCHEMA, generate_pmsi(cfg),
         [("hacts", medical_acts_pmsi())]),
    ]
    rows: List[Dict] = []
    for name, schema, tables, exts in cases:
        def build():
            s = Study(n_patients=cfg.n_patients).flatten(schema,
                                                         name=schema.name)
            for out_name, ex in exts:
                import dataclasses

                s.extract(dataclasses.replace(ex, source=schema.name),
                          name=out_name)
            return s

        study = build()
        pruned = study.optimized_plan(tables=dict(tables))
        unpruned = optimize(study.plan(), tables=dict(tables),
                            prune_cols=False)

        per_pruned = _join_inflow_bytes(pruned, dict(tables))
        per_unpruned = _join_inflow_bytes(unpruned, dict(tables))
        b_pruned, b_unpruned = sum(per_pruned.values()), sum(per_unpruned.values())

        # parity: pruning must not change any extracted event table
        v_pruned = execute(pruned, dict(tables))
        v_unpruned = execute(unpruned, dict(tables))
        parity = "pass"
        for out_name, _ in exts:
            a = v_pruned[pruned.output_ids[out_name]].to_numpy()
            b = v_unpruned[unpruned.output_ids[out_name]].to_numpy()
            if set(a) != set(b) or any((a[k] != b[k]).any() for k in a):
                parity = "FAIL"

        def timed(plan):
            fn = lambda: execute(plan, dict(tables))
            fn()                                   # warm the jit cache
            best = min(_timeit(fn) for _ in range(repeats))
            return best

        rows.append({
            "database": name,
            "join_bytes_unpruned": b_unpruned,
            "join_bytes_pruned": b_pruned,
            "reduction": round(1 - b_pruned / max(b_unpruned, 1), 4),
            "per_join_pruned": per_pruned,
            "per_join_unpruned": per_unpruned,
            "pruned_s": round(timed(pruned), 5),
            "unpruned_s": round(timed(unpruned), 5),
            "parity": parity,
        })
    return rows


def _timeit(fn) -> float:
    import jax

    t0 = time.time()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out))
    return time.time() - t0


def main() -> None:
    import json

    rows = run()
    print(json.dumps(rows, indent=2))


if __name__ == "__main__":
    main()
