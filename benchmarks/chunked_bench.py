"""Out-of-core chunked execution vs the resident path: prefetch overlap +
bit-identity under a partitioned star.

Workload: one flatten/extract/cohort/featurize Study, run three ways over
the same synthetic DCIR star —

* **resident** — the ordinary ``Study.run`` over device-resident tables
  (the reference result);
* **serial**   — ``ChunkedExecutor(prefetch=False)``: load chunk i, run
  chunk i, load chunk i+1 ... (the load-then-execute baseline);
* **pipelined** — ``ChunkedExecutor(prefetch=True)``: a one-worker thread
  loads + stages chunk i+1 while the jitted program for chunk i runs.

The store is written compressed so the load leg prices what an out-of-core
run actually pays (disk read + inflate + device staging).  Measured: wall
clock of both chunked loops (best of ``repeats``), the executor's own
load/exec split, and compile count across all chunks.  The acceptance bars
(enforced by ``run.py --smoke``): pipelined wall < the same run's
``load_s + exec_s`` — the no-overlap accounting; wall can only undercut the
sum of its own two legs if they genuinely ran concurrently — exactly ONE
compile for the whole stream, and the merged chunked result bit-identical
to the resident run: cohort words, event valid-rows and feature tensors.
The measured ``prefetch=False`` wall is reported alongside
(``serial_run_s``) but NOT gated: on a CPU smoke host the jitted program
already saturates every core and the store sits in page cache, so the
serial/pipelined delta there is scheduler noise, not the disk-latency
overlap this engine exists to hide.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import DCIR_SCHEMA, drug_dispenses
from repro.data import partition_star
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.study import Study, clear_jit_cache
from repro.study.chunked import ChunkedExecutor

WORD = 32


def _build(n_patients: int) -> Study:
    return (Study(n_patients=n_patients)
            .flatten(DCIR_SCHEMA)
            .extract(drug_dispenses(), name="drugs")
            .patients("IR_BEN")
            .cohort("base", "extract_patients")
            .cohort("drugged", "drugs")
            .cohort("final", "drugged & base")
            .featurize("X", cohort="final", kind="dense",
                       n_buckets=12, bucket_days=31, n_features=64))


def _same(a, b) -> bool:
    if set(a.events) != set(b.events) or set(a.cohorts) != set(b.cohorts):
        return False
    for k in a.cohorts:
        if not np.array_equal(np.asarray(a.cohorts[k].subjects),
                              np.asarray(b.cohorts[k].subjects)):
            return False
    for k in a.events:
        ta, tb = a.events[k], b.events[k]
        if int(ta.count) != int(tb.count):
            return False
        # valid ROWS in order — capacities (padding) differ by design
        ra, rb = ta.to_numpy(), tb.to_numpy()
        if set(ra) != set(rb) or any(not np.array_equal(ra[c], rb[c])
                                     for c in ra):
            return False
    la, lb = jax.tree.leaves(a.features), jax.tree.leaves(b.features)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(u), np.asarray(v))
        for u, v in zip(la, lb))


def run(n_patients: int = 2_000, repeats: int = 3, seed: int = 13,
        target_chunks: int = 12) -> List[Dict]:
    tables = generate_dcir(SyntheticConfig(n_patients=n_patients, seed=seed))
    src_cap = tables["ER_PRS"].capacity
    chunk_capacity = max(WORD,
                         -(-src_cap // (target_chunks * WORD)) * WORD)

    t0 = time.perf_counter()
    resident = _build(n_patients).run(dict(tables))
    resident_s = time.perf_counter() - t0

    tmp = tempfile.mkdtemp(prefix="chunked_bench_")
    try:
        store = partition_star(tables, os.path.join(tmp, "store"),
                               source="ER_PRS",
                               chunk_capacity=chunk_capacity,
                               compressed=True)

        # cold run: the one compile the whole stream is allowed
        clear_jit_cache()
        cold = ChunkedExecutor(store, prefetch=True)
        res_cold = cold.run(_build(n_patients))
        compiles = cold.report.compiles
        parity = _same(resident, res_cold)

        best: Dict[str, Dict] = {}
        for prefetch, name in ((False, "serial"), (True, "pipelined")):
            for _ in range(repeats):
                ex = ChunkedExecutor(store, prefetch=prefetch)
                res = ex.run(_build(n_patients))
                rep = ex.report.to_json()
                if name not in best or rep["wall_s"] < best[name]["wall_s"]:
                    best[name] = rep
            parity = parity and _same(resident, res)
        pipe, ser = best["pipelined"], best["serial"]
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    return [{
        "name": "stream",
        "n_patients": n_patients,
        "n_chunks": store.n_chunks,
        "chunk_capacity": chunk_capacity,
        "rows": pipe["rows"],
        "repeats": repeats,
        "resident_s": round(resident_s, 4),
        "compiles": compiles,
        "load_s": round(pipe["load_s"], 4),
        "exec_s": round(pipe["exec_s"], 4),
        "serial_s": round(pipe["serial_s"], 4),       # load+exec, no overlap
        "serial_run_s": round(ser["wall_s"], 4),      # measured, not gated
        "pipelined_s": round(pipe["wall_s"], 4),
        "overlap_saved_s": round(pipe["overlap_saved_s"], 4),
        "speedup": round(pipe["serial_s"] / pipe["wall_s"], 2)
        if pipe["wall_s"] else 0.0,
        "parity": "pass" if parity else "FAIL",
    }]


def main() -> None:
    import json

    print(json.dumps(run(n_patients=500, repeats=2), indent=2))


if __name__ == "__main__":
    main()
