"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * table1.*      — dataset characteristics (paper Table 1)
  * fig3.*        — extraction tasks vs the normalized-join baseline +
                    horizontal-scaling evidence (paper Figure 3)
  * flatten.*     — SCALPEL-Flattening throughput (paper §4)
  * roofline.*    — per-cell dry-run roofline summary (§Roofline), if the
                    dry-run matrix artifacts exist
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_table1() -> None:
    from benchmarks import table1_dataset

    for r in table1_dataset.run(n_patients=2_000):
        _emit(
            f"table1.{r['database']}",
            r["flatten_seconds"] * 1e6,
            f"rows={r['rows_central']}->{r['rows_denormalized']} "
            f"csv/columnar={r['csv_over_columnar']}x",
        )


def bench_fig3() -> None:
    from benchmarks import fig3_scaling

    for r in fig3_scaling.run_baseline(n_patients=2_000):
        _emit(
            f"fig3.baseline.{r['task']}",
            r["scalpel3_s"] * 1e6,
            f"normalized_join={r['normalized_join_s']}s speedup={r['speedup']}x",
        )
    for r in fig3_scaling.run_scaling(n_patients=2_000, shard_counts=(1, 2, 4)):
        if "error" in r:
            _emit(f"fig3.scaling.shards{r['shards']}", 0.0, "ERROR")
            continue
        _emit(
            f"fig3.scaling.{r['task']}.shards{r['shards']}",
            r["wall_s"] * 1e6,
            f"per_dev_bytes={r['per_device_bytes']:.3g} "
            f"per_dev_flops={r['per_device_flops']:.3g}",
        )


def bench_flattening() -> None:
    from benchmarks import flattening_bench

    for r in flattening_bench.run(n_patients=4_000):
        _emit(
            f"flatten.{r['database']}",
            r["flatten_s"] * 1e6,
            f"rows_per_s={r.get('rows_per_s')} mb_per_s={r.get('mb_per_s', '')}",
        )


def bench_flatten_plan(n_patients: int = 4_000, repeats: int = 5) -> None:
    """Plan-level Study.flatten vs eager flatten_star (parity-checked)."""
    from benchmarks import flattening_bench

    for r in flattening_bench.run_plan_vs_eager(n_patients=n_patients,
                                                repeats=repeats):
        _emit(
            f"flatten_plan.{r['database']}",
            r["plan_s"] * 1e6,
            f"eager_us={r['eager_s'] * 1e6:.1f} "
            f"plan/eager={r['plan_over_eager']} "
            f"cap={r['plan_capacity']}/{r['eager_capacity']} "
            f"parity={r['parity']}",
        )
        if r["parity"] != "pass":
            raise SystemExit(
                f"flatten_plan.{r['database']}: plan/eager row-set parity "
                "FAILED — the plan path diverged from eager flatten_star")


def bench_pruning(n_patients: int = 2_000, repeats: int = 3) -> None:
    """Column pruning gate: the pruned plan must feed strictly fewer bytes
    into the flatten joins than the unpruned baseline (bytes-materialized
    proxy: sum of column sizes entering each join), with event parity.
    Emits ``BENCH_pruning.json`` next to the working directory."""
    import json

    from benchmarks import pruning_bench

    rows = pruning_bench.run(n_patients=n_patients, repeats=repeats)
    with open("BENCH_pruning.json", "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        _emit(
            f"pruning.{r['database']}",
            r["pruned_s"] * 1e6,
            f"join_bytes={r['join_bytes_pruned']}/{r['join_bytes_unpruned']} "
            f"reduction={r['reduction']} parity={r['parity']}",
        )
        if r["parity"] != "pass":
            raise SystemExit(
                f"pruning.{r['database']}: pruned/unpruned event parity "
                "FAILED — column pruning changed extractor results")
        if r["join_bytes_pruned"] >= r["join_bytes_unpruned"]:
            raise SystemExit(
                f"pruning.{r['database']}: pruning did not reduce the bytes "
                f"materialized into the joins "
                f"({r['join_bytes_pruned']} >= {r['join_bytes_unpruned']})")


def bench_predicate(n_patients: int = 2_000, repeats: int = 3) -> None:
    """Fused-predicate gate: the Pallas Expr->bitset kernel must beat the
    jnp mask algebra on mask-pass bytes (bitset out = 1 bit/row vs bool out
    = 1 byte/row; column reads identical) for every fused_mask of the
    pipeline, with bit-identical extracted events.  Emits
    ``BENCH_predicate.json``."""
    import json

    from benchmarks import predicate_bench

    rows = predicate_bench.run(n_patients=n_patients, repeats=repeats)
    with open("BENCH_predicate.json", "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        _emit(
            f"predicate.{r['database']}",
            r["pallas_s"] * 1e6,
            f"jnp_us={r['jnp_s'] * 1e6:.1f} "
            f"mask_bytes={r['mask_bytes_pallas']}/{r['mask_bytes_jnp']} "
            f"reduction={r['reduction']} masks={r['fused_masks']} "
            f"parity={r['parity']}",
        )
        if r["parity"] != "pass":
            raise SystemExit(
                f"predicate.{r['database']}: jnp/pallas event parity FAILED "
                "— the bitset kernel diverged from the jnp mask path")
        if r["mask_bytes_pallas"] >= r["mask_bytes_jnp"]:
            raise SystemExit(
                f"predicate.{r['database']}: fused kernel did not reduce "
                f"mask-pass bytes ({r['mask_bytes_pallas']} >= "
                f"{r['mask_bytes_jnp']})")


def bench_bitset(n_patients: int = 2_000, repeats: int = 3) -> None:
    """Bitset-native validity gate: the packed-word table layout must shrink
    the end-to-end mask-path validity bytes (predicate -> cohort ->
    compaction) vs the seed's bool-column baseline, with bit-identical
    extracted events across the jnp/pallas predicate engines.  Emits
    ``BENCH_bitset.json``."""
    import json

    from benchmarks import bitset_bench

    rows = bitset_bench.run(n_patients=n_patients, repeats=repeats)
    with open("BENCH_bitset.json", "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        _emit(
            f"bitset.{r['database']}",
            r["pallas_s"] * 1e6,
            f"jnp_us={r['jnp_s'] * 1e6:.1f} "
            f"mask_bytes={r['mask_bytes_bitset']}/{r['mask_bytes_bool']} "
            f"reduction={r['reduction']} nodes={r['mask_path_nodes']} "
            f"parity={r['parity']}",
        )
        if r["parity"] != "pass":
            raise SystemExit(
                f"bitset.{r['database']}: jnp/pallas event parity FAILED "
                "— bitset-native validity diverged between mask engines")
        if r["mask_bytes_bitset"] >= r["mask_bytes_bool"]:
            raise SystemExit(
                f"bitset.{r['database']}: packed validity did not reduce "
                f"mask-path bytes ({r['mask_bytes_bitset']} >= "
                f"{r['mask_bytes_bool']})")


def bench_serving(n_patients: int = 2_000, n_queries: int = 32) -> None:
    """Cohort-query-service gate: under a mixed multi-tenant workload the
    service must (a) stay bit-identical to solo runs — local sync, local
    pipelined, AND sharded, (b) compile at most one executable per plan
    shape on both paths — vs one per query naively, (c) serve at least
    half the cacheable subgraphs from the cross-tenant cache, (d) beat the
    sequential naive wall-clock, (e) pipeline: the async submit/realize
    warm-serve wall must beat its own no-overlap accounting
    (submit_s + realize_s for the same timed serve — realization provably
    hidden behind submission; the measured synchronous wall is reported
    but not gated, as on the core-saturated CPU smoke host the wall race
    is noise — same caveat as ``bench_chunked``), and (f) record ZERO
    engine demotions — hoisted literals ride as Pallas kernel operands,
    for the served queries and the golden example plans alike.  Emits
    ``BENCH_serving.json``."""
    import json

    from benchmarks import serving_bench

    rows = serving_bench.run(n_patients=n_patients, n_queries=n_queries)
    with open("BENCH_serving.json", "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        _emit(
            f"serving.{r['name']}",
            r["service_total_s"] * 1e6,
            f"naive_s={r['naive_total_s']} "
            f"serve_s={r['service_serve_s']}/{r['service_sync_serve_s']} "
            f"speedup={r['speedup']}x pipeline={r['pipeline_speedup']}x "
            f"serve_overlap_s={r['serve_overlap_s']} "
            f"compiles={r['service_compiles']}/{r['naive_compiles']} "
            f"sharded_compiles={r['sharded_compiles']} "
            f"hit_rate={r['hit_rate']} p50={r['service_p50_s']}s "
            f"p95={r['service_p95_s']}s demotions={r['demotions']} "
            f"parity={r['parity']}/{r['sharded_parity']}",
        )
        if r["parity"] != "pass":
            raise SystemExit(
                f"serving.{r['name']}: service/solo result parity FAILED — "
                "served queries diverged from solo Study.run")
        if r["sharded_parity"] != "pass":
            raise SystemExit(
                f"serving.{r['name']}: sharded service parity FAILED — "
                "shard_map-served queries diverged from solo Study.run")
        if not (r["service_compiles"] <= r["n_shapes"]
                < r["naive_compiles"]):
            raise SystemExit(
                f"serving.{r['name']}: shared-plan reuse did not cut "
                f"compiles ({r['service_compiles']} executables for "
                f"{r['n_queries']} queries vs naive {r['naive_compiles']})")
        if r["sharded_compiles"] > r["n_shapes"]:
            raise SystemExit(
                f"serving.{r['name']}: sharded path compiled "
                f"{r['sharded_compiles']} executables for "
                f"{r['n_shapes']} normalized shapes — plan-normalized "
                "sharing is broken under shard_map")
        if r["hit_rate"] < 0.5:
            raise SystemExit(
                f"serving.{r['name']}: subgraph-cache hit rate "
                f"{r['hit_rate']} < 0.5")
        if r["service_total_s"] >= r["naive_total_s"]:
            raise SystemExit(
                f"serving.{r['name']}: service wall-clock did not beat the "
                f"sequential naive path ({r['service_total_s']}s >= "
                f"{r['naive_total_s']}s)")
        if r["service_serve_s"] >= (r["serve_submit_s"]
                                    + r["serve_realize_s"]):
            raise SystemExit(
                f"serving.{r['name']}: async pipeline did not overlap — "
                f"warm-serve wall {r['service_serve_s']}s >= no-overlap "
                f"accounting {r['serve_submit_s']}s + "
                f"{r['serve_realize_s']}s; realization is not being "
                "hidden behind device submission")
        if r["demotions"] or r["golden_demotions"]:
            raise SystemExit(
                f"serving.{r['name']}: engine demotions recorded "
                f"(served={r['demotions']}, "
                f"golden={r['golden_demotions']}) — hoisted literals must "
                "stay on the Pallas kernel path")


def bench_chunked(n_patients: int = 2_000, repeats: int = 3) -> None:
    """Out-of-core gate: streaming the partitioned star through the chunked
    executor must (a) merge to a result bit-identical to the resident run —
    cohort words, event valid-rows, feature tensors, (b) compile exactly
    ONE executable for the whole chunk stream, and (c) overlap load with
    execution: pipelined wall < the same run's load_s + exec_s, the
    no-overlap accounting (the measured prefetch=False wall is reported
    but not gated — see ``chunked_bench`` docstring).  Emits
    ``BENCH_chunked.json``."""
    import json

    from benchmarks import chunked_bench

    rows = chunked_bench.run(n_patients=n_patients, repeats=repeats)
    with open("BENCH_chunked.json", "w") as f:
        json.dump(rows, f, indent=2)
    for r in rows:
        _emit(
            f"chunked.{r['name']}",
            r["pipelined_s"] * 1e6,
            f"serial_s={r['serial_s']} serial_run_s={r['serial_run_s']} "
            f"saved={r['overlap_saved_s']}s speedup={r['speedup']}x "
            f"chunks={r['n_chunks']} compiles={r['compiles']} "
            f"resident_s={r['resident_s']} parity={r['parity']}",
        )
        if r["parity"] != "pass":
            raise SystemExit(
                f"chunked.{r['name']}: chunked/resident parity FAILED — "
                "the merged chunk stream diverged from the resident run")
        if r["compiles"] != 1:
            raise SystemExit(
                f"chunked.{r['name']}: expected ONE compile across "
                f"{r['n_chunks']} chunks, saw {r['compiles']}")
        if r["pipelined_s"] >= r["serial_s"]:
            raise SystemExit(
                f"chunked.{r['name']}: prefetch overlap did not beat serial "
                f"load-then-execute accounting ({r['pipelined_s']}s wall >= "
                f"{r['serial_s']}s load+exec — the legs never overlapped)")


def bench_analyze() -> None:
    """Static-analysis gate: the golden example plans must be free of
    error/warn diagnostics under both predicate engines, and every seeded
    defect fixture must trip exactly its registered code — the same
    contract ``tools/plan_lint.py`` enforces, wired into the smoke run so
    a broken analyzer (or a newly-dirty golden plan) fails CI twice."""
    import time

    from repro.study.analyze import DIAGNOSTIC_CODES, analyze, \
        format_diagnostics
    from repro.study.defects import all_defects, golden_studies

    for name, study in golden_studies().items():
        for engine in ("pallas", "jnp"):
            plan = study.optimized_plan(predicate_engine=engine)
            t0 = time.perf_counter()
            diags = analyze(plan, n_patients=study.n_patients)
            us = (time.perf_counter() - t0) * 1e6
            bad = [d for d in diags if d.severity in ("error", "warn")]
            _emit(f"analyze.{name}.{engine}", us,
                  f"nodes={len(plan.nodes)} diags={len(diags)} "
                  f"error_warn={len(bad)}")
            if bad:
                raise SystemExit(
                    f"analyze.{name}.{engine}: golden plan carries "
                    f"error/warn diagnostics:\n{format_diagnostics(bad)}")
    missed = [code for code, plan, kwargs in all_defects()
              if not any(d.code == code for d in analyze(plan, **kwargs))]
    _emit("analyze.defects", 0.0,
          f"fired={len(DIAGNOSTIC_CODES) - len(missed)}"
          f"/{len(DIAGNOSTIC_CODES)}")
    if missed:
        raise SystemExit(
            f"analyze.defects: seeded defects not detected: {missed}")


def bench_spec(n: int = 24, n_patients: int = 300) -> None:
    """Declarative-front-end gate: a fixed-seed fuzz corpus must show 100%
    parity (every valid spec executes identically under jnp, pallas and the
    chunked path, emptiness verdicts cross-checked) and 100% rejection
    (every catalog mutation refused with its exact SPEC code); the golden
    wire specs must round-trip onto the golden plans under both engines.
    Emits ``BENCH_spec.json``."""
    import json
    import time

    from repro.study.defects import golden_studies
    from repro.study.fuzz import run_corpus
    from repro.study.spec import compile_spec, spec_from_study

    t0 = time.perf_counter()
    for name, study in golden_studies().items():
        rebuilt = compile_spec(spec_from_study(study))
        for engine in ("pallas", "jnp"):
            if (rebuilt.optimized_plan(predicate_engine=engine).key()
                    != study.optimized_plan(predicate_engine=engine).key()):
                raise SystemExit(
                    f"spec.roundtrip.{name}.{engine}: wire spec does not "
                    f"rebuild the golden plan")
    _emit("spec.roundtrip", (time.perf_counter() - t0) * 1e6,
          f"goldens={len(golden_studies())} engines=2")

    t0 = time.perf_counter()
    report = run_corpus(n=n, seed=0, n_patients=n_patients)
    dt = time.perf_counter() - t0
    with open("BENCH_spec.json", "w") as f:
        json.dump(dict(report.to_json(), elapsed_s=round(dt, 2)), f, indent=2)
    _emit("spec.fuzz", dt * 1e6 / max(1, n),
          f"n={report.n} valid={report.n_valid} mutated={report.n_mutated} "
          f"sp003={report.n_sp003} sp014={report.n_sp014} "
          f"gated={report.n_chunk_gated} failures={len(report.failures)}")
    if not report.ok:
        raise SystemExit("spec.fuzz: differential corpus failed:\n"
                         + report.summary())
    if report.n_valid + report.n_mutated != n:
        raise SystemExit(
            f"spec.fuzz: only {report.n_valid}+{report.n_mutated} of {n} "
            f"specs reached a verdict")


def bench_study(n_patients: int = 2_000, repeats: int = 8) -> None:
    from benchmarks import study_plan_bench

    for r in study_plan_bench.run(n_patients=n_patients, repeats=repeats):
        _emit(f"study_plan.{r['name']}", r["seconds"] * 1e6, r["derived"])


def bench_roofline() -> None:
    from benchmarks import roofline

    rows = roofline.run()
    if not rows:
        _emit("roofline", 0.0, "dry-run artifacts missing (run launch.dryrun)")
        return
    for r in rows:
        if r.get("skipped"):
            _emit(f"roofline.{r['arch']}.{r['shape']}", 0.0, "skipped")
            continue
        dom_t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        _emit(
            f"roofline.{r['arch']}.{r['shape']}",
            dom_t * 1e6,
            f"dominant={r['dominant']} ratio={r['useful_ratio']:.2f} "
            f"hbm={r['hbm_gib']:.1f}GiB",
        )


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small synthetic dataset, plan-executor coverage "
                    "only — the CI regression gate")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        bench_table1()
        bench_flatten_plan(n_patients=500, repeats=2)
        bench_pruning(n_patients=500, repeats=2)
        bench_predicate(n_patients=500, repeats=2)
        bench_bitset(n_patients=500, repeats=2)
        bench_study(n_patients=500, repeats=2)
        bench_serving(n_patients=500)
        bench_chunked(n_patients=500, repeats=2)
        bench_analyze()
        bench_spec(n=24, n_patients=300)
        return
    bench_table1()
    bench_flattening()
    bench_flatten_plan()
    bench_pruning()
    bench_predicate()
    bench_bitset()
    bench_fig3()
    bench_study()
    bench_serving()
    bench_chunked()
    bench_analyze()
    bench_spec()
    bench_roofline()


if __name__ == "__main__":
    main()
