"""SCALPEL-Flattening throughput bench (paper §4 ¶2: "about 6 hours on 14
worker nodes") + the temporal-slicing memory/throughput trade + the no-loss
audit.  Reports rows/s and bytes/s at container scale."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro.core.flattening import flatten_sliced, flatten_star
from repro.core.schema import DCIR_SCHEMA, PMSI_MCO_SCHEMA
from repro.data.synthetic import SyntheticConfig, generate_dcir, generate_pmsi


def _bytes_of(tables) -> int:
    return sum(
        sum(np.asarray(c).nbytes for c in t.columns.values())
        for t in tables.values()
    )


def run_plan_vs_eager(n_patients: int = 4_000, seed: int = 0,
                      repeats: int = 5) -> List[Dict]:
    """Plan-level ``Study.flatten`` (optimizer capacity planning, one
    jit-compiled plan) vs the eager ``flatten_star`` wrapper (trace-time
    slack capacities) on the synthetic star schemas — the CI gate asserting
    the plan path stays at least at parity, with a row-set parity check.

    Both sides produce the *materialized* (compacted) flat table AND the
    per-stage no-loss audit — the paper's artifacts — so the comparison
    isolates the capacity planning, not work one path silently skips.
    """
    from repro.core.flattening import STAT_FIELDS
    from repro.study import Study, execute

    cfg = SyntheticConfig(n_patients=n_patients, seed=seed)
    rows: List[Dict] = []
    for name, schema, gen in (("DCIR", DCIR_SCHEMA, generate_dcir),
                              ("PMSI-MCO", PMSI_MCO_SCHEMA, generate_pmsi)):
        tables = gen(cfg)
        n_rows = int(tables[schema.central.name].count)

        def eager(ts, schema=schema):
            f, stats = flatten_star(schema, ts)
            return f.compact(), [{k: getattr(s, k) for k in STAT_FIELDS}
                                 for s in stats]

        jfn = jax.jit(eager)
        flat, _ = jfn(dict(tables))
        jax.block_until_ready(jax.tree.leaves(flat))
        dt_eager = min(_timed(lambda: jfn(dict(tables))) for _ in range(repeats))

        study = Study(n_patients=cfg.n_patients).flatten(schema, name="flat")
        plan = study.optimized_plan(tables=dict(tables))
        out_id = plan.output_ids["flat"]
        run_plan = lambda: execute(plan, dict(tables))[out_id]
        pflat = run_plan()                      # warm the jit cache
        jax.block_until_ready(jax.tree.leaves(pflat))
        dt_plan = min(_timed(run_plan) for _ in range(repeats))

        res = study.run(dict(tables))           # stats + no-loss audit
        res.assert_no_loss()
        parity = (sorted(np.asarray(pflat.to_numpy()[schema.central.key])
                         .tolist())
                  == sorted(flat.to_numpy()[schema.central.key].tolist()))
        rows.append({
            "database": name,
            "central_rows": n_rows,
            "eager_s": round(dt_eager, 4),
            "plan_s": round(dt_plan, 4),
            "plan_over_eager": round(dt_plan / max(dt_eager, 1e-9), 3),
            "plan_capacity": pflat.capacity,
            "eager_capacity": flat.capacity,
            "parity": "pass" if parity else "FAIL",
        })
    return rows


def _timed(fn) -> float:
    t0 = time.time()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out))
    return time.time() - t0


def run(n_patients: int = 4_000, seed: int = 0) -> List[Dict]:
    cfg = SyntheticConfig(n_patients=n_patients, seed=seed)
    rows: List[Dict] = []
    for name, schema, gen in (("DCIR", DCIR_SCHEMA, generate_dcir),
                              ("PMSI-MCO", PMSI_MCO_SCHEMA, generate_pmsi)):
        tables = gen(cfg)
        in_bytes = _bytes_of(tables)
        n_rows = int(tables[schema.central.name].count)

        jfn = jax.jit(lambda ts: flatten_star(schema, ts)[0])
        flat = jfn(dict(tables))
        jax.block_until_ready(jax.tree.leaves(flat))
        t0 = time.time()
        flat = jfn(dict(tables))
        jax.block_until_ready(jax.tree.leaves(flat))
        dt = time.time() - t0

        # no-loss audit (recomputed eagerly with stats)
        _, stats = flatten_star(schema, tables)
        for s in stats:
            s.assert_no_loss()

        rows.append({
            "database": name,
            "central_rows": n_rows,
            "flatten_s": round(dt, 4),
            "rows_per_s": int(n_rows / max(dt, 1e-9)),
            "mb_per_s": round(in_bytes / 2**20 / max(dt, 1e-9), 1),
            "no_loss_audit": "pass",
        })

        if name == "DCIR":
            for n_slices in (2, 6):
                t0 = time.time()
                sliced, _ = flatten_sliced(
                    schema, tables, "execution_date", n_slices,
                    14_600, 14_600 + 3 * 365)
                jax.block_until_ready(jax.tree.leaves(sliced))
                dts = time.time() - t0
                rows.append({
                    "database": f"DCIR[{n_slices} time slices]",
                    "central_rows": n_rows,
                    "flatten_s": round(dts, 4),
                    "rows_per_s": int(n_rows / max(dts, 1e-9)),
                    "row_match": int(sliced.count) == int(flat[0].count
                                     if isinstance(flat, tuple) else flat.count),
                })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
