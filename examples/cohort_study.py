"""Full observational study: fractures vs drug exposures (the paper's §4
evaluation tasks (a)-(g) composed into the Supplementary-A study).

Builds both sub-databases, runs every extraction task, derives exposures and
fracture outcomes, assembles the analysis cohort with a RECORD-style
flowchart, and exports an ML design matrix + the per-stage gender/age
distributions.

Run:  PYTHONPATH=src python examples/cohort_study.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    Cohort, CohortCollection, CohortFlow, DCIR_SCHEMA, FeatureDriver,
    OperationLog, PMSI_MCO_SCHEMA, diagnoses, drug_dispenses, exposures,
    flatten_star, follow_up, fractures, hospital_stays, medical_acts_dcir,
    medical_acts_pmsi, patients, sort_events, stats,
)
from repro.core.columnar import ColumnarTable
from repro.data.synthetic import SyntheticConfig, generate_snds

cfg = SyntheticConfig(n_patients=2_000, seed=42)
P = cfg.n_patients
dcir, pmsi = generate_snds(cfg)
log = OperationLog()

flat_dcir, _ = flatten_star(DCIR_SCHEMA, dcir)
flat_pmsi, _ = flatten_star(PMSI_MCO_SCHEMA, pmsi)

# -- tasks (a)-(g) ------------------------------------------------------------
pats = patients(dcir["IR_BEN"], log)                       # (a)
drugs = drug_dispenses()(flat_dcir, log)                   # (b)
prevalent = drug_dispenses(codes=list(range(65)))(flat_dcir, log)  # (c)
expo = exposures(drugs, P, purview_days=60)                # (d)
acts = medical_acts_dcir()(flat_dcir, log)                 # (e) outpatient
hacts = medical_acts_pmsi()(flat_pmsi, log)                # (e) inpatient
diags = diagnoses()(flat_pmsi, log)                        # (f)
frac = fractures(ColumnarTable.concat([acts, hacts]), diags,
                 fracture_act_codes=list(range(30)),
                 fracture_diag_codes=list(range(40)))      # (g)
fu = follow_up(pats, sort_events(drugs), P, study_end=14_600 + 3 * 365)

cc = CohortCollection.from_extractions(
    {"exposures": expo, "fractures": frac, "drug_purchases": drugs},
    P, metadata=log)
print("cohorts:", cc.cohorts_names)

# -- study assembly (Supplementary In[5]) ---------------------------------------
base = Cohort.from_patient_table("extract_patients", pats, P)
exposed = cc.get("exposures")
fractured = cc.get("fractures")
final = exposed.intersection(base).difference(fractured)
print(f"\nIn [5]: exposed ∩ base \\ fractured -> {final.subject_count()} subjects")
print(f"Out[6]: {final.describe()!r}")

flow = CohortFlow([base, exposed, final])
print("\nflowchart:\n" + flow.render())

for stage in flow.steps:
    d = stats.distribution_by_gender_age_bucket(stage, pats)
    print(f"\n[{stage.name}] gender x age-decade:")
    print("  male  ", d["male"])
    print("  female", d["female"])

# -- ML export (FeatureDriver) ---------------------------------------------------
final.window = (14_600, 14_600 + 3 * 365)
fd = FeatureDriver(final, pats)
X = fd.dense_features(n_buckets=36, bucket_days=31, n_features=128)
toks, mask = fd.token_sequences(seq_len=256)
print(f"\ndesign matrix: {X.shape}, nnz={int((np.asarray(X) > 0).sum())}")
print(f"token corpus:  {toks.shape}, checks={fd.checks}")
