"""Full observational study: fractures vs drug exposures (the paper's §4
evaluation tasks (a)-(g) composed into the Supplementary-A study) — written
against the lazy ``Study`` builder.

One declaration covers both sub-databases: every DCIR extractor shares one
scan of the DCIR flat table (same for PMSI), transformers and cohort algebra
ride the same plan, provenance is logged automatically, and the whole study
executes as one jit-compiled program per source-table spec.

Run:  PYTHONPATH=src python examples/cohort_study.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (
    DCIR_SCHEMA, PMSI_MCO_SCHEMA, diagnoses, drug_dispenses, flatten_star,
    hospital_stays, medical_acts_dcir, medical_acts_pmsi, stats,
)
from repro.data.synthetic import SyntheticConfig, generate_snds
from repro.study import Study, col

cfg = SyntheticConfig(n_patients=2_000, seed=42)
P = cfg.n_patients
STUDY_END = 14_600 + 3 * 365
dcir, pmsi = generate_snds(cfg)

flat_dcir, _ = flatten_star(DCIR_SCHEMA, dcir)
flat_pmsi, _ = flatten_star(PMSI_MCO_SCHEMA, pmsi)

# -- tasks (a)-(g) as one lazy plan -------------------------------------------
# Predicates are typed column expressions (``col()``/``Expr``): the engine
# sees exactly which columns each step reads (fusing them into one mask pass
# per scan branch and pruning everything else), instead of opaque callables.
study = (Study(n_patients=P, window=(14_600, STUDY_END))
         .patients("IR_BEN")                                       # (a)
         .extract(drug_dispenses(), name="drug_purchases")         # (b)
         .extract(drug_dispenses()                                 # (c)
                  .filtered(col("cip13").isin(range(65))
                            & col("execution_date").between(14_600, STUDY_END)),
                  name="prevalent_drugs")
         .extract(medical_acts_dcir(), name="acts")                # (e) outpatient
         .extract(medical_acts_pmsi(), name="hospital_acts")       # (e) inpatient
         .extract(diagnoses(), name="diagnoses")                   # (f)
         .extract(hospital_stays(), name="stays")
         .transform("exposures", "drug_purchases", name="exposures",
                    purview_days=60)                               # (d)
         .concat("all_acts", "acts", "hospital_acts")
         .transform("fractures", "all_acts", "diagnoses", name="fractures",
                    fracture_act_codes=list(range(30)),
                    fracture_diag_codes=list(range(40)))           # (g)
         .transform("follow_up", "extract_patients", "drug_purchases",
                    name="follow_up", study_end=STUDY_END)
         # -- study assembly (Supplementary In[5]) ----------------------------
         # cohort algebra has a real parser now: & binds tighter than | and
         # -, parentheses group — the grouping below is explicit
         .cohort("base", "extract_patients")
         .cohort("exposed", "exposures")
         .cohort("fractured", "fractures")
         .cohort("final", "(exposed & base) - fractured")
         .flow("base", "exposed", "final")
         # -- ML export (FeatureDriver) ---------------------------------------
         .featurize("X", cohort="final", kind="dense",
                    n_buckets=36, bucket_days=31, n_features=128)
         .featurize("tokens", cohort="final", kind="tokens", seq_len=256))

opt = study.optimized_plan()
ops = opt.count_ops()
print(f"plan: {len(opt.nodes)} nodes, scans={ops.get('scan')}, "
      f"fused_masks={ops.get('fused_mask')}, compactions={ops.get('compact')}")

res = study.run({"DCIR": flat_dcir, "PMSI_MCO": flat_pmsi,
                 "IR_BEN": dcir["IR_BEN"]})

print("cohorts:", set(res.cohorts))
final = res.cohorts["final"]
print(f"\nIn [5]: exposed ∩ base \\ fractured -> {final.subject_count()} subjects")
print(f"Out[6]: {final.describe()!r}")
print("\nflowchart:\n" + res.flow.render())

pats = res.events["extract_patients"]
for stage in res.flow.steps:
    d = stats.distribution_by_gender_age_bucket(stage, pats)
    print(f"\n[{stage.name}] gender x age-decade:")
    print("  male  ", d["male"])
    print("  female", d["female"])

X = res.features["X"]
toks, mask = res.features["tokens"]
print(f"\ndesign matrix: {X.shape}, nnz={int((np.asarray(X) > 0).sum())}")
print(f"token corpus:  {toks.shape}, checks={res.feature_checks['tokens']}")
print(f"\nprovenance: {len(res.log.entries)} auto-logged operations "
      f"(commit {res.log.commit[:12]})")
