"""Serving example: continuous-batching engine over a reduced model.

Boots the slot-based engine (vLLM-style admission over a fixed KV pool),
submits event-token prompts, decodes greedily until EOS/max-new.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    main()
