"""End-to-end driver: claims history -> LM training with checkpoint/restart.

The SCALPEL3 hand-off (paper §3.5: "load data into formats used by common
machine learning libraries") taken to its conclusion: patients' claims event
streams become the training corpus for any ``--arch`` in the zoo, through
``FeatureDriver.token_sequences``.

Default: reduced-config model (CPU-friendly) for a few hundred steps with an
async checkpoint + deterministic restart demo.  ``--full-size`` trains the
real config (use on TPU).

Run:  PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --steps 200
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--restart-demo", action="store_true",
                    help="kill at 60%% and restart from the checkpoint")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        if args.restart_demo:
            mid = int(args.steps * 0.6)
            print(f"== phase 1: train to step {mid}, checkpointing ==")
            train(args.arch, steps=mid, batch=args.batch, seq_len=args.seq_len,
                  reduced=not args.full_size, ckpt_dir=ckpt, ckpt_every=20)
            print("== simulated failure; restarting from latest checkpoint ==")
        out = train(args.arch, steps=args.steps, batch=args.batch,
                    seq_len=args.seq_len, reduced=not args.full_size,
                    ckpt_dir=ckpt if args.restart_demo else None,
                    ckpt_every=20)
    first = out["losses"][0] if out["losses"] else float("nan")
    print(f"\nloss: {first:.3f} -> {out['final_loss']:.3f} "
          f"over {args.steps} steps on SCALPEL3 claims tokens")


if __name__ == "__main__":
    main()
