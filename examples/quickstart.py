"""Quickstart: the SCALPEL3 pipeline in ~40 lines (paper Supplementary A).

  synthetic SNDS -> ONE lazy Study plan covering flattening (denormalization
  joins), extraction and cohort algebra, compiled into a single XLA program
  -> stats report.

The ``Study`` builder defers everything: ``flatten`` puts the star-schema
joins into the plan (capacities sized host-side from table statistics),
extractors chain onto the flat node and share a single projection, mask steps
fuse, each output materializes exactly once, and every executed plan node —
including per-join FlatteningStats — lands in the ``OperationLog``
automatically.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import DCIR_SCHEMA, drug_dispenses, medical_acts_dcir, stats
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.study import Study, column_audit_from_log, flow_rows_from_log

# 1. normalized claims data (stand-in for the CSV exports CNAM dumps)
cfg = SyntheticConfig(n_patients=1_000, seed=0)
dcir = generate_dcir(cfg)
print(f"normalized DCIR: {int(dcir['ER_PRS'].count)} cash-flow rows")

# 2-4. SCALPEL-Flattening + Extraction + Analysis as ONE lazy study plan
study = (Study(n_patients=cfg.n_patients)
         .flatten(DCIR_SCHEMA)                      # joins in the plan IR
         .extract(drug_dispenses(), name="drug_purchases")
         .extract(medical_acts_dcir(codes=list(range(30))), name="acts")
         .patients("IR_BEN")
         .cohort("base", "extract_patients")
         .cohort("drugged", "drug_purchases")
         .cohort("final", "drugged & base - acts")
         .flow("base", "drugged", "final"))

opt = study.optimized_plan(tables=dict(dcir))
ops = opt.count_ops()
print(f"\noptimized plan: {ops.get('scan_star', 0)} star-table scans, "
      f"{ops.get('lookup_join', 0)} joins, "
      f"{ops.get('fused_mask', 0)} fused masks, "
      f"{ops.get('compact', 0)} compactions")
# join-aware column pruning: once extractors chain onto the flat node, every
# dimension column no extractor reads is dropped BEFORE the joins — the
# narrowed scan projections are visible right in the plan
for n in opt.nodes:
    if n.op == "select" and n.get("pruned_columns"):
        print(f"  pruned scan -> keeps {list(n.get('cols'))}, "
              f"drops {list(n.get('pruned_columns'))}")

res = study.run(dict(dcir))                         # raw star tables in
res.assert_no_loss()                                # the paper's join audit
for i, d in sorted(res.flatten_stats.items()):
    print(f"  {d['stage']}: rows {d['rows_in']}->{d['rows_out']} "
          f"matched={d['matched']} overflow={d['overflow']}")
final = res.cohorts["final"]
print(f"\nfinal cohort: {final.subject_count()} subjects")
print(f"describe(): {final.describe()}")
print("\n" + res.flow.render())
print("\nflowchart rebuilt from the OperationLog alone:")
print(flow_rows_from_log(res.log))
print("\ncolumn audit (what each stage read) from the OperationLog alone:")
for r in column_audit_from_log(res.log)[:4]:
    print(f"  {r['stage']}: read={r['required_columns']} "
          f"pruned={r['pruned_columns']}")

# 5. automatic statistics report
pats = res.events["extract_patients"]
print("\n" + stats.report(final, pats, names=["gender_distribution", "age_buckets"]))
