"""Quickstart: the SCALPEL3 pipeline in ~40 lines (paper Supplementary A).

  synthetic SNDS -> flatten (denormalize once) -> lazy Study plan
  (extraction + cohort algebra fused into ONE compiled pass) -> stats report.

The ``Study`` builder defers everything: extractors share a single scan over
the flat table, mask steps fuse, each output materializes exactly once, and
every executed plan node lands in the ``OperationLog`` automatically.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import DCIR_SCHEMA, drug_dispenses, flatten_star, medical_acts_dcir, stats
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.study import Study, flow_rows_from_log

# 1. normalized claims data (stand-in for the CSV exports CNAM dumps)
cfg = SyntheticConfig(n_patients=1_000, seed=0)
dcir = generate_dcir(cfg)
print(f"normalized DCIR: {int(dcir['ER_PRS'].count)} cash-flow rows")

# 2. SCALPEL-Flattening: denormalize once, monitored
flat, audit = flatten_star(DCIR_SCHEMA, dcir)
for stage in audit:
    stage.assert_no_loss()
print(f"flat table: {int(flat.count)} rows x {len(flat.column_names)} cols")

# 3+4. SCALPEL-Extraction + Analysis as ONE lazy study plan
study = (Study(n_patients=cfg.n_patients)
         .extract(drug_dispenses(), name="drug_purchases")
         .extract(medical_acts_dcir(codes=list(range(30))), name="acts")
         .patients("IR_BEN")
         .cohort("base", "extract_patients")
         .cohort("drugged", "drug_purchases")
         .cohort("final", "drugged & base - acts")
         .flow("base", "drugged", "final"))

ops = study.optimized_plan().count_ops()
print(f"\noptimized plan: {ops.get('scan', 0)} scan(s) over DCIR+IR_BEN, "
      f"{ops.get('fused_mask', 0)} fused masks, {ops.get('compact', 0)} compactions")

res = study.run({"DCIR": flat, "IR_BEN": dcir["IR_BEN"]})
final = res.cohorts["final"]
print(f"\nfinal cohort: {final.subject_count()} subjects")
print(f"describe(): {final.describe()}")
print("\n" + res.flow.render())
print("\nflowchart rebuilt from the OperationLog alone:")
print(flow_rows_from_log(res.log))

# 5. automatic statistics report
pats = res.events["extract_patients"]
print("\n" + stats.report(final, pats, names=["gender_distribution", "age_buckets"]))
