"""Quickstart: the SCALPEL3 pipeline in ~40 lines (paper Supplementary A).

  synthetic SNDS -> flatten (denormalize once) -> extract concepts ->
  cohort algebra -> stats report.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (
    Cohort, CohortFlow, DCIR_SCHEMA, OperationLog, drug_dispenses,
    flatten_star, medical_acts_dcir, patients, stats,
)
from repro.data.synthetic import SyntheticConfig, generate_dcir

# 1. normalized claims data (stand-in for the CSV exports CNAM dumps)
cfg = SyntheticConfig(n_patients=1_000, seed=0)
dcir = generate_dcir(cfg)
print(f"normalized DCIR: {int(dcir['ER_PRS'].count)} cash-flow rows")

# 2. SCALPEL-Flattening: denormalize once, monitored
flat, audit = flatten_star(DCIR_SCHEMA, dcir)
for stage in audit:
    stage.assert_no_loss()
print(f"flat table: {int(flat.count)} rows x {len(flat.column_names)} cols")

# 3. SCALPEL-Extraction: ready-to-use concepts + provenance
log = OperationLog()
pats = patients(dcir["IR_BEN"], log)
drugs = drug_dispenses()(flat, log)
acts = medical_acts_dcir(codes=list(range(30)))(flat, log)  # a rare-acts subset
print(log.render_flowchart())

# 4. SCALPEL-Analysis: cohort algebra with auto-composed descriptions
base = Cohort.from_patient_table("extract_patients", pats, cfg.n_patients)
drugged = Cohort.from_events("drug_purchases", drugs, cfg.n_patients)
treated = Cohort.from_events("acts", acts, cfg.n_patients)
final = drugged.intersection(base).difference(treated)
print(f"\nfinal cohort: {final.subject_count()} subjects")
print(f"describe(): {final.describe()}")

flow = CohortFlow([base, drugged, final])
print("\n" + flow.render())

# 5. automatic statistics report
print("\n" + stats.report(final, pats, names=["gender_distribution", "age_buckets"]))
