"""Multi-tenant cohort-query service over one resident star schema.

Several analyst teams (tenants) issue cohort studies against the SAME claims
database.  The ``CohortQueryService`` keeps the star schema resident on
device and serves every tenant through three shared layers:

  * admission — slot-based window with per-tenant in-flight quotas and
    priority queueing (``serving.batching.SlotScheduler``);
  * plan normalization — each study's literals (thresholds, code lists) are
    hoisted out of the plan, so all tenants' structurally-equal studies
    share ONE compiled executable;
  * cross-tenant subgraph cache — shared plan prefixes (the flatten joins,
    the common code-whitelist masks) are computed once and served from a
    content-addressed device cache for every later query.

Run:  PYTHONPATH=src python examples/cohort_service.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import DCIR_SCHEMA, drug_dispenses, medical_acts_dcir
from repro.data.io import save_star
from repro.data.synthetic import SyntheticConfig, generate_dcir
from repro.study import CohortQueryService, ServiceConfig, Study, col

cfg = SyntheticConfig(n_patients=2_000, seed=7)
P = cfg.n_patients

# the hospital's shared clinical vocabulary: every team filters drugs to the
# same whitelist (a shared, cacheable plan prefix) ...
WHITELIST = list(range(0, 400, 3))


def team_study(threshold: int) -> Study:
    """One team's study: same shape for every team, team-specific follow-up
    threshold — a literal the service hoists out of the compiled program."""
    s = Study(n_patients=P)
    s.flatten(DCIR_SCHEMA)
    s.extract(drug_dispenses(codes=WHITELIST), name="drugs")
    s.extract(medical_acts_dcir(), name="acts")
    s.filter("acts", col("value") >= threshold, name="acts_hi")
    s.cohort("exposed", "drugs")
    s.cohort("final", "exposed & acts_hi")
    return s


# -- resident star schema: persist once, load once per table version ---------
with tempfile.TemporaryDirectory() as d:
    save_star(generate_dcir(cfg), d)
    svc = CohortQueryService.from_npz_dir(
        d, config=ServiceConfig(n_slots=4, per_tenant_inflight=2,
                                cache_budget_bytes=128 << 20))

# -- four tenants, eight queries each, tenant-specific thresholds -------------
tickets = []
for q in range(8):
    for i, tenant in enumerate(["cardio", "onco", "pharma", "public-health"]):
        t = svc.submit(team_study(threshold=40 + 20 * i + q),
                       tenant=tenant, priority=1 if tenant == "cardio" else 0)
        tickets.append(t)

svc.drain()

done = [t for t in tickets if t.status == "done"]
print(f"completed {len(done)}/{len(tickets)} queries")
for t in done[:4]:
    final = t.result.cohorts["final"]
    print(f"  {t.tenant:14s} final cohort: {final.subject_count():5d} subjects  "
          f"(cache {t.cache_hits} hits / {t.cache_misses} misses, "
          f"{t.latency_s * 1e3:.1f} ms)")

s = svc.stats
print(f"\nexecutables compiled : {s.compile_count} (for {s.queries} queries)")
print(f"subgraph cache       : {s.cache_hits} hits / {s.cache_misses} misses "
      f"({100 * s.hit_rate():.0f}% hit rate), "
      f"{s.cache_bytes / 1e6:.1f} MB resident")
print(f"audit log            : {len(svc.log.entries)} entries "
      f"(see OperationLog.to_json())")
