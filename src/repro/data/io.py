"""Columnar (de)serialization — the Parquet stand-in.

The paper's storage story (Table 1): CSV exports are ~11x larger than the
columnar+compressed Parquet encoding.  Offline we persist ``ColumnarTable``s
as compressed ``.npz`` (column-major, zlib) and measure the same CSV-vs-
columnar ratio in ``benchmarks/table1_dataset.py``.

Out-of-core additions (the ``data.chunkstore`` substrate):

* ``compressed=False`` writes plain ``np.savez`` archives whose members are
  ZIP_STORED — raw ``.npy`` payloads at a fixed byte offset inside the zip.
* ``mmap_mode`` on the load side memory-maps those stored members in place
  (``np.memmap`` at the member's data offset), so slicing a 15 TB-class
  column for chunk partitioning reads only the touched pages instead of
  materializing the whole column and its slice copies — the host's peak
  memory stays ~one chunk, not 2x the table.  Deflated members cannot be
  mapped; they fall back to an eager decompress, loudly documented rather
  than silently doubling memory.
* ``load_columnar_arrays`` exposes the raw host arrays (no device transfer)
  for host-side consumers like the chunk partitioner.
"""
from __future__ import annotations

import io
import os
import warnings
import zipfile
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.columnar import ColumnarTable

__all__ = ["save_columnar", "save_columnar_arrays", "load_columnar",
           "load_columnar_arrays", "save_star", "load_star",
           "csv_size_bytes", "columnar_size_bytes"]


def save_columnar_arrays(cols: Dict[str, np.ndarray], valid: np.ndarray,
                         path: str, compressed: bool = True) -> int:
    """Host-array writer behind ``save_columnar`` — the chunk partitioner
    streams mmap'd slices straight to disk through this, with no device
    round-trip."""
    arrs = {f"col::{k}": np.asarray(v) for k, v in cols.items()}
    arrs["__valid__"] = np.asarray(valid)
    if compressed:
        np.savez_compressed(path, **arrs)
    else:
        np.savez(path, **arrs)
    p = path if path.endswith(".npz") else path + ".npz"
    return os.path.getsize(p)


def save_columnar(table: ColumnarTable, path: str,
                  compressed: bool = True) -> int:
    """Write a columnar ``.npz`` file; returns bytes on disk.

    ``__valid__`` is stored in the canonical packed uint32 bitset form
    (1 bit/row); ``load_columnar`` also accepts legacy files that stored a
    bool row mask.  ``compressed=False`` stores members raw (ZIP_STORED),
    which is what makes them memory-mappable on load."""
    return save_columnar_arrays(table.columns, table.valid, path,
                                compressed=compressed)


def _mapped_member(path: str, info: zipfile.ZipInfo) -> Optional[np.ndarray]:
    """Memory-map one ZIP_STORED ``.npy`` member of an npz archive, or None
    when the member is compressed (deflated bytes cannot be mapped)."""
    if info.compress_type != zipfile.ZIP_STORED:
        return None
    with open(path, "rb") as f:
        # the central directory's header_offset points at the local file
        # header; its name/extra lengths (which may differ from the central
        # copy) give the member's data offset
        f.seek(info.header_offset)
        hdr = f.read(30)
        if len(hdr) < 30 or hdr[:4] != b"PK\x03\x04":
            return None
        fnlen = int.from_bytes(hdr[26:28], "little")
        extralen = int.from_bytes(hdr[28:30], "little")
        data_off = info.header_offset + 30 + fnlen + extralen
        f.seek(data_off)
        buf = io.BytesIO(f.read(min(info.file_size, 4096)))
    version = np.lib.format.read_magic(buf)
    shape, fortran, dtype = np.lib.format._read_array_header(buf, version)
    if dtype.hasobject:
        return None
    return np.memmap(path, dtype=dtype, mode="r",
                     offset=data_off + buf.tell(), shape=shape,
                     order="F" if fortran else "C")


def load_columnar_arrays(path: str, mmap_mode: Optional[str] = None,
                         mapped_sink: Optional[Dict[str, bool]] = None
                         ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Host-side load: ``(columns, valid)`` as numpy arrays, no device hop.

    With ``mmap_mode`` (e.g. ``"r"``), members written by
    ``save_columnar(compressed=False)`` come back as ``np.memmap`` views —
    zero bytes materialized until sliced.  Compressed members degrade to an
    eager read (np.load cannot map deflated payloads) — the degradation is
    *surfaced*, not silent: ``mapped_sink`` (when given) is filled with one
    ``member name -> mapped?`` flag per array, and the first degraded member
    of an archive warns (``RuntimeWarning``, once per file) so an
    out-of-core caller expecting lazy paging learns its peak host memory is
    about to be the whole table."""
    p = path if path.endswith(".npz") else path + ".npz"
    cols: Dict[str, np.ndarray] = {}
    valid: Optional[np.ndarray] = None
    mapped: Dict[str, np.ndarray] = {}
    if mmap_mode is not None:
        with zipfile.ZipFile(p) as z:
            for info in z.infolist():
                arr = _mapped_member(p, info)
                if arr is not None:
                    name = info.filename
                    mapped[name[:-4] if name.endswith(".npy") else name] = arr
    warned = False
    with np.load(p) as z:
        for k in z.files:
            arr = mapped.get(k)
            is_mapped = arr is not None
            if arr is None:
                arr = z[k]
                if mmap_mode is not None and not warned:
                    warnings.warn(
                        f"{p}: member {k!r} is compressed and cannot be "
                        "memory-mapped; falling back to an eager read "
                        "(write with compressed=False for lazy paging)",
                        RuntimeWarning, stacklevel=2)
                    warned = True
            if mapped_sink is not None:
                mapped_sink[k[5:] if k.startswith("col::") else k] = \
                    bool(is_mapped if mmap_mode is not None else False)
            if k.startswith("col::"):
                cols[k[5:]] = arr
            elif k == "__valid__":
                valid = arr
    return cols, valid


def load_columnar(path: str, mmap_mode: Optional[str] = None,
                  mapped_sink: Optional[Dict[str, bool]] = None
                  ) -> ColumnarTable:
    cols, valid = load_columnar_arrays(path, mmap_mode=mmap_mode,
                                       mapped_sink=mapped_sink)
    return ColumnarTable.from_columns(cols, valid=valid)


def save_star(tables: Dict[str, ColumnarTable], dirpath: str,
              compressed: bool = True) -> Dict[str, int]:
    """Persist a star schema (or any named table set) as one ``.npz`` per
    table under ``dirpath``; returns per-table bytes on disk.  The on-disk
    unit the cohort-query service loads a resident table version from (and
    the chunk partitioner streams its central table out of)."""
    os.makedirs(dirpath, exist_ok=True)
    return {name: save_columnar(t, os.path.join(dirpath, name),
                                compressed=compressed)
            for name, t in tables.items()}


def load_star(dirpath: str, mmap_mode: Optional[str] = None
              ) -> Dict[str, ColumnarTable]:
    """Load every ``<name>.npz`` under ``dirpath`` as ``{name: table}``.
    ``mmap_mode`` passes through to ``load_columnar`` — uncompressed stars
    map lazily instead of materializing every column eagerly."""
    out: Dict[str, ColumnarTable] = {}
    for fname in sorted(os.listdir(dirpath)):
        if fname.endswith(".npz"):
            out[fname[:-4]] = load_columnar(os.path.join(dirpath, fname),
                                            mmap_mode=mmap_mode)
    return out


def csv_size_bytes(table: ColumnarTable) -> int:
    """Size of the equivalent CSV export (the paper's raw input format)."""
    data = table.to_numpy()
    buf = io.StringIO()
    names = list(data)
    buf.write(",".join(names) + "\n")
    n = len(next(iter(data.values()))) if data else 0
    for i in range(n):
        buf.write(",".join(str(data[c][i]) for c in names) + "\n")
    return len(buf.getvalue().encode())


def columnar_size_bytes(table: ColumnarTable, path_dir: str, name: str) -> int:
    return save_columnar(table, os.path.join(path_dir, name))
