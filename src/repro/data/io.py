"""Columnar (de)serialization — the Parquet stand-in.

The paper's storage story (Table 1): CSV exports are ~11x larger than the
columnar+compressed Parquet encoding.  Offline we persist ``ColumnarTable``s
as compressed ``.npz`` (column-major, zlib) and measure the same CSV-vs-
columnar ratio in ``benchmarks/table1_dataset.py``.
"""
from __future__ import annotations

import io
import os
from typing import Dict

import numpy as np

from repro.core.columnar import ColumnarTable

__all__ = ["save_columnar", "load_columnar", "save_star", "load_star",
           "csv_size_bytes", "columnar_size_bytes"]


def save_columnar(table: ColumnarTable, path: str) -> int:
    """Write compressed columnar file; returns bytes on disk.

    ``__valid__`` is stored in the canonical packed uint32 bitset form
    (1 bit/row); ``load_columnar`` also accepts legacy files that stored a
    bool row mask."""
    arrs = {f"col::{k}": np.asarray(v) for k, v in table.columns.items()}
    arrs["__valid__"] = np.asarray(table.valid)
    np.savez_compressed(path, **arrs)
    p = path if path.endswith(".npz") else path + ".npz"
    return os.path.getsize(p)


def load_columnar(path: str) -> ColumnarTable:
    with np.load(path) as z:
        cols = {k[5:]: z[k] for k in z.files if k.startswith("col::")}
        valid = z["__valid__"]
    return ColumnarTable.from_columns(cols, valid=valid)


def save_star(tables: Dict[str, ColumnarTable], dirpath: str) -> Dict[str, int]:
    """Persist a star schema (or any named table set) as one ``.npz`` per
    table under ``dirpath``; returns per-table bytes on disk.  The on-disk
    unit the cohort-query service loads a resident table version from."""
    os.makedirs(dirpath, exist_ok=True)
    return {name: save_columnar(t, os.path.join(dirpath, name))
            for name, t in tables.items()}


def load_star(dirpath: str) -> Dict[str, ColumnarTable]:
    """Load every ``<name>.npz`` under ``dirpath`` as ``{name: table}``."""
    out: Dict[str, ColumnarTable] = {}
    for fname in sorted(os.listdir(dirpath)):
        if fname.endswith(".npz"):
            out[fname[:-4]] = load_columnar(os.path.join(dirpath, fname))
    return out


def csv_size_bytes(table: ColumnarTable) -> int:
    """Size of the equivalent CSV export (the paper's raw input format)."""
    data = table.to_numpy()
    buf = io.StringIO()
    names = list(data)
    buf.write(",".join(names) + "\n")
    n = len(next(iter(data.values()))) if data else 0
    for i in range(n):
        buf.write(",".join(str(data[c][i]) for c in names) + "\n")
    return len(buf.getvalue().encode())


def columnar_size_bytes(table: ColumnarTable, path_dir: str, name: str) -> int:
    return save_columnar(table, os.path.join(path_dir, name))
