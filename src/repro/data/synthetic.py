"""Synthetic SNDS-shaped claims generator.

Reproduces the *statistical shape* of the paper's Table 1 dataset at a
configurable scale factor: DCIR (outpatient cash flows, block-sparse detail
tables) and PMSI-MCO (hospital stays with one-to-many child tables).  Events
are timestamped over a 3-year follow-up, with drug/act/diagnosis code
vocabularies, null injection, and demographic distributions (gender, age,
mortality) matching the supplementary-material examples.

Everything is deterministic given ``seed`` — the fault-tolerance story of the
pipeline relies on replayable extraction (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.core.columnar import ColumnarTable
from repro.core.schema import DCIR_SCHEMA, PMSI_MCO_SCHEMA

__all__ = ["SyntheticConfig", "generate_dcir", "generate_pmsi", "generate_snds"]

DAYS_3Y = 3 * 365
EPOCH_OFFSET = 14_600  # ~2010-01-01 in days-since-1970, arbitrary anchor

# Null sentinel must match core.columnar.NULL_INT.
_NULL = np.int32(-2_147_483_648 + 1)


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    n_patients: int = 2_000
    flows_per_patient: float = 24.0     # DCIR cash flows / patient / 3y
    stays_per_patient: float = 0.6      # PMSI stays / patient / 3y
    diags_per_stay: float = 3.0         # one-to-many blow-up (paper Table 1)
    acts_per_stay: float = 2.0
    n_drug_codes: int = 500             # paper: 16,289 distinct CIP13
    n_atc_classes: int = 65             # paper task (c): 65 drugs of interest
    n_act_codes: int = 300              # paper: ~7k distinct CCAM
    n_diag_codes: int = 400             # paper: ~17k distinct ICD
    p_flow_is_drug: float = 0.55        # block-sparsity profile of DCIR
    p_flow_is_act: float = 0.25
    p_null_code: float = 0.01           # dirty-data injection
    p_dead: float = 0.05
    seed: int = 0

    @property
    def n_flows(self) -> int:
        return int(self.n_patients * self.flows_per_patient)

    @property
    def n_stays(self) -> int:
        return max(1, int(self.n_patients * self.stays_per_patient))


def _patients(rng: np.random.Generator, cfg: SyntheticConfig) -> Dict[str, np.ndarray]:
    n = cfg.n_patients
    gender = rng.integers(1, 3, size=n).astype(np.int32)  # 1=M, 2=F
    # Age 18–95 at epoch, skewed old (claims data shape).
    age_years = (18 + 77 * rng.beta(2.0, 1.6, size=n)).astype(np.int32)
    birth = (EPOCH_OFFSET - age_years.astype(np.int64) * 365).astype(np.int32)
    death = np.full(n, _NULL, dtype=np.int32)
    dead = rng.random(n) < cfg.p_dead
    death[dead] = (EPOCH_OFFSET + rng.integers(0, DAYS_3Y, size=dead.sum())).astype(np.int32)
    return {
        "patient_id": np.arange(n, dtype=np.int32),
        "gender": gender,
        "birth_date": birth,
        "death_date": death,
    }


def generate_dcir(cfg: SyntheticConfig) -> Dict[str, ColumnarTable]:
    """Normalized DCIR star: ER_PRS central + ER_PHA / ER_CAM / IR_BEN dims."""
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_flows
    pat = _patients(rng, cfg)

    flow_id = np.arange(n, dtype=np.int32)
    patient_id = rng.integers(0, cfg.n_patients, size=n).astype(np.int32)
    exec_date = (EPOCH_OFFSET + rng.integers(0, DAYS_3Y, size=n)).astype(np.int32)
    # Patients who died stop generating events at death (keeps monitoring
    # stats honest for follow-up transformers).
    death = pat["death_date"][patient_id]
    has_death = death != _NULL
    exec_date = np.where(
        has_death, np.minimum(exec_date, np.where(has_death, death, exec_date)), exec_date
    ).astype(np.int32)
    prestation = rng.integers(1000, 1100, size=n).astype(np.int32)
    amount = np.round(rng.gamma(2.0, 18.0, size=n), 2).astype(np.float32)

    kind = rng.random(n)
    is_drug = kind < cfg.p_flow_is_drug
    is_act = (~is_drug) & (kind < cfg.p_flow_is_drug + cfg.p_flow_is_act)

    # ER_PHA: one row per drug flow (block-sparse: <=1 per central row).
    pha_flow = flow_id[is_drug]
    m = pha_flow.shape[0]
    cip13 = rng.integers(0, cfg.n_drug_codes, size=m).astype(np.int32)
    cip13[rng.random(m) < cfg.p_null_code] = _NULL
    atc = (cip13 % np.int32(cfg.n_atc_classes)).astype(np.int32)
    atc[cip13 == _NULL] = _NULL
    er_pha = {
        "flow_id": pha_flow,
        "cip13": cip13,
        "atc_class": atc,
        "quantity": rng.integers(1, 4, size=m).astype(np.int32),
    }

    # ER_CAM: one row per act flow.
    cam_flow = flow_id[is_act]
    k = cam_flow.shape[0]
    ccam = rng.integers(0, cfg.n_act_codes, size=k).astype(np.int32)
    ccam[rng.random(k) < cfg.p_null_code] = _NULL
    er_cam = {"flow_id": cam_flow, "ccam_code": ccam}

    tables = {
        "ER_PRS": ColumnarTable.from_columns(
            {
                "flow_id": flow_id,
                "patient_id": patient_id,
                "prestation_code": prestation,
                "execution_date": exec_date,
                "amount": amount,
            }
        ),
        "ER_PHA": ColumnarTable.from_columns(er_pha),
        "ER_CAM": ColumnarTable.from_columns(er_cam),
        "IR_BEN": ColumnarTable.from_columns(pat),
    }
    # Schema check: generated columns must match declarations.
    for ts in DCIR_SCHEMA.all_tables():
        got = set(tables[ts.name].column_names)
        want = set(ts.columns)
        assert got == want, (ts.name, got, want)
    return tables


def generate_pmsi(cfg: SyntheticConfig) -> Dict[str, ColumnarTable]:
    """Normalized PMSI-MCO star: MCO_B central + MCO_D / MCO_A children."""
    rng = np.random.default_rng(cfg.seed + 1)
    n = cfg.n_stays
    stay_id = np.arange(n, dtype=np.int32)
    patient_id = rng.integers(0, cfg.n_patients, size=n).astype(np.int32)
    start = (EPOCH_OFFSET + rng.integers(0, DAYS_3Y - 30, size=n)).astype(np.int32)
    length = rng.geometric(0.25, size=n).clip(1, 60).astype(np.int32)
    mco_b = {
        "stay_id": stay_id,
        "patient_id": patient_id,
        "stay_start": start,
        "stay_end": (start + length).astype(np.int32),
        "ghm_code": rng.integers(0, 2000, size=n).astype(np.int32),
    }

    # One-to-many children: Poisson counts per stay (>=1 main diagnosis).
    n_diag = np.maximum(1, rng.poisson(cfg.diags_per_stay, size=n)).astype(np.int64)
    d_stay = np.repeat(stay_id, n_diag)
    md = d_stay.shape[0]
    diag_kind = np.ones(md, dtype=np.int32)  # 1=main
    # mark non-first diagnoses as associated(2)/linked(3)
    first = np.r_[True, d_stay[1:] != d_stay[:-1]]
    diag_kind[~first] = rng.integers(2, 4, size=(~first).sum()).astype(np.int32)
    mco_d = {
        "stay_id": d_stay.astype(np.int32),
        "icd_code": rng.integers(0, cfg.n_diag_codes, size=md).astype(np.int32),
        "diag_kind": diag_kind,
    }

    n_act = rng.poisson(cfg.acts_per_stay, size=n).astype(np.int64)
    a_stay = np.repeat(stay_id, n_act)
    ma = a_stay.shape[0]
    mco_a = {
        "stay_id": a_stay.astype(np.int32),
        "ccam_code": rng.integers(0, cfg.n_act_codes, size=max(ma, 1))[:ma].astype(np.int32),
        "act_date": (start[a_stay] + rng.integers(0, 5, size=ma)).astype(np.int32),
    }
    if ma == 0:  # degenerate tiny configs
        mco_a = {k: np.zeros(0, dtype=np.int32) for k in ("stay_id", "ccam_code", "act_date")}

    tables = {
        "MCO_B": ColumnarTable.from_columns(mco_b),
        "MCO_D": ColumnarTable.from_columns(mco_d),
        "MCO_A": ColumnarTable.from_columns(mco_a),
    }
    for ts in PMSI_MCO_SCHEMA.all_tables():
        got = set(tables[ts.name].column_names)
        want = set(ts.columns)
        assert got == want, (ts.name, got, want)
    return tables


def generate_snds(cfg: SyntheticConfig) -> Tuple[Dict[str, ColumnarTable], Dict[str, ColumnarTable]]:
    """Both sub-databases, sharing the patient universe."""
    return generate_dcir(cfg), generate_pmsi(cfg)


def generate_ssr(cfg: SyntheticConfig) -> Dict[str, ColumnarTable]:
    """SSR rehabilitation star (supplementary Table 2)."""
    rng = np.random.default_rng(cfg.seed + 2)
    n = max(1, int(cfg.n_patients * 0.08))
    stay_id = np.arange(n, dtype=np.int32)
    patient_id = rng.integers(0, cfg.n_patients, size=n).astype(np.int32)
    start = (EPOCH_OFFSET + rng.integers(0, DAYS_3Y - 60, size=n)).astype(np.int32)
    length = rng.geometric(0.05, size=n).clip(7, 120).astype(np.int32)
    ssr_b = {
        "stay_id": stay_id,
        "patient_id": patient_id,
        "stay_start": start,
        "stay_end": (start + length).astype(np.int32),
        "takeover_code": rng.integers(0, 40, size=n).astype(np.int32),
    }
    n_act = rng.poisson(4.0, size=n).astype(np.int64)
    a_stay = np.repeat(stay_id, n_act)
    ma = max(int(a_stay.shape[0]), 1)
    ssr_a = {
        "stay_id": (a_stay if a_stay.shape[0] else np.zeros(0, np.int32)).astype(np.int32),
        "csarr_code": rng.integers(0, 200, size=ma)[: a_stay.shape[0]].astype(np.int32),
        "act_date": (start[a_stay] + rng.integers(0, 30, size=a_stay.shape[0])).astype(np.int32)
        if a_stay.shape[0] else np.zeros(0, np.int32),
    }
    n_diag = np.maximum(1, rng.poisson(1.5, size=n)).astype(np.int64)
    d_stay = np.repeat(stay_id, n_diag)
    ssr_d = {
        "stay_id": d_stay.astype(np.int32),
        "icd_code": rng.integers(0, cfg.n_diag_codes, size=d_stay.shape[0]).astype(np.int32),
        "diag_kind": np.ones(d_stay.shape[0], np.int32),
    }
    return {
        "SSR_B": ColumnarTable.from_columns(ssr_b),
        "SSR_A": ColumnarTable.from_columns(ssr_a),
        "SSR_D": ColumnarTable.from_columns(ssr_d),
    }


def generate_had(cfg: SyntheticConfig) -> Dict[str, ColumnarTable]:
    """HAD home-care episodes (supplementary Table 2)."""
    rng = np.random.default_rng(cfg.seed + 3)
    n = max(1, int(cfg.n_patients * 0.04))
    start = (EPOCH_OFFSET + rng.integers(0, DAYS_3Y - 90, size=n)).astype(np.int32)
    assoc = rng.integers(0, 25, size=n).astype(np.int32)
    assoc[rng.random(n) < 0.5] = _NULL
    had_b = {
        "episode_id": np.arange(n, dtype=np.int32),
        "patient_id": rng.integers(0, cfg.n_patients, size=n).astype(np.int32),
        "episode_start": start,
        "episode_end": (start + rng.integers(14, 90, size=n)).astype(np.int32),
        "main_takeover": rng.integers(0, 25, size=n).astype(np.int32),
        "assoc_takeover": assoc,
    }
    return {"HAD_B": ColumnarTable.from_columns(had_b)}


def generate_ir_imb(cfg: SyntheticConfig) -> Dict[str, ColumnarTable]:
    """IR_IMB_R long-term chronic diseases (ALD)."""
    rng = np.random.default_rng(cfg.seed + 4)
    n = max(1, int(cfg.n_patients * 0.15))
    start = (EPOCH_OFFSET - rng.integers(0, 3650, size=n)).astype(np.int32)
    return {
        "IR_IMB_R": ColumnarTable.from_columns({
            "patient_id": rng.integers(0, cfg.n_patients, size=n).astype(np.int32),
            "ald_icd_code": rng.integers(0, cfg.n_diag_codes, size=n).astype(np.int32),
            "ald_start": start,
            "ald_end": (start + rng.integers(365, 7300, size=n)).astype(np.int32),
        })
    }
