from repro.data.synthetic import (
    SyntheticConfig, generate_dcir, generate_pmsi, generate_snds,
    generate_ssr, generate_had, generate_ir_imb,
)
from repro.data.io import (
    save_columnar, save_columnar_arrays, load_columnar, load_columnar_arrays,
    save_star, load_star, csv_size_bytes, columnar_size_bytes,
)
from repro.data.chunkstore import (
    ChunkManifest, ChunkMeta, ChunkStore, partition_star,
)
