from repro.data.synthetic import (
    SyntheticConfig, generate_dcir, generate_pmsi, generate_snds,
    generate_ssr, generate_had, generate_ir_imb,
)
from repro.data.io import save_columnar, load_columnar, csv_size_bytes, columnar_size_bytes
