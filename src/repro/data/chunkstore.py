"""Partitioned on-disk star schema — the substrate for out-of-core execution.

SCALPEL3's headline run flattens 15e9 events (~15 TB) — far past any single
device's memory.  ``ChunkStore`` makes that shape executable: the star's
central (fact) table is partitioned into fixed-capacity, 32-row-aligned
chunks persisted as packed-npz files (``data/io.py`` format: ``col::*``
members + the canonical ``__valid__`` uint32 bitset), while the small
dimension tables stay resident.  A JSON manifest records per-chunk row
counts, key ranges and content hashes, so a reader can plan, verify and
resume without touching the chunk payloads.

Layout of a store directory::

    store/
      manifest.json            # ChunkManifest (versioned)
      chunk_00000.npz          # fixed-capacity slices of the central table
      chunk_00001.npz
      ...
      resident/<name>.npz      # dimension tables, loaded whole

Alignment contract: ``chunk_capacity % 32 == 0`` (``bitset.WORD_BITS``), so
every chunk boundary falls exactly on a validity-word boundary and the
source table's packed words slice into per-chunk bitsets with **zero**
repacking — the same quantum ``distributed.pipeline.pad_tables_for_mesh``
uses for shard splits (chunks therefore re-pad to any 32*n_shards mesh for
free).  The writer refuses misaligned capacities; the static analyzer
(SP015) rejects misaligned *manifests* before an executor ever streams one.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Dict, Mapping, Optional, Tuple, Union

import numpy as np

from repro.core import bitset as _bs
from repro.core.columnar import ColumnarTable
from repro.data.io import (load_columnar_arrays, load_star,
                           save_columnar_arrays)

__all__ = ["ChunkMeta", "ChunkManifest", "ChunkStore", "partition_star"]

MANIFEST_NAME = "manifest.json"
MANIFEST_VERSION = 1
WORD = _bs.WORD_BITS  # 32 — the row quantum every chunk boundary respects


@dataclasses.dataclass(frozen=True)
class ChunkMeta:
    """Per-chunk facts a reader can plan/verify against without IO."""

    index: int
    rows: int                       # valid rows (popcount of the bitset)
    key_lo: Optional[int]           # min/max partition key among valid rows
    key_hi: Optional[int]           # (None for an all-invalid chunk)
    sha256: str                     # content hash of columns + validity

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Mapping) -> "ChunkMeta":
        return cls(index=int(d["index"]), rows=int(d["rows"]),
                   key_lo=d["key_lo"], key_hi=d["key_hi"],
                   sha256=str(d["sha256"]))


@dataclasses.dataclass(frozen=True)
class ChunkManifest:
    """The store's self-description (``manifest.json``)."""

    source: str                     # name of the chunked central table
    key: str                        # partition key column (row-order ranges)
    chunk_capacity: int             # fixed per-chunk capacity (rows)
    total_rows: int                 # sum of per-chunk valid rows
    columns: Dict[str, str]         # central-table schema: name -> dtype str
    resident: Tuple[str, ...]       # dimension tables stored whole
    chunks: Tuple[ChunkMeta, ...]
    version: int = MANIFEST_VERSION

    @property
    def n_chunks(self) -> int:
        return len(self.chunks)

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["chunks"] = [c.to_json() for c in self.chunks]
        d["resident"] = list(self.resident)
        return d

    @classmethod
    def from_json(cls, d: Mapping) -> "ChunkManifest":
        return cls(source=str(d["source"]), key=str(d["key"]),
                   chunk_capacity=int(d["chunk_capacity"]),
                   total_rows=int(d["total_rows"]),
                   columns=dict(d["columns"]),
                   resident=tuple(d["resident"]),
                   chunks=tuple(ChunkMeta.from_json(c) for c in d["chunks"]),
                   version=int(d.get("version", MANIFEST_VERSION)))

    def fingerprint(self) -> str:
        """Content identity of the whole store (chunk hashes included) —
        what the chunked executor's checkpoint journal stamps, so a resumed
        run refuses to mix partial state from a different dataset."""
        blob = json.dumps(self.to_json(), sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()


def _chunk_hash(cols: Mapping[str, np.ndarray], valid: np.ndarray) -> str:
    h = hashlib.sha256()
    for name in sorted(cols):
        a = np.ascontiguousarray(cols[name])
        h.update(name.encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    h.update(b"__valid__")
    h.update(np.ascontiguousarray(valid).tobytes())
    return h.hexdigest()


def _chunk_fname(i: int) -> str:
    return f"chunk_{i:05d}.npz"


def _host_arrays(t: ColumnarTable) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    return ({k: np.asarray(v) for k, v in t.columns.items()},
            np.asarray(t.valid))


def partition_star(tables: Union[str, Mapping[str, ColumnarTable]],
                   dirpath: str, source: str, chunk_capacity: int,
                   key: str = "patient_id", compressed: bool = False,
                   mmap_mode: Optional[str] = "r") -> "ChunkStore":
    """Write a ``ChunkStore`` under ``dirpath``: ``tables[source]`` split
    into fixed-capacity chunks, every other table stored resident.

    ``tables`` may be an in-memory ``{name: ColumnarTable}`` star or a path
    to a ``data.io.save_star`` directory — the latter streams through
    ``mmap_mode`` so peak host memory stays ~one chunk, not the whole
    central table (the io.py bugfix this subsystem rides on).  Chunks
    default to uncompressed npz so the chunked executor's prefetch thread
    can mmap them back; pass ``compressed=True`` to trade load CPU for disk.
    """
    chunk_capacity = int(chunk_capacity)
    if chunk_capacity <= 0 or chunk_capacity % WORD:
        raise ValueError(
            f"chunk_capacity must be a positive multiple of {WORD} (the "
            f"validity word quantum), got {chunk_capacity}: chunk boundaries "
            "must fall on packed-bitset word boundaries")

    if isinstance(tables, str):
        names = sorted(f[:-4] for f in os.listdir(tables)
                       if f.endswith(".npz"))
        if source not in names:
            raise KeyError(f"source table {source!r} not in star dir "
                           f"{tables!r} (found {names})")
        src_cols, src_valid = load_columnar_arrays(
            os.path.join(tables, source), mmap_mode=mmap_mode)
        resident_arrays = {
            n: load_columnar_arrays(os.path.join(tables, n),
                                    mmap_mode=mmap_mode)
            for n in names if n != source}
    else:
        if source not in tables:
            raise KeyError(f"source table {source!r} not among {sorted(tables)}")
        src_cols, src_valid = _host_arrays(tables[source])
        resident_arrays = {n: _host_arrays(t) for n, t in tables.items()
                           if n != source}
    if key not in src_cols:
        raise KeyError(f"partition key {key!r} not a column of {source!r}")

    os.makedirs(dirpath, exist_ok=True)
    res_dir = os.path.join(dirpath, "resident")
    for name, (cols, valid) in resident_arrays.items():
        os.makedirs(res_dir, exist_ok=True)
        save_columnar_arrays(cols, valid, os.path.join(res_dir, name),
                             compressed=compressed)

    cap = next(iter(src_cols.values())).shape[0] if src_cols else 0
    n_chunks = max(1, -(-cap // chunk_capacity))
    metas = []
    total_rows = 0
    for ci in range(n_chunks):
        i0 = ci * chunk_capacity
        i1 = min(cap, i0 + chunk_capacity)
        # i0 % 32 == 0, so the packed words slice exactly on the chunk
        # boundary — each chunk's words ARE the bitset of its local rows
        words = np.asarray(src_valid[i0 // WORD: -(-i1 // WORD)],
                           dtype=np.uint32)
        cols = {}
        for name, col in src_cols.items():
            sl = np.asarray(col[i0:i1])
            if sl.shape[0] < chunk_capacity:
                pad = np.zeros(chunk_capacity, dtype=sl.dtype)
                pad[: sl.shape[0]] = sl
                sl = pad
            cols[name] = sl
        if words.shape[0] < chunk_capacity // WORD:
            words = np.pad(words,
                           (0, chunk_capacity // WORD - words.shape[0]))
        vmask = _bs.unpack_np(words, chunk_capacity)
        rows = int(vmask.sum())
        lo = hi = None
        if rows:
            kvals = cols[key][vmask]
            lo, hi = int(kvals.min()), int(kvals.max())
        save_columnar_arrays(cols, words,
                             os.path.join(dirpath, _chunk_fname(ci)),
                             compressed=compressed)
        metas.append(ChunkMeta(index=ci, rows=rows, key_lo=lo, key_hi=hi,
                               sha256=_chunk_hash(cols, words)))
        total_rows += rows

    manifest = ChunkManifest(
        source=source, key=key, chunk_capacity=chunk_capacity,
        total_rows=total_rows,
        columns={n: str(np.asarray(c).dtype) for n, c in src_cols.items()},
        resident=tuple(sorted(resident_arrays)), chunks=tuple(metas))
    tmp = os.path.join(dirpath, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest.to_json(), f, indent=1, sort_keys=True)
    os.replace(tmp, os.path.join(dirpath, MANIFEST_NAME))
    # compressed members can never map — don't ask, or every chunk load
    # would warn about the degrade we just chose at write time
    return ChunkStore(dirpath, mmap_mode=None if compressed else mmap_mode)


class ChunkStore:
    """Reader over a partitioned store directory (see module docstring).

    ``load_chunk_arrays`` returns host numpy (mmap-backed when the chunks
    are uncompressed) — the form the chunked executor's prefetch thread
    consumes; ``chunk_table`` wraps one chunk as a device ``ColumnarTable``.
    """

    def __init__(self, dirpath: str, mmap_mode: Optional[str] = "r",
                 verify: bool = False) -> None:
        self.dirpath = dirpath
        self.mmap_mode = mmap_mode
        self.verify = bool(verify)
        mpath = os.path.join(dirpath, MANIFEST_NAME)
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"no {MANIFEST_NAME} under {dirpath!r} — not a chunk store "
                "(write one with partition_star / ChunkStore.create)")
        with open(mpath) as f:
            self.manifest = ChunkManifest.from_json(json.load(f))

    create = staticmethod(partition_star)

    # -- manifest facts ------------------------------------------------------
    @property
    def source(self) -> str:
        return self.manifest.source

    @property
    def n_chunks(self) -> int:
        return self.manifest.n_chunks

    @property
    def chunk_capacity(self) -> int:
        return self.manifest.chunk_capacity

    def fingerprint(self) -> str:
        return self.manifest.fingerprint()

    def chunk_path(self, i: int) -> str:
        return os.path.join(self.dirpath, _chunk_fname(i))

    # -- chunk IO ------------------------------------------------------------
    def load_chunk_arrays(self, i: int, verify: Optional[bool] = None
                          ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Host ``(columns, valid_words)`` of chunk ``i``; optionally check
        the payload against the manifest's content hash (corruption and
        torn-write detection for resumed runs)."""
        meta = self.manifest.chunks[i]
        cols, valid = load_columnar_arrays(self.chunk_path(i),
                                           mmap_mode=self.mmap_mode)
        if verify if verify is not None else self.verify:
            got = _chunk_hash(cols, valid)
            if got != meta.sha256:
                raise IOError(
                    f"chunk {i} content hash mismatch: manifest "
                    f"{meta.sha256[:12]}…, payload {got[:12]}… — the store "
                    "was modified or torn after partitioning")
        return cols, valid

    def chunk_table(self, i: int, verify: Optional[bool] = None
                    ) -> ColumnarTable:
        """Chunk ``i`` as a device-resident ``ColumnarTable``."""
        cols, valid = self.load_chunk_arrays(i, verify=verify)
        return ColumnarTable.from_columns(cols, valid=valid)

    def resident_tables(self) -> Dict[str, ColumnarTable]:
        """The dimension tables (device-resident, loaded whole)."""
        res_dir = os.path.join(self.dirpath, "resident")
        if not os.path.isdir(res_dir):
            return {}
        return load_star(res_dir, mmap_mode=self.mmap_mode)

    # -- integrity -----------------------------------------------------------
    def validate(self) -> None:
        """Structural manifest checks (payloads are checked per-load via
        ``verify``): alignment, chunk-file presence, row-count bounds.
        Plan-level alignment against a mesh is the analyzer's job (SP015)."""
        m = self.manifest
        if m.chunk_capacity <= 0 or m.chunk_capacity % WORD:
            raise ValueError(
                f"manifest chunk_capacity {m.chunk_capacity} is not a "
                f"positive multiple of {WORD}")
        for c in m.chunks:
            if c.rows > m.chunk_capacity:
                raise ValueError(f"chunk {c.index} claims {c.rows} rows > "
                                 f"capacity {m.chunk_capacity}")
            if not os.path.exists(self.chunk_path(c.index)):
                raise FileNotFoundError(f"chunk file missing: "
                                        f"{self.chunk_path(c.index)}")
