"""Continuous batching scheduler (host-side), vLLM-style but slot-based.

A fixed pool of B slots shares one KV cache; requests are admitted into free
slots (their prompt prefilled into the slot's cache region via the decode
path), and every engine step decodes one token for all live slots.  Fixed
shapes keep a single compiled executable — finished slots are simply masked
and re-admitted, so there is no recompilation at 1000-node scale.

``SlotScheduler`` is the admission policy factored out of the batcher —
bounded in-flight window, FIFO-within-priority queue, optional per-key
quotas — so the cohort-query service (``study.service``) shares one
admission idiom with the token-serving engine instead of growing its own.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelBundle
from repro.serving.serve_step import make_serve_step

__all__ = ["Request", "ContinuousBatcher", "SlotScheduler"]


class SlotScheduler:
    """Slot-based admission: a bounded in-flight window over a FIFO-with-
    priority queue, with optional per-key (per-tenant) in-flight quotas and a
    bounded queue depth.

    Items are ``submit``-ted with a key and a priority; ``admit`` moves as
    many queued items as free slots (and quotas) allow, in priority order
    (higher first) then submission order; ``release(key)`` retires one slot.
    Over-quota items stay queued *in place* — later items of other keys may
    overtake them, but order within a key is always FIFO: the heap entries
    carry a monotonic sequence counter, so equal-priority items never fall
    through to comparing ``key``/``item`` (which may not be orderable at
    all) and never reorder within a priority band.

    Thread-safe: the cohort-query service releases slots from its
    realization worker while the main thread admits, so every mutation
    holds an internal lock.
    """

    def __init__(self, n_slots: int, per_key_quota: Optional[int] = None,
                 max_queue: Optional[int] = None):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.n_slots = int(n_slots)
        self.per_key_quota = per_key_quota
        self.max_queue = max_queue
        self._heap: List[Tuple[int, int, Any, Any]] = []  # (-prio, seq, key, item)
        self._seq = itertools.count()
        self._inflight: Dict[Any, int] = {}
        self._live = 0
        self._lock = threading.Lock()

    def queued(self) -> int:
        with self._lock:
            return len(self._heap)

    def inflight(self) -> int:
        with self._lock:
            return self._live

    def submit(self, item: Any, key: Any = None, priority: int = 0) -> bool:
        """Enqueue; returns False (rejecting the item) when the queue is
        at ``max_queue`` depth."""
        with self._lock:
            if self.max_queue is not None \
                    and len(self._heap) >= self.max_queue:
                return False
            heapq.heappush(self._heap,
                           (-int(priority), next(self._seq), key, item))
            return True

    def admit(self) -> List[Tuple[Any, Any]]:
        """Fill free slots from the queue; returns admitted ``(item, key)``
        pairs in admission order."""
        admitted: List[Tuple[Any, Any]] = []
        skipped: List[Tuple[int, int, Any, Any]] = []
        with self._lock:
            while self._heap and self._live < self.n_slots:
                entry = heapq.heappop(self._heap)
                _, _, key, item = entry
                if (self.per_key_quota is not None
                        and self._inflight.get(key, 0) >= self.per_key_quota):
                    skipped.append(entry)  # over quota: stays queued in place
                    continue
                self._inflight[key] = self._inflight.get(key, 0) + 1
                self._live += 1
                admitted.append((item, key))
            for entry in skipped:
                heapq.heappush(self._heap, entry)
        return admitted

    def release(self, key: Any = None) -> None:
        """Retire one in-flight item admitted under ``key``."""
        with self._lock:
            if self._live <= 0:
                raise RuntimeError("release() without a live admission")
            self._live -= 1
            left = self._inflight.get(key, 0) - 1
            if left > 0:
                self._inflight[key] = left
            else:
                self._inflight.pop(key, None)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, bundle: ModelBundle, params, n_slots: int, kv_len: int,
                 eos_id: int = 2):
        self.bundle = bundle
        self.params = params
        self.n_slots = n_slots
        self.kv_len = kv_len
        self.eos_id = eos_id
        self.cache = bundle.init_cache(n_slots, kv_len)
        self.step_fn = jax.jit(make_serve_step(bundle, sample=True),
                               donate_argnums=(1,))
        self.sched = SlotScheduler(n_slots)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.slot_remaining = np.zeros(n_slots, np.int32)
        self.cur_token = np.zeros(n_slots, np.int32)

    def submit(self, req: Request) -> None:
        self.sched.submit(req, key=req.rid)

    def _admit(self) -> None:
        for req, _ in self.sched.admit():
            i = next(j for j in range(self.n_slots) if self.slots[j] is None)
            self.slots[i] = req
            # prefill the prompt token-by-token through the decode path
            # (slot-local; production would use a bulk prefill kernel)
            for t, tok in enumerate(req.prompt[:-1]):
                self._single_token(i, tok, t)
            self.slot_pos[i] = len(req.prompt) - 1
            self.cur_token[i] = req.prompt[-1]
            self.slot_remaining[i] = req.max_new

    def _single_token(self, slot: int, token: int, pos: int) -> None:
        toks = np.zeros((self.n_slots, 1), np.int32)
        toks[slot, 0] = token
        batch = {"tokens": jnp.asarray(toks), "pos": jnp.int32(pos)}
        _, self.cache = self.step_fn(self.params, self.cache, batch)

    def step(self) -> int:
        """One engine step; returns number of live slots."""
        self._admit()
        live = [i for i in range(self.n_slots) if self.slots[i] is not None]
        if not live:
            return 0
        toks = jnp.asarray(self.cur_token.reshape(-1, 1))
        # NOTE: slots decode at a common position in this reference engine;
        # per-slot positions need per-slot pos vectors (kernel supports it via
        # positions arg) — kept scalar here for the fixed-shape path.
        pos = int(self.slot_pos[live[0]])
        out, self.cache = self.step_fn(self.params, self.cache,
                                       {"tokens": toks, "pos": jnp.int32(pos)})
        out = np.asarray(out)
        for i in live:
            tok = int(out[i])
            req = self.slots[i]
            req.out.append(tok)
            self.cur_token[i] = tok
            self.slot_pos[i] += 1
            self.slot_remaining[i] -= 1
            if tok == self.eos_id or self.slot_remaining[i] <= 0 \
                    or self.slot_pos[i] >= self.kv_len - 1:
                req.done = True
                self.slots[i] = None
                self.sched.release(req.rid)
        return len(live)

    def run(self, max_steps: int = 1_000) -> None:
        for _ in range(max_steps):
            if not self.step() and not self.sched.queued():
                break
