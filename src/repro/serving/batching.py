"""Continuous batching scheduler (host-side), vLLM-style but slot-based.

A fixed pool of B slots shares one KV cache; requests are admitted into free
slots (their prompt prefilled into the slot's cache region via the decode
path), and every engine step decodes one token for all live slots.  Fixed
shapes keep a single compiled executable — finished slots are simply masked
and re-admitted, so there is no recompilation at 1000-node scale.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelBundle
from repro.serving.serve_step import make_serve_step

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, bundle: ModelBundle, params, n_slots: int, kv_len: int,
                 eos_id: int = 2):
        self.bundle = bundle
        self.params = params
        self.n_slots = n_slots
        self.kv_len = kv_len
        self.eos_id = eos_id
        self.cache = bundle.init_cache(n_slots, kv_len)
        self.step_fn = jax.jit(make_serve_step(bundle, sample=True),
                               donate_argnums=(1,))
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)
        self.slot_remaining = np.zeros(n_slots, np.int32)
        self.cur_token = np.zeros(n_slots, np.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                # prefill the prompt token-by-token through the decode path
                # (slot-local; production would use a bulk prefill kernel)
                for t, tok in enumerate(req.prompt[:-1]):
                    self._single_token(i, tok, t)
                self.slot_pos[i] = len(req.prompt) - 1
                self.cur_token[i] = req.prompt[-1]
                self.slot_remaining[i] = req.max_new

    def _single_token(self, slot: int, token: int, pos: int) -> None:
        toks = np.zeros((self.n_slots, 1), np.int32)
        toks[slot, 0] = token
        batch = {"tokens": jnp.asarray(toks), "pos": jnp.int32(pos)}
        _, self.cache = self.step_fn(self.params, self.cache, batch)

    def step(self) -> int:
        """One engine step; returns number of live slots."""
        self._admit()
        live = [i for i in range(self.n_slots) if self.slots[i] is not None]
        if not live:
            return 0
        toks = jnp.asarray(self.cur_token.reshape(-1, 1))
        # NOTE: slots decode at a common position in this reference engine;
        # per-slot positions need per-slot pos vectors (kernel supports it via
        # positions arg) — kept scalar here for the fixed-shape path.
        pos = int(self.slot_pos[live[0]])
        out, self.cache = self.step_fn(self.params, self.cache,
                                       {"tokens": toks, "pos": jnp.int32(pos)})
        out = np.asarray(out)
        for i in live:
            tok = int(out[i])
            req = self.slots[i]
            req.out.append(tok)
            self.cur_token[i] = tok
            self.slot_pos[i] += 1
            self.slot_remaining[i] -= 1
            if tok == self.eos_id or self.slot_remaining[i] <= 0 \
                    or self.slot_pos[i] >= self.kv_len - 1:
                req.done = True
                self.slots[i] = None
        return len(live)

    def run(self, max_steps: int = 1_000) -> None:
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
