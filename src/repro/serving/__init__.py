from repro.serving.serve_step import make_serve_step, make_prefill_step, greedy_sample
from repro.serving.batching import ContinuousBatcher, Request
