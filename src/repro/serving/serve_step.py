"""Serving steps: prefill + decode with donated KV caches.

``make_serve_step`` builds the jitted one-token decode used by the dry-run
(``decode_*`` cells lower THIS, not train_step) and by the continuous-batching
scheduler in ``batching.py``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.registry import ModelBundle

__all__ = ["make_prefill_step", "make_serve_step", "greedy_sample"]


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


def make_prefill_step(bundle: ModelBundle) -> Callable:
    def prefill_step(params, batch):
        return bundle.prefill(params, batch)

    return prefill_step


def make_serve_step(bundle: ModelBundle, sample: bool = False) -> Callable:
    """decode step: (params, cache, batch{tokens,pos}) -> (out, new_cache).

    The cache argument is donated by the launcher's jit so decode is
    in-place on device — the steady-state serving memory is exactly one cache.
    """
    def serve_step(params, cache, batch):
        logits, new_cache = bundle.decode(params, cache, batch)
        out = greedy_sample(logits) if sample else logits
        return out, new_cache

    return serve_step
