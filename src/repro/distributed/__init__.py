from repro.distributed.sharding import (
    data_axes, param_shardings, batch_shardings, cache_shardings,
    opt_state_shardings,
)
