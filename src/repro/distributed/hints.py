"""Sharding hints: mesh-aware ``with_sharding_constraint`` helpers callable
from model code without plumbing the mesh through every layer.

Inside a ``jax.set_mesh(mesh)`` scope the ambient abstract mesh exposes the
axis names; outside any mesh (unit tests, single-device smoke runs) every
helper is a no-op, so model code can sprinkle constraints freely.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["axis", "dp_axes", "constrain"]


def _mesh_axes() -> Tuple[str, ...]:
    m = jax.sharding.get_abstract_mesh()
    return tuple(m.axis_names) if m is not None and not m.empty else ()


def axis(name: str) -> Optional[str]:
    """`name` if the ambient mesh has it, else None (spec entry no-op)."""
    return name if name in _mesh_axes() else None


def dp_axes():
    """The data-parallel axes of the ambient mesh ('pod'+'data')."""
    axes = tuple(a for a in ("pod", "data") if a in _mesh_axes())
    return axes if axes else None


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint iff an ambient mesh exists and the spec'd
    axes divide; otherwise identity."""
    axes = _mesh_axes()
    if not axes:
        return x
    m = jax.sharding.get_abstract_mesh()
    norm = []
    for dim, s in enumerate(spec):
        entry = tuple(a for a in ((s,) if isinstance(s, (str, type(None))) else s)
                      if a is not None and a in axes)
        if not entry:
            norm.append(None)
            continue
        size = 1
        for a in entry:
            size *= m.shape[a]
        if x.shape[dim] % size != 0:
            norm.append(None)
            continue
        norm.append(entry if len(entry) > 1 else entry[0])
    return jax.lax.with_sharding_constraint(x, P(*norm))
