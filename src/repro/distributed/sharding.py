"""Sharding rules: DP / TP / EP / SP layouts for every architecture.

The production mesh is (data, model) = (16, 16) per pod, with an outer "pod"
axis across pods (launch/mesh.py).  Rules:

  * DP   — batch over ("pod", "data"); gradients all-reduce hierarchically.
  * TP   — Megatron column/row pairs: projections' *output* features on
           "model" for QKV/wi, *input* features for wo/wo_f; vocab on "model"
           for embed/lm_head (padded to divide); a dim that doesn't divide the
           axis stays unsharded and the SPMD partitioner picks the collective.
  * EP   — MoE expert dim on "model" (experts padded to divide).
  * SP   — long-context decode (batch 1): KV-cache *sequence* on the data
           axis (and model axis when KV heads don't divide), so attention
           reduces over shards (ring-attention-style partial softmax, done by
           the partitioner).
  * ZeRO-1 — optimizer moments additionally sharded over "data" on the
           largest divisible dim.

Rules are name/shape driven over the params pytree (scanned periods carry a
leading stacking dim, handled by rank offset).
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell

__all__ = [
    "data_axes",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "opt_state_shardings",
]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes that play the DP role (pod+data when multi-pod)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.shape and n % mesh.shape[axis] == 0


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------
_COL = ("wq", "wk", "wv", "wi", "wr", "wgate", "wx", "shared_i", "wog",
        "in_i", "in_f", "in_z", "in_o")
_ROW = ("wo_f", "wo_r", "wo_m", "wo_s", "shared_o")


def _param_rule(path: str, shape: Tuple[int, ...], cfg: ModelConfig,
                mesh: Mesh, stacked: bool) -> P:
    """PartitionSpec for one parameter.  `stacked`: leading period dim."""
    lead = (None,) if stacked else ()
    dims = shape[1:] if stacked else shape
    name = path.split("/")[-1]

    def spec(*entries):
        return P(*(lead + entries))

    # embeddings / unembedding
    if name == "embed":
        return spec("model" if _div(dims[0], mesh, "model") else None, None)
    if name == "lm_head":
        return spec(None, "model" if _div(dims[1], mesh, "model") else None)
    if name in ("img_proj", "frontend_proj"):
        return spec(None, "model" if _div(dims[1], mesh, "model") else None)

    # MoE experts: EP on the expert dim
    if name in ("we_i", "we_o"):
        return spec("model" if _div(dims[0], mesh, "model") else None, None, None)
    if name == "router":
        return spec(None, None)

    # biases / norms / scalars
    if len(dims) <= 1:
        return spec(*([None] * len(dims)))

    # column-parallel (output features sharded)
    if name in _COL or (name.startswith("w") and name not in _ROW):
        return spec(None, "model" if _div(dims[1], mesh, "model") else None)
    # row-parallel (input features sharded)
    if name in _ROW:
        return spec("model" if _div(dims[0], mesh, "model") else None, None)
    # conv kernels (cw, R): shard channels
    if name == "conv":
        return spec(None, "model" if _div(dims[1], mesh, "model") else None)
    # per-head tensors (H, hd, hd)
    if len(dims) == 3:
        return spec("model" if _div(dims[0], mesh, "model") else None, None, None)
    return spec(*([None] * len(dims)))


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_abstract: Any) -> Any:
    """NamedSharding pytree matching the params pytree."""

    def visit(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        name = pstr.split("/")[-1]
        stacked = ("periods" in pstr) or ("enc_layers" in pstr) or ("dec_layers" in pstr)
        spec = _param_rule(pstr, leaf.shape, cfg, mesh, stacked)
        # sanity: rank match
        if len(spec) > len(leaf.shape):
            spec = P(*([None] * len(leaf.shape)))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, params_abstract)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------
def batch_shardings(cfg: ModelConfig, mesh: Mesh, specs: Dict[str, jax.ShapeDtypeStruct],
                    cell: ShapeCell) -> Dict[str, NamedSharding]:
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    out = {}
    for name, s in specs.items():
        if s.ndim == 0:
            out[name] = NamedSharding(mesh, P())
            continue
        b = s.shape[0]
        batch_spec = dp if b % dp_size == 0 else (
            dp[-1] if b % mesh.shape[dp[-1]] == 0 else None)
        rest = [None] * (s.ndim - 1)
        out[name] = NamedSharding(mesh, P(batch_spec, *rest))
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_abstract: Any,
                    batch: int) -> Any:
    """KV caches: batch on DP axes when it divides; KV heads on "model" when
    they divide, else the *sequence* dim goes on "model" (SP).  Long-context
    batch-1 decode: sequence is sharded over every available axis."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    tp = mesh.shape.get("model", 1)

    def visit(path, leaf):
        shape = leaf.shape
        if len(shape) == 4 and shape[0] == batch:        # (B, S, Hkv, hd) KV
            b, s, h, _ = shape
            if b % dp_size == 0 and b >= dp_size:
                bspec = dp
                sspec = None
                hspec = "model" if h % tp == 0 else None
                if hspec is None and s % tp == 0:
                    sspec = "model"
                return NamedSharding(mesh, P(bspec, sspec, hspec, None))
            # batch too small (long-context): shard sequence over everything
            axes = list(dp) + (["model"] if s % (dp_size * tp) == 0 else [])
            if s % int(np.prod([mesh.shape[a] for a in axes])) == 0:
                return NamedSharding(mesh, P(None, tuple(axes), None, None))
            return NamedSharding(mesh, P(None, None, None, None))
        if len(shape) == 5:                               # stacked (L/P, B, S, H, hd)
            inner = visit(path, jax.ShapeDtypeStruct(shape[1:], leaf.dtype))
            return NamedSharding(mesh, P(None, *inner.spec))
        if len(shape) >= 1 and shape[0] == batch and batch % dp_size == 0:
            return NamedSharding(mesh, P(dp, *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(visit, cache_abstract)


# ---------------------------------------------------------------------------
# optimizer state (ZeRO-1)
# ---------------------------------------------------------------------------
def opt_state_shardings(cfg: ModelConfig, mesh: Mesh, params_abstract: Any) -> Any:
    """Moments/master params: params' TP sharding + the largest remaining
    unsharded dim over "data" when divisible (ZeRO-1)."""
    base = param_shardings(cfg, mesh, params_abstract)
    dsz = mesh.shape.get("data", 1)

    def widen(leaf, sh):
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        # choose the largest dim not already sharded
        cand = [(leaf.shape[i], i) for i in range(len(spec)) if spec[i] is None]
        for size, i in sorted(cand, reverse=True):
            if size % dsz == 0 and size >= dsz:
                spec[i] = "data"
                break
        return NamedSharding(sh.mesh, P(*spec))

    return jax.tree.map(widen, params_abstract, base)
