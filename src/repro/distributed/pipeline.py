"""Pipeline parallelism (optional feature): GPipe schedule over a "pipe" axis.

Each mesh stage holds one contiguous block of layers; microbatches stream
through via ``collective_permute`` (the TPU ICI neighbor hop).  The schedule
is the classic GPipe fill-drain: ``M + P - 1`` ticks for M microbatches over
P stages, bubble fraction ``(P-1)/(M+P-1)``.

This is the config-flag feature promised in DESIGN.md §5 — the production
meshes default to DP×TP (+EP/SP); PP composes for >2-pod scale-out where a
"pipe" axis replaces "pod".  Correctness is gated by
``tests/test_pipeline.py`` (pipelined == sequential, fwd and grads).
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["gpipe", "pipeline_transformer"]


def gpipe(stage_fn: Callable, mesh: Mesh, n_stages: int, axis_name: str = "pipe"):
    """Build a pipelined apply: ``f(stage_params_stacked, mb_inputs) -> outs``.

    stage_fn(params_one_stage, x_mb) -> y_mb  (same shape as x_mb)
    stage_params_stacked: pytree with leading dim ``n_stages``.
    mb_inputs: (M, mb, ...) microbatches.

    Schedule: at tick t, stage s processes microbatch ``t - s`` (when in
    range); activations hop s -> s+1 between ticks.  Output microbatch m
    leaves the last stage at tick ``m + P - 1``.
    """

    def run(stage_params, mbs):
        M = mbs.shape[0]
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def body(params_local, mbs_local):
            # shard_map keeps the sharded stage dim with local extent 1
            params_local = jax.tree.map(lambda a: a[0], params_local)
            stage = jax.lax.axis_index(axis_name)
            buf = jnp.zeros_like(mbs_local[0])
            outs = jnp.zeros_like(mbs_local)
            for t in range(M + n_stages - 1):
                # stage 0 injects microbatch t; others consume the hop buffer
                inject = mbs_local[min(t, M - 1)]
                x_in = jnp.where(stage == 0, inject, buf)
                y = stage_fn(params_local, x_in)
                # microbatch index currently at this stage: t - stage
                mb_idx = t - stage
                # last stage banks its finished microbatch
                is_last = stage == n_stages - 1
                valid = is_last & (mb_idx >= 0) & (mb_idx < M)
                slot = jnp.clip(mb_idx, 0, M - 1)
                outs = jax.lax.cond(
                    valid,
                    lambda o: jax.lax.dynamic_update_index_in_dim(
                        o, y, slot, 0),
                    lambda o: o,
                    outs,
                )
                buf = jax.lax.ppermute(y, axis_name, perm)
            # everyone returns outs; only the last stage's is real — broadcast
            # it (one hop ring: psum of masked outs)
            outs = jnp.where(stage == n_stages - 1, outs, 0)
            return jax.lax.psum(outs, axis_name)

        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(axis_name), P()),
            out_specs=P(),
            check_vma=False,
        )
        return fn(stage_params, mbs)

    return run


def pipeline_transformer(layer_fn: Callable, mesh: Mesh, n_stages: int,
                         axis_name: str = "pipe"):
    """Pipelined stack of identical layers: params stacked (n_stages,
    layers_per_stage, ...); each stage scans its local layers."""

    def stage_fn(stage_params, x):
        def one(x, lp):
            return layer_fn(lp, x), None

        y, _ = jax.lax.scan(one, x, stage_params)
        return y

    return gpipe(stage_fn, mesh, n_stages, axis_name)
