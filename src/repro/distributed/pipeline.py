"""Pipeline parallelism + sharded study-plan execution.

Part 1 (GPipe): schedule over a "pipe" axis for the model stack.
Part 2 (``execute_plan_sharded``): run a ``repro.study`` Plan shard-local
under ``shard_map`` over patient-partitioned flat tables.

Each mesh stage holds one contiguous block of layers; microbatches stream
through via ``collective_permute`` (the TPU ICI neighbor hop).  The schedule
is the classic GPipe fill-drain: ``M + P - 1`` ticks for M microbatches over
P stages, bubble fraction ``(P-1)/(M+P-1)``.

This is the config-flag feature promised in DESIGN.md §5 — the production
meshes default to DP×TP (+EP/SP); PP composes for >2-pod scale-out where a
"pipe" axis replaces "pod".  Correctness is gated by
``tests/test_pipeline.py`` (pipelined == sequential, fwd and grads).
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["gpipe", "pipeline_transformer", "compat_shard_map",
           "execute_plan_sharded", "pad_tables_for_mesh"]


def pad_tables_for_mesh(tables, n_shards: int):
    """Pad table capacities to a multiple of ``32 * n_shards`` so the packed
    uint32 validity words split across the mesh axis exactly on shard row
    boundaries (each shard's word slice is the bitset of its local rows).
    Idempotent — already-padded tables pass through untouched — so resident
    table sets (``study.service``) can pre-pad once at load time."""
    quantum = 32 * int(n_shards)
    out = {}
    for name, t in tables.items():
        cap = -(-t.capacity // quantum) * quantum
        out[name] = t.pad_to(cap) if cap != t.capacity else t
    return out


def compat_shard_map(f: Callable, mesh: Mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (>=0.6 top-level with check_vma;
    older releases only ship ``jax.experimental.shard_map`` with check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def gpipe(stage_fn: Callable, mesh: Mesh, n_stages: int, axis_name: str = "pipe"):
    """Build a pipelined apply: ``f(stage_params_stacked, mb_inputs) -> outs``.

    stage_fn(params_one_stage, x_mb) -> y_mb  (same shape as x_mb)
    stage_params_stacked: pytree with leading dim ``n_stages``.
    mb_inputs: (M, mb, ...) microbatches.

    Schedule: at tick t, stage s processes microbatch ``t - s`` (when in
    range); activations hop s -> s+1 between ticks.  Output microbatch m
    leaves the last stage at tick ``m + P - 1``.
    """

    def run(stage_params, mbs):
        M = mbs.shape[0]
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def body(params_local, mbs_local):
            # shard_map keeps the sharded stage dim with local extent 1
            params_local = jax.tree.map(lambda a: a[0], params_local)
            stage = jax.lax.axis_index(axis_name)
            buf = jnp.zeros_like(mbs_local[0])
            outs = jnp.zeros_like(mbs_local)
            for t in range(M + n_stages - 1):
                # stage 0 injects microbatch t; others consume the hop buffer
                inject = mbs_local[min(t, M - 1)]
                x_in = jnp.where(stage == 0, inject, buf)
                y = stage_fn(params_local, x_in)
                # microbatch index currently at this stage: t - stage
                mb_idx = t - stage
                # last stage banks its finished microbatch
                is_last = stage == n_stages - 1
                valid = is_last & (mb_idx >= 0) & (mb_idx < M)
                slot = jnp.clip(mb_idx, 0, M - 1)
                outs = jax.lax.cond(
                    valid,
                    lambda o: jax.lax.dynamic_update_index_in_dim(
                        o, y, slot, 0),
                    lambda o: o,
                    outs,
                )
                buf = jax.lax.ppermute(y, axis_name, perm)
            # everyone returns outs; only the last stage's is real — broadcast
            # it (one hop ring: psum of masked outs)
            outs = jnp.where(stage == n_stages - 1, outs, 0)
            return jax.lax.psum(outs, axis_name)

        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(axis_name), P()),
            out_specs=P(),
            check_vma=False,
        )
        return fn(stage_params, mbs)

    return run


def pipeline_transformer(layer_fn: Callable, mesh: Mesh, n_stages: int,
                         axis_name: str = "pipe"):
    """Pipelined stack of identical layers: params stacked (n_stages,
    layers_per_stage, ...); each stage scans its local layers."""

    def stage_fn(stage_params, x):
        def one(x, lp):
            return layer_fn(lp, x), None

        y, _ = jax.lax.scan(one, x, stage_params)
        return y

    return gpipe(stage_fn, mesh, n_stages, axis_name)


# ---------------------------------------------------------------------------
# sharded study-plan execution
# ---------------------------------------------------------------------------
def execute_plan_sharded(plan, tables, n_patients: int, mesh: Mesh,
                         axis_name: str = "data", engine: str = "xla",
                         predicate_engine: str | None = None):
    """Execute a study ``Plan`` shard-local over a mesh ``data`` axis.

    Requirement (same as ``transformers.exposures_sharded``): the flat tables
    are *patient-partitioned* — ``distributed_flatten`` keys its output on
    ``patient_id`` — so every per-patient / per-stay operation (masks, dedupe,
    sorts, transformer folds, subject bitsets) is shard-local and needs no
    collective.  Cross-shard stitches are scalar/bitset ``psum``s only:

      * subject bitsets: each patient lives on exactly one shard, so partial
        bitsets are disjoint and ``psum`` is the bitwise OR;
      * node row counts: local counts sum to the global count.

    Table outputs come back shard-concatenated (each shard's block compacted
    locally, global ``count`` from the psum); they remain valid-masked tables
    like every other plan output.  Returns ``(vals, counts, stats)`` shaped
    like the local executor's so ``Study.run`` shares its realization path —
    ``stats`` holds per-join FlatteningStats as host ints (psum over shards:
    local row counts / overflows / key checksums sum to the global ones).

    Validity is **bitset-sharded**: tables carry packed uint32 validity
    words, so source capacities are padded to a multiple of ``32 * n`` — the
    word array then splits across the mesh axis exactly on shard row
    boundaries, each shard's slice being the packed bitset of its local
    rows — and shard-local table outputs are padded back to a 32-aligned
    capacity before leaving the shard_map so the concatenated global words
    stay row-exact.  Cross-shard subject bitsets and per-node popcounts
    remain scalar/word ``psum``s (disjoint patients: psum == bitwise OR).
    """
    import numpy as np
    from repro.core.bitset import count as _bits_count
    from repro.core.columnar import ColumnarTable
    from repro.study.executor import run_plan_body
    from repro.study.plan import COHORT_OPS, TABLE_OPS

    n = mesh.shape[axis_name]
    env = pad_tables_for_mesh({src: tables[src] for src in plan.sources()}, n)
    cols_in = {s: dict(t.columns) for s, t in env.items()}
    valid_in = {s: t.valid for s, t in env.items()}

    out_ids = {i for _, i in plan.outputs}
    table_ids = tuple(i for i in sorted(out_ids)
                      if plan.nodes[i].op in TABLE_OPS)
    # base cohort bitsets cross shards (psum == OR for disjoint patients);
    # interior cohort_op bits stay local — the Study layer replays the
    # algebra on realized operands — but named cohort outputs still export.
    cohort_ids = tuple(i for i, nd in enumerate(plan.nodes)
                       if nd.op == "cohort_from_events"
                       or (nd.op in COHORT_OPS and i in out_ids))
    # event tables feeding cohorts must be realized too (Cohort.events)
    ev_ids = tuple(sorted(set(table_ids) | {
        nd.inputs[0] for nd in plan.nodes if nd.op == "cohort_from_events"}))

    # key on mesh *content* — an id() key could hand a new mesh allocated at
    # a freed mesh's address a stale compiled fn bound to dead devices.
    # Memoized through the executor's shared cache (``cached_executable``),
    # so sharded executables show up in — and reset with — the same
    # ``jit_cache_info()`` compile/hit audit as local ones.
    mesh_key = (tuple(mesh.axis_names),
                tuple(mesh.shape[a] for a in mesh.axis_names),
                tuple(d.id for d in np.ravel(mesh.devices)))
    from repro.kernels.predicate import resolve_engine
    from repro.study.executor import cached_executable

    peng = resolve_engine(predicate_engine, engine)
    key = (plan.key(), n_patients, engine, peng, mesh_key, axis_name)

    def build():
        def body(cols, valids):
            local = {s: ColumnarTable(c, valids[s],
                                      _bits_count(valids[s]))
                     for s, c in cols.items()}
            vals, counts, stats = run_plan_body(
                plan, local, n_patients, engine, axis_name=axis_name,
                n_shards=n, predicate_engine=peng)

            def _aligned(t):
                # 32-align the local capacity so the shard-concatenated
                # validity words stay row-exact on the host side
                cap = -(-t.capacity // 32) * 32
                return t if cap == t.capacity else t.pad_to(cap)

            t_out = {}
            for i in ev_ids:
                t = _aligned(vals[i])
                t_out[i] = (dict(t.columns), t.valid)
            b_out = {i: jax.lax.psum(vals[i], axis_name) for i in cohort_ids}
            # local counts sum to global counts; stacked -> one psum+transfer
            ids = tuple(sorted(counts))
            c_out = jax.lax.psum(jnp.stack([counts[i] for i in ids]), axis_name)
            s_out = jax.lax.psum(stats, axis_name) if stats else {}
            return t_out, b_out, c_out, s_out

        return jax.jit(compat_shard_map(
            body, mesh,
            in_specs=(P(axis_name), P(axis_name)),
            out_specs=(P(axis_name), P(), P(), P()),
        ))

    fn = cached_executable(key, build)
    t_out, b_out, counts_vec, s_out = fn(cols_in, valid_in)
    from repro.study.executor import _host_stats, traced_ids

    counts = {i: int(c) for i, c in
              zip(traced_ids(plan), np.asarray(counts_vec))}
    vals = {i: ColumnarTable(c, v, jnp.int32(counts[i]))
            for i, (c, v) in t_out.items()}
    vals.update(b_out)
    return vals, counts, _host_stats(s_out)
