"""xlstm-125m [arXiv:2405.04517; unverified]: alternating mLSTM (parallel
matrix-memory) and sLSTM (scalar-memory scan) blocks; no separate FFN
(d_ff=0 — projections live inside the blocks)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    pattern=("mlstm", "slstm"),
    tie_embeddings=True,
)
