"""gemma3-12b [hf:google/gemma-3-12b-pt; unverified]: 5 local (window 1024) :
1 global pattern, 128k context."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,                 # 8 periods of (5×local, global)
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15_360,
    vocab_size=262_144,
    head_dim=240,
    pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
    window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
