"""Model configuration schema for the assigned-architecture zoo.

One frozen dataclass drives every family (dense / MoE / hybrid / ssm / vlm /
audio-encdec).  Layer stacking is expressed as a repeating *pattern period*
(e.g. gemma3's 5 local + 1 global) so the model can ``lax.scan`` over periods —
essential for compile time at 48 layers × 512 devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeCell", "SHAPES"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # layer pattern: one entry per layer within a repeating period.
    # kinds: "attn" (full causal), "swa" (sliding window), "rglru",
    #        "mlstm", "slstm"
    pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                # sliding window for "swa" layers
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # MoE
    n_experts: int = 0             # routed experts (0 = dense FFN)
    n_shared_experts: int = 0
    top_k: int = 0
    first_dense_layers: int = 0    # leading layers with dense FFN
    dense_d_ff: int = 0            # d_ff of those dense layers (0 -> d_ff)
    capacity_factor: float = 1.25

    # recurrent (RG-LRU / xLSTM)
    d_rnn: int = 0                 # recurrence width (0 -> d_model)
    conv_width: int = 4

    # encoder-decoder
    is_encdec: bool = False
    n_encoder_layers: int = 0

    # modality frontend stub (precomputed embeddings supplied as inputs)
    frontend: str = "none"         # none | vision_patches | audio_frames
    frontend_dim: int = 0          # embedding dim of the precomputed frontend
    n_frontend_tokens: int = 0     # tokens contributed by the frontend

    # numerics / parallelism knobs
    dtype: str = "bfloat16"
    remat: bool = True
    # vocab / expert padding so static dims divide the 16-way model axis
    pad_vocab_to: int = 256
    pad_experts_to: int = 16

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.pad_vocab_to)

    @property
    def padded_experts(self) -> int:
        if self.n_experts == 0:
            return 0
        return _round_up(self.n_experts, self.pad_experts_to)

    @property
    def d_rnn_(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        """Layers left over when the pattern doesn't divide n_layers."""
        rem = self.n_layers % len(self.pattern)
        return self.pattern[:rem]

    def params_per_token(self) -> int:
        """Active parameters N (for MODEL_FLOPS = 6·N·D roofline term)."""
        d, hd = self.d_model, self.head_dim_
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        n = 0
        counts = {}
        for i in range(self.n_layers):
            kind = (self.pattern + self.tail_pattern)[i % len(self.pattern)] \
                if i < self.n_periods * len(self.pattern) else self.tail_pattern[
                    i - self.n_periods * len(self.pattern)]
            counts[kind] = counts.get(kind, 0) + 1
        for kind, c in counts.items():
            if kind in ("attn", "swa"):
                n += c * attn
            elif kind == "rglru":
                # two in-proj branches + conv + gates + out-proj
                n += c * (2 * d * self.d_rnn_ + self.conv_width * self.d_rnn_
                          + 2 * self.d_rnn_ * self.d_rnn_ + self.d_rnn_ * d)
            elif kind in ("mlstm", "slstm"):
                n += c * (4 * d * d)
        # FFN
        if self.n_experts:
            moe_layers = self.n_layers - self.first_dense_layers
            active = (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff
            n += moe_layers * active
            n += self.first_dense_layers * 3 * d * (self.dense_d_ff or self.d_ff)
        elif self.d_ff:
            n += self.n_layers * 3 * d * self.d_ff
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.is_encdec:
            # encoder layers: self-attn + ffn; decoder already counted above
            n += self.n_encoder_layers * (attn + 3 * d * self.d_ff)
            # decoder cross-attention
            n += self.n_layers * attn
        return n

    def total_params(self) -> int:
        """Total parameters (MoE: all experts)."""
        if not self.n_experts:
            return self.params_per_token()
        d = self.d_model
        active = (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff
        full = (self.n_experts + self.n_shared_experts) * 3 * d * self.d_ff
        moe_layers = self.n_layers - self.first_dense_layers
        return self.params_per_token() + moe_layers * (full - active)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) column of the assignment matrix."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}
