"""seamless-m4t-medium [arXiv:2308.11596; hf]: encoder-decoder multimodal
backbone.  The speech frontend is a STUB — ``input_specs`` supplies
precomputed frame embeddings (B, S/4, 1024) to the encoder.  Vocab 256206 is
padded to 256256 for the 16-way model axis (Megatron convention)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,                 # decoder layers
    n_encoder_layers=12,
    is_encdec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    pattern=("attn",),
    frontend="audio_frames",
    frontend_dim=1024,
)
