"""recurrentgemma-2b [arXiv:2402.19427; hf]: Griffin — RG-LRU recurrent blocks
mixed with local attention at 1 attention : 2 recurrent; window 2048."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,                     # 8 periods of (rglru, rglru, swa) + 2 tail
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,                    # MQA
    d_ff=7680,                       # GeGLU
    vocab_size=256_000,
    head_dim=256,
    pattern=("rglru", "rglru", "swa"),
    window=2048,
    d_rnn=2560,
    conv_width=4,
    tie_embeddings=True,
)
