"""Assigned-architecture configs (public-literature specs; see each module)."""
from repro.configs.base import ModelConfig, ShapeCell, SHAPES
from repro.configs.archs import ARCHS, get_config, reduced_config

__all__ = ["ModelConfig", "ShapeCell", "SHAPES", "ARCHS", "get_config", "reduced_config"]
