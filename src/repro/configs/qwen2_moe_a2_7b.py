"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B]: 4 shared + 60 routed top-4.

60 routed experts are padded to 64 for the 16-way model axis (router logits of
pad experts are masked to -inf; zero active-parameter change) — the Megatron
vocab/expert padding convention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    pattern=("attn",),
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
