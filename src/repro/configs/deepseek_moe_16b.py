"""deepseek-moe-16b [arXiv:2401.06066; hf]: fine-grained MoE, 2 shared + 64
routed top-6; first layer dense (inter 10944, per the HF config)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    pattern=("attn",),
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    dense_d_ff=10_944,
    rope_theta=10_000.0,
)
