"""Architecture registry: ``--arch <id>`` -> ModelConfig, plus reduced
(smoke-test) variants of each family."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2moe
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma
from repro.configs.h2o_danube_1_8b import CONFIG as _danube
from repro.configs.llama3_2_3b import CONFIG as _llama32
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.qwen2_1_5b import CONFIG as _qwen2
from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.configs.phi3_vision_4_2b import CONFIG as _phi3v
from repro.configs.seamless_m4t_medium import CONFIG as _seamless

ARCHS = {
    c.name: c
    for c in (
        _deepseek, _qwen2moe, _rgemma, _danube, _llama32,
        _gemma3, _qwen2, _xlstm, _phi3v, _seamless,
    )
}

# long_500k applicability (DESIGN.md §Arch-applicability): sub-quadratic decode
LONG_CONTEXT_OK = {
    "recurrentgemma-2b", "h2o-danube-1.8b", "gemma3-12b", "xlstm-125m",
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ModelConfig:
    """Smoke-test variant: same family/pattern, tiny dims (CPU-runnable)."""
    c = get_config(name)
    pat_period = len(c.pattern)
    n_layers = max(pat_period, 2)
    if c.n_layers % pat_period:
        n_layers += c.n_layers % pat_period  # keep a tail layer if the real one has one
    return dataclasses.replace(
        c,
        n_layers=n_layers,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(c.n_kv_heads, 2)) if c.n_kv_heads < c.n_heads else 4,
        head_dim=16,
        d_ff=128 if c.d_ff else 0,
        dense_d_ff=160 if c.dense_d_ff else 0,
        vocab_size=512,
        n_experts=8 if c.n_experts else 0,
        n_shared_experts=min(c.n_shared_experts, 2),
        top_k=min(c.top_k, 2) if c.top_k else 0,
        pad_experts_to=4,
        window=16 if c.window else 0,
        d_rnn=64 if c.d_rnn else 0,
        n_encoder_layers=2 if c.is_encdec else 0,
        frontend_dim=32 if c.frontend != "none" else 0,
        n_frontend_tokens=8 if c.frontend == "vision_patches" else 0,
        pad_vocab_to=64,
        remat=False,
    )
