"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct]: phi3-mini
decoder backbone; the CLIP frontend is a STUB — ``input_specs`` supplies
precomputed patch embeddings (B, 576, 1024) projected into the first 576
sequence positions."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    pattern=("attn",),
    rope_theta=10_000.0,
    frontend="vision_patches",
    frontend_dim=1024,
    n_frontend_tokens=576,
)
