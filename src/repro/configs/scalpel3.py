"""The paper's own pipeline configuration (SCALPEL3's equivalent of the
textual configuration files driving SCALPEL-Flattening/-Extraction, §3.2-3.4).

A declarative study config: which sub-databases to flatten (with temporal
slicing), which concepts to extract, which transformers to run and with what
clinical parameters — the fracture/exposure study of paper §4.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class FlattenJob:
    database: str                 # DCIR | PMSI_MCO | SSR | HAD | IR_IMB
    time_column: str = ""         # temporal slicing column ("" = no slicing)
    n_slices: int = 1


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """One SCALPEL3 study, end to end."""

    name: str
    flatten: Tuple[FlattenJob, ...]
    extractors: Tuple[str, ...]          # names from repro.core.extraction
    drug_granularity: str = "cip13"
    prevalent_drug_codes: Tuple[int, ...] = tuple(range(65))  # task (c)
    exposure_purview_days: int = 60      # task (d)
    fracture_act_codes: Tuple[int, ...] = tuple(range(30))    # task (g)
    fracture_diag_codes: Tuple[int, ...] = tuple(range(40))
    fracture_washout_days: int = 90
    trackloss_gap_days: int = 120
    study_start: int = 14_600
    study_end: int = 14_600 + 3 * 365
    seq_len: int = 256                   # FeatureDriver token stream length


# the paper's §4 evaluation study
PAPER_STUDY = PipelineConfig(
    name="fractures-vs-exposures",
    flatten=(
        FlattenJob("DCIR", time_column="execution_date", n_slices=3),
        FlattenJob("PMSI_MCO"),
    ),
    extractors=(
        "patients", "drug_dispenses", "medical_acts_dcir",
        "medical_acts_pmsi", "diagnoses", "hospital_stays",
    ),
)

# the full Table-2 denormalization scope
FULL_SNDS = PipelineConfig(
    name="full-snds",
    flatten=(
        FlattenJob("DCIR", time_column="execution_date", n_slices=12),
        FlattenJob("PMSI_MCO"),
        FlattenJob("SSR"),
        FlattenJob("HAD"),
        FlattenJob("IR_IMB"),
    ),
    extractors=(
        "patients", "drug_dispenses", "medical_acts_dcir",
        "medical_acts_pmsi", "diagnoses", "hospital_stays",
        "biology_acts", "practitioner_encounters", "csarr_acts",
        "ssr_stays", "takeover_reasons", "long_term_diseases",
    ),
)
