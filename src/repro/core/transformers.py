"""SCALPEL-Extraction Transformers: ``List[Event] -> List[Event]`` per patient.

The paper's Transformer abstraction folds a patient's event list into complex
events (drug exposures, outcomes, follow-up...).  With events kept sorted by
``(patient, ...)`` (one sort at flatten time), every per-patient fold becomes a
*segment operation* — TPU-native, collective-free, and identical across shards
of a patient-partitioned table (DESIGN.md §2).

Implemented (paper Table 4): observation period, follow-up, trackloss,
exposures (limited/unlimited), fractures-per-body-site outcome.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.columnar import ColumnarTable, NULL_INT, is_null
from repro.core.events import Category, make_events, sort_events
from repro.core.metadata import OperationLog

__all__ = [
    "observation_period",
    "follow_up",
    "trackloss",
    "exposures",
    "fractures",
]

_BIG = jnp.int32(2_000_000_000)


def _seg_min(x, seg, num, valid):
    return jax.ops.segment_min(jnp.where(valid, x, _BIG), seg, num_segments=num)


def _seg_max(x, seg, num, valid):
    return jax.ops.segment_max(jnp.where(valid, x, -_BIG), seg, num_segments=num)


def _seg_sum(x, seg, num, valid):
    return jax.ops.segment_sum(jnp.where(valid, x, 0), seg, num_segments=num)


def _clip_seg(events: ColumnarTable, n_patients: int):
    seg = jnp.clip(events.columns["patient_id"], 0, n_patients - 1)
    return jnp.where(events.valid_bool(), seg, n_patients - 1)


# ---------------------------------------------------------------------------
def observation_period(events: ColumnarTable, n_patients: int) -> ColumnarTable:
    """Per-patient [first event, last event] continuous event (Table 4)."""
    seg = _clip_seg(events, n_patients)
    ev_valid = events.valid_bool()
    first = _seg_min(events.columns["start"], seg, n_patients, ev_valid)
    last_s = _seg_max(events.columns["start"], seg, n_patients, ev_valid)
    last_e = _seg_max(
        jnp.where(is_null(events.columns["end"]), events.columns["start"], events.columns["end"]),
        seg, n_patients, ev_valid,
    )
    cnt = _seg_sum(jnp.ones_like(seg), seg, n_patients, ev_valid)
    pid = jnp.arange(n_patients, dtype=jnp.int32)
    return make_events(
        patient_id=pid, category=Category.OBSERVATION, value=jnp.zeros_like(pid),
        start=first, end=jnp.maximum(last_s, last_e), weight=cnt.astype(jnp.float32),
        valid=cnt > 0,
    )


def follow_up(
    patients: ColumnarTable,
    events: ColumnarTable,
    n_patients: int,
    study_end: int,
    delay_days: int = 0,
) -> ColumnarTable:
    """Follow-up window per patient: [first event + delay, min(death, end)].

    Mirrors the paper's Follow-up transformer (sources: patients, observation
    period, optionally trackloss/deaths).
    """
    obs = observation_period(events, n_patients)
    start = obs.columns["start"] + jnp.int32(delay_days)
    # death date scattered into a dense patient-indexed array (robust to gaps
    # in the id space and to table padding)
    pidx = jnp.where(patients.valid_bool(), patients.columns["patient_id"], n_patients)
    death = (
        jnp.full((n_patients,), NULL_INT, jnp.int32)
        .at[pidx]
        .set(patients.columns["death_date"], mode="drop")
    )
    end = jnp.where(is_null(death), jnp.int32(study_end), jnp.minimum(death, study_end))
    valid = obs.valid_bool() & (start < end)
    pid = jnp.arange(n_patients, dtype=jnp.int32)
    return make_events(
        patient_id=pid, category=Category.FOLLOW_UP, value=jnp.zeros_like(pid),
        start=start, end=end, valid=valid,
    )


def trackloss(dispenses: ColumnarTable, n_patients: int, gap_days: int) -> ColumnarTable:
    """Trackloss: a gap > ``gap_days`` between consecutive dispenses of the
    same patient marks loss of follow-up at ``last_seen + gap_days``."""
    ev = sort_events(dispenses)
    pid = ev.columns["patient_id"]
    start = ev.columns["start"]
    evv = ev.valid_bool()
    same = jnp.concatenate([jnp.zeros((1,), bool), (pid[1:] == pid[:-1]) & evv[:-1]])
    prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), start[:-1]])
    gap = jnp.where(same & evv, start - prev, 0)
    hit = gap > gap_days
    out = make_events(
        patient_id=pid, category=Category.TRACKLOSS, value=jnp.zeros_like(pid),
        start=prev + jnp.int32(gap_days), valid=hit,
    )
    # one trackloss per patient: keep the earliest
    seg = _clip_seg(out, n_patients)
    outv = out.valid_bool()
    first = _seg_min(out.columns["start"], seg, n_patients, outv)
    keep = outv & (out.columns["start"] == first[seg])
    dup = jnp.concatenate([jnp.zeros((1,), bool), (seg[1:] == seg[:-1]) & keep[:-1]])
    return out.filter(keep & ~dup)


def exposures(
    dispenses: ColumnarTable,
    n_patients: int,
    purview_days: int = 60,
    limited: bool = True,
    follow_up_events: Optional[ColumnarTable] = None,
    min_dispenses: int = 1,
) -> ColumnarTable:
    """Drug-exposure transformer (paper Table 4, 'Limited in time'/'Unlimited').

    Consecutive dispenses of the same (patient, drug) closer than
    ``purview_days`` merge into one exposure interval.  Vectorized as: sort by
    (patient, drug, date) -> boundary flags -> exposure ids by prefix sum ->
    per-exposure segment min/max/count.  The segmented-scan hot path has a
    Pallas kernel (``kernels/segment_scan``); this is the jnp oracle the
    kernel is validated against.
    """
    ev = dispenses.sort_by(["patient_id", "value", "start"])
    cap = ev.capacity
    pid, val, start = ev.columns["patient_id"], ev.columns["value"], ev.columns["start"]

    evv = ev.valid_bool()
    same_group = jnp.concatenate(
        [jnp.zeros((1,), bool), (pid[1:] == pid[:-1]) & (val[1:] == val[:-1]) & evv[:-1]]
    )
    prev_start = jnp.concatenate([jnp.zeros((1,), jnp.int32), start[:-1]])
    chained = same_group & (start - prev_start <= purview_days)
    new_exposure = evv & ~chained
    # exposure id per row (0-based); invalid rows ride along harmlessly
    eid = jnp.cumsum(new_exposure.astype(jnp.int32)) - 1
    eid = jnp.clip(eid, 0, cap - 1)

    first = _seg_min(start, eid, cap, evv)
    last = _seg_max(start, eid, cap, evv)
    n_disp = _seg_sum(jnp.ones_like(eid), eid, cap, evv)
    e_pid = _seg_max(pid, eid, cap, evv)
    e_val = _seg_max(val, eid, cap, evv)

    end = last + jnp.int32(purview_days)
    if not limited:
        if follow_up_events is None:
            raise ValueError("unlimited exposures require follow_up_events")
        fu_end = follow_up_events.sort_by(["patient_id"]).columns["end"][:n_patients]
        end = jnp.maximum(end, fu_end[jnp.clip(e_pid, 0, n_patients - 1)])

    valid = n_disp >= min_dispenses
    return make_events(
        patient_id=e_pid, category=Category.EXPOSURE, value=e_val,
        start=first, end=end, weight=n_disp.astype(jnp.float32), valid=valid,
    ).compact()


def exposures_sharded(
    dispenses: ColumnarTable,
    n_patients: int,
    mesh,
    axis_name: str = "data",
    **kw,
) -> ColumnarTable:
    """Shard-local exposures over a *patient-partitioned* event table.

    ``distributed_flatten`` keys its output on ``patient_id`` — every patient's
    events live on one shard, so the per-patient fold needs NO collectives:
    each shard runs the plain ``exposures`` transformer on its rows.  This is
    the missing scaling piece for the paper's task (d) (global sort/segment
    ops do not shard; the patient-partitioned layout makes them local).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.pipeline import compat_shard_map

    n = mesh.shape[axis_name]
    # word-aligned shard blocks: the packed validity words split across the
    # mesh axis only when every shard's row block is a multiple of 32
    quantum = 32 * n
    cap = -(-dispenses.capacity // quantum) * quantum
    t = dispenses.pad_to(cap) if cap != dispenses.capacity else dispenses

    def body(cols, valid):
        local = ColumnarTable.from_columns(cols, valid=valid)
        out = exposures(local, n_patients, **kw)
        return dict(out.columns), out.valid

    fn = compat_shard_map(
        body, mesh, in_specs=(P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name)),
    )
    cols, valid = fn(dict(t.columns), t.valid)
    return ColumnarTable.from_columns(cols, valid=valid)


def fractures(
    acts: ColumnarTable,
    diags: ColumnarTable,
    fracture_act_codes: Sequence[int],
    fracture_diag_codes: Sequence[int],
    n_sites: int = 8,
    washout_days: int = 90,
) -> ColumnarTable:
    """Fracture outcome (paper task (g), algorithm of ref. [9]): fracture
    candidates from medical acts + diagnoses, one outcome per body site per
    washout window.

    The greedy per-(patient, site) washout chain is order-dependent, so it is
    a genuine ``lax.scan`` (the only sequential transformer); everything
    before it is columnar.
    """
    a_codes = jnp.asarray(np.asarray(fracture_act_codes, np.int32))
    d_codes = jnp.asarray(np.asarray(fracture_diag_codes, np.int32))
    a = acts.filter(jnp.isin(acts.columns["value"], a_codes))
    d = diags.filter(jnp.isin(diags.columns["value"], d_codes))
    cand = ColumnarTable.concat([a.select(["patient_id", "value", "start"]),
                                 d.select(["patient_id", "value", "start"])])
    # body-site mapping: configurable hash of the code space (stand-in for the
    # ref-[9] site tables; real deployments load a code->site mapping array).
    site = (cand.columns["value"] % jnp.int32(n_sites)).astype(jnp.int32)
    cand = cand.with_columns({"site": site})
    cand = cand.sort_by(["patient_id", "site", "start"])

    pid = cand.columns["patient_id"]
    sit = cand.columns["site"]
    dat = cand.columns["start"]

    def body(carry, x):
        prev_p, prev_s, prev_d = carry
        p, s, t, v = x
        fresh = (p != prev_p) | (s != prev_s) | (t - prev_d >= washout_days)
        keep = v & fresh
        return (
            jnp.where(keep, p, prev_p),
            jnp.where(keep, s, prev_s),
            jnp.where(keep, t, prev_d),
        ), keep

    init = (jnp.int32(-1), jnp.int32(-1), jnp.int32(-2_000_000_000))
    _, keep = jax.lax.scan(body, init, (pid, sit, dat, cand.valid_bool()))

    kept = cand.filter(keep)
    return make_events(
        patient_id=kept.columns["patient_id"], category=Category.OUTCOME_FRACTURE,
        value=kept.columns["value"], start=kept.columns["start"],
        group_id=kept.columns["site"], valid=kept.valid,
    ).compact()


# --- additional transformers (paper Table 4) ---------------------------------
def drug_prescriptions(dispenses: ColumnarTable, n_patients: int,
                       refill_days: int = 30) -> ColumnarTable:
    """Drug-prescription proxy (Table 4): consecutive dispenses of the same
    drug within ``refill_days`` belong to one prescription; the event spans
    first..last dispense (weight = refill count)."""
    ex = exposures(dispenses, n_patients, purview_days=refill_days,
                   limited=True)
    # re-tag: a prescription ends at its last dispense, not +purview
    end = jnp.maximum(ex.columns["end"] - jnp.int32(refill_days),
                      ex.columns["start"])
    return ColumnarTable(
        {**ex.columns, "end": end,
         "category": jnp.full_like(ex.columns["category"], Category.DRUG_DISPENSE)},
        ex.valid, ex.count, ex.capacity,
    )


def drug_interactions(dispenses: ColumnarTable, n_patients: int,
                      window_days: int = 30) -> ColumnarTable:
    """Drug-interaction events (Table 4): two *different* drugs dispensed to
    the same patient within ``window_days``.  Columnar: sort by (patient,
    date); an interaction fires when the previous dispense is a different
    drug within the window.  value = pair hash, group = other drug."""
    ev = dispenses.sort_by(["patient_id", "start"])
    pid = ev.columns["patient_id"]
    val = ev.columns["value"]
    start = ev.columns["start"]
    evv = ev.valid_bool()
    prev_ok = jnp.concatenate([jnp.zeros((1,), bool), evv[:-1]])
    same_p = jnp.concatenate([jnp.zeros((1,), bool), pid[1:] == pid[:-1]]) & prev_ok
    prev_val = jnp.concatenate([jnp.zeros((1,), jnp.int32), val[:-1]])
    prev_start = jnp.concatenate([jnp.zeros((1,), jnp.int32), start[:-1]])
    hit = evv & same_p & (val != prev_val) & (start - prev_start <= window_days)
    pair = jnp.minimum(val, prev_val) * jnp.int32(100_003) + jnp.maximum(val, prev_val)
    out = make_events(
        patient_id=pid, category=Category.EXPOSURE, value=pair,
        start=start, group_id=prev_val, valid=hit,
    )
    return out.compact()


def _code_outcome(name_cat: int, acts: ColumnarTable, diags: ColumnarTable,
                  act_codes, diag_codes, washout_days: int) -> ColumnarTable:
    return fractures(acts, diags, act_codes, diag_codes, n_sites=1,
                     washout_days=washout_days)


def bladder_cancer(acts: ColumnarTable, diags: ColumnarTable,
                   act_codes=(101, 102), diag_codes=(188, 189),
                   washout_days: int = 365) -> ColumnarTable:
    """Bladder-cancer outcome (paper Table 4; the Neumann pioglitazone study
    [29] algorithm shape: act+diagnosis conjunction, yearly washout)."""
    return _code_outcome(Category.OUTCOME_FRACTURE, acts, diags,
                         list(act_codes), list(diag_codes), washout_days)


def infarctus(diags: ColumnarTable, diag_codes=(210, 211, 212),
              washout_days: int = 180) -> ColumnarTable:
    """Myocardial-infarction outcome (Table 4: diagnoses only)."""
    empty = diags.filter(jnp.zeros((diags.capacity,), bool))
    return _code_outcome(Category.OUTCOME_FRACTURE, empty, diags,
                         [], list(diag_codes), washout_days)


def heart_failure(diags: ColumnarTable, diag_codes=(220, 221),
                  washout_days: int = 180) -> ColumnarTable:
    """Heart-failure outcome (Table 4: diagnoses only)."""
    empty = diags.filter(jnp.zeros((diags.capacity,), bool))
    return _code_outcome(Category.OUTCOME_FRACTURE, empty, diags,
                         [], list(diag_codes), washout_days)
