"""Provenance tracking (paper §3.4–3.5): every pipeline operation is logged
with row counts so flowcharts and audits can be rebuilt from metadata alone —
the paper stores this as a JSON file next to the extracted Parquet, plus the
git commit hash of the producing code."""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import time
from typing import Any, Dict, List, Optional

__all__ = ["OperationLog", "git_hash"]


import functools


@functools.lru_cache(maxsize=1)
def git_hash() -> str:
    # memoized: OperationLog is constructed per study run and a subprocess
    # per construction costs more than the run itself on small tables
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True, timeout=5
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except Exception:
        pass
    return os.environ.get("REPRO_GIT_HASH", "no-git")


@dataclasses.dataclass
class OperationLog:
    """Append-only operation log; the SCALPEL-Analysis metadata file."""

    entries: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    commit: str = dataclasses.field(default_factory=git_hash)

    def record(self, op: str, inputs: Dict[str, Any], outputs: Dict[str, Any],
               params: Dict[str, Any]) -> None:
        def _count(v) -> Optional[int]:
            try:
                return int(v.count)
            except Exception:
                return None

        self.entries.append({
            "op": op,
            "inputs": {k: _count(v) for k, v in inputs.items()},
            "outputs": {k: _count(v) for k, v in outputs.items()},
            "params": {k: v for k, v in params.items()},
            "ts": time.time(),
        })

    def to_json(self, path: Optional[str] = None) -> str:
        blob = json.dumps({"commit": self.commit, "entries": self.entries}, indent=2)
        if path:
            with open(path, "w") as f:
                f.write(blob)
        return blob

    @classmethod
    def from_json(cls, blob: str) -> "OperationLog":
        d = json.loads(blob)
        log = cls(entries=d["entries"])
        log.commit = d.get("commit", "no-git")
        return log

    def flowchart(self) -> List[Dict[str, Any]]:
        """Rows-removed-per-stage table (the RECORD-guideline flowchart)."""
        rows = []
        for e in self.entries:
            n_in = sum(v for v in e["inputs"].values() if v is not None)
            n_out = sum(v for v in e["outputs"].values() if v is not None)
            rows.append({"stage": e["op"], "in": n_in, "out": n_out, "removed": n_in - n_out})
        return rows

    def render_flowchart(self) -> str:
        lines = [f"{'stage':40s} {'in':>12s} {'out':>12s} {'removed':>10s}"]
        for r in self.flowchart():
            lines.append(f"{r['stage']:40s} {r['in']:12d} {r['out']:12d} {r['removed']:10d}")
        return "\n".join(lines)
