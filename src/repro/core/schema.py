"""Star-schema declarations for SNDS-shaped claims databases.

SNDS is "multiple sub-databases, each one with a star schema" (paper §3.1): a
central fact table recording cash flows / hospital stays, joined to dimension
tables for medical detail.  We declare the two sub-databases the paper
evaluates (DCIR outpatient, PMSI-MCO inpatient) with the join topology that
SCALPEL-Flattening denormalizes.

Column dtypes are the fixed-width SoA encodings of ``core.columnar``; nullable
columns use sentinel encoding (see ``NULL_INT``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["TableSchema", "JoinEdge", "StarSchema", "DCIR_SCHEMA", "PMSI_MCO_SCHEMA", "FLAT_EVENT_SCHEMA"]


@dataclasses.dataclass(frozen=True)
class TableSchema:
    """One normalized table: name, columns (name -> numpy dtype), primary key."""

    name: str
    columns: Dict[str, np.dtype]
    key: str                        # join key column (into parent)
    nullable: Tuple[str, ...] = ()  # sentinel-encoded nullable columns

    def dtypes(self) -> Dict[str, np.dtype]:
        return dict(self.columns)


@dataclasses.dataclass(frozen=True)
class JoinEdge:
    """A left-join step of the flattening: ``left.key_col == right.key``.

    ``one_to_many`` marks child tables (N child rows per parent row).  The
    denormalized output is keyed on child rows for such joins — this is what
    produces the PMSI blow-up in Table 1 of the paper (35M stays ->
    3.2B denormalized rows), versus DCIR's near-1:1 block-sparse layout.
    """

    left: str
    right: str
    left_key: str
    right_key: str
    one_to_many: bool = False


@dataclasses.dataclass(frozen=True)
class StarSchema:
    """A sub-database: one central fact table + dimension/child tables."""

    name: str
    central: TableSchema
    dims: Tuple[TableSchema, ...]
    joins: Tuple[JoinEdge, ...]
    patient_key: str = "patient_id"

    def table(self, name: str) -> TableSchema:
        if name == self.central.name:
            return self.central
        for d in self.dims:
            if d.name == name:
                return d
        raise KeyError(name)

    def all_tables(self) -> List[TableSchema]:
        return [self.central, *self.dims]

    def flat_columns(self) -> Tuple[str, ...]:
        """Column set of the denormalized flat table: the central columns plus
        every joined table's columns minus its join key (which the joins fold
        into the left side)."""
        cols = set(self.central.columns)
        for e in self.joins:
            cols |= set(self.table(e.right).columns) - {e.right_key}
        return tuple(sorted(cols))


_i32 = np.dtype(np.int32)
_f32 = np.dtype(np.float32)

# ---------------------------------------------------------------------------
# DCIR — outpatient reimbursement (analogue of ER_PRS_F + ER_PHA_F/ER_CAM_F/
# ER_BIO_F + IR_BEN_R).  Central row = one cash flow (paper Table 1 caption).
# Detail tables are *sparse by block*: a cash-flow row has at most one matching
# row per detail table (drug OR act OR bio), so the flat table stays ~1:1.
# ---------------------------------------------------------------------------
DCIR_SCHEMA = StarSchema(
    name="DCIR",
    central=TableSchema(
        name="ER_PRS",
        columns={
            "flow_id": _i32,        # primary key of the cash flow
            "patient_id": _i32,
            "prestation_code": _i32,  # nature of the reimbursed act
            "execution_date": _i32,   # days since epoch
            "amount": _f32,
        },
        key="flow_id",
    ),
    dims=(
        TableSchema(  # pharmacy detail (drug dispenses)
            name="ER_PHA",
            columns={"flow_id": _i32, "cip13": _i32, "atc_class": _i32, "quantity": _i32},
            key="flow_id",
            nullable=("cip13",),
        ),
        TableSchema(  # medical act detail (CCAM)
            name="ER_CAM",
            columns={"flow_id": _i32, "ccam_code": _i32},
            key="flow_id",
            nullable=("ccam_code",),
        ),
        TableSchema(  # patient repository
            name="IR_BEN",
            columns={"patient_id": _i32, "gender": _i32, "birth_date": _i32, "death_date": _i32},
            key="patient_id",
            nullable=("death_date",),
        ),
    ),
    joins=(
        JoinEdge("ER_PRS", "ER_PHA", "flow_id", "flow_id"),
        JoinEdge("ER_PRS", "ER_CAM", "flow_id", "flow_id"),
        JoinEdge("ER_PRS", "IR_BEN", "patient_id", "patient_id"),
    ),
)

# ---------------------------------------------------------------------------
# PMSI-MCO — inpatient stays.  Central row = one hospital stay; events during
# the stay live in child tables with N rows per stay (NOT sparse-by-block),
# which is exactly the layout the paper blames for tasks (e)/(f) slowness.
# ---------------------------------------------------------------------------
PMSI_MCO_SCHEMA = StarSchema(
    name="PMSI_MCO",
    central=TableSchema(
        name="MCO_B",
        columns={
            "stay_id": _i32,
            "patient_id": _i32,
            "stay_start": _i32,
            "stay_end": _i32,
            "ghm_code": _i32,   # diagnosis-related group
        },
        key="stay_id",
    ),
    dims=(
        TableSchema(  # diagnoses during the stay (main/associated/linked)
            name="MCO_D",
            columns={"stay_id": _i32, "icd_code": _i32, "diag_kind": _i32},
            key="stay_id",
        ),
        TableSchema(  # medical acts during the stay
            name="MCO_A",
            columns={"stay_id": _i32, "ccam_code": _i32, "act_date": _i32},
            key="stay_id",
        ),
    ),
    joins=(
        JoinEdge("MCO_B", "MCO_D", "stay_id", "stay_id", one_to_many=True),
        JoinEdge("MCO_B", "MCO_A", "stay_id", "stay_id", one_to_many=True),
    ),
)

# Standardized Event schema the extractors conform to (paper §3.4):
# Event(patientID, category, groupID, value, weight, start, end).
FLAT_EVENT_SCHEMA: Dict[str, np.dtype] = {
    "patient_id": _i32,
    "category": _i32,
    "group_id": _i32,
    "value": _i32,
    "weight": _f32,
    "start": _i32,
    "end": _i32,  # NULL_INT for punctual events
}


# ---------------------------------------------------------------------------
# SSR — rehabilitation stays (supplementary Table 2).  Same star topology as
# MCO: central stay table + 1:N act/diagnosis children.
# ---------------------------------------------------------------------------
SSR_SCHEMA = StarSchema(
    name="SSR",
    central=TableSchema(
        name="SSR_B",
        columns={
            "stay_id": _i32,
            "patient_id": _i32,
            "stay_start": _i32,
            "stay_end": _i32,
            "takeover_code": _i32,   # hospital-takeover reason
        },
        key="stay_id",
    ),
    dims=(
        TableSchema(  # CSARR rehabilitation acts
            name="SSR_A",
            columns={"stay_id": _i32, "csarr_code": _i32, "act_date": _i32},
            key="stay_id",
        ),
        TableSchema(  # diagnoses during rehab
            name="SSR_D",
            columns={"stay_id": _i32, "icd_code": _i32, "diag_kind": _i32},
            key="stay_id",
        ),
    ),
    joins=(
        JoinEdge("SSR_B", "SSR_A", "stay_id", "stay_id", one_to_many=True),
        JoinEdge("SSR_B", "SSR_D", "stay_id", "stay_id", one_to_many=True),
    ),
)

# ---------------------------------------------------------------------------
# HAD — home-to-home care.  Central takeover episodes; main/associated
# takeover reasons are columns (punctual extractors read them directly).
# ---------------------------------------------------------------------------
HAD_SCHEMA = StarSchema(
    name="HAD",
    central=TableSchema(
        name="HAD_B",
        columns={
            "episode_id": _i32,
            "patient_id": _i32,
            "episode_start": _i32,
            "episode_end": _i32,
            "main_takeover": _i32,
            "assoc_takeover": _i32,
        },
        key="episode_id",
        nullable=("assoc_takeover",),
    ),
    dims=(),
    joins=(),
)

# ---------------------------------------------------------------------------
# IR_IMB_R — long-term chronic diseases (ALD).  A plain table (paper suppl.
# Table 2: "were simply converted to Parquet files"); no joins.
# ---------------------------------------------------------------------------
IR_IMB_SCHEMA = StarSchema(
    name="IR_IMB",
    central=TableSchema(
        name="IR_IMB_R",
        columns={
            "patient_id": _i32,
            "ald_icd_code": _i32,   # chronic-disease ICD
            "ald_start": _i32,
            "ald_end": _i32,
        },
        key="patient_id",
    ),
    dims=(),
    joins=(),
)
