"""Packed row-validity bitset: ONE layout shared by the whole stack.

The canonical representation of row validity (and cohort subject membership)
is a packed ``uint32`` word array: row/subject ``i`` lives at word ``i // 32``,
bit ``i % 32`` (LSB-first).  This is the layout the Pallas predicate kernel
emits (``kernels/predicate``), the layout the fused bitset-algebra kernel
consumes (``kernels/bitset_ops``), the layout ``cohort.Bitset`` has always
used for subject sets, and — since the bitset-native validity redesign — the
layout ``ColumnarTable.valid`` carries end-to-end.

Invariant: bits at positions >= the logical length are always ZERO ("tail
bits clear").  Every producer below maintains it; word-wise consumers (AND /
OR / ANDNOT, popcount) rely on it so padded tail words never leak into
counts.

Why one module: ``columnar`` cannot import ``cohort`` (cycle), and the
kernels stay import-light, so the layout primitives live here and everything
else delegates.  ``unpack`` is the *only* word->bool(capacity,) expansion in
the library — tests instrument it to assert the hot predicate->cohort->
compaction path never expands validity back to a bool column.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "WORD_BITS", "n_words", "pack", "unpack", "unpack_np", "count",
    "first_n", "bit_at", "is_packed",
]

WORD_BITS = 32


def n_words(n_bits: int) -> int:
    """Words needed to hold ``n_bits`` bits."""
    return (int(n_bits) + WORD_BITS - 1) // WORD_BITS


def is_packed(valid) -> bool:
    """True when ``valid`` is a packed word array (vs a per-row bool mask).

    The discriminator is the dtype: packed validity is always ``uint32``;
    per-row masks are bool (or any other dtype, coerced to bool).
    """
    return getattr(valid, "dtype", None) == jnp.uint32


def pack(mask: jax.Array) -> jax.Array:
    """Pack a ``(n,) bool`` row mask into ``ceil(n/32)`` uint32 words.

    Tail bits beyond ``n`` are zero (the invariant word-wise consumers rely
    on).
    """
    n = mask.shape[0]
    pad = (-n) % WORD_BITS
    m = jnp.pad(mask.astype(jnp.uint32), (0, pad)).reshape(-1, WORD_BITS)
    weights = jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return (m * weights).sum(axis=1, dtype=jnp.uint32)


def unpack(words: jax.Array, n_bits: int) -> jax.Array:
    """Expand packed words back to a ``(n_bits,) bool`` row mask.

    This is the compatibility hop for consumers that genuinely need a
    per-row mask (sorts, segment folds, host exports).  The hot path never
    calls it — tests monkeypatch this function to count expansions.
    """
    bits = words[:, None] >> jnp.arange(WORD_BITS, dtype=jnp.uint32)[None, :]
    return (bits & 1).astype(bool).reshape(-1)[:n_bits]


def unpack_np(words: np.ndarray, n_bits: int) -> np.ndarray:
    """Host-side ``unpack`` (numpy, for ``to_numpy``/IO/capacity planning)."""
    w = np.asarray(words, np.uint32)
    bits = (w[:, None] >> np.arange(WORD_BITS, dtype=np.uint32)[None, :]) & 1
    return bits.astype(bool).reshape(-1)[:n_bits]


def count(words: jax.Array) -> jax.Array:
    """Total population count (scalar int32)."""
    return jax.lax.population_count(words).sum(dtype=jnp.int32)


def first_n(cnt, capacity: int) -> jax.Array:
    """Packed form of ``arange(capacity) < cnt`` — the validity of a
    compacted table, computed word-wise (no per-row expansion).

    ``cnt`` may be traced; ``capacity`` is static.  Requires
    ``cnt <= capacity`` (always true for a row count).
    """
    base = jnp.arange(n_words(capacity), dtype=jnp.int32) * WORD_BITS
    rem = jnp.clip(jnp.asarray(cnt, jnp.int32) - base, 0, WORD_BITS)
    full = jnp.uint32(0xFFFFFFFF)
    # shift amount stays < 32 (shift-by-width is undefined); rem == 32 takes
    # the ``full`` branch of the where
    part = (jnp.uint32(1) << jnp.minimum(rem, WORD_BITS - 1).astype(jnp.uint32)
            ) - jnp.uint32(1)
    return jnp.where(rem >= WORD_BITS, full, part)


def bit_at(words: jax.Array, idx: jax.Array) -> jax.Array:
    """Gathered bit test: ``mask[idx]`` without materializing the bool mask.

    Reads the packed words (1 bit/row of HBM traffic) and extracts each
    queried bit in registers — the fused select the executor uses on the
    predicate->cohort path instead of a bool-column round trip.
    """
    i = idx.astype(jnp.int32)
    w = words[i >> 5]
    return ((w >> (i & 31).astype(jnp.uint32)) & 1).astype(bool)
