"""SCALPEL-Extraction: concept extractors over the denormalized flat table.

An ``Extractor`` maps flat-table rows to zero-or-more standardized ``Event``
rows (paper §3.4, Figure 2), as a composition of columnar steps:

  step 1  column projection            (metadata-only)
  step 2  null filtering               (mask algebra over validity/sentinels)
  step 2b optional row-value filtering (vectorized predicate, late — on
                                        already-reduced data, as in the paper)
  step 3  schema conformance + compaction to the Event layout

Steps 1–2b never materialize rows (masks only); the single materialization is
the final compaction, for which the production path is the Pallas
``filter_compact`` kernel (``repro.kernels.ops``) with a pure-jnp fallback.

Every extraction records provenance into an ``OperationLog`` so
SCALPEL-Analysis can rebuild flowcharts from metadata (paper §3.4 last ¶).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.columnar import ColumnarTable, NULL_INT, is_null
from repro.core.events import Category, make_events
from repro.core.metadata import OperationLog

__all__ = [
    "Extractor",
    "dedupe_by",
    "drug_dispenses",
    "medical_acts_dcir",
    "medical_acts_pmsi",
    "diagnoses",
    "hospital_stays",
    "patients",
]


def dedupe_by(table: ColumnarTable, keys: Sequence[str]) -> ColumnarTable:
    """DISTINCT over key columns: sort, keep the first row of each run.

    Needed because a denormalized 1:N flat table repeats parent attributes
    (e.g. one hospital stay appears once per diagnosis×act pair).

    Word-wise validity: ``sort_by`` sinks invalid rows, so the sorted
    table's valid rows are exactly the first ``count`` — row validity here
    is an iota compare (no packed-word expansion), and the only new mask is
    the data-derived run-head test ``filter`` packs at its boundary.
    """
    t = table.sort_by(list(keys))
    tv = jnp.arange(t.capacity, dtype=jnp.int32) < t.count
    neq = jnp.zeros((t.capacity,), bool)
    for k in keys:
        col = t.columns[k]
        neq = neq | jnp.concatenate([jnp.ones((1,), bool), col[1:] != col[:-1]])
    # neq[0] is True, so every first-of-run valid row survives; rows past
    # count (the sunk invalid tail) drop via tv
    keep = tv & neq
    return t.filter(keep)


@dataclasses.dataclass(frozen=True)
class Extractor:
    """Declarative concept extractor (paper Table 3 entries are instances)."""

    name: str
    source: str                      # flat-table name this extractor reads
    category: int                    # Event.category to emit
    value_col: str                   # -> Event.value
    start_col: str                   # -> Event.start
    end_col: Optional[str] = None    # -> Event.end (None => punctual)
    group_col: Optional[str] = None  # -> Event.groupID
    weight_col: Optional[str] = None # -> Event.weight
    null_cols: Tuple[str, ...] = ()  # step-2 null filter columns
    codes: Optional[Tuple[int, ...]] = None  # step-2b value whitelist
    distinct: Tuple[str, ...] = ()   # dedupe keys (for 1:N flat layouts)
    # optional typed row predicate (repro.study.expr.Expr) applied after the
    # null/whitelist steps; excluded from equality/hash (Exprs are
    # value-built trees) — use ``filtered()`` to attach one
    where: Optional[Any] = dataclasses.field(default=None, compare=False)

    def filtered(self, expr) -> "Extractor":
        """A copy of this extractor with ``expr`` AND-ed into its ``where``
        predicate: ``drug_dispenses().filtered(col("cip13").isin(codes))``."""
        combined = expr if self.where is None else (self.where & expr)
        return dataclasses.replace(self, where=combined)

    def projection(self) -> Tuple[str, ...]:
        """Step-1 column set: only the columns this extractor touches."""
        needed = ["patient_id", self.value_col, self.start_col]
        for c in (self.end_col, self.group_col, self.weight_col):
            if c:
                needed.append(c)
        needed += [c for c in self.null_cols if c not in needed]
        needed += [c for c in self.distinct if c not in needed]
        if self.where is not None:
            needed += [c for c in self.where.required_columns()
                       if c not in needed]
        return tuple(sorted(set(needed)))

    def contribute(self, b, compact: bool = True,
                   base: Optional[int] = None) -> int:
        """Append this extractor's steps 1-3 to a ``PlanBuilder``; returns the
        output node id.  Scans hash-cons, so every extractor over one source
        shares the scan node, and the optimizer then merges projections and
        fuses the mask steps (``repro.study.optimizer``).  ``base`` chains
        the steps onto an existing plan node (e.g. a ``Study.flatten``
        output) instead of a fresh env scan."""
        t = b.select(base if base is not None else b.scan(self.source),
                     self.projection())
        t = b.drop_nulls(t, self.null_cols or (self.value_col,))
        if self.codes is not None:
            t = b.value_filter(t, self.value_col, self.codes)
        if self.where is not None:
            t = b.predicate(t, self.where, label="where")
        if self.distinct:
            t = b.dedupe(t, self.distinct)
        t = b.conform_events(
            t, name=self.name, category=self.category, value_col=self.value_col,
            start_col=self.start_col, end_col=self.end_col,
            group_col=self.group_col, weight_col=self.weight_col,
        )
        if compact:
            t = b.compact(t)
        return t

    def __call__(self, flat: ColumnarTable, log: Optional[OperationLog] = None,
                 compact: bool = True, engine: str = "xla") -> ColumnarTable:
        """Eager wrapper (backward compatible): builds the single-extractor
        plan and executes it immediately.

        engine: 'xla' (argsort compaction, default) or 'pallas' (the fused
        filter_compact kernel — the TPU production path; on CPU it runs in
        interpret mode, so it is opt-in).  Multi-extractor studies should use
        ``repro.study.Study``, which shares one scan across extractors."""
        from repro.study import executor as _executor
        from repro.study.plan import PlanBuilder

        b = PlanBuilder()
        out = self.contribute(b, compact=compact)
        b.set_output(self.name, out)
        ev = _executor.execute(b.build(), {self.source: flat}, engine=engine)[out]
        if log is not None:
            log.record(
                op=f"extract:{self.name}",
                inputs={self.source: flat},
                outputs={self.name: ev},
                params={"codes": None if self.codes is None else len(self.codes)},
            )
        return ev


# --- ready-to-use extractors (paper Table 3) --------------------------------
def drug_dispenses(granularity: str = "cip13", codes: Optional[Sequence[int]] = None) -> Extractor:
    """Drug dispense extractor; granularity ∈ {cip13, atc} (paper §3.4:
    "events at multiple levels of granularity (drug, molecule, ATC class)")."""
    col = {"cip13": "cip13", "atc": "atc_class"}[granularity]
    return Extractor(
        name=f"drug_purchases[{granularity}]",
        source="DCIR",
        category=Category.DRUG_DISPENSE,
        value_col=col,
        start_col="execution_date",
        weight_col=None,
        null_cols=("cip13",),
        codes=None if codes is None else tuple(int(c) for c in codes),
    )


def medical_acts_dcir(codes: Optional[Sequence[int]] = None) -> Extractor:
    return Extractor(
        name="acts",
        source="DCIR",
        category=Category.MEDICAL_ACT,
        value_col="ccam_code",
        start_col="execution_date",
        null_cols=("ccam_code",),
        codes=None if codes is None else tuple(int(c) for c in codes),
    )


def medical_acts_pmsi(codes: Optional[Sequence[int]] = None) -> Extractor:
    """Acts from the hospital flat table — the paper's slow task (e): the 1:N
    flat layout forces a distinct + more row-value tests (§5 discussion)."""
    return Extractor(
        name="hospital_acts",
        source="PMSI_MCO",
        category=Category.MEDICAL_ACT,
        value_col="ccam_code",
        start_col="act_date",
        null_cols=("ccam_code",),
        codes=None if codes is None else tuple(int(c) for c in codes),
        distinct=("stay_id", "ccam_code", "act_date"),
    )


def diagnoses(kinds: Sequence[int] = (1, 2, 3), codes: Optional[Sequence[int]] = None) -> Extractor:
    """Main/associated/linked diagnoses (paper Table 3); group_id = kind."""
    return Extractor(
        name="diagnoses",
        source="PMSI_MCO",
        category=Category.DIAGNOSIS,
        value_col="icd_code",
        start_col="stay_start",
        group_col="diag_kind",
        null_cols=("icd_code",),
        codes=None if codes is None else tuple(int(c) for c in codes),
        distinct=("stay_id", "icd_code", "diag_kind"),
    )


def hospital_stays() -> Extractor:
    return Extractor(
        name="extract_hospital_stays",
        source="PMSI_MCO",
        category=Category.HOSPITAL_STAY,
        value_col="ghm_code",
        start_col="stay_start",
        end_col="stay_end",
        distinct=("stay_id",),
    )


def patients(ir_ben: ColumnarTable, log: Optional[OperationLog] = None) -> ColumnarTable:
    """Patient demographics (task (a) of the paper's evaluation)."""
    t = dedupe_by(ir_ben.select(["patient_id", "gender", "birth_date", "death_date"]),
                  ["patient_id"]).compact()
    if log is not None:
        log.record(op="extract:extract_patients", inputs={"IR_BEN": ir_ben},
                   outputs={"extract_patients": t}, params={})
    return t


# --- additional extractors (paper Table 3: biology, NGAP, practitioner
# encounters, CSARR, long-term diseases, takeover reasons) --------------------
def biology_acts(codes: Optional[Sequence[int]] = None) -> Extractor:
    """Biological acts from DCIR (paper Table 3 'Biological acts').

    In the synthetic star, biology rides the prestation code space (the real
    ER_BIO_F table joins like ER_CAM); prestation codes >= 1080 model biology.
    """
    return Extractor(
        name="biological_acts",
        source="DCIR",
        category=Category.BIOLOGY,
        value_col="prestation_code",
        start_col="execution_date",
        codes=tuple(codes) if codes is not None else tuple(range(1080, 1100)),
    )


def practitioner_encounters(medical: bool = True) -> Extractor:
    """Practitioner encounters (paper Table 3, medical vs non-medical) —
    identified by the prestation code band of the cash flow."""
    band = range(1000, 1040) if medical else range(1040, 1080)
    return Extractor(
        name=f"{'medical' if medical else 'non_medical'}_encounters",
        source="DCIR",
        category=Category.PRACTITIONER,
        value_col="prestation_code",
        start_col="execution_date",
        codes=tuple(band),
    )


def csarr_acts(codes: Optional[Sequence[int]] = None) -> Extractor:
    """CSARR rehabilitation acts from the SSR flat table."""
    return Extractor(
        name="csarr_acts",
        source="SSR",
        category=Category.MEDICAL_ACT,
        value_col="csarr_code",
        start_col="act_date",
        null_cols=("csarr_code",),
        codes=None if codes is None else tuple(int(c) for c in codes),
        distinct=("stay_id", "csarr_code", "act_date"),
    )


def ssr_stays() -> Extractor:
    """SSR stay (longitudinal) events (paper Table 3 'SSR Stay')."""
    return Extractor(
        name="ssr_stays",
        source="SSR",
        category=Category.HOSPITAL_STAY,
        value_col="takeover_code",
        start_col="stay_start",
        end_col="stay_end",
        distinct=("stay_id",),
    )


def takeover_reasons(main: bool = True) -> Extractor:
    """HAD main/associated takeover reasons (paper Table 3)."""
    return Extractor(
        name=f"{'main' if main else 'associated'}_takeover",
        source="HAD",
        category=Category.PRACTITIONER,
        value_col="main_takeover" if main else "assoc_takeover",
        start_col="episode_start",
        null_cols=("main_takeover",) if main else ("assoc_takeover",),
    )


def long_term_diseases(codes: Optional[Sequence[int]] = None) -> Extractor:
    """Long-term chronic disease (ALD) longitudinal events from IR_IMB_R."""
    return Extractor(
        name="long_term_diseases",
        source="IR_IMB",
        category=Category.DIAGNOSIS,
        value_col="ald_icd_code",
        start_col="ald_start",
        end_col="ald_end",
        group_col=None,
        codes=None if codes is None else tuple(int(c) for c in codes),
    )
