"""FeatureDriver: cohorts -> ML tensor formats (paper §3.5).

The paper exports Spark dataframes to numpy / tf / torch tensors with sanity
checks.  Here the targets are JAX arrays feeding the in-repo LM stack:

  * ``dense_features``   — (patients × time-buckets × features) scatter-add
                           tensor (the ConvSCCS-style longitudinal design
                           matrix of paper ref. [27]);
  * ``token_sequences``  — per-patient event-code token streams for language
                           models (the hand-off to the assigned architectures:
                           the claims history *is* the training corpus);
  * ``to_numpy``         — host export for external libraries.

Sanity checks mirror the paper: events outside the cohort window or with
inconsistent dates are counted and excluded, never silently kept.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cohort import Cohort
from repro.core.columnar import ColumnarTable, is_null
from repro.core.events import Category

__all__ = ["FeatureDriver", "TokenizerSpec"]

# LM special tokens for event streams
PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 8  # room for time-gap buckets etc.


@dataclasses.dataclass(frozen=True)
class TokenizerSpec:
    """Event -> token mapping: token = offset[category] + value (clipped)."""

    category_offsets: Dict[int, int]
    category_sizes: Dict[int, int]

    @classmethod
    def default(cls, n_drug: int = 512, n_act: int = 512, n_diag: int = 512) -> "TokenizerSpec":
        offs, sizes, cur = {}, {}, N_SPECIAL
        for cat, n in ((Category.DRUG_DISPENSE, n_drug), (Category.MEDICAL_ACT, n_act),
                       (Category.DIAGNOSIS, n_diag), (Category.HOSPITAL_STAY, 256),
                       (Category.EXPOSURE, n_drug), (Category.OUTCOME_FRACTURE, 64)):
            offs[cat], sizes[cat] = cur, n
            cur += n
        return cls(offs, sizes)

    @property
    def vocab_size(self) -> int:
        return N_SPECIAL + sum(self.category_sizes.values())


class FeatureDriver:
    def __init__(self, cohort: Cohort, patients: Optional[ColumnarTable] = None):
        if cohort.events is None:
            raise ValueError("FeatureDriver needs a cohort with events")
        self.cohort = cohort
        self.patients = patients
        self.checks: Dict[str, int] = {}

    # -- sanity checks ---------------------------------------------------------
    def _checked_events(self) -> ColumnarTable:
        ev = self.cohort.events
        t0, t1 = self.cohort.window
        start = ev.columns["start"]
        end = ev.columns["end"]
        in_window = (start >= t0) & (start < t1)
        dates_ok = is_null(end) | (end >= start)
        keep = in_window & dates_ok
        evv = ev.valid_bool()
        self.checks = {
            "events_total": int(ev.count),
            "events_out_of_window": int((evv & ~in_window).sum()),
            "events_bad_dates": int((evv & ~dates_ok).sum()),
        }
        return ev.filter(keep)

    # -- dense longitudinal tensor ----------------------------------------------
    def dense_features(self, n_buckets: int, bucket_days: int, n_features: int,
                       feature_of_value: Optional[jax.Array] = None) -> jax.Array:
        """(n_patients, n_buckets, n_features) scatter-add design matrix."""
        ev = self._checked_events()
        P = self.cohort.n_patients
        t0 = self.cohort.window[0]
        b = jnp.clip((ev.columns["start"] - t0) // bucket_days, 0, n_buckets - 1)
        v = ev.columns["value"]
        f = feature_of_value[jnp.clip(v, 0, feature_of_value.shape[0] - 1)] \
            if feature_of_value is not None else jnp.clip(v, 0, n_features - 1)
        pid = jnp.clip(ev.columns["patient_id"], 0, P - 1)
        flat_idx = (pid * n_buckets + b) * n_features + f
        flat_idx = jnp.where(ev.valid_bool(), flat_idx, P * n_buckets * n_features)
        out = jnp.zeros((P * n_buckets * n_features,), jnp.float32)
        out = out.at[flat_idx].add(ev.columns["weight"], mode="drop")
        return out.reshape(P, n_buckets, n_features)

    # -- LM token streams --------------------------------------------------------
    def token_sequences(self, seq_len: int, spec: Optional[TokenizerSpec] = None
                        ) -> Tuple[jax.Array, jax.Array]:
        """(n_patients, seq_len) int32 tokens + bool mask, time-ordered.

        Each patient's claims history becomes a token stream
        ``BOS e1 e2 ... EOS PAD...``; overflowing events are truncated (kept
        count is in ``self.checks``).  This is the corpus the assigned LM
        architectures train on in ``examples/train_lm.py``.
        """
        spec = spec or TokenizerSpec.default()
        ev = self._checked_events().sort_by(["patient_id", "start", "category", "value"])
        P = self.cohort.n_patients

        cat = ev.columns["category"]
        val = ev.columns["value"]
        tok = jnp.full((ev.capacity,), PAD, jnp.int32)
        for c, off in spec.category_offsets.items():
            n = spec.category_sizes[c]
            tok = jnp.where(cat == c, off + jnp.clip(val, 0, n - 1), tok)
        known = tok != PAD

        pid = ev.columns["patient_id"]
        evv = ev.valid_bool()
        ok = evv & known
        # position within patient = rank among valid rows of the same patient
        seg = jnp.where(ok, pid, P)
        one = ok.astype(jnp.int32)
        cum = jnp.cumsum(one) - one  # exclusive prefix count of valid rows
        # min of exclusive-cumsum within a segment = count before segment start
        big = jnp.int32(1 << 30)
        seg_start_count = jnp.full((P + 1,), big, jnp.int32).at[seg].min(cum, mode="drop")
        pos = cum - seg_start_count[jnp.clip(seg, 0, P)]
        slot = jnp.where(ok & (pos < seq_len - 2), pid * seq_len + 1 + pos, P * seq_len)

        toks = jnp.full((P * seq_len,), PAD, jnp.int32).at[slot].set(tok, mode="drop")
        toks = toks.reshape(P, seq_len).at[:, 0].set(BOS)
        n_per = jax.ops.segment_sum(one, jnp.clip(seg, 0, P), num_segments=P + 1)[:P]
        eos_pos = jnp.clip(n_per + 1, 1, seq_len - 1)
        toks = toks.at[jnp.arange(P), eos_pos].set(EOS)
        mask = jnp.arange(seq_len)[None, :] <= eos_pos[:, None]
        self.checks["events_truncated"] = int((evv & known & (pos >= seq_len - 2)).sum())
        return toks, mask

    # -- host export --------------------------------------------------------------
    def to_numpy(self, **kw) -> Dict[str, np.ndarray]:
        X = self.dense_features(**kw)
        return {"features": np.asarray(X), "subjects": np.asarray(self.cohort.subjects_mask())}
