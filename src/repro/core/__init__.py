"""SCALPEL3-JAX core: the paper's contribution as composable JAX modules.

Three components, mirroring the paper's three libraries:
  * flattening  — SCALPEL-Flattening  (denormalize once, columnar, distributed)
  * extraction/transformers — SCALPEL-Extraction (concepts from flat tables)
  * cohort/stats/feature_driver — SCALPEL-Analysis (interactive cohort algebra)
"""
from repro.core.columnar import ColumnarTable, NULL_INT, NULL_FLOAT, is_null
from repro.core.schema import (
    DCIR_SCHEMA, PMSI_MCO_SCHEMA, SSR_SCHEMA, HAD_SCHEMA, IR_IMB_SCHEMA,
    StarSchema, TableSchema, JoinEdge,
)
from repro.core.events import Category, make_events, sort_events
from repro.core.flattening import (
    flatten_star,
    flatten_sliced,
    distributed_flatten,
    lookup_join,
    expand_join,
    exchange,
    hash_partition,
    FlatteningStats,
)
from repro.core.extraction import (
    Extractor,
    drug_dispenses,
    medical_acts_dcir,
    medical_acts_pmsi,
    diagnoses,
    hospital_stays,
    patients,
    dedupe_by,
    biology_acts,
    practitioner_encounters,
    csarr_acts,
    ssr_stays,
    takeover_reasons,
    long_term_diseases,
)
from repro.core.transformers import (
    observation_period,
    follow_up,
    trackloss,
    exposures,
    exposures_sharded,
    fractures,
    drug_prescriptions,
    drug_interactions,
    bladder_cancer,
    infarctus,
    heart_failure,
)
from repro.core.cohort import Bitset, Cohort, CohortCollection, CohortFlow
from repro.core.metadata import OperationLog, git_hash
from repro.core.feature_driver import FeatureDriver, TokenizerSpec
from repro.core import stats
