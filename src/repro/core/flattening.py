"""SCALPEL-Flattening: distributed denormalization of star-schema claims data.

The paper's pitch (§3.3): pay the join cost *once* — recursively left-join the
dimension/child tables onto the central fact table, store the result columnar,
and every later query becomes a shuffle-free columnar scan.

TPU adaptation (DESIGN.md §2):
  * Spark shuffle  -> ``jax.lax.all_to_all`` over the mesh ``data`` axis
                      (fixed-capacity hash-partition exchange; XLA needs static
                      shapes so each destination bucket has a capacity and an
                      overflow counter instead of dynamic spill).
  * N:1 join       -> sorted-lookup join (searchsorted + gather).
  * 1:N join       -> offset-expansion join (prefix-sum over match counts);
                      this is what reproduces the PMSI-MCO row blow-up of
                      Table 1 and its block-sparsity discussion in §5.
  * temporal slice -> host-driven loop over time buckets, each bucket a
                      bounded-capacity flatten, results appended (paper: joins
                      "sequentially appended to the output parquet file").
  * monitoring     -> per-stage row counts + key checksums proving no loss.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.columnar import ColumnarTable, NULL_FLOAT, NULL_INT
from repro.core.schema import JoinEdge, StarSchema

__all__ = [
    "lookup_join",
    "expand_join",
    "flatten_star",
    "flatten_sliced",
    "FlatteningStats",
    "hash_partition",
    "exchange",
    "distributed_flatten",
]


def _sentinel(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(NULL_FLOAT, dtype)
    return jnp.asarray(NULL_INT, dtype)


def _maxval(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.finfo(dtype).max, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


@dataclasses.dataclass
class FlatteningStats:
    """Monitoring statistics computed along the flattening (paper §3.3)."""

    stage: str
    rows_in: jax.Array
    rows_out: jax.Array
    matched: jax.Array      # left rows that found >=1 right match
    overflow: jax.Array     # rows dropped because a static capacity was hit
    key_sum_in: jax.Array
    key_sum_out: jax.Array

    def assert_no_loss(self):
        """Host-side check: every input row survived (paper's no-loss audit)."""
        if int(self.overflow) != 0:
            raise AssertionError(f"stage {self.stage}: {int(self.overflow)} rows overflowed")


# ---------------------------------------------------------------------------
# N:1 sorted-lookup join (DCIR block-sparse detail tables, patient repository)
# ---------------------------------------------------------------------------
def lookup_join(
    left: ColumnarTable,
    right: ColumnarTable,
    left_key: str,
    right_key: str,
    prefix: str = "",
) -> Tuple[ColumnarTable, FlatteningStats]:
    """Left join where ``right`` has at most one row per key.

    Right is sorted by key (invalid rows sink with +inf key), left keys are
    located by ``searchsorted``, right attributes gathered, misses filled with
    null sentinels — exactly a hash-lookup join expressed in sorted-columnar
    form (TPUs vastly prefer sorted gathers over scattered hash probes).
    """
    r = right.sort_by([right_key])
    cap_r = r.capacity
    lk = left.columns[left_key]
    if cap_r == 0:  # empty right table: every left row misses
        pos = jnp.zeros(left.capacity, jnp.int32)
        posc = pos
        found = jnp.zeros(left.capacity, bool)
        r = r.pad_to(1)  # 1-row dummy so gathers below are well-formed
    else:
        rk = jnp.where(r.valid, r.columns[right_key],
                       _maxval(r.columns[right_key].dtype))
        pos = jnp.searchsorted(rk, lk, side="left")
        posc = jnp.clip(pos, 0, cap_r - 1)
        found = (pos < cap_r) & (rk[posc] == lk) & r.valid[posc] & left.valid

    new_cols = dict(left.columns)
    for name in r.column_names:
        if name == right_key:
            continue
        out_name = prefix + name
        if out_name in new_cols:
            raise ValueError(f"column collision {out_name!r}; pass a prefix")
        col = r.columns[name]
        new_cols[out_name] = jnp.where(found, col[posc], _sentinel(col.dtype))

    out = ColumnarTable(new_cols, left.valid, left.count)
    key_col = left.columns[left_key].astype(jnp.uint32)
    stats = FlatteningStats(
        stage=f"lookup_join[{left_key}]",
        rows_in=left.count,
        rows_out=out.count,
        matched=found.sum().astype(jnp.int32),
        overflow=jnp.int32(0),
        key_sum_in=jnp.where(left.valid, key_col, 0).sum(dtype=jnp.uint32),
        key_sum_out=jnp.where(out.valid, key_col, 0).sum(dtype=jnp.uint32),
    )
    return out, stats


# ---------------------------------------------------------------------------
# 1:N offset-expansion join (PMSI child tables -> the Table-1 blow-up)
# ---------------------------------------------------------------------------
def expand_join(
    left: ColumnarTable,
    right: ColumnarTable,
    left_key: str,
    right_key: str,
    out_capacity: int,
    prefix: str = "",
) -> Tuple[ColumnarTable, FlatteningStats]:
    """Left join where ``right`` may hold N rows per key; output row per pair.

    Match counts per left row come from two ``searchsorted`` passes over the
    sorted right keys; an exclusive prefix sum turns them into output offsets;
    each output slot locates its (left row, right row) pair by binary search.
    Unmatched left rows still emit one row (left-join semantics) with null
    right attributes.  ``out_capacity`` bounds the static output size; slots
    beyond the true total are invalid, and a positive ``overflow`` statistic
    flags capacity overruns (the audit the paper computes per stage).
    """
    L = left.capacity
    if right.capacity == 0:
        right = right.pad_to(1)
    r = right.sort_by([right_key])
    cap_r = r.capacity
    rk = jnp.where(r.valid, r.columns[right_key], _maxval(r.columns[right_key].dtype))
    lk = left.columns[left_key]

    start = jnp.searchsorted(rk, lk, side="left")
    stop = jnp.searchsorted(rk, lk, side="right")
    cnt = jnp.where(left.valid, stop - start, 0)
    out_cnt = jnp.where(left.valid, jnp.maximum(cnt, 1), 0)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(out_cnt).astype(jnp.int32)])
    total = offs[-1]

    j = jnp.arange(out_capacity, dtype=jnp.int32)
    src = jnp.clip(jnp.searchsorted(offs, j, side="right") - 1, 0, L - 1)
    rel = j - offs[src]
    has_match = cnt[src] > 0
    ridx = jnp.clip(start[src] + rel, 0, cap_r - 1)
    out_valid = (j < total) & left.valid[src]
    right_ok = has_match & out_valid

    new_cols = {k: jnp.where(out_valid, v[src], _sentinel(v.dtype)) for k, v in left.columns.items()}
    for name in r.column_names:
        if name == right_key:
            continue
        out_name = prefix + name
        if out_name in new_cols:
            raise ValueError(f"column collision {out_name!r}; pass a prefix")
        col = r.columns[name]
        new_cols[out_name] = jnp.where(right_ok, col[ridx], _sentinel(col.dtype))

    out = ColumnarTable(new_cols, out_valid, out_valid.sum().astype(jnp.int32))
    key_u32 = lk.astype(jnp.uint32)
    stats = FlatteningStats(
        stage=f"expand_join[{left_key}]",
        rows_in=left.count,
        rows_out=out.count,
        matched=(cnt > 0).sum().astype(jnp.int32),
        overflow=jnp.maximum(total - out_capacity, 0).astype(jnp.int32),
        key_sum_in=jnp.where(left.valid, key_u32, 0).sum(dtype=jnp.uint32),
        key_sum_out=jnp.where(out_valid, new_cols[left_key].astype(jnp.uint32), 0).sum(dtype=jnp.uint32),
    )
    return out, stats


# ---------------------------------------------------------------------------
# Whole-star flattening
# ---------------------------------------------------------------------------
def flatten_star(
    schema: StarSchema,
    tables: Mapping[str, ColumnarTable],
    expand_capacity: Optional[int] = None,
    expand_slack: float = 1.5,
) -> Tuple[ColumnarTable, List[FlatteningStats]]:
    """Denormalize one sub-database: sequential joins from the central table.

    ``expand_capacity`` bounds each 1:N expansion; when omitted it is derived
    host-side from the child-table capacities (the Spark analogue is the
    driver sizing shuffle partitions from table statistics).
    """
    flat = tables[schema.central.name]
    stats: List[FlatteningStats] = []
    for edge in schema.joins:
        right = tables[edge.right]
        if edge.one_to_many:
            cap = expand_capacity
            if cap is None:
                # worst case: every existing flat row matches avg child rows;
                # slack absorbs skew. Static: derived from capacities only.
                cap = int((flat.capacity + right.capacity) * expand_slack)
            flat, st = expand_join(flat, right, edge.left_key, edge.right_key, cap)
        else:
            flat, st = lookup_join(flat, right, edge.left_key, edge.right_key)
        stats.append(st)
    return flat, stats


def flatten_sliced(
    schema: StarSchema,
    tables: Mapping[str, ColumnarTable],
    time_column: str,
    n_slices: int,
    t0: int,
    t1: int,
    **kw,
) -> Tuple[ColumnarTable, List[FlatteningStats]]:
    """Temporal slicing (paper §3.3): divide the central table by time unit,
    flatten each slice, and append the results — bounds the working set of
    each big join exactly like SCALPEL-Flattening's year/month slicing."""
    central = tables[schema.central.name]
    edges = np.linspace(t0, t1 + 1, n_slices + 1).astype(np.int32)
    parts: List[ColumnarTable] = []
    stats: List[FlatteningStats] = []
    for i in range(n_slices):
        tcol = central.columns[time_column]
        in_slice = (tcol >= int(edges[i])) & (tcol < int(edges[i + 1]))
        sliced = dict(tables)
        sliced[schema.central.name] = central.filter(in_slice).compact()
        flat_i, st = flatten_star(schema, sliced, **kw)
        parts.append(flat_i)
        stats.extend(st)
    return ColumnarTable.concat(parts), stats


# ---------------------------------------------------------------------------
# Distributed exchange: the Spark shuffle on the TPU ICI
# ---------------------------------------------------------------------------
def hash_partition(
    table: ColumnarTable, key: str, n_shards: int, per_dest_capacity: int
) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Bucket rows by ``hash(key) % n_shards`` into a fixed send layout.

    Returns ``(send_cols, send_valid, overflow)`` where each send array has
    shape ``(n_shards, per_dest_capacity[, ...])`` ready for ``all_to_all``.
    Rows beyond a destination's capacity are counted in ``overflow`` (they
    would be spilled in Spark; here the capacity is sized with slack and the
    overflow statistic is asserted zero by the monitoring layer).
    """
    cap = table.capacity
    k = table.columns[key].astype(jnp.uint32)
    # Finalizer-style integer hash (splittable, good avalanche) — cheap on VPU.
    h = k * jnp.uint32(0x9E3779B1)
    h = h ^ (h >> 16)
    dest = jnp.where(table.valid, (h % jnp.uint32(n_shards)).astype(jnp.int32), n_shards)

    order = jnp.argsort(dest, stable=True)           # group rows by destination
    dsort = dest[order]
    group_start = jnp.searchsorted(dsort, jnp.arange(n_shards + 1, dtype=dsort.dtype))
    pos_in_group = jnp.arange(cap, dtype=jnp.int32) - group_start[dsort].astype(jnp.int32)
    ok = (dsort < n_shards) & (pos_in_group < per_dest_capacity)
    oob = n_shards * per_dest_capacity  # scatter target for dropped rows
    slot = jnp.where(ok, dsort * per_dest_capacity + pos_in_group, oob)

    send_valid = (
        jnp.zeros((oob,), bool).at[slot].set(True, mode="drop").reshape(n_shards, per_dest_capacity)
    )
    send_cols = {}
    for name, col in table.columns.items():
        buf = jnp.full((oob,), _sentinel(col.dtype), col.dtype)
        send_cols[name] = buf.at[slot].set(col[order], mode="drop").reshape(
            n_shards, per_dest_capacity
        )
    overflow = ((dsort < n_shards) & ~ok).sum().astype(jnp.int32)
    return send_cols, send_valid, overflow


def exchange(
    table: ColumnarTable, key: str, axis_name: str, n_shards: int, per_dest_capacity: int
) -> Tuple[ColumnarTable, jax.Array]:
    """One shuffle: hash-partition + ``all_to_all`` + local concatenation.

    Must run inside ``shard_map`` over ``axis_name``.  After this call every
    shard holds exactly the rows whose key hashes to it — co-partitioning the
    join inputs the way Spark's exchange does before a sort-merge join.
    """
    send_cols, send_valid, overflow = hash_partition(table, key, n_shards, per_dest_capacity)
    # bool is not a collective-friendly dtype on all backends; move as int8.
    recv_valid = jax.lax.all_to_all(send_valid.astype(jnp.int8), axis_name, 0, 0).astype(bool)
    recv_cols = {n: jax.lax.all_to_all(c, axis_name, 0, 0) for n, c in send_cols.items()}
    out = ColumnarTable(
        {n: c.reshape(-1) for n, c in recv_cols.items()},
        recv_valid.reshape(-1),
        recv_valid.reshape(-1).sum().astype(jnp.int32),
    )
    return out, overflow


def distributed_flatten(
    schema: StarSchema,
    tables: Mapping[str, ColumnarTable],
    mesh: jax.sharding.Mesh,
    axis_name: str = "data",
    slack: float = 2.0,
    min_per_dest: int = 64,
    expand_capacity: Optional[int] = None,
):
    """Multi-shard denormalization: shuffle every table onto the join key,
    then flatten locally — the full SCALPEL-Flattening plan on a mesh.

    Plan (mirrors Spark's physical plan for the paper's §3.3 job):
      1. exchange central + each dimension on their join key (co-partition);
      2. per-shard local joins (lookup/expand);
      3. exchange the flat table on ``patient_id`` so the *output* is
         patient-partitioned — the property that makes every downstream
         extractor collective-free.

    Returns ``(flat_table, overflow_total)``: the flat table is globally
    row-sharded over ``axis_name`` (patient-partitioned), overflow is a
    replicated scalar the caller asserts to be zero.
    """
    from jax.sharding import PartitionSpec as P

    n = mesh.shape[axis_name]

    # Decompose tables into (columns, valid) — shard_map shards raw arrays;
    # per-shard counts are recomputed locally (a global `count` scalar cannot
    # shard over rows).  Capacities are padded to a multiple of the shard
    # count (pad rows are invalid).
    raw = {}
    for name, t in tables.items():
        cap = -(-t.capacity // n) * n
        tp = t.pad_to(cap) if cap != t.capacity else t
        raw[name] = ({k: v for k, v in tp.columns.items()}, tp.valid)

    def plan(raw_tbls):
        overflow = jnp.int32(0)
        local: Dict[str, ColumnarTable] = {}
        for name, (cols, valid) in raw_tbls.items():
            local[name] = ColumnarTable(cols, valid, valid.sum().astype(jnp.int32))

        # Spark physical plan: exchange both sides of every join onto the join
        # key, local join, repeat — then one final exchange onto patient_id.
        # Partitioning-aware (Spark's EnsureRequirements): an exchange is
        # skipped when the table is already hash-partitioned on the key —
        # re-exchanging on the same key would funnel every row to one
        # destination.
        flat = local[schema.central.name]
        flat_pkey = None  # current partitioning key of `flat` (None = arbitrary)
        for edge in schema.joins:
            right = local[edge.right]
            if flat_pkey != edge.left_key:
                per_l = max(min_per_dest, int(flat.capacity * slack / n))
                flat, ov1 = exchange(flat, edge.left_key, axis_name, n, per_l)
                overflow = overflow + ov1
                flat_pkey = edge.left_key
            per_r = max(min_per_dest, int(right.capacity * slack / n))
            right, ov2 = exchange(right, edge.right_key, axis_name, n, per_r)
            overflow = overflow + ov2
            if edge.one_to_many:
                cap = expand_capacity or int((flat.capacity + right.capacity) * 1.5)
                flat, st = expand_join(flat, right, edge.left_key, edge.right_key, cap)
            else:
                flat, st = lookup_join(flat, right, edge.left_key, edge.right_key)
            overflow = overflow + st.overflow

        if schema.patient_key in flat.columns and flat_pkey != schema.patient_key:
            flat, ov = exchange(
                flat, schema.patient_key, axis_name, n,
                max(min_per_dest, int(flat.capacity * slack / n)),
            )
            overflow = overflow + ov
        return (dict(flat.columns), flat.valid), jax.lax.psum(overflow, axis_name)

    shard_fn = jax.shard_map(
        plan,
        mesh=mesh,
        in_specs=(P(axis_name),),   # pytree prefix: every table row-sharded
        out_specs=(P(axis_name), P()),
        check_vma=False,
    )
    (cols, valid), overflow = shard_fn(raw)
    flat = ColumnarTable(cols, valid, valid.sum().astype(jnp.int32))
    return flat, overflow
