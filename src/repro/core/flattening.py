"""SCALPEL-Flattening: distributed denormalization of star-schema claims data.

The paper's pitch (§3.3): pay the join cost *once* — recursively left-join the
dimension/child tables onto the central fact table, store the result columnar,
and every later query becomes a shuffle-free columnar scan.

TPU adaptation (DESIGN.md §2):
  * Spark shuffle  -> ``jax.lax.all_to_all`` over the mesh ``data`` axis
                      (fixed-capacity hash-partition exchange; XLA needs static
                      shapes so each destination bucket has a capacity and an
                      overflow counter instead of dynamic spill).
  * N:1 join       -> sorted-lookup join (searchsorted + gather).
  * 1:N join       -> offset-expansion join (prefix-sum over match counts);
                      this is what reproduces the PMSI-MCO row blow-up of
                      Table 1 and its block-sparsity discussion in §5.
  * temporal slice -> host-driven loop over time buckets, each bucket a
                      bounded-capacity flatten, results appended (paper: joins
                      "sequentially appended to the output parquet file").
  * monitoring     -> per-stage row counts + key checksums proving no loss.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset as _bs
from repro.core.columnar import ColumnarTable, NULL_FLOAT, NULL_INT, is_null
from repro.core.schema import JoinEdge, StarSchema

__all__ = [
    "lookup_join",
    "expand_join",
    "flatten_star",
    "flatten_sliced",
    "FlatteningStats",
    "STAT_FIELDS",
    "stats_from_dict",
    "hash_partition",
    "exchange",
    "distributed_flatten",
]


def _sentinel(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(NULL_FLOAT, dtype)
    return jnp.asarray(NULL_INT, dtype)


def _maxval(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.finfo(dtype).max, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


@dataclasses.dataclass
class FlatteningStats:
    """Monitoring statistics computed along the flattening (paper §3.3)."""

    stage: str
    rows_in: jax.Array
    rows_out: jax.Array
    matched: jax.Array      # left rows that found >=1 (non-null) right match
    overflow: jax.Array     # rows dropped because a static capacity was hit
    key_sum_in: jax.Array
    key_sum_out: jax.Array
    null_keys: jax.Array = None  # key-is-NULL rows excluded from matching

    def assert_no_loss(self):
        """Host-side check: every input row survived (paper's no-loss audit)."""
        if int(self.overflow) != 0:
            raise AssertionError(f"stage {self.stage}: {int(self.overflow)} rows overflowed")


# Field order of the per-node stats dicts the plan executor emits; mirrors the
# FlatteningStats attributes (minus ``stage``, carried by the node label).
STAT_FIELDS = ("rows_in", "rows_out", "matched", "overflow", "null_keys",
               "key_sum_in", "key_sum_out")


def stats_from_dict(stage: str, d: Mapping[str, jax.Array]) -> FlatteningStats:
    """Rehydrate a FlatteningStats from an executor stats dict."""
    return FlatteningStats(stage=stage, **{k: d[k] for k in STAT_FIELDS})


# ---------------------------------------------------------------------------
# N:1 sorted-lookup join (DCIR block-sparse detail tables, patient repository)
# ---------------------------------------------------------------------------
def lookup_join(
    left: ColumnarTable,
    right: ColumnarTable,
    left_key: str,
    right_key: str,
    prefix: str = "",
) -> Tuple[ColumnarTable, FlatteningStats]:
    """Left join where ``right`` has at most one row per key.

    Right is sorted by key (invalid rows sink with +inf key), left keys are
    located by ``searchsorted``, right attributes gathered, misses filled with
    null sentinels — exactly a hash-lookup join expressed in sorted-columnar
    form (TPUs vastly prefer sorted gathers over scattered hash probes).

    SQL left-join semantics for NULLs: a NULL key never matches anything, so
    null-key right rows are masked out up front (they sink with the invalid
    rows) and null-key left rows miss by construction; both are counted in
    ``FlatteningStats.null_keys``.
    """
    # word-wise validity: every row-mask consumer below gathers its bit
    # straight from the packed words (``bit_at`` fuses into the consumer) —
    # the searchsorted key fills never round-trip validity through a bool
    # column (pinned by the no-unpack tests)
    l_valid = _bs.bit_at(left.valid, jnp.arange(left.capacity, dtype=jnp.int32))
    r_rows = jnp.arange(right.capacity, dtype=jnp.int32)
    r_key_null = is_null(right.columns[right_key]) \
        & _bs.bit_at(right.valid, r_rows)
    right = right.filter(~is_null(right.columns[right_key]))
    r = right.sort_by([right_key])
    cap_r = r.capacity
    lk = left.columns[left_key]
    l_key_null = is_null(lk) & l_valid
    if cap_r == 0:  # empty right table: every left row misses
        pos = jnp.zeros(left.capacity, jnp.int32)
        posc = pos
        found = jnp.zeros(left.capacity, bool)
        r = r.pad_to(1)  # 1-row dummy so gathers below are well-formed
    else:
        rk = jnp.where(_bs.bit_at(r.valid, jnp.arange(cap_r, dtype=jnp.int32)),
                       r.columns[right_key],
                       _maxval(r.columns[right_key].dtype))
        pos = jnp.searchsorted(rk, lk, side="left")
        posc = jnp.clip(pos, 0, cap_r - 1)
        found = ((pos < cap_r) & (rk[posc] == lk) & _bs.bit_at(r.valid, posc)
                 & l_valid & ~is_null(lk))

    new_cols = dict(left.columns)
    for name in r.column_names:
        if name == right_key:
            continue
        out_name = prefix + name
        if out_name in new_cols:
            raise ValueError(f"column collision {out_name!r}; pass a prefix")
        col = r.columns[name]
        new_cols[out_name] = jnp.where(found, col[posc], _sentinel(col.dtype))

    out = ColumnarTable(new_cols, left.valid, left.count, left.capacity)
    key_col = left.columns[left_key].astype(jnp.uint32)
    key_sum = jnp.where(l_valid, key_col, 0).sum(dtype=jnp.uint32)
    stats = FlatteningStats(
        stage=f"lookup_join[{left_key}]",
        rows_in=left.count,
        rows_out=out.count,
        matched=found.sum().astype(jnp.int32),
        overflow=jnp.int32(0),
        key_sum_in=key_sum,
        key_sum_out=key_sum,  # validity unchanged: identical by construction
        null_keys=(l_key_null.sum() + r_key_null.sum()).astype(jnp.int32),
    )
    return out, stats


# ---------------------------------------------------------------------------
# 1:N offset-expansion join (PMSI child tables -> the Table-1 blow-up)
# ---------------------------------------------------------------------------
def expand_join(
    left: ColumnarTable,
    right: ColumnarTable,
    left_key: str,
    right_key: str,
    out_capacity: int,
    prefix: str = "",
) -> Tuple[ColumnarTable, FlatteningStats]:
    """Left join where ``right`` may hold N rows per key; output row per pair.

    Match counts per left row come from two ``searchsorted`` passes over the
    sorted right keys; an exclusive prefix sum turns them into output offsets;
    each output slot locates its (left row, right row) pair by binary search.
    Unmatched left rows still emit one row (left-join semantics) with null
    right attributes.  ``out_capacity`` bounds the static output size; slots
    beyond the true total are invalid, and a positive ``overflow`` statistic
    flags capacity overruns (the audit the paper computes per stage).
    """
    L = left.capacity
    # word-wise validity, as in lookup_join: bits gathered from the packed
    # words at each use site, never expanded to a bool column
    l_valid = _bs.bit_at(left.valid, jnp.arange(L, dtype=jnp.int32))
    r_key_null = is_null(right.columns[right_key]) \
        & _bs.bit_at(right.valid, jnp.arange(right.capacity, dtype=jnp.int32))
    right = right.filter(~is_null(right.columns[right_key]))
    if right.capacity == 0:
        right = right.pad_to(1)
    r = right.sort_by([right_key])
    cap_r = r.capacity
    rk = jnp.where(_bs.bit_at(r.valid, jnp.arange(cap_r, dtype=jnp.int32)),
                   r.columns[right_key], _maxval(r.columns[right_key].dtype))
    lk = left.columns[left_key]
    l_key_null = is_null(lk) & l_valid

    start = jnp.searchsorted(rk, lk, side="left")
    stop = jnp.searchsorted(rk, lk, side="right")
    # NULL keys never match (SQL left-join semantics); null-key left rows
    # still emit one row with null right attributes.
    cnt = jnp.where(l_valid & ~is_null(lk), stop - start, 0)
    out_cnt = jnp.where(l_valid, jnp.maximum(cnt, 1), 0)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(out_cnt).astype(jnp.int32)])
    total = offs[-1]

    j = jnp.arange(out_capacity, dtype=jnp.int32)
    src = jnp.clip(jnp.searchsorted(offs, j, side="right") - 1, 0, L - 1)
    rel = j - offs[src]
    has_match = cnt[src] > 0
    ridx = jnp.clip(start[src] + rel, 0, cap_r - 1)
    out_valid = (j < total) & l_valid[src]
    right_ok = has_match & out_valid

    new_cols = {k: jnp.where(out_valid, v[src], _sentinel(v.dtype)) for k, v in left.columns.items()}
    for name in r.column_names:
        if name == right_key:
            continue
        out_name = prefix + name
        if out_name in new_cols:
            raise ValueError(f"column collision {out_name!r}; pass a prefix")
        col = r.columns[name]
        new_cols[out_name] = jnp.where(right_ok, col[ridx], _sentinel(col.dtype))

    out = ColumnarTable(new_cols, out_valid, out_valid.sum().astype(jnp.int32))
    key_u32 = lk.astype(jnp.uint32)
    stats = FlatteningStats(
        stage=f"expand_join[{left_key}]",
        rows_in=left.count,
        rows_out=out.count,
        matched=(cnt > 0).sum().astype(jnp.int32),
        overflow=jnp.maximum(total - out_capacity, 0).astype(jnp.int32),
        key_sum_in=jnp.where(l_valid, key_u32, 0).sum(dtype=jnp.uint32),
        key_sum_out=jnp.where(out_valid, new_cols[left_key].astype(jnp.uint32), 0).sum(dtype=jnp.uint32),
        null_keys=(l_key_null.sum() + r_key_null.sum()).astype(jnp.int32),
    )
    return out, stats


# ---------------------------------------------------------------------------
# Whole-star flattening
# ---------------------------------------------------------------------------
def _run_flatten_plan(plan, out_id, tables):
    """Execute a flattening plan body (traceable) and rehydrate its stats."""
    from repro.study.executor import run_plan_body

    env = {s: tables[s] for s in plan.sources()}
    vals, _, stats = run_plan_body(plan, env, 0, "xla")
    stats_list = [stats_from_dict(plan.nodes[i].label(), stats[i])
                  for i in sorted(stats)]
    return vals[out_id], stats_list


def flatten_star(
    schema: StarSchema,
    tables: Mapping[str, ColumnarTable],
    expand_capacity: Optional[int] = None,
    expand_slack: float = 1.5,
) -> Tuple[ColumnarTable, List[FlatteningStats]]:
    """Denormalize one sub-database: sequential joins from the central table.

    Thin eager wrapper over the plan path (mirrors ``Extractor.__call__``):
    builds the ``scan_star``/join node chain and evaluates it immediately via
    the plan executor's traced body, so it stays jit-able from the outside.
    ``expand_capacity`` bounds each 1:N expansion; when omitted it is derived
    from the static table capacities at trace time.  Studies should instead
    use ``Study.flatten``, whose optimizer pass derives exact capacities from
    table statistics host-side.
    """
    from repro.study.api import contribute_flatten
    from repro.study.plan import PlanBuilder

    b = PlanBuilder()
    out = contribute_flatten(b, schema, expand_capacity=expand_capacity,
                             expand_slack=expand_slack)
    b.set_output("flat", out)
    return _run_flatten_plan(b.build(), out, tables)


def flatten_sliced(
    schema: StarSchema,
    tables: Mapping[str, ColumnarTable],
    time_column: str,
    n_slices: int,
    t0: int,
    t1: int,
    **kw,
) -> Tuple[ColumnarTable, List[FlatteningStats]]:
    """Temporal slicing (paper §3.3): divide the central table by time unit,
    flatten each slice, and append the results — bounds the working set of
    each big join exactly like SCALPEL-Flattening's year/month slicing.

    Host-driven (tables must be concrete, not tracers): the capacity planner
    bounds each slice by its actual row count, so the appended output
    allocates ~sum-of-slice-rows instead of ``n_slices`` copies of the full
    central capacity.
    """
    from repro.study.api import contribute_flatten_sliced
    from repro.study.optimizer import plan_capacities
    from repro.study.plan import PlanBuilder

    b = PlanBuilder()
    out = contribute_flatten_sliced(b, schema, time_column, n_slices, t0, t1,
                                    **kw)
    b.set_output("flat", out)
    plan = plan_capacities(b.build(), tables)
    return _run_flatten_plan(plan, plan.output_ids["flat"], tables)


# ---------------------------------------------------------------------------
# Distributed exchange: the Spark shuffle on the TPU ICI
# ---------------------------------------------------------------------------
def hash_partition(
    table: ColumnarTable, key: str, n_shards: int, per_dest_capacity: int
) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
    """Bucket rows by ``hash(key) % n_shards`` into a fixed send layout.

    Returns ``(send_cols, send_valid, overflow)`` where each send array has
    shape ``(n_shards, per_dest_capacity[, ...])`` ready for ``all_to_all``.
    Rows beyond a destination's capacity are counted in ``overflow`` (they
    would be spilled in Spark; here the capacity is sized with slack and the
    overflow statistic is asserted zero by the monitoring layer).
    """
    cap = table.capacity
    k = table.columns[key].astype(jnp.uint32)
    # Finalizer-style integer hash (splittable, good avalanche) — cheap on VPU.
    h = k * jnp.uint32(0x9E3779B1)
    h = h ^ (h >> 16)
    dest = jnp.where(table.valid_bool(), (h % jnp.uint32(n_shards)).astype(jnp.int32), n_shards)

    order = jnp.argsort(dest, stable=True)           # group rows by destination
    dsort = dest[order]
    group_start = jnp.searchsorted(dsort, jnp.arange(n_shards + 1, dtype=dsort.dtype))
    pos_in_group = jnp.arange(cap, dtype=jnp.int32) - group_start[dsort].astype(jnp.int32)
    ok = (dsort < n_shards) & (pos_in_group < per_dest_capacity)
    oob = n_shards * per_dest_capacity  # scatter target for dropped rows
    slot = jnp.where(ok, dsort * per_dest_capacity + pos_in_group, oob)

    send_valid = (
        jnp.zeros((oob,), bool).at[slot].set(True, mode="drop").reshape(n_shards, per_dest_capacity)
    )
    send_cols = {}
    for name, col in table.columns.items():
        buf = jnp.full((oob,), _sentinel(col.dtype), col.dtype)
        send_cols[name] = buf.at[slot].set(col[order], mode="drop").reshape(
            n_shards, per_dest_capacity
        )
    overflow = ((dsort < n_shards) & ~ok).sum().astype(jnp.int32)
    return send_cols, send_valid, overflow


def exchange(
    table: ColumnarTable, key: str, axis_name: str, n_shards: int, per_dest_capacity: int
) -> Tuple[ColumnarTable, jax.Array]:
    """One shuffle: hash-partition + ``all_to_all`` + local concatenation.

    Must run inside ``shard_map`` over ``axis_name``.  After this call every
    shard holds exactly the rows whose key hashes to it — co-partitioning the
    join inputs the way Spark's exchange does before a sort-merge join.
    """
    send_cols, send_valid, overflow = hash_partition(table, key, n_shards, per_dest_capacity)
    # bool is not a collective-friendly dtype on all backends; move as int8.
    recv_valid = jax.lax.all_to_all(send_valid.astype(jnp.int8), axis_name, 0, 0).astype(bool)
    recv_cols = {n: jax.lax.all_to_all(c, axis_name, 0, 0) for n, c in send_cols.items()}
    out = ColumnarTable(
        {n: c.reshape(-1) for n, c in recv_cols.items()},
        recv_valid.reshape(-1),
        recv_valid.reshape(-1).sum().astype(jnp.int32),
    )
    return out, overflow


def distributed_flatten(
    schema: StarSchema,
    tables: Mapping[str, ColumnarTable],
    mesh: jax.sharding.Mesh,
    axis_name: str = "data",
    slack: float = 2.0,
    min_per_dest: int = 64,
    expand_capacity: Optional[int] = None,
):
    """Multi-shard denormalization: shuffle every table onto the join key,
    then flatten locally — the full SCALPEL-Flattening plan on a mesh.

    Plan (mirrors Spark's physical plan for the paper's §3.3 job):
      1. exchange central + each dimension on their join key (co-partition);
      2. per-shard local joins (lookup/expand);
      3. exchange the flat table on ``patient_id`` so the *output* is
         patient-partitioned — the property that makes every downstream
         extractor collective-free.

    Returns ``(flat_table, overflow_total)``: the flat table is globally
    row-sharded over ``axis_name`` (patient-partitioned), overflow is a
    scalar the caller asserts to be zero.

    Thin wrapper over the plan path: builds the exchange-aware flatten plan
    (``contribute_flatten(exchange=True)`` emits the Spark physical plan —
    exchange both sides of every join onto the join key, then one final
    exchange onto ``patient_id``), lets the optimizer's partitioning-awareness
    pass prune exchanges whose input is already hash-partitioned on the key
    (Spark's EnsureRequirements, formerly a hand-rolled ``flat_pkey`` loop
    here), and executes under ``shard_map`` via ``execute_plan_sharded``.
    """
    from repro.distributed.pipeline import execute_plan_sharded
    from repro.study.api import contribute_flatten
    from repro.study.optimizer import dce, prune_exchanges
    from repro.study.plan import PlanBuilder

    n = mesh.shape[axis_name]
    b = PlanBuilder()
    out = contribute_flatten(b, schema, expand_capacity=expand_capacity,
                             exchange=True, exchange_slack=slack,
                             min_per_dest=min_per_dest)
    b.set_output("flat", out)
    plan = dce(prune_exchanges(b.build(), n_shards=n))
    vals, _, stats = execute_plan_sharded(plan, tables, 0, mesh,
                                          axis_name=axis_name)
    flat = vals[plan.output_ids["flat"]]
    overflow = jnp.int32(sum(s["overflow"] for s in stats.values()))
    return flat, overflow
