"""Patient / Event abstractions (paper §3.4) in columnar batch form.

The paper's two core records:
  * ``Patient(patientID, gender, birthDate, deathDate)``
  * ``Event(patientID, category, groupID, value, weight, start, end)`` —
    punctual events carry ``end == NULL``; continuous events carry a real end.

Batches of either are ``ColumnarTable``s with the standardized schema; the
category vocabulary below is shared by extractors, transformers and the
feature driver (it doubles as the LM token-type space).
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.columnar import ColumnarTable, NULL_INT

__all__ = ["Category", "make_events", "empty_events", "sort_events", "EVENT_COLUMNS"]


class Category:
    """Event-category vocabulary (extractor outputs + transformer outputs)."""

    DRUG_DISPENSE = 1
    MEDICAL_ACT = 2
    DIAGNOSIS = 3
    HOSPITAL_STAY = 4
    BIOLOGY = 5
    PRACTITIONER = 6
    # transformer-produced (complex) events:
    FOLLOW_UP = 10
    EXPOSURE = 11
    OUTCOME_FRACTURE = 12
    TRACKLOSS = 13
    OBSERVATION = 14

    NAMES = {
        1: "drug_dispense", 2: "medical_act", 3: "diagnosis", 4: "hospital_stay",
        5: "biology", 6: "practitioner", 10: "follow_up", 11: "exposure",
        12: "fracture", 13: "trackloss", 14: "observation",
    }


EVENT_COLUMNS = ("patient_id", "category", "group_id", "value", "weight", "start", "end")


def make_events(
    patient_id: jax.Array,
    category,
    value: jax.Array,
    start: jax.Array,
    end: jax.Array | None = None,
    group_id: jax.Array | None = None,
    weight: jax.Array | None = None,
    valid: jax.Array | None = None,
) -> ColumnarTable:
    """Assemble a standardized event batch (step 3 of the extractor design)."""
    n = patient_id.shape[0]
    cat = jnp.broadcast_to(jnp.asarray(category, jnp.int32), (n,))
    cols = {
        "patient_id": patient_id.astype(jnp.int32),
        "category": cat,
        "group_id": (group_id if group_id is not None else jnp.zeros((n,), jnp.int32)).astype(jnp.int32),
        "value": value.astype(jnp.int32),
        "weight": (weight if weight is not None else jnp.ones((n,), jnp.float32)).astype(jnp.float32),
        "start": start.astype(jnp.int32),
        "end": (end if end is not None else jnp.full((n,), NULL_INT, jnp.int32)).astype(jnp.int32),
    }
    return ColumnarTable.from_columns(cols, valid=valid)


def empty_events(capacity: int) -> ColumnarTable:
    z = jnp.zeros((capacity,), jnp.int32)
    return make_events(z, 0, z, z, valid=jnp.zeros((capacity,), bool))


def sort_events(events: ColumnarTable) -> ColumnarTable:
    """Canonical event order: (patient, start, category, value).

    Transformers assume this order; the flattening step emits it once so every
    downstream per-patient fold is a segment operation (DESIGN.md §2).
    """
    return events.sort_by(["patient_id", "start", "category", "value"])
