"""scalpel.stats analogue: patient-centric and event-centric descriptive
statistics over cohorts (paper §3.5 — ">25 statistics", cached, pluggable).

Each statistic is a pure function ``(cohort, patients|events) -> dict`` whose
heavy part is jit-compiled; a tiny registry makes adding a custom statistic a
one-liner, mirroring the paper's "adding a custom one being very easy".

Empty-cohort semantics: every statistic is total over empty cohorts/event
sets and NaN-free.  Ratios and means whose denominator (subject or event
count) is zero return the documented sentinel ``0.0`` / ``0`` alongside an
explicit count key (``n``/``pairs``/…) so a consumer can distinguish "mean
is zero" from "nothing to average" without ever meeting a NaN.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cohort import Cohort
from repro.core.columnar import ColumnarTable, is_null
from repro.core.events import Category

__all__ = ["STATISTICS", "register", "compute", "report", "distribution_by_gender_age_bucket"]

STATISTICS: Dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        STATISTICS[name] = fn
        return fn
    return deco


def _valid_mask(t: ColumnarTable) -> jax.Array:
    """Per-row validity of a table, memoized on the table instance so the
    20+ registered statistics of one ``compute`` call share ONE expansion of
    the packed validity bitset instead of re-unpacking it each.  Tracers are
    never cached (stats are host-side, but a traced caller must not leak)."""
    m = t.__dict__.get("_stats_valid_cache")
    if m is None:
        m = t.valid_bool()
        if not isinstance(m, jax.core.Tracer):
            t.__dict__["_stats_valid_cache"] = m
    return m


def _cohort_patient_mask(cohort: Cohort, patients: ColumnarTable) -> jax.Array:
    """Cohort-membership mask over the patients table's rows, memoized per
    (cohort, patients) pair: the subject-bitset unpack and the membership
    gather run once per ``stats.compute`` battery, not once per statistic.
    The patients table is held by WEAK reference — the cache never extends
    its lifetime beyond the caller's."""
    import weakref

    cached = cohort.__dict__.get("_patient_mask_cache")
    if cached is not None and cached[0]() is patients:
        return cached[1]
    mask = cohort.subjects_mask()          # itself memoized on the cohort
    idx = jnp.clip(patients.columns["patient_id"], 0, cohort.n_patients - 1)
    m = _valid_mask(patients) & mask[idx]
    if not isinstance(m, jax.core.Tracer):
        cohort.__dict__["_patient_mask_cache"] = (weakref.ref(patients), m)
    return m


# -- patient-centric ----------------------------------------------------------
@register("gender_distribution")
def gender_distribution(cohort: Cohort, patients: ColumnarTable, **_) -> Dict:
    m = _cohort_patient_mask(cohort, patients)
    g = patients.columns["gender"]
    male = (m & (g == 1)).sum()
    female = (m & (g == 2)).sum()
    return {"male": int(male), "female": int(female)}


@register("age_buckets")
def age_buckets(cohort: Cohort, patients: ColumnarTable, ref_date: int = 14_600,
                bucket_years: int = 10, n_buckets: int = 11, **_) -> Dict:
    m = _cohort_patient_mask(cohort, patients)
    age = (ref_date - patients.columns["birth_date"]) // 365
    b = jnp.clip(age // bucket_years, 0, n_buckets - 1)
    hist = jax.ops.segment_sum(m.astype(jnp.int32), b, num_segments=n_buckets)
    return {f"{i*bucket_years}-{(i+1)*bucket_years-1}": int(hist[i]) for i in range(n_buckets)}


@register("mortality")
def mortality(cohort: Cohort, patients: ColumnarTable, **_) -> Dict:
    m = _cohort_patient_mask(cohort, patients)
    dead = m & ~is_null(patients.columns["death_date"])
    return {"dead": int(dead.sum()), "alive": int((m & ~dead).sum())}


# -- event-centric ------------------------------------------------------------
def _cohort_events(cohort: Cohort) -> ColumnarTable:
    if cohort.events is None:
        raise ValueError(f"cohort {cohort.name} carries no events")
    return cohort.events


@register("events_per_category")
def events_per_category(cohort: Cohort, *_, **__) -> Dict:
    ev = _cohort_events(cohort)
    cat = jnp.clip(ev.columns["category"], 0, 15)
    hist = jax.ops.segment_sum(_valid_mask(ev).astype(jnp.int32), cat, num_segments=16)
    return {Category.NAMES.get(i, str(i)): int(hist[i]) for i in range(16) if int(hist[i])}


@register("events_per_patient")
def events_per_patient(cohort: Cohort, *_, **__) -> Dict:
    ev = _cohort_events(cohort)
    seg = jnp.where(_valid_mask(ev), ev.columns["patient_id"], cohort.n_patients)
    per = jax.ops.segment_sum(
        jnp.ones_like(seg), jnp.clip(seg, 0, cohort.n_patients), cohort.n_patients + 1
    )[: cohort.n_patients]
    has = per > 0
    total = per.sum()
    n = has.sum()
    return {
        "patients_with_events": int(n),
        "mean": float(total / jnp.maximum(n, 1)),
        "max": int(per.max()),
    }


@register("events_per_month")
def events_per_month(cohort: Cohort, *_, t0: int = 14_600, n_months: int = 37, **__) -> Dict:
    ev = _cohort_events(cohort)
    m = jnp.clip((ev.columns["start"] - t0) // 30, 0, n_months - 1)
    hist = jax.ops.segment_sum(_valid_mask(ev).astype(jnp.int32), m, num_segments=n_months)
    return {"per_month": np.asarray(hist).tolist()}


@register("top_values")
def top_values(cohort: Cohort, *_, k: int = 10, n_codes: int = 4096, **__) -> Dict:
    ev = _cohort_events(cohort)
    v = jnp.clip(ev.columns["value"], 0, n_codes - 1)
    hist = jax.ops.segment_sum(_valid_mask(ev).astype(jnp.int32), v, num_segments=n_codes)
    top = jnp.argsort(-hist)[:k]
    return {int(c): int(hist[c]) for c in np.asarray(top) if int(hist[c]) > 0}


# -- driver -------------------------------------------------------------------
def compute(cohort: Cohort, patients: Optional[ColumnarTable] = None,
            names: Optional[list] = None, **kw) -> Dict[str, Dict]:
    out = {}
    for name in names or list(STATISTICS):
        fn = STATISTICS[name]
        try:
            out[name] = fn(cohort, patients, **kw)
        except (ValueError, TypeError):
            continue  # statistic not applicable (e.g. no events attached)
    return out


def report(cohort: Cohort, patients: Optional[ColumnarTable] = None, **kw) -> str:
    """Automatic textual report (the paper's automated audit reports)."""
    stats = compute(cohort, patients, **kw)
    lines = [f"cohort {cohort.name!r}: {cohort.subject_count()} subjects",
             f"  {cohort.description}"]
    for name, d in stats.items():
        lines.append(f"  [{name}]")
        for k, v in d.items():
            lines.append(f"    {k}: {v}")
    return "\n".join(lines)


def distribution_by_gender_age_bucket(cohort: Cohort, patients: ColumnarTable,
                                      ref_date: int = 14_600) -> Dict:
    """The Supplementary-A figure: age-bucket histogram split by gender."""
    out = {}
    for gname, gval in (("male", 1), ("female", 2)):
        m = _cohort_patient_mask(cohort, patients) & (patients.columns["gender"] == gval)
        age = (ref_date - patients.columns["birth_date"]) // 365
        b = jnp.clip(age // 10, 0, 10)
        hist = jax.ops.segment_sum(m.astype(jnp.int32), b, num_segments=11)
        out[gname] = np.asarray(hist).tolist()
    return out


# -- extended statistics battery (paper: ">25 Patient-centric or
# Event-centric statistics") ---------------------------------------------------
def _per_patient_counts(cohort: Cohort) -> jax.Array:
    ev = _cohort_events(cohort)
    seg = jnp.where(_valid_mask(ev), ev.columns["patient_id"], cohort.n_patients)
    return jax.ops.segment_sum(
        jnp.ones_like(seg), jnp.clip(seg, 0, cohort.n_patients),
        cohort.n_patients + 1)[: cohort.n_patients]


@register("age_mean")
def age_mean(cohort: Cohort, patients: ColumnarTable, ref_date: int = 14_600, **_):
    """Mean/std of age at ``ref_date``.  Empty cohort (no matching patient
    rows): sentinel ``{"mean": 0.0, "std": 0.0, "n": 0}`` — never NaN."""
    m = _cohort_patient_mask(cohort, patients)
    n_true = int(m.sum())
    if n_true == 0:
        return {"mean": 0.0, "std": 0.0, "n": 0}
    age = (ref_date - patients.columns["birth_date"]) / 365.0
    mean = jnp.where(m, age, 0).sum() / n_true
    var = jnp.where(m, (age - mean) ** 2, 0).sum() / n_true
    return {"mean": float(mean), "std": float(jnp.sqrt(var)), "n": n_true}


@register("subject_count")
def subject_count(cohort: Cohort, *_, **__):
    return {"subjects": cohort.subject_count()}


@register("events_total")
def events_total(cohort: Cohort, *_, **__):
    return {"events": int(_cohort_events(cohort).count)}


@register("events_per_patient_percentiles")
def events_per_patient_percentiles(cohort: Cohort, *_, **__):
    """Event-count percentiles over patients with >=1 event.  No such
    patients (empty cohort/event set): sentinel ``p50=p90=p99=0`` with
    ``n=0`` — ``np.percentile`` of an empty array would be NaN."""
    per = np.asarray(_per_patient_counts(cohort))
    per = per[per > 0]
    if per.size == 0:
        return {"p50": 0, "p90": 0, "p99": 0, "n": 0}
    out = {f"p{p}": int(np.percentile(per, p)) for p in (50, 90, 99)}
    out["n"] = int(per.size)
    return out


@register("distinct_values")
def distinct_values(cohort: Cohort, *_, n_codes: int = 65_536, **__):
    ev = _cohort_events(cohort)
    v = jnp.clip(ev.columns["value"], 0, n_codes - 1)
    hist = jax.ops.segment_sum(_valid_mask(ev).astype(jnp.int32), v, num_segments=n_codes)
    return {"distinct": int((hist > 0).sum())}


@register("first_event_date")
def first_event_date(cohort: Cohort, *_, **__):
    ev = _cohort_events(cohort)
    s = jnp.where(_valid_mask(ev), ev.columns["start"], 2_000_000_000)
    return {"min_start": int(s.min())}


@register("last_event_date")
def last_event_date(cohort: Cohort, *_, **__):
    ev = _cohort_events(cohort)
    s = jnp.where(_valid_mask(ev), ev.columns["start"], -2_000_000_000)
    return {"max_start": int(s.max())}


@register("event_duration")
def event_duration(cohort: Cohort, *_, **__):
    from repro.core.columnar import is_null as _is_null

    ev = _cohort_events(cohort)
    longi = _valid_mask(ev) & ~_is_null(ev.columns["end"])
    dur = jnp.where(longi, ev.columns["end"] - ev.columns["start"], 0)
    n = jnp.maximum(longi.sum(), 1)
    return {"longitudinal": int(longi.sum()), "mean_days": float(dur.sum() / n)}


@register("weight_total")
def weight_total(cohort: Cohort, *_, **__):
    ev = _cohort_events(cohort)
    return {"weight_sum": float(jnp.where(_valid_mask(ev), ev.columns["weight"], 0).sum())}


@register("events_by_gender")
def events_by_gender(cohort: Cohort, patients: ColumnarTable, **_):
    ev = _cohort_events(cohort)
    pid = jnp.clip(ev.columns["patient_id"], 0, cohort.n_patients - 1)
    pidx = jnp.where(_valid_mask(patients), patients.columns["patient_id"], cohort.n_patients)
    g_dense = jnp.zeros((cohort.n_patients,), jnp.int32).at[pidx].set(
        patients.columns["gender"], mode="drop")
    g = g_dense[pid]
    male = (_valid_mask(ev) & (g == 1)).sum()
    female = (_valid_mask(ev) & (g == 2)).sum()
    return {"male_events": int(male), "female_events": int(female)}


@register("events_per_year")
def events_per_year(cohort: Cohort, *_, t0: int = 14_600, **__):
    ev = _cohort_events(cohort)
    y = jnp.clip((ev.columns["start"] - t0) // 365, 0, 3)
    hist = jax.ops.segment_sum(_valid_mask(ev).astype(jnp.int32), y, num_segments=4)
    return {f"year_{i}": int(hist[i]) for i in range(4)}


@register("group_distribution")
def group_distribution(cohort: Cohort, *_, n_groups: int = 16, **__):
    ev = _cohort_events(cohort)
    g = jnp.clip(ev.columns["group_id"], 0, n_groups - 1)
    hist = jax.ops.segment_sum(_valid_mask(ev).astype(jnp.int32), g, num_segments=n_groups)
    return {int(i): int(hist[i]) for i in range(n_groups) if int(hist[i])}


@register("patients_without_events")
def patients_without_events(cohort: Cohort, *_, **__):
    per = _per_patient_counts(cohort)
    mask = cohort.subjects_mask()
    return {"in_cohort_without_events": int((mask & (per == 0)).sum())}


@register("mean_gap_days")
def mean_gap_days(cohort: Cohort, *_, **__):
    """Mean gap between a patient's consecutive events.  No consecutive
    same-patient pair (empty or singleton-per-patient event sets): sentinel
    ``{"mean_gap": 0.0, "pairs": 0}`` — the gap sum is never divided by a
    zero pair count."""
    from repro.core.events import sort_events as _sort

    ev = _sort(_cohort_events(cohort))
    pid = ev.columns["patient_id"]
    start = ev.columns["start"]
    same = jnp.concatenate([jnp.zeros((1,), bool),
                            (pid[1:] == pid[:-1]) & _valid_mask(ev)[:-1]]) & _valid_mask(ev)
    pairs = int(same.sum())
    if pairs == 0:
        return {"mean_gap": 0.0, "pairs": 0}
    prev = jnp.concatenate([jnp.zeros((1,), jnp.int32), start[:-1]])
    gaps = jnp.where(same, start - prev, 0)
    return {"mean_gap": float(gaps.sum() / pairs), "pairs": pairs}


@register("mortality_rate")
def mortality_rate(cohort: Cohort, patients: ColumnarTable, **_):
    from repro.core.columnar import is_null as _is_null

    m = _cohort_patient_mask(cohort, patients)
    dead = (m & ~_is_null(patients.columns["death_date"])).sum()
    return {"rate": float(dead / jnp.maximum(m.sum(), 1))}


@register("gender_ratio")
def gender_ratio(cohort: Cohort, patients: ColumnarTable, **_):
    """Male fraction of the cohort.  No gendered subjects at all: sentinel
    ``{"male_fraction": 0.0, "n": 0}`` (a 0/0 ratio is reported as 0.0 with
    the zero denominator made explicit, never NaN)."""
    d = gender_distribution(cohort, patients)
    tot = d["male"] + d["female"]
    if tot == 0:
        return {"male_fraction": 0.0, "n": 0}
    return {"male_fraction": round(d["male"] / tot, 4), "n": tot}


@register("value_range")
def value_range(cohort: Cohort, *_, **__):
    ev = _cohort_events(cohort)
    v = ev.columns["value"]
    return {"min": int(jnp.where(_valid_mask(ev), v, 2**30).min()),
            "max": int(jnp.where(_valid_mask(ev), v, -2**30).max())}


@register("events_per_category_per_patient")
def events_per_category_per_patient(cohort: Cohort, *_, **__):
    ev = _cohort_events(cohort)
    cat = jnp.clip(ev.columns["category"], 0, 15)
    hist = jax.ops.segment_sum(_valid_mask(ev).astype(jnp.int32), cat, num_segments=16)
    n = max(cohort.subject_count(), 1)
    return {Category.NAMES.get(i, str(i)): round(float(hist[i]) / n, 3)
            for i in range(16) if int(hist[i])}


@register("age_at_first_event")
def age_at_first_event(cohort: Cohort, patients: ColumnarTable, **_):
    from repro.core.transformers import observation_period as _obs

    ev = _cohort_events(cohort)
    obs = _obs(ev, cohort.n_patients)
    pidx = jnp.where(_valid_mask(patients), patients.columns["patient_id"], cohort.n_patients)
    birth = jnp.zeros((cohort.n_patients,), jnp.int32).at[pidx].set(
        patients.columns["birth_date"], mode="drop")
    age = (obs.columns["start"] - birth) / 365.0
    n = jnp.maximum(_valid_mask(obs).sum(), 1)
    return {"mean": float(jnp.where(_valid_mask(obs), age, 0).sum() / n)}


@register("top_patients_by_events")
def top_patients_by_events(cohort: Cohort, *_, k: int = 5, **__):
    per = np.asarray(_per_patient_counts(cohort))
    top = np.argsort(-per)[:k]
    return {int(p): int(per[p]) for p in top if per[p] > 0}
