"""ColumnarTable: the TPU-native analogue of SCALPEL3's Parquet-backed tables.

SCALPEL3 stores denormalized claims in Parquet (struct-of-arrays on disk) and
exploits three columnar properties (paper §3.4):
  (1) column projection is a metadata lookup,
  (2) null filtering exploits sparsity (nulls are not materialized),
  (3) row-value filtering happens late, on already-reduced data.

On TPU the equivalent resident format is a struct-of-arrays of fixed-capacity
``jnp`` arrays plus a validity mask.  XLA requires static shapes, so a table
has a *capacity* (allocated rows) and a *count* (valid rows); "null skipping"
becomes mask algebra (masked lanes are never re-materialized), and compaction
is an explicit, vectorized gather (see ``kernels/filter_compact``).

Validity representation: ``valid`` is a **packed uint32 bitset** (row ``i`` at
word ``i // 32``, bit ``i % 32`` — the one layout shared with
``cohort.Bitset`` and the Pallas kernels; see ``core/bitset``).  A validity
word costs 1 bit/row instead of the 1 byte/row of a bool column, so mask
algebra, cohort set-ops and the compaction keep-mask stay memory-bandwidth-
bound on *metadata*; the Pallas predicate kernel's packed output drops into
the table without an unpack hop.  Consumers that need a per-row mask (sorts,
segment folds, host export) call ``valid_bool()`` — the explicit, auditable
expansion boundary.

The class is a registered pytree so tables flow through ``jit``/``shard_map``
unchanged and shard across a mesh ``data`` axis like Spark partitions across
executors.  ``capacity`` is static pytree aux-data (shapes are static under
XLA anyway); the raw constructor accepts a bool row mask for ``valid`` and
packs it at the boundary, so eager call sites migrate incrementally.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset as _bs

__all__ = [
    "ColumnarTable",
    "NULL_INT",
    "NULL_FLOAT",
    "is_null",
]

# Sentinel encodings for nulls.  Parquet stores nulls out-of-band (definition
# levels); in fixed-width SoA we reserve a sentinel per dtype and track
# per-column null masks only where a column is declared nullable.
NULL_INT = jnp.int32(-2_147_483_648 + 1)  # INT32_MIN+1, keeps INT32_MIN usable for -inf keys
NULL_FLOAT = jnp.float32(jnp.nan)


def is_null(col: jax.Array) -> jax.Array:
    """Elementwise null mask for a sentinel-encoded column."""
    if jnp.issubdtype(col.dtype, jnp.floating):
        return jnp.isnan(col)
    return col == jnp.asarray(NULL_INT, dtype=col.dtype)


def _max_key(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.finfo(dtype).max, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ColumnarTable:
    """Fixed-capacity struct-of-arrays table with a packed-bitset validity.

    Attributes:
      columns:  name -> (capacity,) array.  All columns share the capacity.
      valid:    (ceil(capacity/32),) uint32 — packed row-validity bitset
                (``core.bitset`` layout; bits >= capacity are always 0).
                A bool ``(capacity,)`` row mask may be passed instead; the
                constructor packs it at the boundary.
      count:    scalar int32 — number of valid rows (== popcount(valid);
                carried so downstream code never re-reduces).
      capacity: static row capacity (pytree aux-data); derived from the
                columns (or a bool mask) when omitted.
    """

    columns: Dict[str, jax.Array]
    valid: jax.Array
    count: jax.Array
    capacity: Optional[int] = None

    def __post_init__(self):
        v = self.valid
        if not _bs.is_packed(v):
            v = jnp.asarray(v, bool)
            if self.capacity is None:
                self.capacity = int(v.shape[0])
            self.valid = _bs.pack(v)
        elif self.capacity is None:
            if not self.columns:
                raise ValueError(
                    "packed validity needs at least one column (or an "
                    "explicit capacity) to recover the row capacity")
            self.capacity = int(next(iter(self.columns.values())).shape[0])

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.valid, self.count)
        return children, (names, self.capacity)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, capacity = aux
        cols = dict(zip(names, children[: len(names)]))
        valid, count = children[len(names)], children[len(names) + 1]
        return cls(cols, valid, count, capacity)

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_columns(cls, columns: Mapping[str, jax.Array],
                     valid: jax.Array | None = None) -> "ColumnarTable":
        """Build a table; ``valid`` may be a ``(capacity,) bool`` row mask OR
        an already-packed ``(ceil(capacity/32),) uint32`` bitset (e.g. a
        predicate-kernel output).  Either form is length-validated against
        the column capacity — a mismatched mask would silently corrupt
        ``count`` and every downstream popcount."""
        cols = {k: jnp.asarray(v) for k, v in columns.items()}
        cap = next(iter(cols.values())).shape[0]
        for k, v in cols.items():
            if v.shape[0] != cap:
                raise ValueError(f"column {k!r} capacity {v.shape[0]} != {cap}")
        if valid is None:
            words = _bs.first_n(cap, cap)
            return cls(dict(cols), words, jnp.int32(cap), int(cap))
        if _bs.is_packed(valid):
            valid = jnp.asarray(valid)
            if valid.shape[0] != _bs.n_words(cap):
                raise ValueError(
                    f"packed valid has {valid.shape[0]} words but capacity "
                    f"{cap} needs {_bs.n_words(cap)}")
            # enforce the tail-bits-clear invariant on caller-supplied words
            valid = valid & _bs.first_n(cap, cap)
            return cls(dict(cols), valid, _bs.count(valid), int(cap))
        valid = jnp.asarray(valid, dtype=bool)
        if valid.shape[0] != cap:
            raise ValueError(
                f"valid mask length {valid.shape[0]} != capacity {cap}")
        return cls(dict(cols), _bs.pack(valid),
                   valid.sum().astype(jnp.int32), int(cap))

    @classmethod
    def empty(cls, spec: Mapping[str, np.dtype], capacity: int) -> "ColumnarTable":
        cols = {k: jnp.zeros((capacity,), dtype=dt) for k, dt in spec.items()}
        valid = jnp.zeros((_bs.n_words(capacity),), jnp.uint32)
        return cls(cols, valid, jnp.int32(0), int(capacity))

    # -- basic properties ----------------------------------------------------
    @property
    def column_names(self) -> tuple:
        return tuple(sorted(self.columns))

    def num_valid(self) -> jax.Array:
        return self.count

    def valid_bool(self) -> jax.Array:
        """Per-row bool validity — the compatibility expansion for consumers
        that need a row mask (sorts, segment folds).  The packed ``valid``
        words are the canonical form; this is a fused bitwise expansion."""
        return _bs.unpack(self.valid, self.capacity)

    def valid_numpy(self) -> np.ndarray:
        """Host-side per-row bool validity (numpy)."""
        return _bs.unpack_np(np.asarray(self.valid), self.capacity)

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    # -- columnar ops (paper Fig. 2 steps) ------------------------------------
    def select(self, names: Sequence[str]) -> "ColumnarTable":
        """Step 1 — column projection.  Pure metadata: no data movement."""
        return ColumnarTable({n: self.columns[n] for n in names},
                             self.valid, self.count, self.capacity)

    def with_columns(self, extra: Mapping[str, jax.Array]) -> "ColumnarTable":
        cols = dict(self.columns)
        for k, v in extra.items():
            cols[k] = jnp.asarray(v)
        return ColumnarTable(cols, self.valid, self.count, self.capacity)

    def filter(self, mask: jax.Array) -> "ColumnarTable":
        """Lazy row filter: narrows the validity bitset only (zero data
        movement).  ``mask`` is a ``(capacity,) bool`` row mask or an
        already-packed word array — either way the update is a word-wise AND
        (the columnar analogue of Parquet predicate pushdown; invalid lanes
        stay allocated but are never consumed).
        """
        if _bs.is_packed(mask):
            new_valid = self.valid & mask
        else:
            new_valid = self.valid & _bs.pack(jnp.asarray(mask, bool))
        return ColumnarTable(self.columns, new_valid, _bs.count(new_valid),
                             self.capacity)

    def drop_nulls(self, names: Sequence[str]) -> "ColumnarTable":
        """Step 2 — null filtering via mask algebra (cost ~ metadata)."""
        mask = None
        for n in names:
            ok = ~is_null(self.columns[n])
            mask = ok if mask is None else mask & ok
        if mask is None:
            return self
        return self.filter(mask)

    def compact(self) -> "ColumnarTable":
        """Gather valid rows to the front, preserving order (stream compaction).

        Bitset-native: the inclusive rank of row ``i`` (== the old
        ``cumsum(valid_bool)``) is rebuilt from the packed words — an
        exclusive cumsum of per-word popcounts plus an in-word masked
        popcount — so the keep-mask read is 1 bit/row.  The gather index for
        output slot j is then ``searchsorted(rank, j+1)``; slots past
        ``count`` hold clamped garbage and are masked invalid via a word-wise
        ``first_n``.  The Pallas ``filter_compact`` kernel (bitset keep-mask
        variant) is the fused production path; this is the always-correct jnp
        fallback used inside larger traced programs.
        """
        cap = self.capacity
        if cap == 0:
            return self
        words = self.valid
        per_word = jax.lax.population_count(words).astype(jnp.int32)
        excl = jnp.cumsum(per_word) - per_word           # popcount cumsum
        rows = jnp.arange(cap, dtype=jnp.int32)
        w, b = rows >> 5, (rows & 31).astype(jnp.uint32)
        upto = (jnp.uint32(2) << b) - jnp.uint32(1)      # bits <= b (wraps ok)
        within = jax.lax.population_count(words[w] & upto).astype(jnp.int32)
        rank = excl[w] + within                          # inclusive valid rank
        idx = jnp.searchsorted(rank, rows + 1, side="left")
        idx = jnp.minimum(idx, max(cap - 1, 0))
        cols = {k: v[idx] for k, v in self.columns.items()}
        return ColumnarTable(cols, _bs.first_n(self.count, cap), self.count,
                             cap)

    def take(self, idx: jax.Array, idx_valid: jax.Array | None = None) -> "ColumnarTable":
        """Row gather.  ``idx_valid`` marks which gathered rows exist."""
        cols = {k: v[idx] for k, v in self.columns.items()}
        valid = _bs.bit_at(self.valid, idx)
        if idx_valid is not None:
            valid = valid & idx_valid
        return ColumnarTable(cols, valid, valid.sum().astype(jnp.int32))

    def sort_by(self, names: Sequence[str]) -> "ColumnarTable":
        """Stable lexicographic sort; invalid rows sink to the end.

        Bitset-native: the per-row validity bit is gathered straight from
        the packed words (``bitset.bit_at`` — 1 bit/row of HBM, no bool
        column) and folded into the sort keys.  Because invalid rows sink,
        the sorted validity is exactly "first ``count`` rows" — emitted
        word-wise via ``bitset.first_n``, so the sort boundary never expands
        or re-packs a bool mask."""
        if self.capacity == 0:
            return self
        rows = jnp.arange(self.capacity, dtype=jnp.int32)
        bit = _bs.bit_at(self.valid, rows)
        keys = []
        for n in reversed(list(names)):  # lexsort: LAST key is primary
            col = self.columns[n]
            keys.append(jnp.where(bit, col, _max_key(col.dtype)))
        # Most-significant key: invalid rows sink last even if a valid row
        # happens to carry the max key value.
        keys.append((~bit).astype(jnp.int32))
        idx = jnp.lexsort(tuple(keys))
        cols = {k: v[idx] for k, v in self.columns.items()}
        return ColumnarTable(cols, _bs.first_n(self.count, self.capacity),
                             self.count, self.capacity)

    def shrink_to(self, capacity: int) -> "ColumnarTable":
        """Truncate to a smaller static capacity (inverse of ``pad_to``).

        Meant for already-compacted tables (valid rows at the front): valid
        rows beyond ``capacity`` are dropped, so callers size ``capacity``
        from the row count and audit the loss (see the ``slice_time`` node's
        overflow statistic).  Capacities >= the current one are a no-op.
        """
        if capacity >= self.capacity:
            return self
        cols = {k: v[:capacity] for k, v in self.columns.items()}
        valid = self.valid[: _bs.n_words(capacity)] & _bs.first_n(capacity,
                                                                  capacity)
        return ColumnarTable(cols, valid, _bs.count(valid), int(capacity))

    def pad_to(self, capacity: int) -> "ColumnarTable":
        if capacity < self.capacity:
            raise ValueError("pad_to cannot shrink a table")
        extra = capacity - self.capacity
        cols = {k: jnp.pad(v, (0, extra)) for k, v in self.columns.items()}
        # word-wise: new rows are invalid; existing tail bits are already 0
        valid = jnp.pad(self.valid,
                        (0, _bs.n_words(capacity) - self.valid.shape[0]))
        return ColumnarTable(cols, valid, self.count, int(capacity))

    @staticmethod
    def concat(tables: Sequence["ColumnarTable"]) -> "ColumnarTable":
        names = tables[0].column_names
        for t in tables[1:]:
            if t.column_names != names:
                raise ValueError("concat: mismatched schemas")
        cols = {n: jnp.concatenate([t.columns[n] for t in tables]) for n in names}
        if all(t.capacity % _bs.WORD_BITS == 0 for t in tables[:-1]):
            # word-aligned fast path (planner capacities are 64-aligned):
            # packed words concatenate directly, no expansion
            valid = jnp.concatenate([t.valid for t in tables])
        else:
            valid = _bs.pack(jnp.concatenate(
                [t.valid_bool() for t in tables]))
        count = sum((t.count for t in tables), jnp.int32(0))
        capacity = sum(t.capacity for t in tables)
        return ColumnarTable(cols, valid, count, capacity)

    # -- monitoring (paper §3.3: statistics proving no information loss) -----
    def monitoring_stats(self, key: str) -> Dict[str, jax.Array]:
        """Row-count + order-independent key checksum, computed per stage."""
        # uint32 modular arithmetic: stable under JAX's default x64-disabled mode.
        k = self.columns[key].astype(jnp.uint32)
        masked = jnp.where(self.valid_bool(), k, jnp.uint32(0))
        return {
            "rows": self.count.astype(jnp.int32),
            "key_sum": masked.sum(dtype=jnp.uint32),
            "key_xor": jnp.bitwise_xor.reduce(masked),
        }

    # -- host-side conveniences ----------------------------------------------
    def to_numpy(self) -> Dict[str, np.ndarray]:
        n = int(self.count)
        idx = np.argsort(~self.valid_numpy(), kind="stable")[:n]
        return {k: np.asarray(v)[idx] for k, v in self.columns.items()}

    def head(self, n: int = 8) -> str:
        data = self.to_numpy()
        names = list(data)
        lines = ["| " + " | ".join(names) + " |"]
        m = min(n, len(next(iter(data.values()))) if data else 0)
        for i in range(m):
            lines.append("| " + " | ".join(str(data[c][i]) for c in names) + " |")
        return "\n".join(lines)
