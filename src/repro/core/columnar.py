"""ColumnarTable: the TPU-native analogue of SCALPEL3's Parquet-backed tables.

SCALPEL3 stores denormalized claims in Parquet (struct-of-arrays on disk) and
exploits three columnar properties (paper §3.4):
  (1) column projection is a metadata lookup,
  (2) null filtering exploits sparsity (nulls are not materialized),
  (3) row-value filtering happens late, on already-reduced data.

On TPU the equivalent resident format is a struct-of-arrays of fixed-capacity
``jnp`` arrays plus a validity mask.  XLA requires static shapes, so a table has
a *capacity* (allocated rows) and a *count* (valid rows); "null skipping"
becomes mask algebra (masked lanes are never re-materialized), and compaction is
an explicit, vectorized gather (see ``kernels/filter_compact``).

The class is a registered pytree so tables flow through ``jit``/``shard_map``
unchanged and shard across a mesh ``data`` axis like Spark partitions across
executors.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ColumnarTable",
    "NULL_INT",
    "NULL_FLOAT",
    "is_null",
]

# Sentinel encodings for nulls.  Parquet stores nulls out-of-band (definition
# levels); in fixed-width SoA we reserve a sentinel per dtype and track
# per-column null masks only where a column is declared nullable.
NULL_INT = jnp.int32(-2_147_483_648 + 1)  # INT32_MIN+1, keeps INT32_MIN usable for -inf keys
NULL_FLOAT = jnp.float32(jnp.nan)


def is_null(col: jax.Array) -> jax.Array:
    """Elementwise null mask for a sentinel-encoded column."""
    if jnp.issubdtype(col.dtype, jnp.floating):
        return jnp.isnan(col)
    return col == jnp.asarray(NULL_INT, dtype=col.dtype)


def _max_key(dtype) -> jax.Array:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.asarray(jnp.finfo(dtype).max, dtype)
    return jnp.asarray(jnp.iinfo(dtype).max, dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ColumnarTable:
    """Fixed-capacity struct-of-arrays table with a validity mask.

    Attributes:
      columns: name -> (capacity,) array.  All columns share the capacity.
      valid:   (capacity,) bool — row validity (Spark row existence).
      count:   scalar int32 — number of valid rows (== valid.sum(); carried so
               downstream code never re-reduces).
    """

    columns: Dict[str, jax.Array]
    valid: jax.Array
    count: jax.Array

    # -- pytree protocol -----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[n] for n in names) + (self.valid, self.count)
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        cols = dict(zip(names, children[: len(names)]))
        valid, count = children[len(names)], children[len(names) + 1]
        return cls(cols, valid, count)

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_columns(cls, columns: Mapping[str, jax.Array], valid: jax.Array | None = None) -> "ColumnarTable":
        cols = {k: jnp.asarray(v) for k, v in columns.items()}
        cap = next(iter(cols.values())).shape[0]
        for k, v in cols.items():
            if v.shape[0] != cap:
                raise ValueError(f"column {k!r} capacity {v.shape[0]} != {cap}")
        if valid is None:
            valid = jnp.ones((cap,), dtype=bool)
        valid = jnp.asarray(valid, dtype=bool)
        return cls(dict(cols), valid, valid.sum().astype(jnp.int32))

    @classmethod
    def empty(cls, spec: Mapping[str, np.dtype], capacity: int) -> "ColumnarTable":
        cols = {k: jnp.zeros((capacity,), dtype=dt) for k, dt in spec.items()}
        valid = jnp.zeros((capacity,), dtype=bool)
        return cls(cols, valid, jnp.int32(0))

    # -- basic properties ----------------------------------------------------
    @property
    def capacity(self) -> int:
        return int(self.valid.shape[0])

    @property
    def column_names(self) -> tuple:
        return tuple(sorted(self.columns))

    def num_valid(self) -> jax.Array:
        return self.count

    def __getitem__(self, name: str) -> jax.Array:
        return self.columns[name]

    # -- columnar ops (paper Fig. 2 steps) ------------------------------------
    def select(self, names: Sequence[str]) -> "ColumnarTable":
        """Step 1 — column projection.  Pure metadata: no data movement."""
        return ColumnarTable({n: self.columns[n] for n in names}, self.valid, self.count)

    def with_columns(self, extra: Mapping[str, jax.Array]) -> "ColumnarTable":
        cols = dict(self.columns)
        for k, v in extra.items():
            cols[k] = jnp.asarray(v)
        return ColumnarTable(cols, self.valid, self.count)

    def filter(self, mask: jax.Array) -> "ColumnarTable":
        """Lazy row filter: narrows the validity mask only (zero data movement).

        This is the columnar analogue of Parquet predicate pushdown — invalid
        lanes stay allocated but are never consumed.
        """
        new_valid = self.valid & mask
        return ColumnarTable(self.columns, new_valid, new_valid.sum().astype(jnp.int32))

    def drop_nulls(self, names: Sequence[str]) -> "ColumnarTable":
        """Step 2 — null filtering via mask algebra (cost ~ metadata)."""
        mask = self.valid
        for n in names:
            mask = mask & ~is_null(self.columns[n])
        return ColumnarTable(self.columns, mask, mask.sum().astype(jnp.int32))

    def compact(self) -> "ColumnarTable":
        """Gather valid rows to the front, preserving order (stream compaction).

        The gather index for output slot j is the position of the (j+1)-th
        valid row — a vectorized binary search over ``cumsum(valid)``, O(n log
        n) with a tiny constant (~3x faster than the stable bool argsort it
        replaces).  Slots past ``count`` hold clamped garbage and are masked
        invalid.  The Pallas ``filter_compact`` kernel is the fused production
        path; this is the always-correct jnp fallback used inside larger
        traced programs.
        """
        c = jnp.cumsum(self.valid.astype(jnp.int32))
        idx = jnp.searchsorted(
            c, jnp.arange(1, self.capacity + 1, dtype=jnp.int32), side="left")
        idx = jnp.minimum(idx, max(self.capacity - 1, 0))
        cols = {k: v[idx] for k, v in self.columns.items()}
        valid = jnp.arange(self.capacity) < self.count
        return ColumnarTable(cols, valid, self.count)

    def take(self, idx: jax.Array, idx_valid: jax.Array | None = None) -> "ColumnarTable":
        """Row gather.  ``idx_valid`` marks which gathered rows exist."""
        cols = {k: v[idx] for k, v in self.columns.items()}
        valid = self.valid[idx]
        if idx_valid is not None:
            valid = valid & idx_valid
        return ColumnarTable(cols, valid, valid.sum().astype(jnp.int32))

    def sort_by(self, names: Sequence[str]) -> "ColumnarTable":
        """Stable lexicographic sort; invalid rows sink to the end."""
        keys = []
        for n in reversed(list(names)):  # lexsort: LAST key is primary
            col = self.columns[n]
            keys.append(jnp.where(self.valid, col, _max_key(col.dtype)))
        # Most-significant key: invalid rows sink last even if a valid row
        # happens to carry the max key value.
        keys.append((~self.valid).astype(jnp.int32))
        idx = jnp.lexsort(tuple(keys))
        cols = {k: v[idx] for k, v in self.columns.items()}
        valid = self.valid[idx]
        return ColumnarTable(cols, valid, self.count)

    def shrink_to(self, capacity: int) -> "ColumnarTable":
        """Truncate to a smaller static capacity (inverse of ``pad_to``).

        Meant for already-compacted tables (valid rows at the front): valid
        rows beyond ``capacity`` are dropped, so callers size ``capacity``
        from the row count and audit the loss (see the ``slice_time`` node's
        overflow statistic).  Capacities >= the current one are a no-op.
        """
        if capacity >= self.capacity:
            return self
        cols = {k: v[:capacity] for k, v in self.columns.items()}
        valid = self.valid[:capacity]
        return ColumnarTable(cols, valid, valid.sum().astype(jnp.int32))

    def pad_to(self, capacity: int) -> "ColumnarTable":
        if capacity < self.capacity:
            raise ValueError("pad_to cannot shrink a table")
        extra = capacity - self.capacity
        cols = {k: jnp.pad(v, (0, extra)) for k, v in self.columns.items()}
        valid = jnp.pad(self.valid, (0, extra))
        return ColumnarTable(cols, valid, self.count)

    @staticmethod
    def concat(tables: Sequence["ColumnarTable"]) -> "ColumnarTable":
        names = tables[0].column_names
        for t in tables[1:]:
            if t.column_names != names:
                raise ValueError("concat: mismatched schemas")
        cols = {n: jnp.concatenate([t.columns[n] for t in tables]) for n in names}
        valid = jnp.concatenate([t.valid for t in tables])
        count = sum((t.count for t in tables), jnp.int32(0))
        return ColumnarTable(cols, valid, count)

    # -- monitoring (paper §3.3: statistics proving no information loss) -----
    def monitoring_stats(self, key: str) -> Dict[str, jax.Array]:
        """Row-count + order-independent key checksum, computed per stage."""
        # uint32 modular arithmetic: stable under JAX's default x64-disabled mode.
        k = self.columns[key].astype(jnp.uint32)
        masked = jnp.where(self.valid, k, jnp.uint32(0))
        return {
            "rows": self.count.astype(jnp.int32),
            "key_sum": masked.sum(dtype=jnp.uint32),
            "key_xor": jnp.bitwise_xor.reduce(masked),
        }

    # -- host-side conveniences ----------------------------------------------
    def to_numpy(self) -> Dict[str, np.ndarray]:
        n = int(self.count)
        idx = np.argsort(~np.asarray(self.valid), kind="stable")[:n]
        return {k: np.asarray(v)[idx] for k, v in self.columns.items()}

    def head(self, n: int = 8) -> str:
        data = self.to_numpy()
        names = list(data)
        lines = ["| " + " | ".join(names) + " |"]
        m = min(n, len(next(iter(data.values()))) if data else 0)
        for i in range(m):
            lines.append("| " + " | ".join(str(data[c][i]) for c in names) + " |")
        return "\n".join(lines)
