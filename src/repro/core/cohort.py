"""SCALPEL-Analysis: Cohort / CohortCollection / CohortFlow abstractions.

A ``Cohort`` is a set of patients + their events in a time window (paper
§3.5).  Subject membership is a packed ``uint32`` bitset over the patient
universe, so the paper's algebra (∩ ∪ \\) is bitwise ops + popcount — the hot
path has a Pallas kernel (``kernels/bitset_ops``); counts are
``lax.population_count`` reductions.  Descriptions compose automatically, as
in the paper's Supplementary Out[6].

``CohortFlow`` is the left fold ``(((c0 ∩ c1) ∩ c2) ∩ ...)`` with per-stage
retention counts — the RECORD-statement flowchart generator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset as _bs
from repro.core.columnar import ColumnarTable
from repro.core.metadata import OperationLog

__all__ = ["Bitset", "Cohort", "CohortCollection", "CohortFlow"]


# ---------------------------------------------------------------------------
# Packed-bitset subject sets — thin facade over the shared ``core.bitset``
# layout (ONE packing for subject sets, table validity and kernel outputs)
# ---------------------------------------------------------------------------
class Bitset:
    """Fixed-universe packed bitset (uint32 words, ``core.bitset`` layout)."""

    @staticmethod
    def n_words(n_patients: int) -> int:
        return _bs.n_words(n_patients)

    @staticmethod
    def from_mask(mask: jax.Array) -> jax.Array:
        return _bs.pack(mask)

    @staticmethod
    def from_indices(idx: jax.Array, valid: jax.Array, n_patients: int) -> jax.Array:
        """Subject bitset from event-row patient indices.  ``valid`` is the
        event rows' validity: a bool row mask or (bitset-native tables) the
        packed word form — the packed path selects bits by word gather
        (``bitset.bit_at``), never expanding a bool validity column."""
        if _bs.is_packed(valid):
            valid = _bs.bit_at(valid, jnp.arange(idx.shape[0]))
        mask = (
            jnp.zeros((n_patients,), bool)
            .at[jnp.where(valid, idx, n_patients)]
            .set(True, mode="drop")
        )
        return _bs.pack(mask)

    @staticmethod
    def to_mask(bits: jax.Array, n_patients: int) -> jax.Array:
        return _bs.unpack(bits, n_patients)

    @staticmethod
    def count(bits: jax.Array) -> jax.Array:
        return _bs.count(bits)


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Cohort:
    """Patients + events in a [start, end] window (paper §3.5)."""

    name: str
    description: str
    subjects: jax.Array                      # packed uint32 bitset
    n_patients: int
    events: Optional[ColumnarTable] = None   # associated Event table
    window: Tuple[int, int] = (0, 2_000_000_000)

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_events(cls, name: str, events: ColumnarTable, n_patients: int,
                    description: Optional[str] = None) -> "Cohort":
        bits = Bitset.from_indices(events.columns["patient_id"], events.valid, n_patients)
        return cls(
            name=name,
            description=description or f"subjects with event {name}",
            subjects=bits,
            n_patients=n_patients,
            events=events,
        )

    @classmethod
    def from_patient_table(cls, name: str, patients: ColumnarTable, n_patients: int) -> "Cohort":
        bits = Bitset.from_indices(patients.columns["patient_id"], patients.valid, n_patients)
        return cls(name=name, description=name, subjects=bits, n_patients=n_patients)

    # -- paper API ------------------------------------------------------------
    def subject_count(self) -> int:
        return int(Bitset.count(self.subjects))

    def subjects_mask(self) -> jax.Array:
        """Per-patient bool membership mask.  The unpack of the packed
        subject bitset is memoized per subjects array — the ">25 statistics"
        battery hits this once per ``stats.compute`` instead of once per
        statistic."""
        cached = self.__dict__.get("_subjects_mask_cache")
        if cached is not None and cached[0] is self.subjects:
            return cached[1]
        mask = Bitset.to_mask(self.subjects, self.n_patients)
        self.__dict__["_subjects_mask_cache"] = (self.subjects, mask)
        return mask

    def describe(self) -> str:
        return self.description

    def _combine(self, other: "Cohort", bits: jax.Array, desc: str, name: str,
                 window: Tuple[int, int]) -> "Cohort":
        if self.n_patients != other.n_patients:
            raise ValueError("cohorts live in different patient universes")
        ev = self.events
        if ev is not None:
            keep_mask = Bitset.to_mask(bits, self.n_patients)
            ev = ev.filter(keep_mask[jnp.clip(ev.columns["patient_id"], 0, self.n_patients - 1)])
        return Cohort(name=name, description=desc, subjects=bits,
                      n_patients=self.n_patients, events=ev, window=window)

    def intersection(self, other: "Cohort") -> "Cohort":
        # a subject must satisfy both -> coverage is the window overlap
        return self._combine(
            other, self.subjects & other.subjects,
            f"{self.description} with {other.description}",
            f"{self.name}&{other.name}",
            (max(self.window[0], other.window[0]),
             min(self.window[1], other.window[1])),
        )

    def union(self, other: "Cohort") -> "Cohort":
        # either side suffices -> coverage spans both windows
        return self._combine(
            other, self.subjects | other.subjects,
            f"{self.description} or {other.description}",
            f"{self.name}|{other.name}",
            (min(self.window[0], other.window[0]),
             max(self.window[1], other.window[1])),
        )

    def difference(self, other: "Cohort") -> "Cohort":
        # subjects (and events) all come from self -> keep self's coverage
        return self._combine(
            other, self.subjects & ~other.subjects,
            f"{self.description} without {other.description}",
            f"{self.name}-{other.name}",
            self.window,
        )

    # granular control: underlying tables stay reachable (paper: "More
    # granular control is kept available through accesses to the underlying
    # Spark DataFrames")
    def events_of(self) -> Optional[ColumnarTable]:
        return self.events


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CohortCollection:
    """Named cohorts + shared metadata (paper §3.5)."""

    cohorts: Dict[str, Cohort]
    metadata: Optional[OperationLog] = None

    @property
    def cohorts_names(self) -> set:
        return set(self.cohorts)

    def get(self, name: str) -> Cohort:
        return self.cohorts[name]

    def add(self, cohort: Cohort) -> None:
        self.cohorts[cohort.name] = cohort

    @classmethod
    def from_extractions(cls, named_events: Dict[str, ColumnarTable], n_patients: int,
                         metadata: Optional[OperationLog] = None) -> "CohortCollection":
        return cls(
            {n: Cohort.from_events(n, ev, n_patients) for n, ev in named_events.items()},
            metadata=metadata,
        )


# ---------------------------------------------------------------------------
class CohortFlow:
    """Ordered left fold of intersections with per-stage tracking."""

    def __init__(self, cohorts: Sequence[Cohort]):
        if not cohorts:
            raise ValueError("empty flow")
        self.inputs = list(cohorts)
        self.steps: List[Cohort] = [cohorts[0]]
        for c in cohorts[1:]:
            self.steps.append(self.steps[-1].intersection(c))

    @property
    def final(self) -> Cohort:
        return self.steps[-1]

    def flowchart(self) -> List[Dict[str, object]]:
        rows = []
        prev = None
        for inp, st in zip(self.inputs, self.steps):
            n = st.subject_count()
            rows.append({
                "stage": inp.name,
                "subjects": n,
                "removed": (prev - n) if prev is not None else 0,
                "description": st.description,
            })
            prev = n
        return rows

    def render(self) -> str:
        lines = [f"{'stage':32s} {'subjects':>10s} {'removed':>8s}"]
        for r in self.flowchart():
            lines.append(f"{r['stage']:32s} {r['subjects']:10d} {r['removed']:8d}")
        return "\n".join(lines)
