"""Production meshes.

Single pod: (data, model) = (16, 16) — 256 chips (TPU v5e pod).
Multi-pod:  (pod, data, model) = (2, 16, 16) — 512 chips, the "pod" axis
crossing the DCN.  Functions, not module constants: importing this module must
never touch jax device state (dryrun.py sets the forced device count before
any jax initialization).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(n_data: int = 1, n_model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / CPU benches)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
