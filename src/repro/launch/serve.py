"""Serving launcher: continuous-batching engine over a reduced model.

``python -m repro.launch.serve --arch qwen2-1.5b --requests 8`` boots the
slot-based engine (serving/batching.py), submits synthetic event-token
prompts drawn from the SCALPEL3 tokenizer space, and decodes until done.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models.registry import get_bundle
from repro.serving.batching import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    bundle = get_bundle(args.arch, reduced=True)
    params = bundle.init(jax.random.key(0))
    engine = ContinuousBatcher(bundle, params, n_slots=args.slots,
                               kv_len=args.kv_len)

    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(args.requests):
        prompt = [1] + rng.integers(8, bundle.cfg.vocab_size,
                                    size=rng.integers(4, 12)).tolist()
        req = Request(rid=rid, prompt=prompt, max_new=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.time()
    steps = 0
    while any(not r.done for r in reqs) and steps < 10_000:
        engine.step()
        steps += 1
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in reqs)
    print(f"{len(reqs)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok/max(dt,1e-9):.1f} tok/s, {steps} engine steps)")
    for r in reqs[:4]:
        print(f"  req {r.rid}: prompt={len(r.prompt)} out={r.out[:8]}...")


if __name__ == "__main__":
    main()
