import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This proves the distribution config is coherent without hardware: the forced
512 host devices let ``jax.make_mesh`` build the production meshes, every
cell's step function is ``.lower().compile()``d with ShapeDtypeStruct inputs
(no allocation), and the compiled artifact yields the §Roofline terms:
``memory_analysis()`` (fits-in-HBM proof), ``cost_analysis()`` (FLOPs/bytes),
and the post-SPMD HLO text (collective bytes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SHAPES, ShapeCell
from repro.launch.mesh import make_production_mesh
from repro.models.registry import ModelBundle, get_bundle, all_archs
from repro.distributed.sharding import (
    batch_shardings, cache_shardings, opt_state_shardings, param_shardings,
)
from repro.serving.serve_step import make_prefill_step, make_serve_step
from repro.train.train_step import abstract_train_state, make_train_step

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum result-shape bytes of every collective op in post-SPMD HLO."""
    out: Dict[str, Dict[str, float]] = {
        c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = re.search(
            r"=\s+((?:\([^)]*\))|(?:\S+))\s+(all-reduce|all-gather|"
            r"reduce-scatter|all-to-all|collective-permute)(?:-start|-done)?\(",
            line,
        )
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # counted at -start
        out[op]["count"] += 1
        out[op]["bytes"] += _shape_bytes(type_str)
    return out


def op_histogram(hlo_text: str) -> Dict[str, int]:
    hist: Dict[str, int] = {}
    for m in re.finditer(r"=\s+(?:\([^)]*\)|\S+)\s+([a-z][a-z0-9\-]*)\(", hlo_text):
        op = m.group(1)
        hist[op] = hist.get(op, 0) + 1
    return {k: v for k, v in sorted(hist.items(), key=lambda kv: -kv[1])[:30]}


def _as_specs(tree: Any) -> Any:
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# Per-cell performance knobs promoted from the §Perf hillclimb.
PERF_OVERRIDES = {
    ("gemma3-12b", "train_4k"): {"microbatches": 4},
    # RG-LRU associative_scan holds f32 (B,S,R) gate tensors; halving the
    # microbatch halves them (18.3 -> fits)
    ("recurrentgemma-2b", "train_4k"): {"microbatches": 2},
}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               donate: bool = True, bundle: Optional[ModelBundle] = None):
    """Build and lower the cell's step function; returns (lowered, meta)."""
    bundle = bundle or get_bundle(arch)
    cell = SHAPES[shape_name]
    if not bundle.supports(cell):
        return None, {"skipped": True, "reason": "full-attention arch at 500k"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = bundle.cfg

    specs = bundle.input_specs(cell)
    batch_sh = batch_shardings(cfg, mesh, specs, cell)

    with jax.set_mesh(mesh):
        if cell.kind == "train":
            state = abstract_train_state(bundle)
            p_sh = param_shardings(cfg, mesh, state["params"])
            o_sh = {
                "master": opt_state_shardings(cfg, mesh, state["params"]),
                "m": opt_state_shardings(cfg, mesh, state["params"]),
                "v": opt_state_shardings(cfg, mesh, state["params"]),
                "step": jax.NamedSharding(mesh, jax.P()),
            }
            state_sh = {"params": p_sh, "opt": o_sh}
            knobs = PERF_OVERRIDES.get((arch, shape_name), {})
            step = make_train_step(bundle, microbatches=knobs.get("microbatches", 1))
            meta_extra = {"microbatches": knobs.get("microbatches", 1)}
            fn = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, jax.NamedSharding(mesh, jax.P())),
                donate_argnums=(0,) if donate else (),
            )
            lowered = fn.lower(state, specs)
        elif cell.kind == "prefill":
            params = bundle.abstract_params()
            p_sh = param_shardings(cfg, mesh, params)
            step = make_prefill_step(bundle)
            fn = jax.jit(step, in_shardings=(p_sh, batch_sh))
            lowered = fn.lower(params, specs)
        else:  # decode
            params = bundle.abstract_params()
            p_sh = param_shardings(cfg, mesh, params)
            cache = bundle.abstract_cache(cell.global_batch, cell.seq_len)
            c_sh = cache_shardings(cfg, mesh, cache, cell.global_batch)
            step = make_serve_step(bundle)
            fn = jax.jit(
                step,
                in_shardings=(p_sh, c_sh, batch_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = fn.lower(params, cache, specs)
    meta = {"mesh": dict(mesh.shape), "cell": cell.name, "arch": arch}
    try:
        meta.update(meta_extra)
    except NameError:
        pass
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False) -> Dict[str, Any]:
    t0 = time.time()
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
    }
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod)
        if lowered is None:
            rec.update(meta)
            return rec
        rec["microbatches"] = meta.get("microbatches", 1)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost"] = {
            "flops": float(ca.get("flops", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        }
        txt = compiled.as_text()
        rec["collectives"] = parse_collectives(txt)
        rec["ops"] = op_histogram(txt)
        rec["hlo_lines"] = txt.count("\n")
        if keep_hlo:
            rec["hlo"] = txt
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the matrix
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def _probe_bundle(arch: str, n_periods: int) -> ModelBundle:
    """Reduced-depth variant for while-body cost probing: XLA cost analysis
    counts a while (scan) body ONCE regardless of trip count (verified:
    scan flops == unroll flops / trips), so per-cell cost is reconstructed as
       total = f(0 periods) + n_periods · (f(1 period) − f(0 periods)),
    with chunked attention disabled in probes so the inner KV-chunk scan does
    not hide score flops the same way.
    """
    import dataclasses

    cfg = get_bundle(arch).cfg
    base = cfg.first_dense_layers + (
        (cfg.n_layers - cfg.first_dense_layers) % len(cfg.pattern))
    n_layers = base + n_periods * len(cfg.pattern)
    kw = {"n_layers": n_layers}
    if cfg.is_encdec:
        kw["n_encoder_layers"] = n_periods
    return ModelBundle(dataclasses.replace(cfg, **kw))


def run_cell_with_probes(arch: str, shape_name: str) -> Dict[str, Any]:
    """Single-pod cell + the two depth probes (for §Roofline correction)."""
    from repro.models import layers as Lmod

    rec = run_cell(arch, shape_name, multi_pod=False)
    if not rec.get("ok"):
        return rec
    cfg = get_bundle(arch).cfg
    eff = cfg.n_layers - cfg.first_dense_layers
    rec["n_periods"] = eff // len(cfg.pattern)
    # enc-dec cannot instantiate a 0-layer probe (empty stacked pytree);
    # use trips (1, 2): total = f(1) + (n-1)·(f(2) - f(1))
    levels = (1, 2) if cfg.is_encdec else (0, 1)
    rec["probe_levels"] = list(levels)

    old_thresh = Lmod._CHUNKED_THRESHOLD
    Lmod._CHUNKED_THRESHOLD = 1 << 62   # dense attention in probes
    try:
        probes = {}
        for n in levels:
            bundle = _probe_bundle(arch, n)
            t0 = time.time()
            try:
                lowered, _ = lower_cell(arch, shape_name, multi_pod=False,
                                        bundle=bundle)
                compiled = lowered.compile()
                ca = compiled.cost_analysis() or {}
                probes[f"p{n}"] = {
                    "flops": float(ca.get("flops", 0.0)),
                    "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                    "transcendentals": float(ca.get("transcendentals", 0.0)),
                    "collectives": parse_collectives(compiled.as_text()),
                    "compile_s": round(time.time() - t0, 1),
                }
            except Exception as e:  # noqa: BLE001
                probes[f"p{n}"] = {"error": f"{type(e).__name__}: {e}"[:500]}
        rec["probes"] = probes
    finally:
        Lmod._CHUNKED_THRESHOLD = old_thresh
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--probes", action="store_true",
                    help="also compile depth probes (roofline correction)")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = all_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                if args.probes and not mp:
                    rec = run_cell_with_probes(arch, shape)
                else:
                    rec = run_cell(arch, shape, mp)
                tag = f"{arch}__{shape}__{rec['mesh']}"
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                status = ("SKIP" if rec.get("skipped")
                          else "OK" if rec.get("ok") else "FAIL")
                if status == "FAIL":
                    n_fail += 1
                    print(f"[{status}] {tag}: {rec.get('error')}", flush=True)
                else:
                    mem = rec.get("memory", {})
                    print(
                        f"[{status}] {tag} lower={rec.get('lower_s')}s "
                        f"compile={rec.get('compile_s')}s "
                        f"args={mem.get('argument_bytes', 0)/2**30:.2f}GiB "
                        f"temp={mem.get('temp_bytes', 0)/2**30:.2f}GiB "
                        f"flops={rec.get('cost', {}).get('flops', 0):.3g}",
                        flush=True,
                    )
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
