"""Training launcher: the end-to-end driver wiring SCALPEL3 features to LMs.

Pipeline: synthetic SNDS -> flatten -> extract -> tokenize (FeatureDriver) ->
sharded train loop with checkpoint/restart.  On the container this runs small
models on CPU (examples/train_lm.py drives it); on a real cluster the same
code runs under the production mesh with per-host data sharding.

Fault tolerance in the loop (DESIGN.md §5):
  * data order is deterministic in (seed, step) -> restart replays exactly;
  * AsyncCheckpointer writes sharded state in the background, atomically;
  * on start, the latest checkpoint (if any) is restored — including onto a
    *different* mesh (elastic restart);
  * straggler policy: fixed-shape steps; a slow host never changes
    collective shapes, and the launcher logs step-time outliers (the
    backup-replica failover hook).
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import get_bundle
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step
from repro.train.checkpointing import AsyncCheckpointer, latest_step, restore_checkpoint


def claims_token_stream(seq_len: int, batch: int, vocab: int, seed: int,
                        n_patients: int = 512) -> Iterator[Dict[str, jax.Array]]:
    """Deterministic batch stream from the SCALPEL3 pipeline.

    Builds the full paper pipeline once (flatten -> extract -> cohort ->
    FeatureDriver.token_sequences), then yields fixed-shape batches; batch t
    is a pure function of (seed, t) — the determinism the restart story
    needs."""
    from repro.core import (
        Cohort, DCIR_SCHEMA, FeatureDriver, TokenizerSpec, flatten_star,
        sort_events, drug_dispenses, medical_acts_dcir,
    )
    from repro.core.columnar import ColumnarTable
    from repro.data.synthetic import SyntheticConfig, generate_dcir

    cfg = SyntheticConfig(n_patients=n_patients, seed=seed)
    dcir = generate_dcir(cfg)
    flat, _ = flatten_star(DCIR_SCHEMA, dcir)
    drugs = drug_dispenses()(flat)
    acts = medical_acts_dcir()(flat)
    events = sort_events(ColumnarTable.concat([drugs, acts]))
    cohort = Cohort.from_events("all", events, cfg.n_patients)
    spec = TokenizerSpec.default()
    fd = FeatureDriver(cohort)
    toks, mask = fd.token_sequences(seq_len, spec)
    toks = np.asarray(jnp.clip(toks, 0, vocab - 1))
    mask = np.asarray(mask, np.float32)

    step = 0
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_patients)
    while True:
        idx = order[(step * batch + np.arange(batch)) % n_patients]
        yield {
            "tokens": jnp.asarray(toks[idx]),
            "loss_mask": jnp.asarray(mask[idx]),
        }
        step += 1


def train(arch: str, steps: int = 100, batch: int = 8, seq_len: int = 128,
          reduced: bool = True, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, log_every: int = 10,
          microbatches: int = 1, seed: int = 0) -> Dict[str, Any]:
    bundle = get_bundle(arch, reduced=reduced)
    cfg = bundle.cfg
    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=20, total_steps=steps)
    step_fn = jax.jit(
        make_train_step(bundle, opt_cfg, microbatches=microbatches),
        donate_argnums=(0,),
    )

    state = init_train_state(bundle, jax.random.key(seed))
    start_step = 0
    ckpt = None
    if ckpt_dir:
        ckpt = AsyncCheckpointer(ckpt_dir)
        last = latest_step(ckpt_dir)
        if last is not None:
            state, manifest = restore_checkpoint(ckpt_dir, last, state)
            start_step = manifest["step"]
            print(f"[restore] resumed from step {start_step}")

    stream = claims_token_stream(seq_len, batch, cfg.vocab_size, seed)
    for _ in range(start_step):  # replay the cursor deterministically
        next(stream)

    losses = []
    step_times = []
    for t in range(start_step, steps):
        batch_t = next(stream)
        t0 = time.time()
        state, metrics = step_fn(state, batch_t)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        step_times.append(dt)
        if len(step_times) > 10:
            med = float(np.median(step_times[-50:]))
            if dt > 3.0 * med:
                print(f"[straggler] step {t} took {dt:.2f}s (median {med:.2f}s)")
        if t % log_every == 0:
            print(f"step {t:5d} loss {loss:8.4f} ({dt*1e3:6.1f} ms)", flush=True)
        if ckpt and (t + 1) % ckpt_every == 0:
            ckpt.save(t + 1, state, meta={"arch": arch, "seed": seed})
    if ckpt:
        ckpt.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "state": state}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full (non-reduced) config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, batch=args.batch,
                seq_len=args.seq_len, reduced=not args.full_size,
                ckpt_dir=args.ckpt_dir, microbatches=args.microbatches)
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
