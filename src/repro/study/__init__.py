"""repro.study — the lazy query-plan layer over SCALPEL3's three libraries.

``Study`` (api) builds a ``Plan`` (plan) of scan/predicate/conform/compact/
cohort/featurize nodes; predicates are typed ``col()``/``Expr`` trees (expr)
the optimizer can analyze; ``optimize`` (optimizer) fuses predicate chains
into single-pass masks, shares source scans, defers compaction and prunes
unread columns backwards through the flatten joins; ``execute`` (executor)
jit-compiles the plan once per (structure, table spec, engine) and
auto-records ``OperationLog`` provenance, including per-stage column audits.

``normalize`` canonicalizes optimized plans (literal hoisting, stable order,
label stripping) so structurally-equal queries share one executable;
``CohortQueryService`` (service) serves many tenants' studies against one
resident star schema with plan-normalized jit sharing and a cross-tenant
subgraph result cache.

``analyze`` statically verifies plans before execution — abstract
interpretation over the IR computing schema/capacity/kind facts and
predicate semantics, reported as stable-coded ``Diagnostic``s; surfaced via
``Study.check()``, service admission, and the ``tools/plan_lint.py`` gate.
"""
from repro.study.plan import Node, Plan, PlanBuilder
from repro.study.expr import (
    Expr, col, lit, all_of, any_of, expr_from_param, fused_predicate,
    node_predicate, parse_cohort_expr, CohortParseError,
)
from repro.study.optimizer import (
    optimize, merge_projections, fuse_masks, defer_compaction,
    prune_columns, plan_capacities, prune_exchanges, dce, assign_engines,
    available_columns, required_columns,
)
from repro.study.executor import execute, TRANSFORMS, jit_cache_info, clear_jit_cache
from repro.study.api import (
    Study, StudyResult, contribute_flatten, contribute_flatten_sliced,
    flow_rows_from_log, column_audit_from_log,
)
from repro.study.normalize import (
    NormalPlan, normalize, device_params, params_signature, cut_points,
    subgraph_hashes,
)
from repro.study.service import (
    CohortQueryService, ServiceConfig, ServiceStats, TenantStats, QueryTicket,
)
from repro.study.analyze import (
    Diagnostic, DIAGNOSTIC_CODES, PlanValidationError, analyze,
)
from repro.study.chunked import ChunkedExecutor, ChunkedReport
from repro.study.spec import (
    SPEC_CODES, SpecIssue, SpecValidationError, compile_spec, error_payload,
    spec_from_study, validate_spec,
)

__all__ = [
    "Node", "Plan", "PlanBuilder",
    "Expr", "col", "lit", "all_of", "any_of", "expr_from_param",
    "fused_predicate", "node_predicate", "parse_cohort_expr",
    "optimize", "merge_projections", "fuse_masks", "defer_compaction",
    "prune_columns", "plan_capacities", "prune_exchanges", "dce",
    "assign_engines", "available_columns", "required_columns",
    "execute", "TRANSFORMS", "jit_cache_info", "clear_jit_cache",
    "Study", "StudyResult", "contribute_flatten", "contribute_flatten_sliced",
    "flow_rows_from_log", "column_audit_from_log",
    "NormalPlan", "normalize", "device_params", "params_signature",
    "cut_points", "subgraph_hashes",
    "CohortQueryService", "ServiceConfig", "ServiceStats", "TenantStats",
    "QueryTicket",
    "Diagnostic", "DIAGNOSTIC_CODES", "PlanValidationError", "analyze",
    "ChunkedExecutor", "ChunkedReport",
    "CohortParseError", "SPEC_CODES", "SpecIssue", "SpecValidationError",
    "compile_spec", "error_payload", "spec_from_study", "validate_spec",
]
