"""repro.study — the lazy query-plan layer over SCALPEL3's three libraries.

``Study`` (api) builds a ``Plan`` (plan) of scan/mask/conform/compact/cohort/
featurize nodes; ``optimize`` (optimizer) fuses masks, shares source scans and
defers compaction; ``execute`` (executor) jit-compiles the plan once per
(structure, table spec, engine) and auto-records ``OperationLog`` provenance.
"""
from repro.study.plan import Node, Plan, PlanBuilder
from repro.study.optimizer import (
    optimize, merge_projections, fuse_masks, defer_compaction,
    plan_capacities, prune_exchanges, dce,
)
from repro.study.executor import execute, TRANSFORMS, jit_cache_info, clear_jit_cache
from repro.study.api import (
    Study, StudyResult, contribute_flatten, contribute_flatten_sliced,
    flow_rows_from_log,
)

__all__ = [
    "Node", "Plan", "PlanBuilder",
    "optimize", "merge_projections", "fuse_masks", "defer_compaction",
    "plan_capacities", "prune_exchanges", "dce",
    "execute", "TRANSFORMS", "jit_cache_info", "clear_jit_cache",
    "Study", "StudyResult", "contribute_flatten", "contribute_flatten_sliced",
    "flow_rows_from_log",
]
