"""Plan rewrites: shared scans, fused masks, deferred compaction, DCE.

The passes encode the paper's three columnar properties (§3.4) at the *plan*
level instead of inside each extractor:

  * ``merge_projections`` — all extractors reading one source share a single
    scan + a single union projection, so a study makes ONE pass over DCIR
    instead of one per extractor.
  * ``fuse_masks`` — adjacent null-filter / value-filter nodes collapse into
    one ``fused_mask`` node, executed as a single vectorized predicate (one
    mask kernel per extractor branch instead of one per step).
  * ``defer_compaction`` — compaction (the only materialization) is removed
    from plan interiors and appears exactly once per named table output.
  * ``dce`` — drops nodes unreachable from any output (rewrites above strand
    the per-extractor projections).

All passes are pure ``Plan -> Plan`` functions; ``optimize`` is the default
pipeline used by the executor.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.study.plan import MASK_OPS, Node, Plan, PlanBuilder

__all__ = ["optimize", "merge_projections", "fuse_masks", "defer_compaction", "dce"]


def _rebuild(plan: Plan, replace: Dict[int, Node], drop: Optional[set] = None,
             redirect: Optional[Dict[int, int]] = None) -> Plan:
    """Re-emit ``plan`` through a fresh builder with node rewrites applied.

    ``replace`` swaps a node's definition; ``redirect`` makes consumers (and
    outputs) read another old node's value instead; ``drop`` marks old ids
    whose definition must not be re-emitted (their redirect target is used).
    Hash-consing in the builder re-deduplicates rewritten nodes.
    """
    drop = drop or set()
    redirect = redirect or {}
    b = PlanBuilder()
    new_id: Dict[int, int] = {}

    def resolve(old: int) -> int:
        seen = set()
        while old in redirect:
            if old in seen:
                raise ValueError("cyclic redirect in plan rewrite")
            seen.add(old)
            old = redirect[old]
        return new_id[old]

    for i, node in enumerate(plan.nodes):
        if i in drop or i in redirect:
            continue
        n = replace.get(i, node)
        inputs = tuple(resolve(j) for j in n.inputs)
        new_id[i] = b.add(n.op, inputs, **dict(n.params))
    for name, i in plan.outputs:
        b.set_output(name, resolve(i))
    return b.build()


# ---------------------------------------------------------------------------
def merge_projections(plan: Plan) -> Plan:
    """One shared scan+projection per source: the union of every consumer's
    column set.  (Scan nodes themselves already unify by hash-consing; this
    pass merges the per-extractor ``select`` nodes hanging off them.)"""
    selects_by_scan: Dict[int, List[int]] = {}
    for i, n in enumerate(plan.nodes):
        if n.op == "select" and plan.nodes[n.inputs[0]].op == "scan":
            selects_by_scan.setdefault(n.inputs[0], []).append(i)

    replace: Dict[int, Node] = {}
    redirect: Dict[int, int] = {}
    for scan_id, sel_ids in selects_by_scan.items():
        if len(sel_ids) < 2:
            continue
        union = sorted({c for i in sel_ids for c in plan.nodes[i].get("cols")})
        keep = sel_ids[0]
        replace[keep] = Node("select", (scan_id,), (("cols", tuple(union)),))
        for i in sel_ids[1:]:
            redirect[i] = keep
    if not (replace or redirect):
        return plan
    return _rebuild(plan, replace, redirect=redirect)


# ---------------------------------------------------------------------------
def _mask_params(node: Node) -> Tuple[Tuple[str, ...], Tuple]:
    """(null_cols, value_filters) contribution of one mask-op node."""
    if node.op == "drop_nulls":
        return tuple(node.get("cols")), ()
    if node.op == "value_filter":
        return (), ((node.get("col"), node.get("codes")),)
    if node.op == "fused_mask":
        return tuple(node.get("null_cols")), tuple(node.get("filters"))
    raise AssertionError(node.op)


def fuse_masks(plan: Plan) -> Plan:
    """Collapse chains of mask-only nodes into single ``fused_mask`` nodes.

    Every drop_nulls/value_filter is first normalized to a fused_mask; then a
    fused_mask whose (sole-consumer) input is another fused_mask absorbs it.
    Runs to fixpoint, so arbitrarily long mask chains become one node.
    """
    # normalize
    replace = {}
    for i, n in enumerate(plan.nodes):
        if n.op in MASK_OPS:
            nulls, filters = _mask_params(n)
            replace[i] = Node("fused_mask", n.inputs,
                              (("filters", filters), ("null_cols", nulls)))
    plan = _rebuild(plan, replace)

    while True:
        consumers = plan.consumers()
        out_ids = {i for _, i in plan.outputs}
        redirect: Dict[int, int] = {}
        replace = {}
        for i, n in enumerate(plan.nodes):
            if n.op != "fused_mask":
                continue
            j = n.inputs[0]
            up = plan.nodes[j]
            if (up.op != "fused_mask" or len(consumers[j]) != 1
                    or j in replace or j in out_ids):
                continue
            u_nulls, u_filters = _mask_params(up)
            n_nulls, n_filters = _mask_params(n)
            nulls = u_nulls + tuple(c for c in n_nulls if c not in u_nulls)
            replace[i] = Node("fused_mask", up.inputs,
                              (("filters", u_filters + n_filters),
                               ("null_cols", nulls)))
            redirect[j] = i  # j had only this consumer; drop its definition
        if not replace:
            return plan
        # re-emit: replaced nodes take their new def; absorbed nodes vanish.
        b = PlanBuilder()
        new_id: Dict[int, int] = {}
        absorbed = set(redirect)
        for i, node in enumerate(plan.nodes):
            if i in absorbed:
                continue
            n = replace.get(i, node)
            inputs = tuple(new_id[j] for j in n.inputs)
            new_id[i] = b.add(n.op, inputs, **dict(n.params))
        for name, i in plan.outputs:
            b.set_output(name, new_id[i])
        plan = b.build()


# ---------------------------------------------------------------------------
def defer_compaction(plan: Plan) -> Plan:
    """Exactly one materialization per table output.

    Interior compact nodes (anything downstream still reads them) are
    bypassed — masks and event conformance operate on uncompacted tables for
    free — and every named table output gets a final compact if it lacks one.
    """
    out_ids = {i for _, i in plan.outputs}
    consumers = plan.consumers()
    redirect: Dict[int, int] = {}
    for i, n in enumerate(plan.nodes):
        if n.op == "compact" and consumers[i] and i not in out_ids:
            redirect[i] = n.inputs[0]
    if redirect:
        plan = _rebuild(plan, {}, redirect=redirect)

    # append a compact to table outputs that end uncompacted
    b = PlanBuilder()
    new_id: Dict[int, int] = {}
    for i, n in enumerate(plan.nodes):
        new_id[i] = b.add(n.op, tuple(new_id[j] for j in n.inputs), **dict(n.params))
    from repro.study.plan import TABLE_OPS
    for name, i in plan.outputs:
        n = plan.nodes[i]
        if n.op in TABLE_OPS and n.op not in ("compact", "transform"):
            b.set_output(name, b.compact(new_id[i]))
        else:
            b.set_output(name, new_id[i])
    return b.build()


# ---------------------------------------------------------------------------
def dce(plan: Plan) -> Plan:
    """Drop nodes unreachable from any named output."""
    live = set()
    stack = [i for _, i in plan.outputs]
    while stack:
        i = stack.pop()
        if i in live:
            continue
        live.add(i)
        stack.extend(plan.nodes[i].inputs)
    if len(live) == len(plan.nodes):
        return plan
    b = PlanBuilder()
    new_id: Dict[int, int] = {}
    for i, n in enumerate(plan.nodes):
        if i not in live:
            continue
        new_id[i] = b.add(n.op, tuple(new_id[j] for j in n.inputs), **dict(n.params))
    for name, i in plan.outputs:
        b.set_output(name, new_id[i])
    return b.build()


# ---------------------------------------------------------------------------
def optimize(plan: Plan) -> Plan:
    """Default rewrite pipeline (executor calls this unless told not to)."""
    plan = merge_projections(plan)
    plan = fuse_masks(plan)
    plan = defer_compaction(plan)
    return dce(plan)
