"""Plan rewrites: shared scans, fused masks, deferred compaction, column
pruning through joins, join rewrites (capacity planning +
partitioning-awareness), DCE.

The passes encode the paper's three columnar properties (§3.4) at the *plan*
level instead of inside each extractor:

  * ``merge_projections`` — all extractors reading one source share a single
    scan + a single union projection, so a study makes ONE pass over DCIR
    instead of one per extractor.
  * ``fuse_masks`` — adjacent predicate / null-filter / value-filter nodes
    collapse into one ``fused_mask`` node, executed as a single vectorized
    Expr conjunction (one mask evaluation per extractor branch instead of
    one per step).
  * ``defer_compaction`` — compaction (the only materialization) is removed
    from plan interiors and appears exactly once per named table output.
  * ``prune_columns`` — join-aware dead-column elimination: every node's
    ``required_columns`` (Expr reads, join/exchange keys, conform/dedupe
    column sets, projections) is propagated *backwards* through
    lookup_join/expand_join/exchange into the star scans, and scans are
    narrowed so unused dimension columns never enter the flatten join chain.
  * ``plan_capacities`` — join capacity planning from table statistics,
    host-side (the Spark driver sizing shuffle partitions): exact output
    sizes for ``expand_join``/``slice_time`` nodes, replacing trace-time
    slack heuristics.
  * ``eliminate_joins`` — a ``lookup_join`` whose right side was pruned to
    the bare join key adds no columns and drops no left rows; it degrades to
    an audit-only ``key_count`` node (the no-loss stats survive as a cheap
    key-membership count).
  * ``prune_exchanges`` — partitioning-awareness (Spark's
    EnsureRequirements): an exchange whose input is already hash-partitioned
    on its key is dropped; off-mesh every exchange drops.
  * ``dce`` — drops nodes unreachable from any output (rewrites above strand
    the per-extractor projections).

All passes are pure ``Plan -> Plan`` functions (``plan_capacities`` also
reads concrete tables); ``optimize`` is the default pipeline used by the
executor.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Mapping, Optional, Set, Tuple

import numpy as np

from repro.core.columnar import NULL_INT
from repro.kernels import predicate as _pk
from repro.study import expr as _expr
from repro.study.plan import (JOIN_OPS, MASK_OPS, PREDICATE_OPS, Node, Plan,
                              PlanBuilder)

__all__ = ["optimize", "merge_projections", "fuse_masks", "defer_compaction",
           "prune_columns", "eliminate_joins", "plan_capacities",
           "prune_exchanges", "dce", "assign_engines", "available_columns",
           "required_columns", "join_right_cols", "OPTIMIZER_VERSION"]

# Bumped whenever a pass changes what an optimized plan *means* for a given
# builder-level study.  Cross-run caches keyed on optimized-plan content
# (the service's subgraph result cache, normalization goldens) salt their
# keys with this so stale entries die with the rewrite that produced them.
OPTIMIZER_VERSION = 1

# selects hanging off any of these get merged into one union projection
_MERGE_UPSTREAM = frozenset({
    "scan", "scan_star", "lookup_join", "expand_join", "exchange",
    "slice_time", "compact", "concat", "key_count",
})


def _rebuild(plan: Plan, replace: Dict[int, Node], drop: Optional[set] = None,
             redirect: Optional[Dict[int, int]] = None) -> Plan:
    """Re-emit ``plan`` through a fresh builder with node rewrites applied.

    ``replace`` swaps a node's definition; ``redirect`` makes consumers (and
    outputs) read another old node's value instead; ``drop`` marks old ids
    whose definition must not be re-emitted (their redirect target is used).
    Hash-consing in the builder re-deduplicates rewritten nodes.
    """
    drop = drop or set()
    redirect = redirect or {}
    b = PlanBuilder()
    new_id: Dict[int, int] = {}

    def resolve(old: int) -> int:
        seen = set()
        while old in redirect:
            if old in seen:
                raise ValueError("cyclic redirect in plan rewrite")
            seen.add(old)
            old = redirect[old]
        return new_id[old]

    for i, node in enumerate(plan.nodes):
        if i in drop or i in redirect:
            continue
        n = replace.get(i, node)
        inputs = tuple(resolve(j) for j in n.inputs)
        new_id[i] = b.add(n.op, inputs, **dict(n.params))
    for name, i in plan.outputs:
        b.set_output(name, resolve(i))
    return b.build()


# ---------------------------------------------------------------------------
def merge_projections(plan: Plan) -> Plan:
    """One shared projection per source (or per flattened table): the union
    of every consumer's column set.  (Scan nodes themselves already unify by
    hash-consing; this pass merges the per-extractor ``select`` nodes hanging
    off them.)  Selects that are themselves named outputs keep their exact
    column set — widening them would change the output schema."""
    out_ids = {i for _, i in plan.outputs}
    selects_by_scan: Dict[int, List[int]] = {}
    for i, n in enumerate(plan.nodes):
        if (n.op == "select" and i not in out_ids
                and plan.nodes[n.inputs[0]].op in _MERGE_UPSTREAM):
            selects_by_scan.setdefault(n.inputs[0], []).append(i)

    replace: Dict[int, Node] = {}
    redirect: Dict[int, int] = {}
    for scan_id, sel_ids in selects_by_scan.items():
        if len(sel_ids) < 2:
            continue
        union = sorted({c for i in sel_ids for c in plan.nodes[i].get("cols")})
        keep = sel_ids[0]
        replace[keep] = Node("select", (scan_id,), (("cols", tuple(union)),))
        for i in sel_ids[1:]:
            redirect[i] = keep
    if not (replace or redirect):
        return plan
    return _rebuild(plan, replace, redirect=redirect)


# ---------------------------------------------------------------------------
def _mask_params(node: Node) -> Tuple[Tuple[str, ...], Tuple, Tuple]:
    """(null_cols, value_filters, exprs) contribution of one mask-op node."""
    if node.op == "drop_nulls":
        return tuple(node.get("cols")), (), ()
    if node.op == "value_filter":
        return (), ((node.get("col"), node.get("codes")),), ()
    if node.op == "predicate":
        return (), (), (node.get("expr"),)
    if node.op == "fused_mask":
        return (tuple(node.get("null_cols")), tuple(node.get("filters")),
                tuple(node.get("exprs") or ()))
    raise AssertionError(node.op)


def fuse_masks(plan: Plan) -> Plan:
    """Collapse chains of mask-only nodes into single ``fused_mask`` nodes.

    Every predicate/drop_nulls/value_filter is first normalized to a
    fused_mask; then a fused_mask whose (sole-consumer) input is another
    fused_mask absorbs it.  Runs to fixpoint, so arbitrarily long mask
    chains become one node, executed as a single Expr conjunction (see
    ``expr.fused_predicate``).
    """
    # normalize
    replace = {}
    for i, n in enumerate(plan.nodes):
        if n.op in MASK_OPS:
            nulls, filters, exprs = _mask_params(n)
            replace[i] = Node("fused_mask", n.inputs,
                              (("exprs", exprs), ("filters", filters),
                               ("null_cols", nulls)))
    plan = _rebuild(plan, replace)

    while True:
        consumers = plan.consumers()
        out_ids = {i for _, i in plan.outputs}
        redirect: Dict[int, int] = {}
        replace = {}
        for i, n in enumerate(plan.nodes):
            if n.op != "fused_mask":
                continue
            j = n.inputs[0]
            up = plan.nodes[j]
            if (up.op != "fused_mask" or len(consumers[j]) != 1
                    or j in replace or j in out_ids):
                continue
            u_nulls, u_filters, u_exprs = _mask_params(up)
            n_nulls, n_filters, n_exprs = _mask_params(n)
            nulls = u_nulls + tuple(c for c in n_nulls if c not in u_nulls)
            replace[i] = Node("fused_mask", up.inputs,
                              (("exprs", u_exprs + n_exprs),
                               ("filters", u_filters + n_filters),
                               ("null_cols", nulls)))
            redirect[j] = i  # j had only this consumer; drop its definition
        if not replace:
            return plan
        # re-emit: replaced nodes take their new def; absorbed nodes vanish.
        b = PlanBuilder()
        new_id: Dict[int, int] = {}
        absorbed = set(redirect)
        for i, node in enumerate(plan.nodes):
            if i in absorbed:
                continue
            n = replace.get(i, node)
            inputs = tuple(new_id[j] for j in n.inputs)
            new_id[i] = b.add(n.op, inputs, **dict(n.params))
        for name, i in plan.outputs:
            b.set_output(name, new_id[i])
        plan = b.build()


# ---------------------------------------------------------------------------
def defer_compaction(plan: Plan) -> Plan:
    """Exactly one materialization per table output.

    Interior compact nodes (anything downstream still reads them) are
    bypassed — masks and event conformance operate on uncompacted tables for
    free — and every named table output gets a final compact if it lacks one.
    """
    out_ids = {i for _, i in plan.outputs}
    consumers = plan.consumers()
    redirect: Dict[int, int] = {}
    for i, n in enumerate(plan.nodes):
        if n.op == "compact" and consumers[i] and i not in out_ids:
            redirect[i] = n.inputs[0]
    if redirect:
        plan = _rebuild(plan, {}, redirect=redirect)

    # append a compact to table outputs that end uncompacted
    b = PlanBuilder()
    new_id: Dict[int, int] = {}
    for i, n in enumerate(plan.nodes):
        new_id[i] = b.add(n.op, tuple(new_id[j] for j in n.inputs), **dict(n.params))
    from repro.study.plan import TABLE_OPS
    for name, i in plan.outputs:
        n = plan.nodes[i]
        if n.op in TABLE_OPS and n.op not in ("compact", "transform"):
            b.set_output(name, b.compact(new_id[i]))
        else:
            b.set_output(name, new_id[i])
    return b.build()


# ---------------------------------------------------------------------------
# row-preserving ops through which hash partitioning survives (masks don't
# move rows between shards; joins keep left rows on their shard)
_PART_PRESERVING = frozenset({
    "select", "predicate", "drop_nulls", "value_filter", "fused_mask",
    "dedupe", "conform_events", "compact", "slice_time", "lookup_join",
    "expand_join", "key_count",
})


def prune_exchanges(plan: Plan, n_shards: int = 1) -> Plan:
    """Partitioning-awareness (Spark's EnsureRequirements, lifted out of
    ``distributed_flatten``'s hand-rolled ``flat_pkey`` loop): drop an
    exchange whose input is already hash-partitioned on its key —
    re-exchanging would funnel every local row to one destination bucket.
    With ``n_shards <= 1`` every exchange is the identity and all drop.
    """
    part: Dict[int, Optional[str]] = {}
    redirect: Dict[int, int] = {}
    for i, n in enumerate(plan.nodes):
        if n.op == "scan_star":
            part[i] = n.get("partitioned_on")
        elif n.op == "exchange":
            upstream = part.get(n.inputs[0])
            if n_shards <= 1 or upstream == n.get("key"):
                redirect[i] = n.inputs[0]
                part[i] = upstream
            else:
                part[i] = n.get("key")
        elif n.op in _PART_PRESERVING and n.inputs:
            part[i] = part.get(n.inputs[0])
        else:
            part[i] = None
    if not redirect:
        return plan
    return _rebuild(plan, {}, redirect=redirect)


# ---------------------------------------------------------------------------
# column pruning through joins (the ROADMAP "join-aware DCE of flat columns")
# ---------------------------------------------------------------------------
# the standardized Event layout produced by conform_events (schema.FLAT_EVENT_
# SCHEMA) — conform is a schema boundary, so requirements never propagate
# through it
_EVENT_COLS = frozenset({"patient_id", "category", "group_id", "value",
                         "weight", "start", "end"})
# ops whose output carries exactly their (single) input's column set
_COLS_PRESERVING = frozenset({
    "predicate", "drop_nulls", "value_filter", "fused_mask", "dedupe",
    "compact", "exchange", "slice_time",
})


def join_right_cols(node: Node, right_avail: FrozenSet[str]) -> Dict[str, str]:
    """{output column name: right column name} contributed by a join's right
    side (the right key folds into the left side and never surfaces).

    Shared with ``study/analyze.py``: the static analyzer's schema inference
    must agree with the pruner's view of join output columns."""
    prefix = node.get("prefix") or ""
    rk = node.get("right_key")
    return {prefix + c: c for c in right_avail if c != rk}


_join_right_cols = join_right_cols  # internal alias (pre-analyzer name)


def available_columns(plan: Plan) -> Dict[int, Optional[FrozenSet[str]]]:
    """Forward dataflow: the column set each table node produces, where it is
    statically known (``None`` = unknown).  ``scan_star`` nodes learn their
    schema from the ``columns`` param ``contribute_flatten`` stamps."""
    avail: Dict[int, Optional[FrozenSet[str]]] = {}
    for i, n in enumerate(plan.nodes):
        if n.op == "scan_star" and n.get("columns") is not None:
            avail[i] = frozenset(n.get("columns"))
        elif n.op == "select":
            avail[i] = frozenset(n.get("cols"))
        elif n.op == "conform_events":
            avail[i] = _EVENT_COLS
        elif n.op in _COLS_PRESERVING and n.inputs:
            avail[i] = avail.get(n.inputs[0])
        elif n.op == "key_count":        # value = the left table unchanged
            avail[i] = avail.get(n.inputs[0])
        elif n.op in JOIN_OPS:
            la, ra = avail.get(n.inputs[0]), avail.get(n.inputs[1])
            avail[i] = (None if la is None or ra is None
                        else la | frozenset(_join_right_cols(n, ra)))
        elif n.op == "concat":
            ins = [avail.get(j) for j in n.inputs]
            avail[i] = ins[0] if ins and all(a == ins[0] for a in ins) else None
        else:
            avail[i] = None
    return avail


def required_columns(plan: Plan) -> Dict[int, Optional[FrozenSet[str]]]:
    """Backward dataflow: the columns each table node must *provide* —
    the union over its consumers of what they read (Expr columns, join and
    exchange keys, conform/dedupe column sets, projections).  ``None`` means
    "everything" (named outputs keep their full schema; opaque transforms
    and exported event tables pin their inputs)."""
    avail = available_columns(plan)
    req: Dict[int, Optional[Set[str]]] = {}

    def _push(j: int, cols: Optional[Set[str]]) -> None:
        if cols is None:
            req[j] = None
        elif req.get(j, set()) is not None:
            req[j] = req.get(j, set()) | set(cols)

    for _, i in plan.outputs:
        req[i] = None  # an output's schema is part of the study contract
    for i in range(len(plan.nodes) - 1, -1, -1):
        n = plan.nodes[i]
        r = req.get(i, set())
        if n.op in ("scan", "scan_star"):
            continue
        if n.op == "select":
            # the projection itself declares what it reads; narrowing it
            # would change its (possibly output-visible) schema
            _push(n.inputs[0], set(n.get("cols")))
        elif n.op in ("predicate", "drop_nulls", "value_filter", "fused_mask"):
            e = _expr.node_predicate(n)
            own = set() if e is None else set(e.required_columns())
            _push(n.inputs[0], None if r is None else r | own)
        elif n.op == "dedupe":
            _push(n.inputs[0], None if r is None else r | set(n.get("keys")))
        elif n.op == "compact":
            _push(n.inputs[0], r)
        elif n.op == "exchange":
            _push(n.inputs[0], None if r is None else r | {n.get("key")})
        elif n.op == "slice_time":
            _push(n.inputs[0], None if r is None else r | {n.get("col")})
        elif n.op == "conform_events":
            need = {"patient_id", n.get("value_col"), n.get("start_col")}
            need |= {c for c in (n.get("end_col"), n.get("group_col"),
                                 n.get("weight_col")) if c}
            _push(n.inputs[0], need)
        elif n.op == "concat":
            for j in n.inputs:
                _push(j, r)
        elif n.op == "key_count":
            _push(n.inputs[0],
                  None if r is None else r | {n.get("left_key")})
            _push(n.inputs[1], {n.get("right_key")})
        elif n.op in JOIN_OPS:
            l_in, r_in = n.inputs
            ra = avail.get(r_in)
            if r is None or ra is None:
                _push(l_in, None)
                _push(r_in, None)
                continue
            right_named = _join_right_cols(n, ra)
            from_right = {right_named[c] for c in r if c in right_named}
            _push(r_in, from_right | {n.get("right_key")})
            _push(l_in, {c for c in r if c not in right_named}
                  | {n.get("left_key")})
        elif n.op == "transform":
            for j in n.inputs:
                _push(j, None)  # registered fns are opaque: keep everything
        elif n.op == "cohort_from_events":
            # the event table leaves the program as Cohort.events — full schema
            _push(n.inputs[0], None)
        elif n.op == "featurize":
            if len(n.inputs) > 1:
                _push(n.inputs[1], None)  # the patients table is host-visible
        # cohort_op / flow consume bitsets, not tables
    return {i: (None if c is None else frozenset(c))
            for i, c in req.items()}


# nodes worth stamping with their required-column set for the OperationLog
# audit (the paper's "what did each stage read" data-flow story)
_AUDIT_OPS = frozenset({"lookup_join", "expand_join", "exchange",
                        "slice_time", "scan_star"})


def prune_columns(plan: Plan) -> Plan:
    """Join-aware column pruning: narrow every statically-known scan to the
    columns some consumer actually reads.

    The union projection of all extractors/featurize/conform consumers is
    propagated backwards through ``lookup_join``/``expand_join``/``exchange``
    into the star scans (``required_columns``); each prunable ``scan_star``
    gets a ``select`` of only the required columns inserted directly above
    it, so unused dimension columns are dropped before the flatten join
    chain ever materializes them.  Audited nodes are stamped with
    ``required_columns`` (and pruning selects with ``pruned_columns``) so
    the OperationLog records what each stage read.
    """
    avail = available_columns(plan)
    req = required_columns(plan)

    prune: Dict[int, Tuple[Tuple[str, ...], Tuple[str, ...]]] = {}
    for i, n in enumerate(plan.nodes):
        if n.op != "scan_star" or avail.get(i) is None:
            continue
        r = req.get(i, frozenset())
        if r is None:
            continue
        keep = r & avail[i]
        if keep and keep < avail[i]:
            prune[i] = (tuple(sorted(keep)), tuple(sorted(avail[i] - keep)))
    if not prune and not any(
            n.op in _AUDIT_OPS and req.get(i) is not None
            for i, n in enumerate(plan.nodes)):
        return plan

    b = PlanBuilder()
    new_id: Dict[int, int] = {}
    for i, n in enumerate(plan.nodes):
        params = dict(n.params)
        if n.op in _AUDIT_OPS and req.get(i) is not None:
            params["required_columns"] = tuple(sorted(req[i]))
        nid = b.add(n.op, tuple(new_id[j] for j in n.inputs), **params)
        if i in prune:
            keep, dropped = prune[i]
            nid = b.add("select", (nid,), cols=keep, pruned_columns=dropped)
        new_id[i] = nid
    for name, i in plan.outputs:
        b.set_output(name, new_id[i])
    return b.build()


# ---------------------------------------------------------------------------
def eliminate_joins(plan: Plan) -> Plan:
    """Join elimination on pruned N:1 joins (the ROADMAP item).

    Column pruning can narrow a ``lookup_join``'s right side to the bare
    join key; such a join contributes no output column and — N:1 left-join
    semantics — never drops a left row, so the join itself is dead.  The
    node degrades to an audit-only ``key_count``: the left table passes
    through unchanged (no sort-gather of right attributes), while the
    paper's no-loss audit survives as a cheap key-membership count
    (matched / null_keys FlatteningStats against the pruned-to-key right
    side).  Runs after ``prune_columns`` so the stamped
    ``required_columns`` audit fields carry over.
    """
    avail = available_columns(plan)
    req = required_columns(plan)
    replace: Dict[int, Node] = {}
    for i, n in enumerate(plan.nodes):
        if n.op != "lookup_join":
            continue
        r, ra = req.get(i, frozenset()), avail.get(n.inputs[1])
        if r is None or ra is None:
            continue
        right_named = _join_right_cols(n, ra)
        if any(c in right_named for c in r):
            continue
        params = {"left_key": n.get("left_key"),
                  "right_key": n.get("right_key"),
                  "name": f"[{n.get('left_key')}]"}
        if n.get("required_columns") is not None:
            params["required_columns"] = n.get("required_columns")
        replace[i] = Node("key_count", n.inputs, tuple(sorted(params.items())))
    if not replace:
        return plan
    return _rebuild(plan, replace)


# ---------------------------------------------------------------------------
def _np_null_mask(a: np.ndarray) -> np.ndarray:
    """Host-side mirror of ``columnar.is_null`` (same sentinel source)."""
    if np.issubdtype(a.dtype, np.floating):
        return np.isnan(a)
    return a == int(NULL_INT)


def _round_up(n: int, quantum: int) -> int:
    return -(-max(n, 1) // quantum) * quantum


def plan_capacities(plan: Plan, tables: Mapping, round_to: int = 64,
                    ops: Tuple[str, ...] = ("expand_join", "slice_time")
                    ) -> Plan:
    """Capacity planning from table statistics, host-side.

    Replaces the ad-hoc ``expand_slack`` guesses: the plan's join-key columns
    are simulated through the node graph with numpy (the Spark analogue is
    the driver deriving shuffle sizes from table statistics), giving the
    *exact* output row count of every ``expand_join`` and ``slice_time``
    node, which is rounded up to ``round_to`` (jit-cache stability) and
    written into the node's ``capacity`` param.  ``ops`` restricts which node
    kinds get a capacity stamped (the simulation always runs in full).
    Nodes already carrying an explicit capacity, or whose inputs cannot be
    resolved to concrete tables, are left to the executor's trace-time
    heuristics.
    """
    if not any(n.op in ops and n.get("capacity") is None for n in plan.nodes):
        return plan  # nothing consumes table statistics — skip the sim
    needed = set()
    for n in plan.nodes:
        if n.op in JOIN_OPS:
            needed.add(n.get("left_key"))
            needed.add(n.get("right_key"))
        elif n.op == "slice_time":
            needed.add(n.get("col"))

    sim: Dict[int, Optional[Dict[str, np.ndarray]]] = {}
    replace: Dict[int, Node] = {}

    def _with_capacity(n: Node, cap: int) -> Node:
        p = dict(n.params)
        p["capacity"] = int(cap)
        return Node(n.op, n.inputs, tuple(sorted(p.items())))

    for i, n in enumerate(plan.nodes):
        if n.op in ("scan", "scan_star"):
            t = tables.get(n.get("source"))
            if t is None:
                sim[i] = None
                continue
            valid = t.valid_numpy()
            sim[i] = {c: np.asarray(t.columns[c])[valid]
                      for c in needed if c in t.columns}
        elif n.op == "select":
            up = sim.get(n.inputs[0])
            sim[i] = (None if up is None else
                      {c: v for c, v in up.items() if c in n.get("cols")})
        elif n.op in ("compact", "exchange", "lookup_join", "key_count"):
            # row-multiset preserved (lookup_join: N:1 keeps left rows; the
            # gained right attributes are not join keys in a star schema)
            sim[i] = sim.get(n.inputs[0])
        elif n.op == "slice_time":
            up = sim.get(n.inputs[0])
            col = n.get("col")
            if up is None or col not in up:
                sim[i] = None
                continue
            m = (up[col] >= n.get("lo")) & (up[col] < n.get("hi"))
            if n.op in ops and n.get("capacity") is None:
                replace[i] = _with_capacity(n, _round_up(int(m.sum()),
                                                         round_to))
            sim[i] = {c: v[m] for c, v in up.items()}
        elif n.op == "expand_join":
            left = sim.get(n.inputs[0])
            right = sim.get(n.inputs[1])
            lk_name, rk_name = n.get("left_key"), n.get("right_key")
            if left is None or right is None or lk_name not in left \
                    or rk_name not in right:
                sim[i] = None
                continue
            lk = left[lk_name]
            rk = right[rk_name]
            rs = np.sort(rk[~_np_null_mask(rk)])
            cnt = (np.searchsorted(rs, lk, side="right")
                   - np.searchsorted(rs, lk, side="left"))
            cnt[_np_null_mask(lk)] = 0
            reps = np.maximum(cnt, 1)
            if n.op in ops and n.get("capacity") is None:
                replace[i] = _with_capacity(n, _round_up(int(reps.sum()),
                                                         round_to))
            sim[i] = {c: np.repeat(v, reps) for c, v in left.items()}
        else:
            sim[i] = None
    if not replace:
        return plan
    return _rebuild(plan, replace)


# ---------------------------------------------------------------------------
def assign_engines(plan: Plan, predicate_engine: str = "auto",
                   engine: str = "xla",
                   block: Optional[int] = None) -> Plan:
    """Stamp every predicate-evaluating node with its chosen engine and, for
    the Pallas path, the bitset layout (block quantum + word dtype).

    The stamp is what the executor obeys (run-level ``predicate_engine`` is
    only the fallback for un-stamped plans), and because node params flow
    into ``record_plan`` verbatim, the ``OperationLog`` audit records *which*
    engine and layout each mask pass actually used — the same legibility
    story as ``required_columns``/``pruned_columns``.  Exprs whose root is
    not boolean-valued (not kernel-compilable) are stamped ``jnp``.
    """
    resolved = _pk.resolve_engine(predicate_engine, engine)
    block = int(block or _pk.DEFAULT_BLOCK)
    replace: Dict[int, Node] = {}
    for i, n in enumerate(plan.nodes):
        if n.op not in PREDICATE_OPS and n.op != "compact":
            continue
        p = dict(n.params)
        # table validity is the packed-word bitset end-to-end; the stamp
        # pins the layout in plan goldens and the OperationLog audit
        p["valid_layout"] = "bitset_u32"
        if n.op in PREDICATE_OPS:
            e = _expr.node_predicate(n)
            eng = resolved
            if eng == "pallas" and (e is None
                                    or not _pk.compilable(e.to_param())):
                eng = "jnp"
            p["engine"] = eng
            if eng == "pallas":
                p["bitset_block"] = block
                p["bitset_word"] = "uint32"
            else:
                p.pop("bitset_block", None)
                p.pop("bitset_word", None)
        node = Node(n.op, n.inputs, tuple(sorted(p.items())))
        if node != n:
            replace[i] = node
    if not replace:
        return plan
    return _rebuild(plan, replace)


# ---------------------------------------------------------------------------
def dce(plan: Plan) -> Plan:
    """Drop nodes unreachable from any named output."""
    live = set()
    stack = [i for _, i in plan.outputs]
    while stack:
        i = stack.pop()
        if i in live:
            continue
        live.add(i)
        stack.extend(plan.nodes[i].inputs)
    if len(live) == len(plan.nodes):
        return plan
    b = PlanBuilder()
    new_id: Dict[int, int] = {}
    for i, n in enumerate(plan.nodes):
        if i not in live:
            continue
        new_id[i] = b.add(n.op, tuple(new_id[j] for j in n.inputs), **dict(n.params))
    for name, i in plan.outputs:
        b.set_output(name, new_id[i])
    return b.build()


# ---------------------------------------------------------------------------
def optimize(plan: Plan, tables: Optional[Mapping] = None,
             n_shards: int = 1, prune_cols: bool = True,
             predicate_engine: str = "auto", engine: str = "xla") -> Plan:
    """Default rewrite pipeline (executor calls this unless told not to).

    ``tables`` (concrete run-time tables) enables host-side capacity
    planning; ``n_shards`` informs exchange pruning (off-mesh, every exchange
    is the identity and drops); ``prune_cols=False`` disables join-aware
    column pruning (the benchmark baseline); ``predicate_engine``/``engine``
    feed the engine-assignment pass that stamps predicate nodes with their
    evaluation engine + bitset layout.
    """
    plan = merge_projections(plan)
    plan = fuse_masks(plan)
    plan = defer_compaction(plan)
    plan = prune_exchanges(plan, n_shards=n_shards)
    if prune_cols:
        plan = prune_columns(plan)
        plan = eliminate_joins(plan)
    plan = assign_engines(plan, predicate_engine=predicate_engine,
                          engine=engine)
    if tables:
        # The planner's exact sizes are GLOBAL row counts.  Under shard_map
        # each shard would allocate that full size, so sharded expand_joins
        # keep the executor's per-shard trace-time heuristic (see ROADMAP);
        # slice_time is still planned there — a global slice count is a sound
        # per-shard bound (the executor's shrink is a no-op when the local
        # capacity is already smaller) and slice_time has no trace-time
        # fallback at all.
        ops = (("expand_join", "slice_time") if n_shards <= 1
               else ("slice_time",))
        plan = plan_capacities(plan, tables, ops=ops)
    return dce(plan)
