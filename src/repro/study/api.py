"""``Study``: the fluent, lazy entry point unifying extraction → cohort →
features (the paper's three layers) behind one Plan.

User code reads like the paper's supplementary notebooks::

    result = (Study(n_patients=P)
              .extract(drug_dispenses(), name="drugs")
              .extract(medical_acts_dcir(), name="acts")
              .patients("IR_BEN")
              .transform("exposures", "drugs", name="exposed", purview_days=60)
              .cohort("base", "extract_patients")
              .cohort("final", "exposed & base - acts")
              .flow("base", "exposed", "final")
              .featurize("X", cohort="final", kind="dense",
                         n_buckets=36, bucket_days=31, n_features=128)
              .run({"DCIR": flat, "IR_BEN": ir_ben}, engine="xla"))

Nothing executes until ``run()``: the builder accumulates Plan nodes, the
optimizer fuses masks / shares scans / defers compaction, and the executor
runs ONE jit-compiled program for all extractors, transformers and cohort
algebra, logging every node into an ``OperationLog`` automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cohort import Cohort, CohortCollection, CohortFlow
from repro.core.columnar import ColumnarTable
from repro.core.metadata import OperationLog
from repro.study import executor as _executor
from repro.study import optimizer as _optimizer
from repro.study.expr import CohortRef, parse_cohort_expr
from repro.study.plan import COHORT_OPS, Plan, PlanBuilder, TABLE_OPS

__all__ = ["Study", "StudyResult", "contribute_flatten",
           "contribute_flatten_sliced", "flow_rows_from_log",
           "column_audit_from_log"]

_FLOW_OUT = "__flow__"


def contribute_flatten(b: PlanBuilder, schema, central: Optional[int] = None,
                       expand_capacity: Optional[int] = None,
                       expand_slack: float = 1.5, exchange: bool = False,
                       exchange_slack: float = 2.0, min_per_dest: int = 64,
                       partitioned_on: Optional[str] = None) -> int:
    """Append one sub-database's flattening to ``b``; returns the flat node.

    The join chain mirrors ``StarSchema.joins`` (lookup for N:1 dimension
    tables, expand for 1:N children).  ``exchange=True`` emits the Spark
    physical plan for mesh execution — exchange both sides of every join
    onto the join key, then one final exchange onto ``patient_key`` so the
    output is patient-partitioned.  The left side's partitioning is tracked
    while building, so a same-key exchange is never emitted in the first
    place (re-exchanging an already-partitioned shard would funnel every
    local row into one destination bucket — this must hold even for raw,
    unoptimized plans); the optimizer's ``prune_exchanges`` pass additionally
    drops exchanges made redundant by rewrites, and all of them off-mesh.
    ``central`` overrides the central-table node (e.g. a ``slice_time`` of
    it), with ``partitioned_on`` describing *its* partitioning.
    """
    t = central if central is not None else b.scan_star(
        schema.central.name, star=schema.name, partitioned_on=partitioned_on,
        columns=tuple(schema.central.columns))
    pkey = partitioned_on
    for edge in schema.joins:
        r = b.scan_star(edge.right, star=schema.name,
                        columns=tuple(schema.table(edge.right).columns))
        if exchange:
            if pkey != edge.left_key:
                t = b.exchange(t, edge.left_key, slack=exchange_slack,
                               min_per_dest=min_per_dest)
                pkey = edge.left_key
            r = b.exchange(r, edge.right_key, slack=exchange_slack,
                           min_per_dest=min_per_dest)
        if edge.one_to_many:
            t = b.expand_join(t, r, edge.left_key, edge.right_key,
                              capacity=expand_capacity, slack=expand_slack)
        else:
            t = b.lookup_join(t, r, edge.left_key, edge.right_key)
    if exchange and pkey != schema.patient_key \
            and schema.patient_key in schema.flat_columns():
        t = b.exchange(t, schema.patient_key, slack=exchange_slack,
                       min_per_dest=min_per_dest)
    return t


def contribute_flatten_sliced(b: PlanBuilder, schema, time_column: str,
                              n_slices: int, t0: int, t1: int,
                              name: str = "sliced_flatten",
                              partitioned_on: Optional[str] = None,
                              **kw) -> int:
    """Temporal slicing (paper §3.3) as plan nodes: one ``slice_time`` +
    join chain per slice, concatenated.  Slice capacities stay unset here —
    the optimizer's capacity planner bounds each one by the slice's actual
    row count (``plan_capacities``), which is what keeps the concatenated
    output at ~sum-of-slice-rows instead of ``n_slices`` full copies."""
    edges = np.linspace(int(t0), int(t1) + 1,
                        int(n_slices) + 1).astype(np.int32)
    parts = []
    for i in range(int(n_slices)):
        t = b.scan_star(schema.central.name, star=schema.name,
                        partitioned_on=partitioned_on,
                        columns=tuple(schema.central.columns))
        t = b.slice_time(t, time_column, int(edges[i]), int(edges[i + 1]))
        parts.append(contribute_flatten(b, schema, central=t,
                                        partitioned_on=partitioned_on, **kw))
    return b.concat(parts, name=name)


@dataclasses.dataclass
class StudyResult:
    """Realized outputs of one ``Study.run``.

    Table outputs carry the bitset-native validity contract: ``.valid`` is
    the packed uint32 word form (``core.bitset`` layout, ``count`` ==
    popcount); use ``.valid_bool()`` / ``.to_numpy()`` for per-row views.
    """

    events: Dict[str, ColumnarTable]          # named table outputs
    cohorts: Dict[str, Cohort]                # named cohorts
    flow: Optional[CohortFlow]                # if .flow(...) was declared
    features: Dict[str, Any]                  # named featurize outputs
    log: OperationLog                         # automatic provenance
    plan: Plan                                # the plan that actually ran
    feature_checks: Dict[str, Dict[str, int]] = dataclasses.field(default_factory=dict)
    flatten_stats: Dict[int, Dict[str, int]] = dataclasses.field(default_factory=dict)
    # ^ per-join FlatteningStats (host ints, keyed by plan node id; each dict
    #   carries a "stage" label) — also recorded in ``log`` automatically

    def assert_no_loss(self) -> None:
        """The paper's flattening audit: no join/exchange overflowed."""
        for i, d in self.flatten_stats.items():
            if d.get("overflow", 0):
                raise AssertionError(
                    f"plan node #{i} ({d.get('stage')}): "
                    f"{d['overflow']} rows overflowed")

    def collection(self) -> CohortCollection:
        return CohortCollection(dict(self.cohorts), metadata=self.log)


class Study:
    """Deferred study builder over the Plan IR (see module docstring)."""

    def __init__(self, n_patients: int,
                 window: Tuple[int, int] = (0, 2_000_000_000)) -> None:
        self.n_patients = int(n_patients)
        self._window = (int(window[0]), int(window[1]))
        self._b = PlanBuilder()
        self._names: Dict[str, int] = {}      # name -> node id (pre-optimize)
        self._kinds: Dict[str, str] = {}      # name -> events|table|cohort|feature
        self._sources: Dict[str, ColumnarTable] = {}
        self._flow_names: Optional[List[str]] = None
        self._feature_names: List[str] = []
        self._flatten_keep: Dict[str, Optional[bool]] = {}  # name -> keep mode
        self._chained: set = set()            # flatten names extractors read
        self._opt_cache: Optional[Tuple[Tuple, Plan]] = None  # (key, optimized)
        # declarative build log: one (step, kwargs) record per successful
        # builder call, holding the *arguments* (schema/extractor/Expr
        # objects, not plan nodes).  ``study.spec.spec_from_study`` serializes
        # it into the wire-format spec; methods with no wire equivalent
        # (``source``) record an explicit marker so the exporter can refuse
        # loudly instead of silently dropping state.
        self._recipe: List[Tuple[str, Dict[str, Any]]] = []

    # -- builder steps -------------------------------------------------------
    def _register(self, name: str, nid: int, kind: str) -> "Study":
        if name in self._names:
            raise ValueError(f"duplicate study output name {name!r}")
        self._names[name] = self._b.set_output(name, nid)
        self._kinds[name] = kind
        return self

    def source(self, name: str, table: ColumnarTable) -> "Study":
        """Pre-bind a flat table (alternative to passing it at run())."""
        self._sources[name] = table
        self._recipe.append(("source", {"name": name}))
        return self

    def flatten(self, schema, name: Optional[str] = None,
                time_slices: Optional[int] = None,
                time_column: Optional[str] = None, t0: Optional[int] = None,
                t1: Optional[int] = None, expand_capacity: Optional[int] = None,
                expand_slack: float = 1.5, exchange: bool = True,
                partitioned_on: Optional[str] = None,
                keep: Optional[bool] = None) -> "Study":
        """SCALPEL-Flattening as plan nodes: the star schema's
        denormalization joins enter the same Plan IR as extraction, so one
        ``optimize()`` + executor pass jit-compiles raw star tables all the
        way to features.  The flat table registers under ``name`` (default:
        the schema name, e.g. ``"DCIR"``), and later ``extract()`` calls
        whose extractor ``source`` matches chain onto it instead of scanning
        the run-time env — ``run()`` then takes the *normalized* star tables.

        ``time_slices`` (with ``time_column``/``t0``/``t1``) splits the
        central table into temporal slices flattened independently and
        concatenated, each with a bounded capacity set by the optimizer's
        capacity planner.  ``exchange`` keeps the plan mesh-ready (exchange
        nodes are pruned off-mesh and are the identity when unpruned).

        ``keep`` controls whether the flat table is a *realized output* of
        the study (full schema in ``result.events[name]``) or just the
        chaining point for later ``extract()`` calls.  The default ``None``
        is automatic: keep the flat table unless an extractor chains onto it
        — once extraction consumes it, demoting it to an interior node lets
        the optimizer's column-pruning pass drop every dimension column no
        extractor reads *before the joins materialize it* (a named output
        would pin the full flat schema).  Pass ``keep=True`` to always
        materialize the flat table, ``keep=False`` to never.
        """
        b = self._b
        if time_slices:
            if time_column is None or t0 is None or t1 is None:
                raise ValueError("time_slices needs time_column, t0 and t1")
            nid = contribute_flatten_sliced(
                b, schema, time_column, time_slices, t0, t1,
                name=name or schema.name, partitioned_on=partitioned_on,
                expand_capacity=expand_capacity, expand_slack=expand_slack,
                exchange=exchange)
        else:
            nid = contribute_flatten(
                b, schema, expand_capacity=expand_capacity,
                expand_slack=expand_slack, exchange=exchange,
                partitioned_on=partitioned_on)
        self._flatten_keep[name or schema.name] = keep
        self._register(name or schema.name, nid, "table")
        self._recipe.append(("flatten", {
            "schema": schema, "name": name, "time_slices": time_slices,
            "time_column": time_column, "t0": t0, "t1": t1,
            "expand_capacity": expand_capacity, "expand_slack": expand_slack,
            "exchange": exchange, "partitioned_on": partitioned_on,
            "keep": keep}))
        return self

    def extract(self, extractor, name: Optional[str] = None,
                compact: bool = True) -> "Study":
        """Append a declarative ``Extractor``'s steps to the plan.  When the
        extractor's ``source`` names a table built earlier in this study
        (e.g. by ``flatten``), the steps chain onto that node; otherwise they
        scan the run-time env."""
        base = None
        if (extractor.source in self._names
                and self._kinds.get(extractor.source) == "table"):
            base = self._names[extractor.source]
            self._chained.add(extractor.source)
        nid = extractor.contribute(self._b, compact=compact, base=base)
        self._register(name or extractor.name, nid, "events")
        self._recipe.append(("extract", {
            "extractor": extractor, "name": name or extractor.name,
            "compact": compact}))
        return self

    def patients(self, source: str = "IR_BEN",
                 name: str = "extract_patients") -> "Study":
        """Patient demographics table (paper task (a)) as a plan branch."""
        b = self._b
        t = b.select(b.scan(source),
                     ["patient_id", "gender", "birth_date", "death_date"])
        t = b.compact(b.dedupe(t, ["patient_id"]))
        self._register(name, t, "table")
        self._recipe.append(("patients", {"source": source, "name": name}))
        return self

    def transform(self, fn: str, *inputs: str, name: Optional[str] = None,
                  **kwargs: Any) -> "Study":
        """Defer a registered transformer (``executor.TRANSFORMS``) over named
        upstream outputs; ``n_patients`` is injected at execution."""
        if fn not in _executor.TRANSFORMS:
            raise ValueError(f"unknown transform {fn!r}; registered: "
                             f"{sorted(_executor.TRANSFORMS)}")
        ids = [self._node_of(x) for x in inputs]
        nid = self._b.transform(fn, ids, name=name or fn, **kwargs)
        self._register(name or fn, nid, "events")
        self._recipe.append(("transform", {
            "fn": fn, "inputs": list(inputs), "name": name or fn,
            "kwargs": dict(kwargs)}))
        return self

    def concat(self, name: str, *inputs: str) -> "Study":
        """Stack named event outputs into one table (schemas must match)."""
        nid = self._b.concat([self._node_of(x) for x in inputs], name=name)
        self._register(name, nid, "events")
        self._recipe.append(("concat", {"name": name, "inputs": list(inputs)}))
        return self

    def filter(self, source: str, expr, name: Optional[str] = None) -> "Study":
        """Filter a named table/events output with a typed column expression:
        ``study.filter("drugs", col("start") >= t0, name="recent")``.  The
        predicate rides the plan like any extractor mask (fusable, prunable);
        the filtered table registers under ``name`` with one compaction."""
        if name is None:
            name = f"{source}_filtered"
        kind = self._kinds.get(source)
        if kind not in ("table", "events"):
            raise ValueError(f"filter source {source!r} is not a table output")
        nid = self._b.predicate(self._node_of(source), expr, label=name)
        self._register(name, nid, kind)
        self._recipe.append(("filter", {
            "source": source, "where": expr, "name": name}))
        return self

    def cohort(self, name: str, expr: str,
               description: Optional[str] = None) -> "Study":
        """Define a cohort from an algebra expression over previously
        declared cohorts / extractions / transforms, e.g.
        ``"(exposed & base) - fractured"``.  Parsed by a real
        recursive-descent parser (``expr.parse_cohort_expr``): ``&`` (∩)
        binds tighter than ``|`` (∪) and ``-`` (\\), parentheses group, and
        each level is left-associative.  Legacy flat expressions keep their
        meaning bit-for-bit wherever the old single-precedence left fold
        agreed with standard precedence (single-operator chains, and mixes
        where every ``&`` precedes ``|``/``-``); where the old fold
        disagreed — ``"a | b & c"``, ``"a - b & c"`` — the old reading was
        the bug this parser fixes, and parentheses restore it explicitly."""
        nid = self._lower_cohort(parse_cohort_expr(expr), name)
        self._register(name, nid, "cohort")
        self._recipe.append(("cohort", {"name": name, "expr": expr}))
        return self

    def flow(self, *names: str) -> "Study":
        """Declare the RECORD-flowchart fold over named cohorts, in order."""
        ids = [self._cohort_node(n) for n in names]
        fid = self._b.flow(ids, name="flow")
        self._flow_names = list(names)
        self._names[_FLOW_OUT] = self._b.set_output(_FLOW_OUT, fid)
        self._kinds[_FLOW_OUT] = "flow"
        self._recipe.append(("flow", {"names": list(names)}))
        return self

    def featurize(self, name: str, cohort: str, kind: str = "dense",
                  patients: Optional[str] = None, **kwargs: Any) -> "Study":
        """Defer a FeatureDriver export (``dense`` or ``tokens``) of a cohort."""
        if kind not in ("dense", "tokens"):
            raise ValueError(f"featurize kind must be dense|tokens, got {kind!r}")
        cid = self._cohort_node(cohort)
        pid = self._node_of(patients) if patients else None
        nid = self._b.featurize(cid, name=name, kind=kind, patients=pid, **kwargs)
        self._feature_names.append(name)
        self._register(name, nid, "feature")
        self._recipe.append(("featurize", {
            "name": name, "cohort": cohort, "kind": kind,
            "patients": patients, "kwargs": dict(kwargs)}))
        return self

    def window(self, start: int, end: int) -> "Study":
        self._window = (int(start), int(end))
        return self

    # -- name resolution -----------------------------------------------------
    def _node_of(self, name: str) -> int:
        if name not in self._names:
            raise ValueError(f"unknown study output {name!r}; defined: "
                             f"{sorted(self._names)}")
        return self._names[name]

    def _cohort_node(self, name: str) -> int:
        """Node id of a cohort; event/table outputs auto-wrap via
        ``cohort_from_events`` (membership = has-any-row, as in the paper)."""
        nid = self._node_of(name)
        if self._kinds[name] == "cohort":
            return nid
        return self._b.cohort_from_events(nid, name=name)

    def _lower_cohort(self, tree, name: str) -> int:
        """Lower a parsed ``CohortExpr`` onto ``cohort_op`` plan nodes.
        Post-order, left-to-right — for legacy flat expressions the node
        names ``name[1]``, ``name[2]``, ... match the old left-fold."""
        counter = [0]

        def lower(t) -> int:
            if isinstance(t, CohortRef):
                return self._cohort_node(t.name)
            left = lower(t.left)
            right = lower(t.right)
            counter[0] += 1
            return self._b.cohort_op(t.op, left, right,
                                     name=f"{name}[{counter[0]}]")

        return lower(tree)

    # -- plans ---------------------------------------------------------------
    def plan(self) -> Plan:
        """The raw (unoptimized) plan built so far.  Flatten outputs in
        automatic ``keep`` mode that an extractor chained onto are demoted
        from named outputs here — they stay the chaining point but stop
        pinning the full flat schema, which is what lets ``optimize()``
        prune unused dimension columns out of the join chain."""
        raw = self._b.build()
        drop = {nm for nm, keep in self._flatten_keep.items()
                if keep is False or (keep is None and nm in self._chained)}
        if drop:
            raw = Plan(raw.nodes, tuple((n, i) for n, i in raw.outputs
                                        if n not in drop))
        return raw

    def optimized_plan(self, tables: Optional[Dict[str, ColumnarTable]] = None,
                       n_shards: int = 1, predicate_engine: str = "auto",
                       engine: str = "xla") -> Plan:
        """Optimize the built plan.  ``tables`` (concrete run-time tables)
        lets the capacity planner size join outputs from table statistics —
        the planned capacities depend on table *content* (join-key
        distributions), which no shape fingerprint can capture, so that path
        re-plans on every call (reusing a stale exact capacity on
        differently-distributed data would silently truncate rows); the
        executor's jit cache still dedupes compilation whenever the planned
        capacities come out unchanged.  Plans with nothing to capacity-plan
        (no capacity-less expand_join/slice_time node) are content-independent
        and keep the cached path."""
        raw = self.plan()
        needs_stats = any(n.op in ("expand_join", "slice_time")
                          and n.get("capacity") is None for n in raw.nodes)
        if tables and needs_stats:
            return _optimizer.optimize(raw, tables=tables, n_shards=n_shards,
                                       predicate_engine=predicate_engine,
                                       engine=engine)
        key = (raw.key(), n_shards, predicate_engine, engine)
        if self._opt_cache is not None and self._opt_cache[0] == key:
            return self._opt_cache[1]
        opt = _optimizer.optimize(raw, n_shards=n_shards,
                                  predicate_engine=predicate_engine,
                                  engine=engine)
        self._opt_cache = (key, opt)
        return opt

    def check(self, tables: Optional[Dict[str, ColumnarTable]] = None,
              n_shards: int = 1, predicate_engine: str = "auto",
              engine: str = "xla", optimize: bool = True) -> List:
        """Statically verify the study's plan without executing it.

        Runs the abstract-interpretation analyzer (``study/analyze.py``)
        over the optimized plan (or the raw plan with ``optimize=False``)
        and returns the list of ``Diagnostic`` findings — schema errors,
        provably-empty predicates, misaligned capacities, engine-feasibility
        notes — each with a stable ``SPnnn`` code and a fix hint.  Bound
        sources (``Study.source``) and the ``tables`` argument ground scans
        in real schemas/dtypes, which is what enables the content-dependent
        checks; without them the structural checks still run.

        A clean bill of health is ``[]``; error-level findings are exactly
        what ``CohortQueryService`` rejects at admission time.
        """
        # member import, not `from repro.study import analyze`: the package
        # re-exports the analyze() function, shadowing the submodule
        from repro.study.analyze import analyze as _analyze_plan

        env = dict(self._sources)
        env.update(tables or {})
        plan = (self.optimized_plan(tables=env or None, n_shards=n_shards,
                                    predicate_engine=predicate_engine,
                                    engine=engine)
                if optimize else self.plan())
        return _analyze_plan(plan, tables=env or None, n_shards=n_shards,
                             n_patients=self.n_patients)

    # -- execution -----------------------------------------------------------
    def run(self, tables: Optional[Dict[str, ColumnarTable]] = None,
            engine: str = "xla", optimize: bool = True, jit: bool = True,
            log: Optional[OperationLog] = None, mesh=None,
            axis_name: str = "data",
            predicate_engine: Optional[str] = None) -> StudyResult:
        """Optimize, execute (optionally under ``shard_map`` on ``mesh``),
        realize cohorts/flow/features, and auto-log provenance.

        ``predicate_engine`` ("jnp" | "pallas" | "auto"/None) picks how
        predicate/fused_mask nodes evaluate: jnp mask algebra (packed back
        into the bitset validity at the boundary) or the Pallas Expr->bitset
        kernel, whose packed words become the table validity directly.
        "auto" follows the backend (and ``engine="pallas"``); the optimizer
        stamps the resolved choice — and the ``bitset_u32`` validity layout
        — on each node so the OperationLog records it.
        """
        env = dict(self._sources)
        env.update(tables or {})
        n_shards = mesh.shape[axis_name] if mesh is not None else 1
        plan = (self.optimized_plan(tables=env, n_shards=n_shards,
                                    predicate_engine=predicate_engine or "auto",
                                    engine=engine)
                if optimize else self.plan())
        log = log if log is not None else OperationLog()

        join_stats: Dict[int, Dict[str, int]] = {}
        if mesh is not None:
            from repro.distributed.pipeline import execute_plan_sharded

            vals, counts, join_stats = execute_plan_sharded(
                plan, env, self.n_patients, mesh, axis_name=axis_name,
                engine=engine, predicate_engine=predicate_engine)
            _executor.record_plan(plan, counts, log, engine,
                                  stats=join_stats,
                                  predicate_engine=predicate_engine)
        else:
            vals = _executor.execute(plan, env, n_patients=self.n_patients,
                                     engine=engine, log=log, jit=jit,
                                     stats_sink=join_stats,
                                     predicate_engine=predicate_engine)
        for i, d in join_stats.items():
            d.setdefault("stage", plan.nodes[i].label())
        return self._finish_result(plan, vals, join_stats, log)

    def run_chunked(self, store, tables: Optional[Dict[str, ColumnarTable]] = None,
                    engine: str = "xla", predicate_engine: Optional[str] = None,
                    checkpoint_dir: Optional[str] = None, prefetch: bool = True,
                    log: Optional[OperationLog] = None,
                    report_sink: Optional[Dict[str, Any]] = None,
                    **executor_kwargs: Any) -> StudyResult:
        """Execute this study out-of-core over a partitioned star
        (``data.chunkstore.ChunkStore``): the central table streams through
        the device chunk by chunk — ONE executor compile for all chunks —
        with chunk i+1's host load + device staging overlapping chunk i's
        execution, and results merged bit-identical to ``run()`` over the
        unpartitioned star.  ``checkpoint_dir`` enables the per-chunk
        journal: a killed run re-invoked with the same arguments resumes,
        executing only the chunks the journal does not record.  ``tables``
        supplies extra resident sources (the store's own ``resident/``
        dimension tables bind automatically).  ``report_sink`` (a dict)
        receives the run's timing/resume audit (``ChunkedReport`` fields).
        See ``study/chunked.py`` for merge semantics and the chunk-unsafe
        op guard."""
        from repro.study.chunked import ChunkedExecutor

        ex = ChunkedExecutor(store, engine=engine,
                             predicate_engine=predicate_engine,
                             checkpoint_dir=checkpoint_dir,
                             prefetch=prefetch, **executor_kwargs)
        result = ex.run(self, tables=tables, log=log)
        if report_sink is not None:
            report_sink.update(ex.report.to_json())
        return result

    def _finish_result(self, plan: Plan, vals: Dict[int, Any],
                       join_stats: Dict[int, Dict[str, int]],
                       log: OperationLog) -> StudyResult:
        """Realize a StudyResult from executed node values: events from named
        table outputs, cohorts by replaying the algebra on wrapped operands,
        then the host ops (flow/featurize).  ``vals`` must cover
        ``executor.keep_ids(plan)`` — exactly what ``execute`` (or the
        service's cached runner, after mapping canonical ids back) returns.
        Factored out of ``run`` so ``study.service`` produces bit-identical
        results through the same realization code."""
        nodes = plan.nodes
        out_ids = plan.output_ids
        events = {name: vals[i] for name, i in out_ids.items()
                  if nodes[i].op in TABLE_OPS and i in vals}

        # realize cohorts by replaying the algebra on wrapped operands — the
        # thin eager layer keeps description/window/event semantics identical
        # to the interactive Cohort API.  A node can carry several names when
        # two cohort expressions hash-cons to the same sub-plan (aliases), so
        # names are grouped, never inverted into an id-keyed dict.
        names_by_id: Dict[int, List[str]] = {}
        for name, i in out_ids.items():
            if nodes[i].op in COHORT_OPS:
                names_by_id.setdefault(i, []).append(name)
        cohort_names = {i: ns[0] for i, ns in names_by_id.items()}
        realized: Dict[int, Cohort] = {}

        def _realize(i: int) -> Cohort:
            if i in realized:
                return realized[i]
            node = nodes[i]
            if node.op == "cohort_from_events":
                nm = node.get("name")
                ev = vals.get(node.inputs[0])
                c = Cohort(name=nm, description=f"subjects with event {nm}",
                           subjects=vals[i], n_patients=self.n_patients,
                           events=ev, window=self._window)
            else:
                left = _realize(node.inputs[0])
                right = _realize(node.inputs[1])
                kind = node.get("kind")
                c = (left.intersection(right) if kind == "&"
                     else left.union(right) if kind == "|"
                     else left.difference(right))
            if i in cohort_names:
                c.name = cohort_names[i]
            realized[i] = c
            return c

        cohorts = {}
        for i, names in names_by_id.items():
            c = _realize(i)
            for name in names:
                cohorts[name] = (c if c.name == name
                                 else dataclasses.replace(c, name=name))

        flow = None
        if self._flow_names:
            fid = out_ids[_FLOW_OUT]
            flow = CohortFlow([_realize(j) for j in nodes[fid].inputs])
            prev = None
            for nm, stage in zip(self._flow_names, flow.steps):
                n = stage.subject_count()
                log.record(op=f"flow:{nm}",
                           inputs={} if prev is None else {"prev": _Count(prev)},
                           outputs={nm: _Count(n)}, params={})
                prev = n

        features: Dict[str, Any] = {}
        checks: Dict[str, Dict[str, int]] = {}
        for name in self._feature_names:
            fnode = nodes[out_ids[name]]
            cohort = _realize(fnode.inputs[0])
            pats = vals.get(fnode.inputs[1]) if len(fnode.inputs) > 1 else None
            from repro.core.feature_driver import FeatureDriver

            fd = FeatureDriver(cohort, pats)
            kwargs = {k: v for k, v in (fnode.get("kwargs") or ())}
            if fnode.get("kind") == "dense":
                features[name] = fd.dense_features(**kwargs)
            else:
                features[name] = fd.token_sequences(**kwargs)
            checks[name] = dict(fd.checks)
            log.record(op=f"featurize:{name}",
                       inputs={cohort.name: _Count(cohort.subject_count())},
                       outputs={name: _Count(checks[name].get(
                           "events_total", 0))},
                       params={"kind": fnode.get("kind")})

        return StudyResult(events=events, cohorts=cohorts, flow=flow,
                           features=features, log=log, plan=plan,
                           feature_checks=checks, flatten_stats=join_stats)


class _Count:
    """Adapter giving OperationLog.record a ``.count`` to introspect."""

    def __init__(self, c: int) -> None:
        self.count = c


def flow_rows_from_log(log: OperationLog) -> List[Dict[str, object]]:
    """Rebuild the CohortFlow flowchart rows from an OperationLog alone —
    the paper's promise that flowcharts come from metadata, not re-execution."""
    rows: List[Dict[str, object]] = []
    prev: Optional[int] = None
    for e in log.entries:
        if not e["op"].startswith("flow:"):
            continue
        stage = e["op"][len("flow:"):]
        n = next(iter(e["outputs"].values()))
        rows.append({"stage": stage, "subjects": n,
                     "removed": (prev - n) if prev is not None else 0})
        prev = n
    return rows


def column_audit_from_log(log: OperationLog) -> List[Dict[str, object]]:
    """Per-stage column audit from an OperationLog alone: which columns each
    executed plan node *read* (``required_columns``, stamped by the
    optimizer's pruning pass) and which a pruned scan *dropped*
    (``pruned_columns``) — the paper's data-flow flowchart extended from row
    counts to column sets."""
    rows: List[Dict[str, object]] = []
    for e in log.entries:
        if not e["op"].startswith("plan:"):
            continue
        p = e["params"]
        if "required_columns" not in p and "pruned_columns" not in p:
            continue
        rows.append({
            "stage": e["op"][len("plan:"):],
            "rows_out": next(iter(e["outputs"].values())),
            "required_columns": p.get("required_columns"),
            "pruned_columns": p.get("pruned_columns"),
        })
    return rows
