"""Plan executor: one jit-compiled XLA program per (plan, table spec, engine).

The executor walks a (usually optimizer-rewritten) ``Plan`` and evaluates each
node.  Everything array-valued — scans, masks, dedupe, event conformance,
compaction, cohort bitset algebra, registered transformers — runs inside a
single ``jax.jit`` body, so XLA fuses the shared-scan mask pipelines end to
end; host-side nodes (``featurize``, ``flow``) run after, on realized values.

jit caching: the traced closure is memoized on ``(plan structural key, engine,
n_patients)``; ``jax.jit`` then re-specializes per table spec (shapes/dtypes)
as usual, giving the "plan structure + table spec" cache key for free.

Provenance: the jitted body returns a per-node row/subject count alongside the
outputs, and ``execute`` appends one ``OperationLog`` entry per executed node
— no manual ``log.record`` calls in user code, and flowcharts reconstruct
from the log alone (see ``api.flow_rows_from_log``).
"""
from __future__ import annotations

import inspect
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitset as _bs
from repro.core import flattening as _fl
from repro.core import transformers as _tr
from repro.core.cohort import Bitset
from repro.core.columnar import ColumnarTable, is_null
from repro.core.events import make_events
from repro.core.metadata import OperationLog
from repro.kernels import predicate as _pk
from repro.study import expr as _expr
from repro.study.plan import (COHORT_OPS, PREDICATE_OPS, Plan, STATS_OPS,
                              TABLE_OPS)

__all__ = ["execute", "TRANSFORMS", "jit_cache_info", "clear_jit_cache",
           "cached_executable"]


# Registered transformer free functions usable from ``transform`` nodes.
# Values are (fn, wants_n_patients); params must stay hashable in the plan.
def _registry() -> Dict[str, Tuple[Callable, bool]]:
    fns = {}
    for name in ("observation_period", "follow_up", "trackloss", "exposures",
                 "fractures", "drug_prescriptions", "drug_interactions",
                 "bladder_cancer", "infarctus", "heart_failure"):
        fn = getattr(_tr, name)
        wants = "n_patients" in inspect.signature(fn).parameters
        fns[name] = (fn, wants)
    return fns


TRANSFORMS = _registry()

_JIT_CACHE: Dict[Tuple, Callable] = {}
_JIT_STATS: Dict[str, int] = {"compiles": 0, "hits": 0}
# the serving layer's realization worker may build/look up executables
# concurrently with the main thread (e.g. featurize replays) — one lock
# guards the cache dict and its counters
_JIT_LOCK = threading.Lock()


def jit_cache_info() -> Dict[str, int]:
    """Cache-surface audit: ``plans`` (live entries), ``compiles`` (traced
    closures built — the executable count the serving layer budgets), and
    ``hits`` (runner lookups served by an existing entry).  Counters reset
    with ``clear_jit_cache``."""
    with _JIT_LOCK:
        return {"plans": len(_JIT_CACHE), **_JIT_STATS}


def clear_jit_cache() -> None:
    with _JIT_LOCK:
        _JIT_CACHE.clear()
        _JIT_STATS["compiles"] = 0
        _JIT_STATS["hits"] = 0


def cached_executable(key: Tuple, build: Callable[[], Callable]) -> Callable:
    """THE process-wide compiled-executable cache: the local jitted runner,
    the sharded ``execute_plan_sharded`` path and the chunked executor all
    memoize through here, so ``jit_cache_info()`` audits every executable in
    the process (and the serving layer's compile budget covers all three
    physical strategies).  ``build`` runs once per distinct ``key``; later
    lookups count as hits."""
    with _JIT_LOCK:
        fn = _JIT_CACHE.get(key)
        if fn is None:
            _JIT_STATS["compiles"] += 1
            fn = _JIT_CACHE[key] = build()
        else:
            _JIT_STATS["hits"] += 1
        return fn


# ---------------------------------------------------------------------------
# node evaluation (traced)
# ---------------------------------------------------------------------------
def _compact_table(t: ColumnarTable, engine: str) -> ColumnarTable:
    if engine == "xla":
        return t.compact()
    if engine != "pallas":
        raise ValueError(f"unknown engine {engine!r}")
    from repro.kernels import ops as kops

    cols = {}
    count = None
    for name, col in t.columns.items():
        # packed keep-mask straight into the kernel (1 bit/row of HBM)
        out, cnt = kops.filter_compact(col, t.valid)
        cols[name] = out
        count = cnt if count is None else count
    count = count.astype(jnp.int32)
    return ColumnarTable(cols, _bs.first_n(count, t.capacity), count,
                         t.capacity)


def _stats_dict(fs) -> Dict[str, jax.Array]:
    return {k: getattr(fs, k) for k in _fl.STAT_FIELDS}


def _key_checksum(t: ColumnarTable, key: str) -> jax.Array:
    k = t.columns[key].astype(jnp.uint32)
    return jnp.where(t.valid_bool(), k, 0).sum(dtype=jnp.uint32)


def _eval_node(node, ins, env: Dict[str, ColumnarTable], n_patients: int,
               engine: str, axis_name: Optional[str] = None,
               n_shards: int = 1, predicate_engine: str = "jnp"):
    op = node.op
    if op in ("scan", "scan_star"):
        src = node.get("source")
        if src not in env:
            raise KeyError(f"plan scans source {src!r} but run() got "
                           f"{sorted(env)}")
        return env[src]
    if op == "lookup_join":
        out, fs = _fl.lookup_join(ins[0], ins[1], node.get("left_key"),
                                  node.get("right_key"),
                                  prefix=node.get("prefix") or "")
        return out, _stats_dict(fs)
    if op == "expand_join":
        cap = node.get("capacity")
        if cap is None:
            # trace-time fallback when the host-side capacity planner did not
            # run (e.g. optimize=False, or tables unknown at optimize time)
            cap = int((ins[0].capacity + ins[1].capacity)
                      * (node.get("slack") or 1.5))
        out, fs = _fl.expand_join(ins[0], ins[1], node.get("left_key"),
                                  node.get("right_key"), cap,
                                  prefix=node.get("prefix") or "")
        return out, _stats_dict(fs)
    if op == "exchange":
        t = ins[0]
        key = node.get("key")
        ksum_in = _key_checksum(t, key)
        zero = jnp.int32(0)
        if axis_name is None or n_shards <= 1:
            # off-mesh (or single shard): the shuffle is the identity
            return t, {"rows_in": t.count, "rows_out": t.count,
                       "matched": t.count, "overflow": zero,
                       "null_keys": zero, "key_sum_in": ksum_in,
                       "key_sum_out": ksum_in}
        per = node.get("per_dest_capacity")
        if per is None:
            per = max(int(node.get("min_per_dest") or 64),
                      int(t.capacity * (node.get("slack") or 2.0) / n_shards))
        out, overflow = _fl.exchange(t, key, axis_name, n_shards, per)
        return out, {"rows_in": t.count, "rows_out": out.count,
                     "matched": out.count, "overflow": overflow,
                     "null_keys": zero, "key_sum_in": ksum_in,
                     "key_sum_out": _key_checksum(out, key)}
    if op == "slice_time":
        t = ins[0]
        # the bounds are an Expr like any other predicate (col.between)
        out = t.filter(_expr.node_predicate(node).evaluate(t))
        n_sel = out.count
        ksum_in = _key_checksum(out, node.get("col"))
        cap = node.get("capacity")
        overflow = jnp.int32(0)
        if cap is not None and cap < t.capacity:
            out = _compact_table(out, engine).shrink_to(cap)
            overflow = jnp.maximum(n_sel - cap, 0).astype(jnp.int32)
        return out, {"rows_in": t.count, "rows_out": out.count,
                     "matched": n_sel, "overflow": overflow,
                     "null_keys": jnp.int32(0), "key_sum_in": ksum_in,
                     "key_sum_out": _key_checksum(out, node.get("col"))}
    if op == "key_count":
        # an eliminated (column-pruned) lookup_join: the value is the LEFT
        # table unchanged; the join's no-loss audit survives as a cheap
        # key-membership count over the (pruned-to-key) right side
        left, right = ins
        lk = left.columns[node.get("left_key")]
        lvb = left.valid_bool()
        l_null = is_null(lk) & lvb
        rk_col = right.columns[node.get("right_key")]
        rvb = right.valid_bool()
        r_null = is_null(rk_col) & rvb
        if right.capacity == 0:   # empty right: every key misses (lookup_join
            found = jnp.zeros((left.capacity,), bool)        # has this guard)
        else:
            r_ok = rvb & ~is_null(rk_col)
            rk = jnp.where(r_ok, rk_col, _fl._maxval(rk_col.dtype))
            order = jnp.argsort(rk)
            rs = rk[order]
            pos = jnp.searchsorted(rs, lk, side="left")
            posc = jnp.clip(pos, 0, right.capacity - 1)
            found = ((pos < right.capacity) & (rs[posc] == lk)
                     & r_ok[order][posc] & lvb & ~is_null(lk))
        ksum = jnp.where(lvb, lk.astype(jnp.uint32), 0).sum(dtype=jnp.uint32)
        zero = jnp.int32(0)
        return left, {"rows_in": left.count, "rows_out": left.count,
                      "matched": found.sum().astype(jnp.int32),
                      "overflow": zero,
                      "null_keys": (l_null.sum() + r_null.sum()).astype(jnp.int32),
                      "key_sum_in": ksum, "key_sum_out": ksum}
    if op == "select":
        return ins[0].select(list(node.get("cols")))
    if op in PREDICATE_OPS:
        # every predicate-ish op re-expresses as an Expr; a fused_mask's
        # accumulated conjuncts compile to ONE mask evaluation over the
        # projected columns (expr.fused_predicate).  The node's stamped
        # engine (``assign_engines``) — or the run-level predicate engine —
        # picks between jnp mask algebra and the Pallas Expr->bitset kernel.
        t = ins[0]
        e = _expr.node_predicate(node)
        if e is None:
            return t
        eng = node.get("engine") or predicate_engine
        param = e.to_param()
        if eng == "pallas" and _pk.compilable(param):
            # hoisted slot refs (normalized plans) become kernel operands:
            # the bound (lits, vecs) pair rides along explicitly — the
            # kernel module never reaches back into expr's binding stack
            words, cnt = _pk.predicate_bitset(
                t.columns, t.valid, expr_param=param,
                block=node.get("bitset_block") or _pk.DEFAULT_BLOCK,
                capacity=t.capacity,
                params=_expr.current_bound_params())
            # the kernel's packed words ARE the table's validity — no unpack
            # hop: they flow into cohort_from_events, the cohort bitset
            # algebra and the compaction keep-mask as 1 bit/row metadata
            return ColumnarTable(t.columns, words, cnt, t.capacity)
        mask = e.mask(t)
        return ColumnarTable(t.columns, mask, mask.sum().astype(jnp.int32))
    if op == "dedupe":
        from repro.core.extraction import dedupe_by

        return dedupe_by(ins[0], list(node.get("keys")))
    if op == "conform_events":
        t = ins[0]
        end_col, group_col, weight_col = (node.get("end_col"),
                                          node.get("group_col"),
                                          node.get("weight_col"))
        return make_events(
            patient_id=t.columns["patient_id"],
            category=node.get("category"),
            value=t.columns[node.get("value_col")],
            start=t.columns[node.get("start_col")],
            end=t.columns[end_col] if end_col else None,
            group_id=t.columns[group_col] if group_col else None,
            weight=t.columns[weight_col] if weight_col else None,
            valid=t.valid,
        )
    if op == "compact":
        return _compact_table(ins[0], node.get("engine") or engine)
    if op == "transform":
        fn, wants_np = TRANSFORMS[node.get("fn")]
        kwargs = {k: (list(v) if isinstance(v, tuple) else v)
                  for k, v in (node.get("kwargs") or ())}
        if wants_np:
            kwargs.setdefault("n_patients", n_patients)
        return fn(*ins, **kwargs)
    if op == "concat":
        return ColumnarTable.concat(list(ins))
    if op == "cohort_from_events":
        ev = ins[0]
        return Bitset.from_indices(ev.columns["patient_id"], ev.valid, n_patients)
    if op == "cohort_op":
        a, b = ins
        kind = node.get("kind")
        if engine == "pallas":
            # fused bitwise-op + popcount Pallas kernel (one HBM pass)
            from repro.kernels import ops as kops

            words, _ = kops.bitset_op(
                a, b, {"&": "and", "|": "or", "-": "andnot"}[kind])
            return words
        if kind == "&":
            return a & b
        if kind == "|":
            return a | b
        return a & ~b
    raise ValueError(f"unknown traced op {node.op!r}")


def _node_count(node, val) -> jax.Array:
    if node.op in COHORT_OPS:
        return Bitset.count(val)
    return val.count.astype(jnp.int32)


# ---------------------------------------------------------------------------
# plan-level execution
# ---------------------------------------------------------------------------
def traced_ids(plan: Plan) -> Tuple[int, ...]:
    return tuple(i for i, n in enumerate(plan.nodes)
                 if n.op in TABLE_OPS or n.op in COHORT_OPS)


def keep_ids(plan: Plan) -> Tuple[int, ...]:
    """Node values that must leave the jitted body: named outputs, base
    cohort bitsets, and the event tables cohorts were built from
    (Cohort.events).  Interior ``cohort_op`` bitsets stay internal — the
    Study layer replays the algebra on realized operands, so exporting them
    would be a dead device->host transfer per node.  Everything else stays
    internal so XLA fuses the mask pipelines instead of materializing each
    intermediate into an output buffer."""
    traced = set(traced_ids(plan))
    keep = {i for _, i in plan.outputs if i in traced}
    for i, n in enumerate(plan.nodes):
        if n.op == "cohort_from_events":
            keep.add(i)
            keep.update(j for j in n.inputs if j in traced)
    return tuple(sorted(keep))


def run_plan_body(plan: Plan, env: Dict[str, ColumnarTable], n_patients: int,
                  engine: str, axis_name: Optional[str] = None,
                  n_shards: int = 1, predicate_engine: Optional[str] = None):
    """Pure traced body: node id -> value for every array-valued node, plus
    per-node counts and per-join FlatteningStats dicts.  Reused verbatim by
    ``distributed.pipeline`` under ``shard_map`` (``axis_name``/``n_shards``
    make exchange nodes run real collectives there; off-mesh they are the
    identity).  ``predicate_engine`` is the fallback for predicate nodes the
    optimizer did not stamp (``"auto"``/None resolve by backend)."""
    peng = _pk.resolve_engine(predicate_engine, engine)
    vals: Dict[int, Any] = {}
    counts: Dict[int, jax.Array] = {}
    stats: Dict[int, Dict[str, jax.Array]] = {}
    for i in traced_ids(plan):
        node = plan.nodes[i]
        ins = [vals[j] for j in node.inputs]
        out = _eval_node(node, ins, env, n_patients, engine, axis_name,
                         n_shards, predicate_engine=peng)
        if node.op in STATS_OPS:
            out, stats[i] = out
        vals[i] = out
        counts[i] = _node_count(node, vals[i])
    return vals, counts, stats


def _jitted_runner(plan: Plan, n_patients: int, engine: str,
                   predicate_engine: Optional[str] = None,
                   params_sig: Optional[Tuple] = None) -> Callable:
    peng = _pk.resolve_engine(predicate_engine, engine)
    key = (plan.key(), n_patients, engine, peng, params_sig)

    def build():
        keep = keep_ids(plan)

        def run(env, lits=(), vecs=()):
            # hoisted-literal slots (normalized plans) read the traced
            # lits/vecs arguments; plans with baked literals ignore them
            with _expr.bound_params(lits, vecs):
                vals, counts, stats = run_plan_body(
                    plan, env, n_patients, engine, predicate_engine=peng)
            # counts leave as ONE stacked vector: a single host transfer for
            # provenance instead of one device sync per node.
            ids = tuple(sorted(counts))
            return ({i: vals[i] for i in keep},
                    jnp.stack([counts[i] for i in ids]),
                    stats)

        if params_sig is None:
            def body(env):
                return run(env)
        else:
            def body(env, lits, vecs):
                return run(env, lits, vecs)

        return jax.jit(body)

    return cached_executable(key, build)


def _host_stats(stats) -> Dict[int, Dict[str, int]]:
    return {i: {k: int(np.asarray(v)) for k, v in d.items()}
            for i, d in stats.items()}


def execute(plan: Plan, tables: Dict[str, ColumnarTable], n_patients: int = 0,
            engine: str = "xla", log: Optional[OperationLog] = None,
            jit: bool = True,
            stats_sink: Optional[Dict[int, Dict[str, int]]] = None,
            predicate_engine: Optional[str] = None,
            expr_params: Optional[Tuple[Tuple, Tuple]] = None
            ) -> Dict[int, Any]:
    """Evaluate every array-valued node of ``plan`` over ``tables``.

    Returns {node id: value} for the ``keep_ids`` subset — named outputs,
    cohort bitsets and their source event tables (intermediates never leave
    the compiled program).  Host ops (featurize/flow) are the Study layer's
    job — they need realized Cohort objects (see ``api.Study.run``).
    Per-join ``FlatteningStats`` are recorded into ``log`` automatically and,
    when ``stats_sink`` is given, copied into it as host ints keyed by node
    id.  ``predicate_engine`` ("jnp" | "pallas" | "auto"/None) picks how
    un-stamped predicate nodes evaluate — jnp mask algebra or the Pallas
    Expr->bitset kernel; nodes the optimizer stamped keep their engine.
    ``expr_params`` is the ``(lits, vecs)`` pair backing a *normalized*
    plan's hoisted-literal slots (see ``study.normalize``): the values enter
    the compiled program as traced arguments, so the jit cache keys only on
    their shape/dtype signature — same structure + different literals reuses
    one executable.
    """
    missing = [s for s in plan.sources() if s not in tables]
    if missing:
        raise KeyError(f"plan scans source(s) {missing} but run() only got "
                       f"{sorted(tables)}")
    env = {src: tables[src] for src in plan.sources()}
    if jit:
        if expr_params is None:
            fn, args = _jitted_runner(
                plan, n_patients, engine, predicate_engine), (env,)
        else:
            from repro.study.normalize import params_signature

            lits, vecs = expr_params
            fn = _jitted_runner(plan, n_patients, engine, predicate_engine,
                                params_sig=params_signature(lits, vecs))
            args = (env, tuple(lits), tuple(vecs))
        vals, counts_vec, stats = fn(*args)
        counts = dict(zip(traced_ids(plan),
                          (int(c) for c in np.asarray(counts_vec))))
    else:
        lits, vecs = expr_params or ((), ())
        with _expr.bound_params(lits, vecs):
            vals, counts_dev, stats = run_plan_body(
                plan, env, n_patients, engine,
                predicate_engine=predicate_engine)
        vals = {i: vals[i] for i in keep_ids(plan)}
        counts = {i: int(c) for i, c in counts_dev.items()}
    if log is not None or stats_sink is not None:
        # host conversion is one blocking transfer per stat scalar — only
        # pay it when someone consumes the stats
        host_stats = _host_stats(stats)
        if log is not None:
            record_plan(plan, counts, log, engine, stats=host_stats,
                        predicate_engine=predicate_engine)
        if stats_sink is not None:
            stats_sink.update(host_stats)
    return vals


def record_plan(plan: Plan, counts: Dict[int, int], log: OperationLog,
                engine: str,
                stats: Optional[Dict[int, Dict[str, int]]] = None,
                predicate_engine: Optional[str] = None) -> None:
    """One OperationLog entry per executed node — automatic provenance.
    ``counts``/``stats`` must already be host ints (see ``execute`` / the
    sharded path in ``distributed.pipeline``: counts cross as one stacked
    vector).  Join/exchange nodes carry their FlatteningStats fields
    (rows_in/out, matched, overflow, null_keys, key checksums) in the entry
    params — the paper's no-loss audit, for free on every flattened study.
    ``predicate_engine`` must match the executing call so un-stamped
    predicate nodes log the engine they actually ran (stamped nodes carry
    their own)."""
    peng = _pk.resolve_engine(predicate_engine, engine)
    out_names = {i: name for name, i in plan.outputs}
    host_counts = {i: int(c) for i, c in counts.items()}

    class _N:  # OperationLog.record introspects ``.count``
        def __init__(self, c):
            self.count = c

    for i, c in host_counts.items():
        node = plan.nodes[i]
        ins = {f"#{j}:{plan.nodes[j].label()}": _N(host_counts[j])
               for j in node.inputs if j in host_counts}
        label = out_names.get(i, node.label())
        params = {}
        for k, v in node.params:
            if k in ("required_columns", "pruned_columns", "cols"):
                params[k] = list(v)          # the column-audit story: record
            elif k == "expr":                # what each stage read, legibly
                params[k] = _expr.render_param(v)
            elif k == "exprs":
                params[k] = [_expr.render_param(e) for e in v]
            elif isinstance(v, (int, float, str, bool, type(None))):
                params[k] = v
            else:
                params[k] = len(v)
        if params.get("engine") is None:
            # nodes the optimizer stamped (predicate engine, explicit compact
            # engine) keep their own; un-stamped predicate nodes log what the
            # executor's fallback actually ran (mirroring _eval_node's
            # compilability check); everything else records the global engine
            if node.op in PREDICATE_OPS:
                e = _expr.node_predicate(node)
                params["engine"] = (
                    "pallas" if peng == "pallas" and e is not None
                    and _pk.compilable(e.to_param()) else "jnp")
            else:
                params["engine"] = engine
        if stats and i in stats:
            params.update(stats[i])
        log.record(op=f"plan:{node.op}:{label}", inputs=ins,
                   outputs={label: _N(c)}, params=params)
