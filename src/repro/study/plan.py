"""Plan IR: the lazy query representation behind ``repro.study``.

SCALPEL3's eager API runs one projection→mask→compaction pass per extractor,
so N extractors over DCIR cost N scans and N argsort compactions.  The Plan IR
defers everything: user code (the ``Study`` builder, retrofitted ``Extractor``
and ``Cohort`` wrappers) appends *nodes* to a ``PlanBuilder``; the optimizer
rewrites the node graph (shared scans, fused masks, deferred compaction); the
executor jit-compiles the whole plan into one XLA program.

Design notes:
  * Nodes are immutable value objects ``(op, inputs, params)`` — hashable, so
    the builder hash-conses (identical sub-plans share nodes) and the executor
    can key its jit cache on plan structure alone.
  * ``inputs`` are node ids (ints); the node list is append-only, so a built
    ``Plan``'s node tuple is always topologically ordered.
  * ``params`` are a frozen (sorted key/value tuple) mapping; lists/dicts are
    recursively frozen so any user-supplied config stays hashable.

Node vocabulary (executor semantics in ``executor.py``):
  scan(source)                      -> flat table from the run-time env
  scan_star(source, star)           -> raw star-schema table (pre-flattening)
  lookup_join(l, r, keys)           -> N:1 sorted-lookup left join
  expand_join(l, r, keys, capacity) -> 1:N offset-expansion left join
  exchange(t, key)                  -> hash-partition shuffle (identity off-mesh)
  slice_time(t, col, lo, hi)        -> temporal slice, bounded per-slice capacity
  select(cols)                      -> column projection       (metadata only)
  predicate(expr)                   -> typed Expr row filter   (mask algebra)
  drop_nulls(cols)                  -> null mask (sugar: emits a predicate)
  value_filter(col, codes)          -> whitelist mask (sugar: emits a predicate)
  fused_mask(null_cols,filters,exprs)-> optimizer-fused single predicate,
                                       evaluated by the stamped engine (jnp
                                       mask algebra | pallas bitset kernel)
  dedupe(keys)                      -> DISTINCT over keys (sort + run heads)
  conform_events(...)               -> Event-schema conformance
  compact()                         -> the one materialization per output
  key_count(l, r, keys)             -> eliminated pruned lookup_join: passes
                                       the left table through, keeps the
                                       join audit as a key-membership count
  cohort_from_events(name)          -> packed subject bitset from an event table
  cohort_op(kind ∈ {&,|,-})         -> bitset algebra over two cohorts
  transform(fn, kwargs)             -> registered List[Event]->List[Event] fn
  featurize(kind, kwargs)           -> FeatureDriver export (host-side)
  flow(names)                       -> CohortFlow fold over cohort nodes
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

__all__ = ["Node", "Plan", "PlanBuilder", "MASK_OPS", "TABLE_OPS", "COHORT_OPS",
           "JOIN_OPS", "STATS_OPS", "PREDICATE_OPS", "HOST_OPS", "OP_KINDS"]

# ops whose value is a ColumnarTable
TABLE_OPS = frozenset({
    "scan", "scan_star", "select", "predicate", "drop_nulls", "value_filter",
    "fused_mask", "dedupe", "conform_events", "compact", "transform", "concat",
    "lookup_join", "expand_join", "exchange", "slice_time", "key_count",
})
# flattening joins (left input 0, right input 1)
JOIN_OPS = frozenset({"lookup_join", "expand_join"})
# ops that emit FlatteningStats metadata alongside their table value
STATS_OPS = frozenset({"lookup_join", "expand_join", "exchange", "slice_time",
                       "key_count"})
# ops whose value is a packed subject bitset
COHORT_OPS = frozenset({"cohort_from_events", "cohort_op"})
# mask-only ops the optimizer may fuse into one vectorized predicate
# (drop_nulls/value_filter survive as raw op names for hand-built plans; the
# PlanBuilder sugar lowers both to typed ``predicate`` nodes)
MASK_OPS = frozenset({"predicate", "drop_nulls", "value_filter"})
# predicate-evaluating ops the executor routes through a predicate engine
# ("jnp" mask algebra or the "pallas" Expr->bitset kernel); the optimizer's
# ``assign_engines`` pass stamps each with its chosen engine + bitset layout
PREDICATE_OPS = MASK_OPS | frozenset({"fused_mask"})
# ops executed host-side, after the jitted portion
HOST_OPS = frozenset({"featurize", "flow"})

# op signatures: op -> (input kind spec, output kind).  The spec is a tuple of
# kind tokens matched positionally against the input nodes' output kinds;
# a trailing "*" means zero-or-more of that kind, a trailing "?" optional.
# ``study/analyze.py`` kind-checks plans against this table and
# ``tools/lint_invariants.py`` asserts it stays in sync with the op sets
# above — registering a new op in one place but not the other is a lint error.
OP_KINDS: Mapping[str, Tuple[Tuple[str, ...], str]] = {
    "scan": ((), "table"),
    "scan_star": ((), "table"),
    "select": (("table",), "table"),
    "predicate": (("table",), "table"),
    "drop_nulls": (("table",), "table"),
    "value_filter": (("table",), "table"),
    "fused_mask": (("table",), "table"),
    "dedupe": (("table",), "table"),
    "conform_events": (("table",), "table"),
    "compact": (("table",), "table"),
    "transform": (("table*",), "table"),
    "concat": (("table*",), "table"),
    "lookup_join": (("table", "table"), "table"),
    "expand_join": (("table", "table"), "table"),
    "exchange": (("table",), "table"),
    "slice_time": (("table",), "table"),
    "key_count": (("table", "table"), "table"),
    "cohort_from_events": (("table",), "cohort"),
    "cohort_op": (("cohort", "cohort"), "cohort"),
    "featurize": (("cohort", "table?"), "host"),
    "flow": (("cohort*",), "host"),
}


def _freeze(v: Any) -> Any:
    """Recursively convert params to hashable value objects."""
    if isinstance(v, (list, tuple)):
        return tuple(_freeze(x) for x in v)
    if isinstance(v, (set, frozenset)):
        return tuple(sorted(_freeze(x) for x in v))
    if isinstance(v, Mapping):
        return tuple(sorted((k, _freeze(x)) for k, x in v.items()))
    if isinstance(v, (str, bytes, int, float, bool, type(None))):
        return v
    raise TypeError(f"plan param of unhashable type {type(v).__name__}: {v!r}")


@dataclasses.dataclass(frozen=True)
class Node:
    """One IR operation: ``op`` applied to the values of ``inputs``."""

    op: str
    inputs: Tuple[int, ...]
    params: Tuple[Tuple[str, Any], ...]

    def get(self, key: str, default: Any = None) -> Any:
        for k, v in self.params:
            if k == key:
                return v
        return default

    def label(self) -> str:
        name = self.get("name")
        return f"{self.op}:{name}" if name else self.op


@dataclasses.dataclass(frozen=True)
class Plan:
    """An immutable, topologically-ordered node graph with named outputs."""

    nodes: Tuple[Node, ...]
    outputs: Tuple[Tuple[str, int], ...]

    # -- identity ------------------------------------------------------------
    def key(self) -> Tuple:
        """Structural identity — the jit-cache key component."""
        return (self.nodes, self.outputs)

    # -- introspection -------------------------------------------------------
    @property
    def output_ids(self) -> Dict[str, int]:
        return dict(self.outputs)

    def count_ops(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for n in self.nodes:
            out[n.op] = out.get(n.op, 0) + 1
        return out

    def consumers(self) -> Dict[int, List[int]]:
        cons: Dict[int, List[int]] = {i: [] for i in range(len(self.nodes))}
        for i, n in enumerate(self.nodes):
            for j in n.inputs:
                cons[j].append(i)
        return cons

    def sources(self) -> Tuple[str, ...]:
        return tuple(sorted({n.get("source") for n in self.nodes
                             if n.op in ("scan", "scan_star")}))

    def render(self) -> str:
        """Human-readable plan dump (debugging / notebooks)."""
        names = {i: name for name, i in self.outputs}
        lines = []
        for i, n in enumerate(self.nodes):
            params = ", ".join(f"{k}={v!r}" for k, v in n.params)
            tag = f"  -> {names[i]}" if i in names else ""
            ins = ",".join(str(j) for j in n.inputs)
            lines.append(f"[{i:3d}] {n.op}({ins}) {params}{tag}")
        return "\n".join(lines)


class PlanBuilder:
    """Append-only, hash-consing plan constructor."""

    def __init__(self) -> None:
        self._nodes: List[Node] = []
        self._cse: Dict[Node, int] = {}
        self._outputs: Dict[str, int] = {}

    # -- generic -------------------------------------------------------------
    def add(self, op: str, inputs: Sequence[int] = (), **params: Any) -> int:
        for j in inputs:
            if not (0 <= j < len(self._nodes)):
                raise ValueError(f"{op}: unknown input node {j}")
        node = Node(op, tuple(int(j) for j in inputs),
                    tuple(sorted((k, _freeze(v)) for k, v in params.items())))
        if node in self._cse:
            return self._cse[node]
        self._nodes.append(node)
        nid = len(self._nodes) - 1
        self._cse[node] = nid
        return nid

    def set_output(self, name: str, nid: int) -> int:
        self._outputs[name] = nid
        return nid

    def node(self, nid: int) -> Node:
        return self._nodes[nid]

    def build(self) -> Plan:
        return Plan(tuple(self._nodes), tuple(sorted(self._outputs.items())))

    # -- table ops -----------------------------------------------------------
    def scan(self, source: str) -> int:
        return self.add("scan", source=source)

    def scan_star(self, source: str, star: Optional[str] = None,
                  partitioned_on: Optional[str] = None,
                  columns: Optional[Sequence[str]] = None) -> int:
        """Scan a raw (normalized) star-schema table by name.  ``star`` tags
        the sub-database for plan introspection; ``partitioned_on`` declares a
        pre-existing hash partitioning (lets the optimizer prune exchanges);
        ``columns`` declares the table's schema, which is what lets the
        optimizer's column-pruning pass narrow the scan statically."""
        return self.add("scan_star", source=source, star=star,
                        partitioned_on=partitioned_on,
                        columns=None if columns is None else tuple(columns))

    def lookup_join(self, left: int, right: int, left_key: str,
                    right_key: str, prefix: str = "") -> int:
        """N:1 sorted-lookup left join (``core.flattening.lookup_join``)."""
        return self.add("lookup_join", (left, right), left_key=left_key,
                        right_key=right_key, prefix=prefix,
                        name=f"[{left_key}]")

    def expand_join(self, left: int, right: int, left_key: str,
                    right_key: str, capacity: Optional[int] = None,
                    slack: float = 1.5, prefix: str = "") -> int:
        """1:N offset-expansion left join.  ``capacity`` bounds the static
        output size; ``None`` defers it to the optimizer's capacity planner
        (or, failing that, a trace-time ``(L+R)*slack`` heuristic)."""
        return self.add("expand_join", (left, right), left_key=left_key,
                        right_key=right_key, prefix=prefix,
                        capacity=None if capacity is None else int(capacity),
                        slack=float(slack), name=f"[{left_key}]")

    def exchange(self, t: int, key: str,
                 per_dest_capacity: Optional[int] = None, slack: float = 2.0,
                 min_per_dest: int = 64) -> int:
        """Hash-partition shuffle on ``key``.  Identity when executed off-mesh
        (n_shards == 1); under ``shard_map`` it is the Spark exchange."""
        return self.add(
            "exchange", (t,), key=key, slack=float(slack),
            min_per_dest=int(min_per_dest),
            per_dest_capacity=(None if per_dest_capacity is None
                               else int(per_dest_capacity)),
            name=f"[{key}]")

    def slice_time(self, t: int, col: str, lo: int, hi: int,
                   capacity: Optional[int] = None) -> int:
        """Rows with ``lo <= col < hi``, compacted to ``capacity`` rows when
        given (the capacity planner sets it from the slice's actual count)."""
        return self.add("slice_time", (t,), col=col, lo=int(lo), hi=int(hi),
                        capacity=None if capacity is None else int(capacity),
                        name=f"[{lo},{hi})")

    def select(self, t: int, cols: Sequence[str]) -> int:
        return self.add("select", (t,), cols=tuple(sorted(set(cols))))

    def predicate(self, t: int, expr: Any, label: Optional[str] = None) -> int:
        """Typed row filter: ``expr`` is an ``expr.Expr`` (or its serialized
        param form), evaluated as one vectorized mask over the table."""
        from repro.study.expr import as_param

        return self.add("predicate", (t,), expr=as_param(expr), name=label)

    def drop_nulls(self, t: int, cols: Sequence[str]) -> int:
        """Null filter — sugar for a conjunction-of-``not_null`` predicate."""
        from repro.study.expr import all_of, col as _col

        return self.predicate(t, all_of(*[_col(c).not_null() for c in cols]),
                              label="drop_nulls")

    def value_filter(self, t: int, col: str, codes: Sequence[int]) -> int:
        """Whitelist filter — sugar for an ``isin`` predicate."""
        from repro.study.expr import col as _col

        return self.predicate(t, _col(col).isin(int(c) for c in codes),
                              label="value_filter")

    def key_count(self, left: int, right: int, left_key: str,
                  right_key: str) -> int:
        """Audit-only remnant of an eliminated N:1 join: the node's value is
        the left table unchanged; its FlatteningStats record a cheap
        key-membership count against the right side (see the optimizer's
        ``eliminate_joins``)."""
        return self.add("key_count", (left, right), left_key=left_key,
                        right_key=right_key, name=f"[{left_key}]")

    def dedupe(self, t: int, keys: Sequence[str]) -> int:
        return self.add("dedupe", (t,), keys=tuple(keys))

    def conform_events(self, t: int, name: str, category: int, value_col: str,
                       start_col: str, end_col: Optional[str] = None,
                       group_col: Optional[str] = None,
                       weight_col: Optional[str] = None) -> int:
        return self.add("conform_events", (t,), name=name, category=int(category),
                        value_col=value_col, start_col=start_col, end_col=end_col,
                        group_col=group_col, weight_col=weight_col)

    def compact(self, t: int, engine: Optional[str] = None) -> int:
        return self.add("compact", (t,), engine=engine)

    def transform(self, fn: str, inputs: Sequence[int], name: Optional[str] = None,
                  **kwargs: Any) -> int:
        return self.add("transform", tuple(inputs), fn=fn,
                        name=name or fn, kwargs=kwargs)

    def concat(self, tables: Sequence[int], name: str = "concat") -> int:
        return self.add("concat", tuple(tables), name=name)

    # -- cohort ops ----------------------------------------------------------
    def cohort_from_events(self, events: int, name: str) -> int:
        return self.add("cohort_from_events", (events,), name=name)

    def cohort_op(self, kind: str, left: int, right: int, name: str) -> int:
        if kind not in ("&", "|", "-"):
            raise ValueError(f"cohort_op kind must be one of & | -, got {kind!r}")
        return self.add("cohort_op", (left, right), kind=kind, name=name)

    # -- host ops ------------------------------------------------------------
    def featurize(self, cohort: int, name: str, kind: str = "dense",
                  patients: Optional[int] = None, **kwargs: Any) -> int:
        ins = (cohort,) if patients is None else (cohort, patients)
        return self.add("featurize", ins, name=name, kind=kind, kwargs=kwargs)

    def flow(self, cohorts: Sequence[int], name: str = "flow") -> int:
        return self.add("flow", tuple(cohorts), name=name)
