"""Out-of-core chunked plan execution — streaming a ``ChunkStore`` through
the resident executor with double-buffered prefetch and resumable
checkpoints.

The paper's headline run (15e9 events, ~15 TB, 49 minutes) cannot be
device-resident; this module is the physical strategy that retargets an
unchanged logical Study plan onto a partitioned star (PolyFrame's
one-logical-plan / many-physical-plans seam, Conquery's partitioned-storage
scan).  The pieces:

* **One executable for all chunks.**  Every chunk has the same fixed
  capacity, so per-chunk tables are pytree-identical in shape/dtype and the
  executor's jit cache serves chunk 2..N from the chunk-1 compile.  Plans
  whose join capacities are content-dependent are capacity-planned per
  chunk and the stamped capacities merged to the elementwise max
  (``_merge_capacity_plans``) — one conservative executable instead of one
  compile per chunk.
* **Double-buffered prefetch.**  A one-worker thread pool loads chunk i+1
  from disk (mmap/decompress, the GIL-released part) and stages it onto the
  device while the jitted program for chunk i runs — the classic
  load/execute overlap; measured and gated by ``benchmarks/chunked_bench``.
* **Exact merge.**  Chunk-dependent table outputs concatenate in chunk
  order (row-local plan ops preserve per-chunk row order, so the valid rows
  of the concat ARE the resident path's valid rows, in order); cohort
  bitsets OR together (has-any-event membership is a union over the
  patient's chunks); FlatteningStats fields sum (uint32 key checksums are
  modular); chunk-independent branches (resident dimension lineage) are
  taken from one chunk instead of summed N times; interior cohort-algebra
  counts are replayed host-side over the merged words so provenance is
  exact, not a sum of per-chunk popcounts.  Plan-level ``concat`` outputs
  get a *branch-aware* merge: the resident path emits [branch1; branch2]
  while each chunk emits its own [branch1_ci; branch2_ci], so naive
  chunk-order concatenation would interleave the branches — instead each
  chunk's concat table is sliced back into its branch windows (boundaries
  read off ``jax.eval_shape`` of the plan body; capacities are 32-row
  aligned so validity slices word-wise) and reassembled branch-major.
* **Checkpoint journal.**  With ``checkpoint_dir`` set, each completed
  chunk spills its kept values via ``data/io.py`` and appends a journal
  line (fsync'd); a killed run re-opens the journal, verifies the plan/
  store stamp, loads the spilled partial state and executes only the
  remaining chunks (see ``tests/test_chunked.py`` kill-and-resume battery).

Soundness guard: ``transform`` (per-patient folds) and ``dedupe`` nodes
downstream of the chunked scan see only one chunk's rows at a time — a
patient's events may span chunks, so per-chunk evaluation + concat is NOT
the resident semantics.  Such plans are rejected with a clear error
(``allow_unsafe=True`` opts out, documented as approximate).  The static
analyzer additionally rejects misaligned chunk capacities (SP015) before
any IO happens.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.columnar import ColumnarTable
from repro.core.metadata import OperationLog
from repro.data.chunkstore import ChunkStore
from repro.data.io import load_columnar_arrays, save_columnar_arrays
from repro.study import executor as _executor
from repro.study import optimizer as _optimizer
from repro.study.plan import Node, Plan

__all__ = ["ChunkedExecutor", "ChunkedReport", "chunk_dependent_ids",
           "chunk_unsafe_ops"]

JOURNAL_NAME = "journal.jsonl"

# ops whose per-chunk evaluation differs from whole-table evaluation when a
# patient's rows span a chunk boundary (cross-row folds / cross-row dedupe)
CHUNK_UNSAFE_OPS = ("transform", "dedupe")


def _fsync_dir(path: str) -> None:
    """Durably record directory entries (the renamed meta.json) — best
    effort on platforms whose directories cannot be opened for fsync."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def chunk_dependent_ids(plan: Plan, source: str) -> Set[int]:
    """Node ids whose value depends on the chunked ``source`` — everything
    reachable from its scans.  Complement = resident lineage (dimension
    branches), computed once and merged by reference, not summed N times."""
    dep: Set[int] = set()
    for i, n in enumerate(plan.nodes):
        if n.op in ("scan", "scan_star") and n.get("source") == source:
            dep.add(i)
        elif any(j in dep for j in n.inputs):
            dep.add(i)
    return dep


def chunk_unsafe_ops(plan: Plan, source: str) -> List[Tuple[int, str]]:
    """(node id, op) for every chunk-unsafe op downstream of the chunked
    scan (see module docstring)."""
    dep = chunk_dependent_ids(plan, source)
    return [(i, plan.nodes[i].op) for i in sorted(dep)
            if plan.nodes[i].op in CHUNK_UNSAFE_OPS]


def _unwrap_compacted_concats(plan: Plan, dep: Set[int]) -> Plan:
    """Retarget named outputs that are compact wrappers over chunk-dependent
    concats at the concat node itself.  Each chunk's compact squeezes ITS
    OWN branch rows together, so the dense layout's branch boundaries are
    dynamic and the merge could not slice branches back apart; the raw
    concat's branch windows are static (trace-time capacities) and its
    valid-row contents are identical — compaction only drops padding."""
    new_out = []
    changed = False
    for name, nid in plan.outputs:
        tgt = nid
        while plan.nodes[tgt].op == "compact":
            tgt = plan.nodes[tgt].inputs[0]
        if (tgt != nid and tgt in dep and plan.nodes[tgt].op == "concat"
                and len(plan.nodes[tgt].inputs) > 1):
            new_out.append((name, tgt))
            changed = True
        else:
            new_out.append((name, nid))
    return dataclasses.replace(plan, outputs=tuple(new_out)) if changed \
        else plan


def _concat_probe_ids(plan: Plan, nid: int, dep: Set[int]) -> Set[int]:
    """Node ids whose padded row counts the branch-aware concat merge needs:
    every input reachable through nested chunk-dependent concats."""
    out: Set[int] = set()
    stack = [nid]
    while stack:
        for k in plan.nodes[stack.pop()].inputs:
            out.add(k)
            if plan.nodes[k].op == "concat" and k in dep:
                stack.append(k)
    return out


def _padded_rows(plan: Plan, env: Dict[str, ColumnarTable], n_patients: int,
                 engine: str, predicate_engine: Optional[str],
                 nids: List[int]) -> Dict[int, int]:
    """Padded (capacity) row counts of table nodes ``nids`` under the
    per-chunk env — shapes only, via ``jax.eval_shape``: no FLOPs, no
    transfers, and identical for every chunk (one executable ⇒ pytree-
    identical shapes)."""
    def body(e):
        vals, _, _ = _executor.run_plan_body(
            plan, e, n_patients, engine, predicate_engine=predicate_engine)
        return {i: vals[i].valid for i in nids}
    words = jax.eval_shape(body, env)
    return {i: int(w.shape[0]) * 32 for i, w in words.items()}


def _concat_windows(plan: Plan, nid: int, dep: Set[int],
                    rows_of: Dict[int, int], off: int = 0
                    ) -> List[Tuple[int, int, int]]:
    """Resident-ordered ``(node, start, stop)`` padded-row windows of a
    concat node's branches inside its per-chunk output table, recursing
    through nested chunk-dependent concats so a concat-of-concats flattens
    to the same leaf order the resident path materializes."""
    out: List[Tuple[int, int, int]] = []
    for k in plan.nodes[nid].inputs:
        if plan.nodes[k].op == "concat" and k in dep:
            out.extend(_concat_windows(plan, k, dep, rows_of, off))
        else:
            out.append((k, off, off + rows_of[k]))
        off += rows_of[k]
    return out


def _slice_rows(t: ColumnarTable, a: int, b: int) -> ColumnarTable:
    """Padded-row window [a, b) of a table.  Capacities are 32-row aligned
    end to end, so the validity bitset slices word-wise — no repacking."""
    if a % 32 or b % 32:
        raise RuntimeError(
            f"concat branch window [{a}, {b}) is not 32-row aligned")
    cols = {c: v[a:b] for c, v in t.columns.items()}
    return ColumnarTable.from_columns(cols, valid=t.valid[a // 32: b // 32])


def _merge_capacity_plans(plans: List[Plan]) -> Plan:
    """Merge per-chunk capacity-planned plans into one: identical structure
    required; ``capacity``/``per_dest_capacity`` params take the max across
    chunks so ONE executable holds every chunk's rows."""
    base = plans[0]
    if any(p.outputs != base.outputs or len(p.nodes) != len(base.nodes)
           for p in plans[1:]):
        raise ValueError("per-chunk optimized plans diverged structurally; "
                         "cannot share one executable")
    nodes = []
    for idx, n0 in enumerate(base.nodes):
        variants = [p.nodes[idx] for p in plans]
        if all(v == n0 for v in variants[1:]):
            nodes.append(n0)
            continue
        keys = [k for k, _ in n0.params]
        if any(v.op != n0.op or v.inputs != n0.inputs
               or [k for k, _ in v.params] != keys for v in variants[1:]):
            raise ValueError(f"per-chunk plans diverged at node {idx} "
                             f"({n0.op}) beyond planned capacities")
        params = []
        for k in keys:
            vals = [v.get(k) for v in variants]
            if all(v == vals[0] for v in vals[1:]):
                params.append((k, vals[0]))
            elif k in ("capacity", "per_dest_capacity") and all(
                    isinstance(v, int) for v in vals):
                params.append((k, max(vals)))
            else:
                raise ValueError(f"per-chunk plans disagree on param {k!r} "
                                 f"of node {idx} ({n0.op}); only planned "
                                 "capacities may vary across chunks")
        nodes.append(Node(n0.op, n0.inputs, tuple(params)))
    return Plan(tuple(nodes), base.outputs)


def _sum_stats(acc: Dict[str, int], d: Dict[str, int]) -> Dict[str, int]:
    out = dict(acc)
    for k, v in d.items():
        s = out.get(k, 0) + int(v)
        if k.startswith("key_sum"):
            s &= 0xFFFFFFFF          # uint32 modular checksum
        out[k] = s
    return out


def _replay_cohort_counts(plan: Plan, base_bits: Dict[int, np.ndarray]
                          ) -> Dict[int, int]:
    """Exact merged counts for EVERY cohort node: replay the bitset algebra
    host-side over the merged base words (summing per-chunk popcounts of an
    intersection would overcount patients present in several chunks)."""
    words: Dict[int, np.ndarray] = {}
    counts: Dict[int, int] = {}
    for i, n in enumerate(plan.nodes):
        if n.op == "cohort_from_events":
            words[i] = base_bits[i]
        elif n.op == "cohort_op":
            a, b = (words[j] for j in n.inputs)
            kind = n.get("kind")
            words[i] = (a & b if kind == "&" else
                        a | b if kind == "|" else a & ~b)
        else:
            continue
        counts[i] = int(np.bitwise_count(words[i]).sum())
    return counts


@dataclasses.dataclass
class ChunkedReport:
    """Timing/audit facts of one chunked run (the bench gate's evidence)."""

    n_chunks: int = 0
    executed: int = 0                # chunks run in this process
    resumed: int = 0                 # chunks restored from the journal
    compiles: int = 0                # executor compiles during the run (==1)
    load_s: float = 0.0              # sum of host load + device staging
    exec_s: float = 0.0              # sum of on-device execution
    wall_s: float = 0.0              # pipelined wall clock of the loop
    rows: int = 0                    # valid rows streamed

    @property
    def serial_s(self) -> float:
        """What a load-then-execute loop would have cost (no overlap)."""
        return self.load_s + self.exec_s

    @property
    def overlap_saved_s(self) -> float:
        return max(0.0, self.serial_s - self.wall_s)

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["serial_s"] = self.serial_s
        d["overlap_saved_s"] = self.overlap_saved_s
        return d


class _InjectedCrash(RuntimeError):
    """Raised by the ``crash_after`` test/ops hook — simulates preemption
    mid-extraction after N chunks committed to the journal."""


class ChunkedExecutor:
    """Drives one Study over a ``ChunkStore`` (see module docstring).

    ``checkpoint_dir`` enables the resumable journal; ``prefetch=False``
    degrades to serial load-then-execute (the bench baseline);
    ``crash_after=k`` kills the run after k chunks committed (tests)."""

    def __init__(self, store: ChunkStore, engine: str = "xla",
                 predicate_engine: Optional[str] = None,
                 checkpoint_dir: Optional[str] = None, prefetch: bool = True,
                 allow_unsafe: bool = False,
                 crash_after: Optional[int] = None) -> None:
        self.store = store
        self.engine = engine
        self.predicate_engine = predicate_engine
        self.checkpoint_dir = checkpoint_dir
        self.prefetch = bool(prefetch)
        self.allow_unsafe = bool(allow_unsafe)
        self.crash_after = crash_after
        self.report = ChunkedReport()

    # -- planning ------------------------------------------------------------
    def _resident_env(self, study, tables) -> Dict[str, ColumnarTable]:
        env = self.store.resident_tables()
        env.update(study._sources)
        env.update(tables or {})
        return env

    def _chunk_env(self, resident: Dict[str, ColumnarTable],
                   chunk: ColumnarTable) -> Dict[str, ColumnarTable]:
        env = dict(resident)
        env[self.store.source] = chunk
        return env

    def _plan(self, study, resident: Dict[str, ColumnarTable]) -> Plan:
        raw = study.plan()
        needs_stats = any(n.op in ("expand_join", "slice_time")
                          and n.get("capacity") is None for n in raw.nodes)
        peng = self.predicate_engine or "auto"
        if not needs_stats:
            return study.optimized_plan(tables=None, n_shards=1,
                                        predicate_engine=peng,
                                        engine=self.engine)
        # content-dependent capacities: plan each chunk exactly, then take
        # the elementwise max so one executable serves every chunk
        plans = []
        for ci in range(self.store.n_chunks):
            env = self._chunk_env(resident, self.store.chunk_table(ci))
            plans.append(_optimizer.optimize(
                raw, tables=env, n_shards=1, predicate_engine=peng,
                engine=self.engine))
        return _merge_capacity_plans(plans)

    def _preflight(self, study, plan: Plan,
                   env0: Dict[str, ColumnarTable]) -> None:
        from repro.study.analyze import PlanValidationError, analyze, errors

        diags = analyze(plan, tables=env0, n_shards=1,
                        n_patients=study.n_patients,
                        chunk_capacity=self.store.chunk_capacity)
        if errors(diags):
            raise PlanValidationError(diags)
        unsafe = chunk_unsafe_ops(plan, self.store.source)
        if unsafe and not self.allow_unsafe:
            ops = ", ".join(f"#{i}:{op}" for i, op in unsafe)
            raise ValueError(
                f"plan has chunk-unsafe ops downstream of the chunked scan "
                f"({ops}): per-patient folds/dedupe see one chunk at a time, "
                "so chunked results would differ from the resident path when "
                "a patient's rows span chunks.  Run resident, or pass "
                "allow_unsafe=True to accept approximate semantics")

    # -- checkpoint journal --------------------------------------------------
    def _stamp(self, plan: Plan, n_patients: int) -> str:
        blob = repr((plan.key(), self.engine, self.predicate_engine,
                     int(n_patients),
                     self.store.fingerprint())).encode()
        return hashlib.sha256(blob).hexdigest()

    def _journal_path(self) -> str:
        return os.path.join(self.checkpoint_dir, JOURNAL_NAME)

    def _spill_dir(self, ci: int) -> str:
        return os.path.join(self.checkpoint_dir, "spill", f"chunk_{ci:05d}")

    def _read_journal(self, stamp: str) -> Set[int]:
        """Completed chunk ids from a valid journal; a stamp mismatch (other
        plan/store/engine) discards the journal rather than mixing state.

        Parsed line by line: a kill mid-append leaves a torn final line, and
        that must cost exactly the one uncommitted chunk — not every chunk
        before it.  Parsing stops at the first undecodable line; everything
        already read stays resumable (the append-only protocol guarantees
        all prior lines are complete).  The valid prefix length is kept in
        ``_journal_keep_bytes`` so ``_start_journal`` can truncate the torn
        tail before new lines append onto it."""
        path = self._journal_path()
        self._journal_keep_bytes = None
        if not os.path.exists(path):
            return set()
        lines = []
        keep = 0
        try:
            with open(path, "rb") as f:
                for raw in f:
                    if not raw.endswith(b"\n"):
                        break            # unterminated tail: treat as torn
                    ln = raw.decode("utf-8", errors="replace")
                    if not ln.strip():
                        keep += len(raw)
                        continue
                    try:
                        lines.append(json.loads(ln))
                    except json.JSONDecodeError:
                        break            # torn tail: keep the valid prefix
                    keep += len(raw)
            self._journal_keep_bytes = keep
        except OSError:
            return set()
        if not lines or lines[0].get("kind") != "header" \
                or lines[0].get("stamp") != stamp:
            return set()
        done: Set[int] = set()
        for ln in lines[1:]:
            if ln.get("kind") == "chunk":
                done.add(int(ln["index"]))
        return done

    def _start_journal(self, stamp: str, resumed: Set[int]) -> None:
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        path = self._journal_path()
        if resumed:
            # keep appending to the valid journal — after cutting off any
            # torn tail, or the next append would concatenate onto it and
            # corrupt a good record
            keep = getattr(self, "_journal_keep_bytes", None)
            if keep is not None and keep < os.path.getsize(path):
                with open(path, "r+b") as f:
                    f.truncate(keep)
                    f.flush()
                    os.fsync(f.fileno())
            return
        with open(path, "w") as f:
            f.write(json.dumps({"kind": "header", "stamp": stamp,
                                "n_chunks": self.store.n_chunks}) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def _commit_chunk(self, ci: int, vals: Dict[int, Any],
                      counts: Dict[int, int],
                      stats: Dict[int, Dict[str, int]], plan: Plan) -> None:
        """Spill chunk ci's kept values, then append+fsync the journal line.
        The line is written only after the spill completes, so a kill at any
        point leaves either a resumable chunk or a re-executable one."""
        sd = self._spill_dir(ci)
        os.makedirs(sd, exist_ok=True)
        table_ids = []
        for nid, v in vals.items():
            if isinstance(v, ColumnarTable):
                save_columnar_arrays(
                    {k: np.asarray(c) for k, c in v.columns.items()},
                    np.asarray(v.valid), os.path.join(sd, f"table_{nid}"),
                    compressed=False)
                table_ids.append(nid)
        bits = {str(nid): np.asarray(v) for nid, v in vals.items()
                if not isinstance(v, ColumnarTable)}
        np.savez(os.path.join(sd, "bits"), **bits)
        meta = {"counts": {str(k): int(v) for k, v in counts.items()},
                "stats": {str(k): {kk: int(vv) for kk, vv in d.items()}
                          for k, d in stats.items()},
                "tables": table_ids}
        tmp = os.path.join(sd, "meta.json.tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(sd, "meta.json"))
        # the rename itself must be durable before the journal line commits
        # the chunk, or a crash could journal a chunk whose meta.json the
        # directory never learned about
        _fsync_dir(sd)
        with open(self._journal_path(), "a") as f:
            f.write(json.dumps({"kind": "chunk", "index": ci}) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def _load_spill(self, ci: int) -> Tuple[Dict[int, Any], Dict[int, int],
                                            Dict[int, Dict[str, int]]]:
        sd = self._spill_dir(ci)
        with open(os.path.join(sd, "meta.json")) as f:
            meta = json.load(f)
        vals: Dict[int, Any] = {}
        for nid in meta["tables"]:
            cols, valid = load_columnar_arrays(
                os.path.join(sd, f"table_{nid}"))
            vals[int(nid)] = ColumnarTable.from_columns(cols, valid=valid)
        with np.load(os.path.join(sd, "bits.npz")) as z:
            for k in z.files:
                vals[int(k)] = z[k]
        counts = {int(k): int(v) for k, v in meta["counts"].items()}
        stats = {int(k): dict(d) for k, d in meta["stats"].items()}
        return vals, counts, stats

    # -- the run -------------------------------------------------------------
    def run(self, study, tables: Optional[Dict[str, ColumnarTable]] = None,
            log: Optional[OperationLog] = None):
        """Execute ``study`` over the store; returns its ``StudyResult``
        (bit-identical valid rows / cohort words / features to
        ``Study.run`` over the unpartitioned star).  ``self.report`` holds
        the timing + resume audit afterwards."""
        store = self.store
        store.validate()
        resident = self._resident_env(study, tables)
        plan = self._plan(study, resident)
        dep = chunk_dependent_ids(plan, store.source)
        plan = _unwrap_compacted_concats(plan, dep)
        chunk0 = store.chunk_table(0)
        self._preflight(study, plan, self._chunk_env(resident, chunk0))

        keep = _executor.keep_ids(plan)
        cohort_keep = [i for i in keep
                       if plan.nodes[i].op in ("cohort_from_events",
                                               "cohort_op")]
        log = log if log is not None else OperationLog()
        rep = self.report = ChunkedReport(n_chunks=store.n_chunks)
        compiles0 = _executor.jit_cache_info()["compiles"]

        stamp = self._stamp(plan, study.n_patients)
        done: Set[int] = set()
        if self.checkpoint_dir is not None:
            done = self._read_journal(stamp)
            self._start_journal(stamp, done)

        # merge state
        dep_tables: Dict[int, Dict[int, ColumnarTable]] = {}  # nid -> ci -> t
        indep_vals: Dict[int, Any] = {}
        bits_acc: Dict[int, np.ndarray] = {}
        counts_dep: Dict[int, int] = {}
        counts_indep: Dict[int, int] = {}
        stats_dep: Dict[int, Dict[str, int]] = {}
        stats_indep: Dict[int, Dict[str, int]] = {}

        def merge(ci: int, vals: Dict[int, Any], counts: Dict[int, int],
                  stats: Dict[int, Dict[str, int]]) -> None:
            for nid, v in vals.items():
                if nid in cohort_keep or not isinstance(v, ColumnarTable):
                    w = np.asarray(v)
                    if nid in bits_acc:
                        bits_acc[nid] = bits_acc[nid] | w
                    else:
                        bits_acc[nid] = w
                elif nid in dep:
                    dep_tables.setdefault(nid, {})[ci] = v
                    rep.rows += int(counts.get(nid, 0))
                elif nid not in indep_vals:
                    indep_vals[nid] = v
            for nid, c in counts.items():
                if nid in dep:
                    counts_dep[nid] = counts_dep.get(nid, 0) + int(c)
                elif nid not in counts_indep:
                    counts_indep[nid] = int(c)
            for nid, d in stats.items():
                if nid in dep:
                    stats_dep[nid] = _sum_stats(stats_dep.get(nid, {}), d)
                elif nid not in stats_indep:
                    stats_indep[nid] = {k: int(v) for k, v in d.items()}

        for ci in sorted(done):
            vals, counts, stats = self._load_spill(ci)
            merge(ci, vals, counts, stats)
            rep.resumed += 1
            log.record(op=f"chunked:resume:{ci}", inputs={}, outputs={},
                       params={"chunk": ci, "rows":
                               store.manifest.chunks[ci].rows})

        todo = [ci for ci in range(store.n_chunks) if ci not in done]

        def _load(ci: int) -> Tuple[ColumnarTable, float]:
            t0 = time.perf_counter()
            # chunk 0 was already loaded for planning/preflight — reuse it
            t = chunk0 if ci == 0 else store.chunk_table(ci)
            jax.block_until_ready(t.valid)   # staging done, not just enqueued
            return t, time.perf_counter() - t0

        pool = ThreadPoolExecutor(max_workers=1) if self.prefetch and todo \
            else None
        t_loop = time.perf_counter()
        try:
            fut = pool.submit(_load, todo[0]) if pool else None
            for pos, ci in enumerate(todo):
                if self.crash_after is not None and \
                        rep.executed >= self.crash_after:
                    raise _InjectedCrash(
                        f"injected crash after {rep.executed} chunks")
                chunk, load_s = fut.result() if fut else _load(ci)
                rep.load_s += load_s
                if pool and pos + 1 < len(todo):
                    fut = pool.submit(_load, todo[pos + 1])
                t0 = time.perf_counter()
                stats_sink: Dict[int, Dict[str, int]] = {}
                vals = _executor.execute(
                    plan, self._chunk_env(resident, chunk),
                    n_patients=study.n_patients, engine=self.engine,
                    log=None, jit=True, stats_sink=stats_sink,
                    predicate_engine=self.predicate_engine)
                jax.block_until_ready(vals)
                exec_s = time.perf_counter() - t0
                rep.exec_s += exec_s
                counts = {i: int(np.asarray(vals[i].count))
                          if isinstance(vals[i], ColumnarTable)
                          else int(np.bitwise_count(np.asarray(vals[i]))
                                   .sum())
                          for i in vals}
                if self.checkpoint_dir is not None:
                    self._commit_chunk(ci, vals, counts, stats_sink, plan)
                merge(ci, vals, counts, stats_sink)
                rep.executed += 1
                log.record(op=f"chunked:chunk:{ci}", inputs={}, outputs={},
                           params={"chunk": ci, "load_s": round(load_s, 6),
                                   "exec_s": round(exec_s, 6)})
        finally:
            if pool:
                pool.shutdown(wait=False, cancel_futures=True)
        rep.wall_s = time.perf_counter() - t_loop
        rep.compiles = _executor.jit_cache_info()["compiles"] - compiles0

        # -- merge into one StudyResult -------------------------------------
        merged_vals: Dict[int, Any] = dict(indep_vals)
        # branch-aware concat merge: chunk order would interleave the
        # branches the resident path lays out branch-major (module docstring)
        windows: Dict[int, List[Tuple[int, int, int]]] = {}
        concat_ids = [nid for nid in dep_tables
                      if plan.nodes[nid].op == "concat"
                      and len(plan.nodes[nid].inputs) > 1]
        if concat_ids:
            probe: Set[int] = set()
            for nid in concat_ids:
                probe.update(_concat_probe_ids(plan, nid, dep))
            rows_of = _padded_rows(
                plan, self._chunk_env(resident, chunk0), study.n_patients,
                self.engine, self.predicate_engine, sorted(probe))
            for nid in concat_ids:
                windows[nid] = _concat_windows(plan, nid, dep, rows_of)
        for nid, by_chunk in dep_tables.items():
            if nid in windows:
                cis = sorted(by_chunk)
                parts = []
                for k, a, b in windows[nid]:
                    # chunk-independent branches are identical every chunk —
                    # take the window once, not once per chunk
                    for ci in (cis if k in dep else cis[:1]):
                        parts.append(_slice_rows(by_chunk[ci], a, b))
                t = parts[0] if len(parts) == 1 else ColumnarTable.concat(parts)
                merged_vals[nid] = t
                # the per-chunk count sum double-counts chunk-independent
                # branches; the merged popcount is exact either way
                counts_dep[nid] = int(
                    np.bitwise_count(np.asarray(t.valid)).sum())
                continue
            parts = [by_chunk[ci] for ci in sorted(by_chunk)]
            merged_vals[nid] = (parts[0] if len(parts) == 1
                                else ColumnarTable.concat(parts))
        for nid, w in bits_acc.items():
            merged_vals[nid] = jnp.asarray(w)

        counts = dict(counts_indep)
        counts.update(counts_dep)
        counts.update(_replay_cohort_counts(
            plan, {i: bits_acc[i] for i in bits_acc
                   if plan.nodes[i].op == "cohort_from_events"}))
        # dependent table counts: the merged table's popcount, already the
        # per-chunk sum; nothing to fix up
        join_stats = dict(stats_indep)
        join_stats.update(stats_dep)
        _executor.record_plan(plan, counts, log, self.engine,
                              stats=join_stats,
                              predicate_engine=self.predicate_engine)
        for i, d in join_stats.items():
            d.setdefault("stage", plan.nodes[i].label())
        log.record(op="chunked:summary", inputs={}, outputs={},
                   params=rep.to_json())
        return study._finish_result(plan, merged_vals, join_stats, log)
