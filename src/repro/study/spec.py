"""Declarative study front end: wire-format specs that compile to ``Study``.

SCALPEL3's pitch is studies as legible, reproducible artifacts.  This module
is the layer that makes a study *data*: a versioned JSON/dict schema
(cf. Conquery's declarative query format) covering concept extraction,
predicate trees, cohort algebra, flatten directives and feature exports —
compiled onto the exact same ``Study`` builder Python callers use, so a spec
and its hand-written equivalent produce bit-identical plans, results and
cache keys.

Three entry points:

  * ``validate_spec(spec)`` — strict structural validation.  Every problem
    is reported as a ``SpecIssue`` with a stable ``SPEC-nnn`` code, a
    JSON-style ``path`` to the offending field, and a fix hint; validation
    happens entirely *before* plan construction.
  * ``compile_spec(spec) -> Study`` — validate, then replay the spec onto a
    ``Study``; raises ``SpecValidationError`` (never builds a plan) when
    validation fails.
  * ``spec_from_study(study) -> spec`` — the inverse, serialized from the
    builder's declarative recipe log, so existing Python studies (and the
    plan goldens) round-trip into public wire artifacts:
    ``compile_spec(spec_from_study(s))`` rebuilds the identical plan.

``error_payload(exc)`` renders any admission failure — spec validation,
``SPnnn`` analyzer findings, runtime surprises — as the service's structured
wire payload ``{"status": "invalid", "errors": [...]}``; a traceback never
crosses the wire.

Spec shape (see README "Declarative study specs" for the reference table)::

    {"spec_version": 1,
     "n_patients": 1000,
     "window": [14600, 15695],                   # optional
     "schema": [{"star": "DCIR", ...}],          # optional flatten directives
     "concepts": [{"kind": "extract", ...},      # ordered declarations
                  {"kind": "patients"},
                  {"kind": "transform", ...},
                  {"kind": "filter", ...},
                  {"kind": "concat", ...}],
     "cohorts": {"base": "extract_patients",     # ordered algebra strings
                 "final": "(exposed & base) - fractured"},
     "flow": ["base", "final"],                  # optional
     "outputs": [{"kind": "featurize", ...}]}    # optional
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.extraction import Extractor
from repro.core.schema import DCIR_SCHEMA, HAD_SCHEMA, IR_IMB_SCHEMA, \
    PMSI_MCO_SCHEMA, SSR_SCHEMA
from repro.study.api import Study
from repro.study.expr import CohortParseError, CohortCombine, CohortRef, \
    _ARITH_FNS, _CMP_FNS, as_param, expr_from_param, parse_cohort_expr

__all__ = [
    "SPEC_VERSION", "SPEC_CODES", "STAR_SCHEMAS",
    "SpecIssue", "SpecValidationError",
    "validate_spec", "compile_spec", "spec_from_study",
    "expr_to_dict", "expr_dict_to_param", "error_payload",
]

SPEC_VERSION = 1

# star schemas addressable from the wire, by name.  Registration is what
# makes a schema spec-expressible: ``spec_from_study`` refuses studies built
# over unregistered ad-hoc stars rather than emit a spec that cannot compile.
STAR_SCHEMAS = {s.name: s for s in (
    DCIR_SCHEMA, PMSI_MCO_SCHEMA, SSR_SCHEMA, HAD_SCHEMA, IR_IMB_SCHEMA)}

# stable wire-error vocabulary (mirrors analyze.DIAGNOSTIC_CODES for SPnnn).
# Codes are append-only: tools and tenants match on them.
SPEC_CODES: Mapping[str, str] = {
    "SPEC-001": "spec root is not a JSON object",
    "SPEC-002": "spec_version missing or unsupported",
    "SPEC-003": "unknown field",
    "SPEC-004": "required field missing",
    "SPEC-005": "field has the wrong type or value",
    "SPEC-006": "unknown star schema",
    "SPEC-007": "unknown transform function",
    "SPEC-008": "duplicate output name",
    "SPEC-009": "reference to an undefined output",
    "SPEC-010": "malformed expression node",
    "SPEC-011": "bad literal (expected int/float/bool)",
    "SPEC-012": "cohort algebra syntax error",
    "SPEC-013": "bad enumeration value",
    "SPEC-014": "incomplete time-slice directive",
    "SPEC-429": "service queue is full",
    "SPEC-900": "internal error while serving a wire request",
}


@dataclasses.dataclass(frozen=True)
class SpecIssue:
    """One validation finding: stable code + JSON path + message + hint."""

    code: str
    path: str
    message: str
    hint: str = ""

    def as_dict(self) -> Dict[str, str]:
        return {"code": self.code, "path": self.path,
                "message": self.message, "hint": self.hint}

    def __str__(self) -> str:
        return f"{self.code} at {self.path or '$'}: {self.message}"


class SpecValidationError(ValueError):
    """Raised by ``compile_spec`` when validation finds any issue."""

    def __init__(self, issues: Sequence[SpecIssue]) -> None:
        self.issues = list(issues)
        super().__init__("; ".join(str(i) for i in self.issues))


# ---------------------------------------------------------------------------
# expression trees: wire dicts <-> Expr params
# ---------------------------------------------------------------------------
_EXPR_FIELDS = {
    "col": ("name",), "lit": ("value",),
    "cmp": ("cmp", "lhs", "rhs"), "arith": ("arith", "lhs", "rhs"),
    "and": ("lhs", "rhs"), "or": ("lhs", "rhs"), "not": ("x",),
    "isin": ("x", "values"), "is_null": ("x",), "not_null": ("x",),
}
_SCALARS = (bool, int, float)


def expr_to_dict(param: Tuple) -> Dict[str, Any]:
    """Serialize an Expr param (``to_param()`` tuple) as a wire dict."""
    tag = param[0]
    if tag == "col":
        return {"op": "col", "name": param[1]}
    if tag == "lit":
        return {"op": "lit", "value": _py_scalar(param[1])}
    if tag == "cmp":
        return {"op": "cmp", "cmp": param[1],
                "lhs": expr_to_dict(param[2]), "rhs": expr_to_dict(param[3])}
    if tag == "arith":
        return {"op": "arith", "arith": param[1],
                "lhs": expr_to_dict(param[2]), "rhs": expr_to_dict(param[3])}
    if tag == "bool":
        return {"op": param[1],
                "lhs": expr_to_dict(param[2]), "rhs": expr_to_dict(param[3])}
    if tag == "not":
        return {"op": "not", "x": expr_to_dict(param[1])}
    if tag == "isin":
        return {"op": "isin", "x": expr_to_dict(param[1]),
                "values": [_py_scalar(v) for v in param[2]]}
    if tag == "isnull":
        return {"op": "is_null", "x": expr_to_dict(param[1])}
    if tag == "notnull":
        return {"op": "not_null", "x": expr_to_dict(param[1])}
    raise ValueError(f"Expr tag {tag!r} is not wire-expressible "
                     f"(hoisted slots are an internal plan form)")


def expr_dict_to_param(d: Mapping[str, Any]) -> Tuple:
    """Rebuild the Expr param from its wire dict (assumes validated)."""
    op = d["op"]
    if op == "col":
        return ("col", d["name"])
    if op == "lit":
        return ("lit", d["value"])
    if op == "cmp":
        return ("cmp", d["cmp"], expr_dict_to_param(d["lhs"]),
                expr_dict_to_param(d["rhs"]))
    if op == "arith":
        return ("arith", d["arith"], expr_dict_to_param(d["lhs"]),
                expr_dict_to_param(d["rhs"]))
    if op in ("and", "or"):
        return ("bool", op, expr_dict_to_param(d["lhs"]),
                expr_dict_to_param(d["rhs"]))
    if op == "not":
        return ("not", expr_dict_to_param(d["x"]))
    if op == "isin":
        return ("isin", expr_dict_to_param(d["x"]), tuple(d["values"]))
    if op == "is_null":
        return ("isnull", expr_dict_to_param(d["x"]))
    if op == "not_null":
        return ("notnull", expr_dict_to_param(d["x"]))
    raise ValueError(f"unknown expression op {op!r}")


def _py_scalar(v: Any) -> Any:
    """numpy scalars -> plain Python, so specs are json.dumps-able."""
    if isinstance(v, bool) or v is None:
        return v
    if isinstance(v, int):
        return int(v)
    if isinstance(v, float):
        return float(v)
    if hasattr(v, "item"):                       # np.int32 / np.float32 ...
        return v.item()
    return v


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
class _Issues:
    """Collector with path bookkeeping."""

    def __init__(self) -> None:
        self.items: List[SpecIssue] = []

    def add(self, code: str, path: str, message: str, hint: str = "") -> None:
        self.items.append(SpecIssue(code, path, message,
                                    hint or SPEC_CODES.get(code, "")))


def _is_str(v: Any) -> bool:
    return isinstance(v, str)


def _is_int(v: Any) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def _is_num(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _check_keys(d: Mapping, allowed: Sequence[str], required: Sequence[str],
                path: str, iss: _Issues) -> bool:
    ok = True
    for k in d:
        if k not in allowed:
            iss.add("SPEC-003", f"{path}.{k}" if path else str(k),
                    f"unknown field {k!r}",
                    f"allowed fields: {', '.join(allowed)}")
            ok = False
    for k in required:
        if k not in d:
            iss.add("SPEC-004", f"{path}.{k}" if path else str(k),
                    f"required field {k!r} is missing")
            ok = False
    return ok


def _check_expr(d: Any, path: str, iss: _Issues) -> None:
    if not isinstance(d, Mapping):
        iss.add("SPEC-010", path, "expression node must be an object "
                f"with an 'op' field, got {type(d).__name__}")
        return
    op = d.get("op")
    if op not in _EXPR_FIELDS:
        iss.add("SPEC-010", f"{path}.op", f"unknown expression op {op!r}",
                f"one of: {', '.join(sorted(_EXPR_FIELDS))}")
        return
    if not _check_keys(d, ("op",) + _EXPR_FIELDS[op], _EXPR_FIELDS[op],
                       path, iss):
        return
    if op == "col" and not _is_str(d["name"]):
        iss.add("SPEC-005", f"{path}.name", "column name must be a string")
    elif op == "lit" and not isinstance(d["value"], _SCALARS):
        iss.add("SPEC-011", f"{path}.value",
                f"literal must be int/float/bool, got "
                f"{type(d['value']).__name__}")
    elif op == "cmp":
        if d["cmp"] not in _CMP_FNS:
            iss.add("SPEC-013", f"{path}.cmp",
                    f"unknown comparison {d['cmp']!r}",
                    f"one of: {', '.join(_CMP_FNS)}")
        _check_expr(d["lhs"], f"{path}.lhs", iss)
        _check_expr(d["rhs"], f"{path}.rhs", iss)
    elif op == "arith":
        if d["arith"] not in _ARITH_FNS:
            iss.add("SPEC-013", f"{path}.arith",
                    f"unknown arithmetic op {d['arith']!r}",
                    f"one of: {', '.join(_ARITH_FNS)}")
        _check_expr(d["lhs"], f"{path}.lhs", iss)
        _check_expr(d["rhs"], f"{path}.rhs", iss)
    elif op in ("and", "or"):
        _check_expr(d["lhs"], f"{path}.lhs", iss)
        _check_expr(d["rhs"], f"{path}.rhs", iss)
    elif op in ("not", "is_null", "not_null"):
        _check_expr(d["x"], f"{path}.x", iss)
    elif op == "isin":
        _check_expr(d["x"], f"{path}.x", iss)
        vs = d["values"]
        if not isinstance(vs, (list, tuple)):
            iss.add("SPEC-005", f"{path}.values",
                    "isin values must be a list")
        else:
            for i, v in enumerate(vs):
                if not _is_num(v):
                    iss.add("SPEC-011", f"{path}.values[{i}]",
                            f"whitelist value must be int/float, got "
                            f"{type(v).__name__}")


_EXTRACTOR_REQ = ("name", "source", "category", "value_col", "start_col")
_EXTRACTOR_OPT = ("end_col", "group_col", "weight_col", "null_cols",
                  "codes", "distinct", "where")


def _check_extractor(d: Any, path: str, iss: _Issues) -> None:
    if not isinstance(d, Mapping):
        iss.add("SPEC-005", path, "extractor must be an object")
        return
    if not _check_keys(d, _EXTRACTOR_REQ + _EXTRACTOR_OPT, _EXTRACTOR_REQ,
                       path, iss):
        return
    for k in ("name", "source", "value_col", "start_col"):
        if not _is_str(d[k]):
            iss.add("SPEC-005", f"{path}.{k}", f"{k} must be a string")
    if not _is_int(d["category"]) or d["category"] < 0:
        iss.add("SPEC-005", f"{path}.category",
                "category must be a non-negative integer "
                "(see core.events.Category)")
    for k in ("end_col", "group_col", "weight_col"):
        if d.get(k) is not None and not _is_str(d[k]):
            iss.add("SPEC-005", f"{path}.{k}", f"{k} must be a string or null")
    for k in ("null_cols", "distinct"):
        v = d.get(k, [])
        if not isinstance(v, (list, tuple)) or \
                not all(_is_str(c) for c in v):
            iss.add("SPEC-005", f"{path}.{k}",
                    f"{k} must be a list of column names")
    codes = d.get("codes")
    if codes is not None:
        if not isinstance(codes, (list, tuple)):
            iss.add("SPEC-005", f"{path}.codes",
                    "codes must be a list of numbers or null")
        else:
            for i, v in enumerate(codes):
                if not _is_num(v):
                    iss.add("SPEC-011", f"{path}.codes[{i}]",
                            f"whitelist code must be int/float, got "
                            f"{type(v).__name__}")
    if d.get("where") is not None:
        _check_expr(d["where"], f"{path}.where", iss)


_FLATTEN_DEFAULTS: Dict[str, Any] = {
    "name": None, "time_slices": None, "time_column": None, "t0": None,
    "t1": None, "expand_capacity": None, "expand_slack": 1.5,
    "exchange": True, "partitioned_on": None, "keep": None,
}

_CONCEPT_FIELDS = {
    "extract": (("extractor",), ("name", "compact")),
    "patients": ((), ("source", "name")),
    "transform": (("fn", "inputs"), ("name", "kwargs")),
    "concat": (("name", "inputs"), ()),
    "filter": (("source", "where"), ("name",)),
}

_ROOT_FIELDS = ("spec_version", "n_patients", "window", "description",
                "schema", "concepts", "cohorts", "flow", "outputs")


def _cohort_refs(tree) -> List[str]:
    if isinstance(tree, CohortRef):
        return [tree.name]
    assert isinstance(tree, CohortCombine)
    return _cohort_refs(tree.left) + _cohort_refs(tree.right)


def validate_spec(spec: Any) -> List[SpecIssue]:
    """Strict structural validation; returns every finding (never raises).

    An empty list means ``compile_spec`` will build the Study without
    touching plan construction error paths.  The validator is two-phase
    free: names are checked against *previously declared* outputs, in spec
    order, exactly as ``Study`` resolves them."""
    iss = _Issues()
    if not isinstance(spec, Mapping):
        iss.add("SPEC-001", "", f"spec must be a JSON object, got "
                f"{type(spec).__name__}")
        return iss.items
    _check_keys(spec, _ROOT_FIELDS, (), "", iss)
    ver = spec.get("spec_version")
    if ver != SPEC_VERSION:
        iss.add("SPEC-002", "spec_version",
                f"spec_version must be {SPEC_VERSION}, got {ver!r}")
    n = spec.get("n_patients")
    if n is None:
        iss.add("SPEC-004", "n_patients",
                "required field 'n_patients' is missing")
    elif not _is_int(n) or n <= 0:
        iss.add("SPEC-005", "n_patients",
                f"n_patients must be a positive integer, got {n!r}")
    win = spec.get("window")
    if win is not None and (not isinstance(win, (list, tuple))
                            or len(win) != 2
                            or not all(_is_int(x) for x in win)):
        iss.add("SPEC-005", "window",
                f"window must be [start_day, end_day] integers, got {win!r}")
    if "description" in spec and not _is_str(spec["description"]):
        iss.add("SPEC-005", "description", "description must be a string")

    defined: Dict[str, str] = {}       # name -> kind (table|events|cohort)

    def declare(name: Any, kind: str, path: str) -> None:
        if not _is_str(name) or not name:
            iss.add("SPEC-005", path, "output name must be a non-empty "
                    f"string, got {name!r}")
            return
        if name in defined:
            iss.add("SPEC-008", path, f"duplicate output name {name!r}")
            return
        defined[name] = kind

    def require_ref(name: Any, path: str, kinds: Optional[Tuple[str, ...]]
                    = None) -> None:
        if not _is_str(name):
            iss.add("SPEC-005", path, f"reference must be a string, "
                    f"got {name!r}")
        elif name not in defined:
            iss.add("SPEC-009", path, f"reference to undefined output "
                    f"{name!r}", f"defined so far: "
                    f"{', '.join(sorted(defined)) or '(none)'}")
        elif kinds is not None and defined[name] not in kinds:
            iss.add("SPEC-005", path, f"{name!r} is a "
                    f"{defined[name]} output; expected one of "
                    f"{'/'.join(kinds)}")

    # -- schema (flatten directives) ----------------------------------------
    schema = spec.get("schema", [])
    if not isinstance(schema, (list, tuple)):
        iss.add("SPEC-005", "schema",
                "schema must be a list of flatten directives")
        schema = []
    for i, f in enumerate(schema):
        path = f"schema[{i}]"
        if not isinstance(f, Mapping):
            iss.add("SPEC-005", path, "flatten directive must be an object")
            continue
        if not _check_keys(f, ("star",) + tuple(_FLATTEN_DEFAULTS),
                           ("star",), path, iss):
            continue
        star = f.get("star")
        if star not in STAR_SCHEMAS:
            iss.add("SPEC-006", f"{path}.star",
                    f"unknown star schema {star!r}",
                    f"registered: {', '.join(sorted(STAR_SCHEMAS))}")
            continue
        for k in ("time_slices", "t0", "t1", "expand_capacity"):
            if f.get(k) is not None and not _is_int(f[k]):
                iss.add("SPEC-005", f"{path}.{k}", f"{k} must be an integer")
        for k in ("name", "time_column", "partitioned_on"):
            if f.get(k) is not None and not _is_str(f[k]):
                iss.add("SPEC-005", f"{path}.{k}", f"{k} must be a string")
        for k in ("exchange", "keep"):
            if f.get(k) is not None and not isinstance(f[k], bool):
                iss.add("SPEC-005", f"{path}.{k}", f"{k} must be a boolean")
        if f.get("expand_slack") is not None and not _is_num(
                f["expand_slack"]):
            iss.add("SPEC-005", f"{path}.expand_slack",
                    "expand_slack must be a number")
        if f.get("time_slices"):
            missing = [k for k in ("time_column", "t0", "t1")
                       if f.get(k) is None]
            if missing:
                iss.add("SPEC-014", path,
                        f"time_slices needs {', '.join(missing)}",
                        "temporal slicing requires time_column, t0 and t1")
        declare(f.get("name") or star, "table", path)

    # -- concepts -----------------------------------------------------------
    concepts = spec.get("concepts", [])
    if not isinstance(concepts, (list, tuple)):
        iss.add("SPEC-005", "concepts", "concepts must be a list")
        concepts = []
    for i, c in enumerate(concepts):
        path = f"concepts[{i}]"
        if not isinstance(c, Mapping):
            iss.add("SPEC-005", path, "concept must be an object")
            continue
        kind = c.get("kind")
        if kind not in _CONCEPT_FIELDS:
            iss.add("SPEC-013", f"{path}.kind",
                    f"unknown concept kind {kind!r}",
                    f"one of: {', '.join(sorted(_CONCEPT_FIELDS))}")
            continue
        req, opt = _CONCEPT_FIELDS[kind]
        if not _check_keys(c, ("kind",) + req + opt, req, path, iss):
            continue
        if kind == "extract":
            _check_extractor(c["extractor"], f"{path}.extractor", iss)
            if c.get("compact") is not None and not isinstance(
                    c["compact"], bool):
                iss.add("SPEC-005", f"{path}.compact",
                        "compact must be a boolean")
            ex_name = c.get("name")
            if ex_name is None and isinstance(c["extractor"], Mapping):
                ex_name = c["extractor"].get("name")
            declare(ex_name, "events", path)
        elif kind == "patients":
            if c.get("source") is not None and not _is_str(c["source"]):
                iss.add("SPEC-005", f"{path}.source",
                        "source must be a string")
            declare(c.get("name", "extract_patients"), "table", path)
        elif kind == "transform":
            fn = c.get("fn")
            from repro.study import executor as _executor
            if not _is_str(fn) or fn not in _executor.TRANSFORMS:
                iss.add("SPEC-007", f"{path}.fn",
                        f"unknown transform {fn!r}",
                        f"registered: "
                        f"{', '.join(sorted(_executor.TRANSFORMS))}")
            inputs = c.get("inputs")
            if not isinstance(inputs, (list, tuple)) or not inputs:
                iss.add("SPEC-005", f"{path}.inputs",
                        "inputs must be a non-empty list of output names")
            else:
                for j, nm in enumerate(inputs):
                    require_ref(nm, f"{path}.inputs[{j}]",
                                ("table", "events"))
            kw = c.get("kwargs", {})
            if not isinstance(kw, Mapping) or \
                    not all(_is_str(k) for k in kw):
                iss.add("SPEC-005", f"{path}.kwargs",
                        "kwargs must be an object with string keys")
            else:
                clash = sorted(set(kw) & {"fn", "inputs", "name"})
                if clash:
                    iss.add("SPEC-005", f"{path}.kwargs",
                            f"kwargs may not override reserved "
                            f"parameter(s): {', '.join(clash)}",
                            "set fn/inputs/name on the concept itself")
            declare(c.get("name", fn if _is_str(fn) else None),
                    "events", path)
        elif kind == "concat":
            inputs = c.get("inputs")
            if not isinstance(inputs, (list, tuple)) or not inputs:
                iss.add("SPEC-005", f"{path}.inputs",
                        "inputs must be a non-empty list of output names")
            else:
                for j, nm in enumerate(inputs):
                    require_ref(nm, f"{path}.inputs[{j}]",
                                ("table", "events"))
            declare(c.get("name"), "events", path)
        elif kind == "filter":
            src = c.get("source")
            require_ref(src, f"{path}.source", ("table", "events"))
            _check_expr(c["where"], f"{path}.where", iss)
            nm = c.get("name")
            if nm is None and _is_str(src):
                nm = f"{src}_filtered"
            # src may be any JSON value (require_ref only records the
            # issue); hash it only when it is a usable key.
            src_kind = defined.get(src, "events") if _is_str(src) \
                else "events"
            declare(nm, src_kind, path)

    # -- cohorts ------------------------------------------------------------
    cohorts = spec.get("cohorts", {})
    if not isinstance(cohorts, Mapping):
        iss.add("SPEC-005", "cohorts",
                "cohorts must be an object of name -> algebra string")
        cohorts = {}
    for name, alg in cohorts.items():
        path = f"cohorts.{name}"
        if not _is_str(alg):
            iss.add("SPEC-005", path,
                    f"cohort algebra must be a string, got {alg!r}")
            declare(name, "cohort", path)
            continue
        try:
            tree = parse_cohort_expr(alg)
        except CohortParseError as e:
            iss.add("SPEC-012", path, str(e),
                    "operators are whitespace-separated; parentheses group")
            declare(name, "cohort", path)
            continue
        for ref in _cohort_refs(tree):
            require_ref(ref, path)
        declare(name, "cohort", path)

    # -- flow ---------------------------------------------------------------
    flow = spec.get("flow")
    if flow is not None:
        if not isinstance(flow, (list, tuple)) or not flow:
            iss.add("SPEC-005", "flow",
                    "flow must be a non-empty list of cohort names")
        else:
            for j, nm in enumerate(flow):
                require_ref(nm, f"flow[{j}]")

    # -- outputs (feature exports) ------------------------------------------
    outputs = spec.get("outputs", [])
    if not isinstance(outputs, (list, tuple)):
        iss.add("SPEC-005", "outputs", "outputs must be a list")
        outputs = []
    for i, o in enumerate(outputs):
        path = f"outputs[{i}]"
        if not isinstance(o, Mapping):
            iss.add("SPEC-005", path, "output directive must be an object")
            continue
        if o.get("kind") != "featurize":
            iss.add("SPEC-013", f"{path}.kind",
                    f"unknown output kind {o.get('kind')!r}",
                    "only 'featurize' outputs are defined")
            continue
        if not _check_keys(o, ("kind", "name", "cohort", "feature_kind",
                               "patients", "kwargs"),
                           ("name", "cohort"), path, iss):
            continue
        require_ref(o["cohort"], f"{path}.cohort")
        fk = o.get("feature_kind", "dense")
        if fk not in ("dense", "tokens"):
            iss.add("SPEC-013", f"{path}.feature_kind",
                    f"feature_kind must be dense|tokens, got {fk!r}")
        if o.get("patients") is not None:
            require_ref(o["patients"], f"{path}.patients", ("table",))
        kw = o.get("kwargs", {})
        if not isinstance(kw, Mapping) or not all(_is_str(k) for k in kw):
            iss.add("SPEC-005", f"{path}.kwargs",
                    "kwargs must be an object with string keys")
        else:
            clash = sorted(set(kw) & {"name", "cohort", "kind",
                                      "feature_kind", "patients"})
            if clash:
                iss.add("SPEC-005", f"{path}.kwargs",
                        f"kwargs may not override reserved "
                        f"parameter(s): {', '.join(clash)}",
                        "set them on the output directive itself")
        declare(o.get("name"), "feature", path)
    return iss.items


# ---------------------------------------------------------------------------
# compile: spec -> Study
# ---------------------------------------------------------------------------
def _extractor_from_dict(d: Mapping[str, Any]) -> Extractor:
    where = d.get("where")
    codes = d.get("codes")
    return Extractor(
        name=d["name"], source=d["source"], category=int(d["category"]),
        value_col=d["value_col"], start_col=d["start_col"],
        end_col=d.get("end_col"), group_col=d.get("group_col"),
        weight_col=d.get("weight_col"),
        null_cols=tuple(d.get("null_cols", ())),
        codes=None if codes is None else tuple(codes),
        distinct=tuple(d.get("distinct", ())),
        where=None if where is None
        else expr_from_param(expr_dict_to_param(where)))


def compile_spec(spec: Mapping[str, Any]) -> Study:
    """Validate ``spec`` and replay it onto a ``Study``.

    Raises ``SpecValidationError`` (with every ``SpecIssue``) on any
    validation finding — plan construction is never reached with a bad
    spec.  A compiled spec is indistinguishable from the equivalent
    hand-written builder chain: same plan, same optimizer cache key, same
    service admission path."""
    issues = validate_spec(spec)
    if issues:
        raise SpecValidationError(issues)
    window = spec.get("window")
    s = Study(n_patients=spec["n_patients"],
              window=tuple(window) if window else (0, 2_000_000_000))
    for f in spec.get("schema", []):
        kw = {k: f[k] for k in _FLATTEN_DEFAULTS if k in f}
        s.flatten(STAR_SCHEMAS[f["star"]], **kw)
    for c in spec.get("concepts", []):
        kind = c["kind"]
        if kind == "extract":
            ex = _extractor_from_dict(c["extractor"])
            s.extract(ex, name=c.get("name") or ex.name,
                      compact=c.get("compact", True))
        elif kind == "patients":
            s.patients(source=c.get("source", "IR_BEN"),
                       name=c.get("name", "extract_patients"))
        elif kind == "transform":
            s.transform(c["fn"], *c["inputs"],
                        name=c.get("name") or c["fn"],
                        **dict(c.get("kwargs", {})))
        elif kind == "concat":
            s.concat(c["name"], *c["inputs"])
        elif kind == "filter":
            s.filter(c["source"],
                     expr_from_param(expr_dict_to_param(c["where"])),
                     name=c.get("name"))
    for name, alg in spec.get("cohorts", {}).items():
        s.cohort(name, alg)
    if spec.get("flow"):
        s.flow(*spec["flow"])
    for o in spec.get("outputs", []):
        s.featurize(o["name"], cohort=o["cohort"],
                    kind=o.get("feature_kind", "dense"),
                    patients=o.get("patients"),
                    **dict(o.get("kwargs", {})))
    return s


# ---------------------------------------------------------------------------
# inverse: Study -> spec
# ---------------------------------------------------------------------------
def _extractor_to_dict(ex: Extractor) -> Dict[str, Any]:
    d: Dict[str, Any] = {
        "name": ex.name, "source": ex.source, "category": int(ex.category),
        "value_col": ex.value_col, "start_col": ex.start_col,
    }
    if ex.end_col is not None:
        d["end_col"] = ex.end_col
    if ex.group_col is not None:
        d["group_col"] = ex.group_col
    if ex.weight_col is not None:
        d["weight_col"] = ex.weight_col
    if ex.null_cols:
        d["null_cols"] = list(ex.null_cols)
    if ex.codes is not None:
        d["codes"] = [_py_scalar(v) for v in ex.codes]
    if ex.distinct:
        d["distinct"] = list(ex.distinct)
    if ex.where is not None:
        d["where"] = expr_to_dict(as_param(ex.where))
    return d


def spec_from_study(study: Study) -> Dict[str, Any]:
    """Serialize a builder-constructed ``Study`` as a wire spec.

    Reads the builder's declarative recipe log, so only studies built
    through the public ``Study`` methods serialize; ``source()``-bound
    tables (runtime data, not declarations) and unregistered ad-hoc star
    schemas raise ``ValueError``.  Sections are grouped in canonical order
    (schema, concepts, cohorts, flow, outputs) — round-tripping is exact
    (identical plans) whenever declarations are grouped that way, which
    every spec-compiled study is by construction."""
    spec: Dict[str, Any] = {"spec_version": SPEC_VERSION,
                            "n_patients": study.n_patients}
    if study._window != (0, 2_000_000_000):
        spec["window"] = list(study._window)
    schema: List[Dict[str, Any]] = []
    concepts: List[Dict[str, Any]] = []
    cohorts: Dict[str, str] = {}
    flow: Optional[List[str]] = None
    outputs: List[Dict[str, Any]] = []
    for step, kw in study._recipe:
        if step == "source":
            raise ValueError(
                f"study binds runtime table {kw['name']!r} via source(); "
                f"bound tables are data, not declarations — pass them to "
                f"run() instead to make the study spec-expressible")
        if step == "flatten":
            sch = kw["schema"]
            if STAR_SCHEMAS.get(sch.name) is not sch:
                raise ValueError(
                    f"star schema {sch.name!r} is not registered in "
                    f"spec.STAR_SCHEMAS; only registered schemas are "
                    f"wire-expressible")
            f: Dict[str, Any] = {"star": sch.name}
            for k, default in _FLATTEN_DEFAULTS.items():
                if kw[k] != default:
                    f[k] = kw[k]
            schema.append(f)
        elif step == "extract":
            c: Dict[str, Any] = {"kind": "extract", "name": kw["name"],
                                 "extractor": _extractor_to_dict(
                                     kw["extractor"])}
            if kw["compact"] is not True:
                c["compact"] = kw["compact"]
            concepts.append(c)
        elif step == "patients":
            c = {"kind": "patients"}
            if kw["source"] != "IR_BEN":
                c["source"] = kw["source"]
            if kw["name"] != "extract_patients":
                c["name"] = kw["name"]
            concepts.append(c)
        elif step == "transform":
            c = {"kind": "transform", "fn": kw["fn"],
                 "inputs": list(kw["inputs"])}
            if kw["name"] != kw["fn"]:
                c["name"] = kw["name"]
            if kw["kwargs"]:
                c["kwargs"] = {k: _py_list(v)
                               for k, v in kw["kwargs"].items()}
            concepts.append(c)
        elif step == "concat":
            concepts.append({"kind": "concat", "name": kw["name"],
                             "inputs": list(kw["inputs"])})
        elif step == "filter":
            concepts.append({"kind": "filter", "source": kw["source"],
                             "where": expr_to_dict(as_param(kw["where"])),
                             "name": kw["name"]})
        elif step == "cohort":
            cohorts[kw["name"]] = kw["expr"]
        elif step == "flow":
            flow = list(kw["names"])
        elif step == "featurize":
            o: Dict[str, Any] = {"kind": "featurize", "name": kw["name"],
                                 "cohort": kw["cohort"],
                                 "feature_kind": kw["kind"]}
            if kw["patients"] is not None:
                o["patients"] = kw["patients"]
            if kw["kwargs"]:
                o["kwargs"] = {k: _py_list(v)
                               for k, v in kw["kwargs"].items()}
            outputs.append(o)
    if schema:
        spec["schema"] = schema
    if concepts:
        spec["concepts"] = concepts
    if cohorts:
        spec["cohorts"] = cohorts
    if flow:
        spec["flow"] = flow
    if outputs:
        spec["outputs"] = outputs
    return spec


def _py_list(v: Any) -> Any:
    """JSON-friendly form for transform/featurize kwargs values."""
    if isinstance(v, (list, tuple, range)):
        return [_py_list(x) for x in v]
    return _py_scalar(v)


# ---------------------------------------------------------------------------
# wire error payloads
# ---------------------------------------------------------------------------
def error_payload(exc: BaseException) -> List[Dict[str, Any]]:
    """Render any admission/serving failure as structured wire errors.

    ``SpecValidationError`` -> one entry per ``SpecIssue`` (code + path);
    ``PlanValidationError`` -> one entry per error-severity ``Diagnostic``
    (``SPnnn`` code + plan node id); anything else -> a single ``SPEC-900``
    entry naming only the exception *type* — messages of unexpected
    exceptions (and tracebacks) never reach a tenant."""
    if isinstance(exc, SpecValidationError):
        return [i.as_dict() for i in exc.issues]
    from repro.study.analyze import PlanValidationError
    if isinstance(exc, PlanValidationError):
        return [{"code": d.code, "node": d.node, "message": d.message,
                 "hint": d.hint} for d in exc.diagnostics
                if d.severity == "error"] or \
               [{"code": d.code, "node": d.node, "message": d.message,
                 "hint": d.hint} for d in exc.diagnostics]
    return [{"code": "SPEC-900",
             "message": f"internal error ({type(exc).__name__}) while "
                        f"serving the request",
             "hint": "the request was rejected; no partial state was kept"}]
