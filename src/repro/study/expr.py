"""Typed column-expression IR: the ``col()``/``Expr`` DSL.

SCALPEL3's pitch is "sharp interactive control of data processing through
legible code": extraction concepts are declarative queries the engine can
*analyze*, not opaque callables.  This module is the analyzable predicate
layer of the Plan IR:

  * ``col("BEN_NIR_PSA") >= 18`` builds an ``Expr`` tree (comparisons,
    arithmetic, set membership, null tests, ``&``/``|``/``~`` combinators);
  * Expr trees serialize to hashable nested tuples (``to_param``), so they
    ride plan nodes (``predicate``/``fused_mask``) through hash-consing and
    the executor's jit cache unchanged;
  * every predicate-ish plan op (``drop_nulls``, ``value_filter``,
    ``fused_mask``, ``slice_time`` bounds) re-expresses as an ``Expr`` via
    ``node_predicate`` — one evaluation semantics for the whole IR;
  * ``Expr.required_columns()`` is what the optimizer's column-pruning pass
    propagates backwards through the flatten joins into the star scans;
  * ``fused_predicate`` compiles a fused node's accumulated conjuncts into a
    single Expr, evaluated as ONE pass over the projected columns (the plan
    analogue of the ROADMAP's Pallas fused-predicate kernel).

Null semantics are deliberately "raw" for comparisons/arithmetic (sentinel
values compare like any other value, as in the fixed-width SoA encoding);
``is_null()``/``not_null()`` are the explicit sentinel tests — mirroring how
the eager mask algebra has always behaved.

The module also hosts the ``CohortExpr`` layer: a recursive-descent parser
for cohort algebra strings (``"(exposed & base) - fractured"``) with real
operator precedence (``&`` binds tighter than ``|``/``-``) and parentheses,
lowered by ``Study.cohort`` onto the same plan machinery.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import operator as _op
import threading
from typing import Any, Callable, Iterable, List, Optional, Sequence, Tuple, \
    Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.columnar import ColumnarTable, is_null

# host-side mirror of the int32 NULL sentinel (columnar.NULL_INT is a jnp
# scalar; const folding stays device-free)
_NULL_SENTINEL_INT = -2_147_483_648 + 1

__all__ = [
    "Expr", "Col", "Lit", "col", "lit", "all_of", "any_of",
    "expr_from_param", "fused_predicate", "node_predicate",
    "param_conjuncts", "const_fold_param",
    "HoistedLit", "HoistedIsIn", "bound_params", "current_bound_params",
    "CohortRef", "CohortCombine", "CohortParseError", "parse_cohort_expr",
]


# ---------------------------------------------------------------------------
# Expr trees
# ---------------------------------------------------------------------------
_CMP_FNS = {"==": _op.eq, "!=": _op.ne, "<": _op.lt, "<=": _op.le,
            ">": _op.gt, ">=": _op.ge}
_ARITH_FNS = {"+": _op.add, "-": _op.sub, "*": _op.mul,
              "//": _op.floordiv, "%": _op.mod}


def _coerce(v: Any) -> "Expr":
    if isinstance(v, Expr):
        return v
    if isinstance(v, (bool, int, float, np.integer, np.floating)):
        return Lit(v)
    raise TypeError(f"cannot use {type(v).__name__} in a column expression; "
                    f"wrap columns with col(...) and use scalar literals")


class Expr:
    """Base of the expression tree.  Build with ``col()``/``lit()`` and the
    overloaded operators; combine predicates with ``&``/``|``/``~`` (never
    Python's ``and``/``or``, which cannot be overloaded)."""

    __slots__ = ()
    # value-semantics __eq__ builds a node, so identity hashing would be
    # incoherent — Exprs are deliberately unhashable (plans store to_param()).
    __hash__ = None

    # -- comparisons ---------------------------------------------------------
    def __eq__(self, other):  # type: ignore[override]
        return Cmp("==", self, _coerce(other))

    def __ne__(self, other):  # type: ignore[override]
        return Cmp("!=", self, _coerce(other))

    def __lt__(self, other):
        return Cmp("<", self, _coerce(other))

    def __le__(self, other):
        return Cmp("<=", self, _coerce(other))

    def __gt__(self, other):
        return Cmp(">", self, _coerce(other))

    def __ge__(self, other):
        return Cmp(">=", self, _coerce(other))

    # -- arithmetic ----------------------------------------------------------
    def __add__(self, other):
        return Arith("+", self, _coerce(other))

    def __radd__(self, other):
        return Arith("+", _coerce(other), self)

    def __sub__(self, other):
        return Arith("-", self, _coerce(other))

    def __rsub__(self, other):
        return Arith("-", _coerce(other), self)

    def __mul__(self, other):
        return Arith("*", self, _coerce(other))

    def __rmul__(self, other):
        return Arith("*", _coerce(other), self)

    def __floordiv__(self, other):
        return Arith("//", self, _coerce(other))

    def __rfloordiv__(self, other):
        return Arith("//", _coerce(other), self)

    def __mod__(self, other):
        return Arith("%", self, _coerce(other))

    def __rmod__(self, other):
        return Arith("%", _coerce(other), self)

    # -- boolean combinators -------------------------------------------------
    def __and__(self, other):
        return BoolOp("and", self, _coerce(other))

    def __rand__(self, other):
        return BoolOp("and", _coerce(other), self)

    def __or__(self, other):
        return BoolOp("or", self, _coerce(other))

    def __ror__(self, other):
        return BoolOp("or", _coerce(other), self)

    def __invert__(self):
        return Not(self)

    def __bool__(self):
        raise TypeError("Expr has no truth value; use & | ~ to combine "
                        "predicates (not and/or/not)")

    # -- predicate sugar -----------------------------------------------------
    def isin(self, values: Iterable) -> "Expr":
        """Set membership against a static whitelist (SQL ``IN``)."""
        return IsIn(self, tuple(values))

    def is_null(self) -> "Expr":
        """Sentinel-encoded null test (see ``columnar.is_null``)."""
        return NullTest(self, negate=False)

    def not_null(self) -> "Expr":
        return NullTest(self, negate=True)

    def between(self, lo, hi) -> "Expr":
        """Half-open range test ``lo <= self < hi`` (slice_time semantics)."""
        return (self >= lo) & (self < hi)

    # -- analysis ------------------------------------------------------------
    def required_columns(self) -> frozenset:
        """Every column this expression reads — the unit the optimizer's
        column-pruning pass propagates backwards through joins."""
        raise NotImplementedError

    def to_param(self) -> Tuple:
        """Hashable nested-tuple serialization for plan-node params."""
        raise NotImplementedError

    def evaluate(self, table: ColumnarTable):
        """Naive per-node evaluation over a table (the reference semantics;
        the fused path must agree bit-for-bit — see tests/test_expr.py)."""
        raise NotImplementedError

    def mask(self, table: ColumnarTable) -> jax.Array:
        """Row-filter mask: the expression's boolean value AND row validity.

        This is the jnp fallback path — the per-row expansion here is packed
        back into the table's bitset validity at the constructor boundary;
        the Pallas engine emits packed words directly and never takes it."""
        return table.valid_bool() & self.evaluate(table)


class Col(Expr):
    __slots__ = ("name",)

    def __init__(self, name: str):
        object.__setattr__(self, "name", str(name))

    def __setattr__(self, *a):  # immutable value object
        raise AttributeError("Expr nodes are immutable")

    def required_columns(self):
        return frozenset((self.name,))

    def to_param(self):
        return ("col", self.name)

    def evaluate(self, table):
        return table.columns[self.name]

    def __repr__(self):
        return self.name


class Lit(Expr):
    __slots__ = ("value",)

    def __init__(self, value):
        object.__setattr__(self, "value", value)

    def __setattr__(self, *a):
        raise AttributeError("Expr nodes are immutable")

    def required_columns(self):
        return frozenset()

    def to_param(self):
        return ("lit", self.value)

    def evaluate(self, table):
        return self.value

    def __repr__(self):
        return repr(self.value)


class _Binary(Expr):
    __slots__ = ("op", "lhs", "rhs")
    _tag = ""

    def __init__(self, op: str, lhs: Expr, rhs: Expr):
        object.__setattr__(self, "op", op)
        object.__setattr__(self, "lhs", lhs)
        object.__setattr__(self, "rhs", rhs)

    def __setattr__(self, *a):
        raise AttributeError("Expr nodes are immutable")

    def required_columns(self):
        return self.lhs.required_columns() | self.rhs.required_columns()

    def to_param(self):
        return (self._tag, self.op, self.lhs.to_param(), self.rhs.to_param())


class Cmp(_Binary):
    __slots__ = ()
    _tag = "cmp"

    def evaluate(self, table):
        return _CMP_FNS[self.op](self.lhs.evaluate(table),
                                 self.rhs.evaluate(table))

    def __repr__(self):
        return f"({self.lhs!r} {self.op} {self.rhs!r})"


class Arith(_Binary):
    __slots__ = ()
    _tag = "arith"

    def evaluate(self, table):
        return _ARITH_FNS[self.op](self.lhs.evaluate(table),
                                   self.rhs.evaluate(table))

    def __repr__(self):
        return f"({self.lhs!r} {self.op} {self.rhs!r})"


class BoolOp(_Binary):
    __slots__ = ()
    _tag = "bool"

    def evaluate(self, table):
        l, r = self.lhs.evaluate(table), self.rhs.evaluate(table)
        return (l & r) if self.op == "and" else (l | r)

    def __repr__(self):
        sym = "&" if self.op == "and" else "|"
        return f"({self.lhs!r} {sym} {self.rhs!r})"


class Not(Expr):
    __slots__ = ("x",)

    def __init__(self, x: Expr):
        object.__setattr__(self, "x", x)

    def __setattr__(self, *a):
        raise AttributeError("Expr nodes are immutable")

    def required_columns(self):
        return self.x.required_columns()

    def to_param(self):
        return ("not", self.x.to_param())

    def evaluate(self, table):
        return ~self.x.evaluate(table)

    def __repr__(self):
        return f"~{self.x!r}"


class IsIn(Expr):
    __slots__ = ("x", "values")

    def __init__(self, x: Expr, values: Tuple):
        object.__setattr__(self, "x", x)
        object.__setattr__(self, "values", tuple(values))

    def __setattr__(self, *a):
        raise AttributeError("Expr nodes are immutable")

    def required_columns(self):
        return self.x.required_columns()

    def to_param(self):
        return ("isin", self.x.to_param(), self.values)

    def evaluate(self, table):
        v = self.x.evaluate(table)
        dt = np.float32 if any(isinstance(c, float) for c in self.values) \
            else np.int32
        if not self.values:  # empty whitelist matches nothing
            return jnp.zeros(jnp.shape(v), bool)
        return jnp.isin(v, jnp.asarray(np.asarray(self.values, dt)))

    def __repr__(self):
        vs = (list(self.values) if len(self.values) <= 4
              else f"<{len(self.values)} values>")
        return f"{self.x!r} in {vs}"


class NullTest(Expr):
    __slots__ = ("x", "negate")

    def __init__(self, x: Expr, negate: bool):
        object.__setattr__(self, "x", x)
        object.__setattr__(self, "negate", bool(negate))

    def __setattr__(self, *a):
        raise AttributeError("Expr nodes are immutable")

    def required_columns(self):
        return self.x.required_columns()

    def to_param(self):
        return ("notnull" if self.negate else "isnull", self.x.to_param())

    def evaluate(self, table):
        m = is_null(self.x.evaluate(table))
        return ~m if self.negate else m

    def __repr__(self):
        return f"{self.x!r} is {'not ' if self.negate else ''}null"


# ---------------------------------------------------------------------------
# hoisted literals (plan normalization)
# ---------------------------------------------------------------------------
# Binding stack for hoisted literal slots.  ``normalize.normalize`` rewrites
# ``("lit", v)`` / ``("isin", x, values)`` leaves into slot references so
# structurally-equal plans from different tenants serialize identically; the
# actual values are passed to the compiled program as *traced arguments* and
# bound here for the duration of one trace/evaluation.  The stack is consulted
# synchronously while jax traces the jitted body; it is thread-LOCAL because
# the cohort-query service traces on its main thread while a realization
# worker concurrently replays host-side algebra — each thread sees only its
# own bindings.
_BOUND_LOCAL = threading.local()


def _bound_stack() -> List[Tuple[Sequence, Sequence]]:
    stack = getattr(_BOUND_LOCAL, "stack", None)
    if stack is None:
        stack = _BOUND_LOCAL.stack = []
    return stack


@contextlib.contextmanager
def bound_params(lits: Sequence, vecs: Sequence):
    """Bind the literal/whitelist vectors hoisted-Expr slots read from.

    ``lits[i]`` backs ``HoistedLit(slot=i)`` (a scalar, possibly traced);
    ``vecs[j]`` backs ``HoistedIsIn(slot=j)`` (a 1-D whitelist array)."""
    stack = _bound_stack()
    stack.append((tuple(lits), tuple(vecs)))
    try:
        yield
    finally:
        stack.pop()


def current_bound_params() -> Optional[Tuple[Sequence, Sequence]]:
    """The innermost ``bound_params`` binding on this thread, or None.  The
    executor hands this to the Pallas predicate kernel so hoisted slots
    become kernel operands (``kernels.predicate`` stays import-light — it
    never reads this module's state itself)."""
    stack = _bound_stack()
    return stack[-1] if stack else None


def _bound(kind: int, slot: int):
    stack = _bound_stack()
    if not stack:
        raise RuntimeError(
            "hoisted Expr evaluated outside expr.bound_params(...); "
            "normalized plans need their literal vector bound at execution")
    vec = stack[-1][kind]
    if slot >= len(vec):
        raise IndexError(f"hoisted slot {slot} out of range "
                         f"({len(vec)} bound)")
    return vec[slot]


class HoistedLit(Expr):
    """A scalar literal hoisted out of the plan into params slot ``slot``.

    Serializes as ``("hlit", slot)`` — no value — so plans differing only in
    literal values share one structural key (and one compiled executable);
    the value arrives as a traced scalar via ``bound_params``."""

    __slots__ = ("slot",)

    def __init__(self, slot: int):
        object.__setattr__(self, "slot", int(slot))

    def __setattr__(self, *a):
        raise AttributeError("Expr nodes are immutable")

    def required_columns(self):
        return frozenset()

    def to_param(self):
        return ("hlit", self.slot)

    def evaluate(self, table):
        return _bound(0, self.slot)

    def __repr__(self):
        return f"?{self.slot}"


class HoistedIsIn(Expr):
    """Set membership against a hoisted whitelist (params slot ``slot``).

    The whitelist *size* and element kind stay structural (``n``,
    ``isfloat`` — they fix the traced vector's shape/dtype); the member
    values travel in the params vector.  An empty whitelist matches nothing,
    mirroring ``IsIn``."""

    __slots__ = ("x", "slot", "n", "isfloat")

    def __init__(self, x: Expr, slot: int, n: int, isfloat: bool):
        object.__setattr__(self, "x", x)
        object.__setattr__(self, "slot", int(slot))
        object.__setattr__(self, "n", int(n))
        object.__setattr__(self, "isfloat", bool(isfloat))

    def __setattr__(self, *a):
        raise AttributeError("Expr nodes are immutable")

    def required_columns(self):
        return self.x.required_columns()

    def to_param(self):
        return ("hisin", self.x.to_param(), self.slot, self.n, self.isfloat)

    def evaluate(self, table):
        v = self.x.evaluate(table)
        if self.n == 0:  # empty whitelist matches nothing
            return jnp.zeros(jnp.shape(v), bool)
        return jnp.isin(v, _bound(1, self.slot))

    def __repr__(self):
        return f"{self.x!r} in ?set{self.slot}<{self.n}>"


# ---------------------------------------------------------------------------
# factories / combinators
# ---------------------------------------------------------------------------
def col(name: str) -> Col:
    """Reference a table column by name — the DSL entry point."""
    return Col(name)


def lit(value) -> Lit:
    return Lit(value)


def all_of(*exprs: Expr) -> Expr:
    """Conjunction of one or more predicates (left-assoc ``&`` fold)."""
    if not exprs:
        raise ValueError("all_of needs at least one expression")
    return functools.reduce(_op.and_, exprs)


def any_of(*exprs: Expr) -> Expr:
    if not exprs:
        raise ValueError("any_of needs at least one expression")
    return functools.reduce(_op.or_, exprs)


# ---------------------------------------------------------------------------
# (de)serialization + node re-expression
# ---------------------------------------------------------------------------
def expr_from_param(p: Tuple) -> Expr:
    """Rebuild an Expr tree from its ``to_param()`` nested-tuple form."""
    tag = p[0]
    if tag == "col":
        return Col(p[1])
    if tag == "lit":
        return Lit(p[1])
    if tag == "cmp":
        return Cmp(p[1], expr_from_param(p[2]), expr_from_param(p[3]))
    if tag == "arith":
        return Arith(p[1], expr_from_param(p[2]), expr_from_param(p[3]))
    if tag == "bool":
        return BoolOp(p[1], expr_from_param(p[2]), expr_from_param(p[3]))
    if tag == "not":
        return Not(expr_from_param(p[1]))
    if tag == "isin":
        return IsIn(expr_from_param(p[1]), p[2])
    if tag == "hlit":
        return HoistedLit(p[1])
    if tag == "hisin":
        return HoistedIsIn(expr_from_param(p[1]), p[2], p[3], p[4])
    if tag == "isnull":
        return NullTest(expr_from_param(p[1]), negate=False)
    if tag == "notnull":
        return NullTest(expr_from_param(p[1]), negate=True)
    raise ValueError(f"unknown Expr param tag {tag!r}")


def as_param(e: Union[Expr, Tuple]) -> Tuple:
    """Accept an Expr or an already-serialized param; return the param."""
    if isinstance(e, Expr):
        return e.to_param()
    if isinstance(e, tuple):
        expr_from_param(e)  # validate
        return e
    raise TypeError(f"expected Expr or serialized param, got {type(e).__name__}")


def required_columns_of_param(p: Tuple) -> frozenset:
    return expr_from_param(p).required_columns()


def fused_predicate(null_cols: Sequence[str] = (),
                    filters: Sequence[Tuple[str, Tuple]] = (),
                    exprs: Sequence[Tuple] = ()) -> Optional[Expr]:
    """Compile a fused_mask node's accumulated conjuncts — legacy null
    columns, legacy (col, codes) whitelists, and serialized Exprs — into ONE
    Expr, so the executor evaluates a single mask function per scan branch
    (one pass over the projected columns)."""
    parts = [col(c).not_null() for c in null_cols]
    parts += [col(c).isin(codes) for c, codes in filters]
    parts += [expr_from_param(e) for e in exprs]
    if not parts:
        return None
    return all_of(*parts)


def node_predicate(node) -> Optional[Expr]:
    """Re-express any predicate-ish plan node as an Expr (the canonical
    view): ``predicate``/``drop_nulls``/``value_filter``/``fused_mask`` and
    the bounds of ``slice_time``.  Returns None for non-predicate ops."""
    op = node.op
    if op == "predicate":
        return expr_from_param(node.get("expr"))
    if op == "drop_nulls":
        return all_of(*[col(c).not_null() for c in node.get("cols")])
    if op == "value_filter":
        return col(node.get("col")).isin(node.get("codes"))
    if op == "fused_mask":
        return fused_predicate(node.get("null_cols") or (),
                               node.get("filters") or (),
                               node.get("exprs") or ())
    if op == "slice_time":
        return col(node.get("col")).between(node.get("lo"), node.get("hi"))
    return None


def render_param(p: Tuple) -> str:
    """Compact human-readable form for OperationLog entries."""
    return repr(expr_from_param(p))


def param_conjuncts(p: Tuple) -> Tuple[Tuple, ...]:
    """Split a serialized Expr into its top-level AND conjuncts.

    The static analyzer reasons conjunct-by-conjunct (interval intersection,
    constant folding): ``(a < 3) & (a > 5) & b.not_null()`` yields three
    parts.  Non-conjunction roots come back as a single-element tuple."""
    if isinstance(p, tuple) and p and p[0] == "bool" and p[1] == "and":
        return param_conjuncts(p[2]) + param_conjuncts(p[3])
    return (p,)


def const_fold_param(p: Tuple):
    """Evaluate a serialized Expr that touches no columns or hoisted slots.

    Returns the folded Python value, or ``None`` when the result depends on
    runtime data (column refs, hoisted slots, unsupported folds).  Boolean
    connectives only fold over boolean operands — predicate algebra on raw
    ints is left to the runtime's bitwise semantics.  ``isin`` over an empty
    whitelist folds to ``False`` regardless of its operand: no value is ever
    a member of the empty set (the analyzer's always-false check rides on
    this)."""
    tag = p[0]
    if tag == "lit":
        return p[1]
    if tag == "cmp":
        l, r = const_fold_param(p[2]), const_fold_param(p[3])
        if l is None or r is None:
            return None
        try:
            return bool(_CMP_FNS[p[1]](l, r))
        except TypeError:
            return None
    if tag == "arith":
        l, r = const_fold_param(p[2]), const_fold_param(p[3])
        if l is None or r is None:
            return None
        try:
            return _ARITH_FNS[p[1]](l, r)
        except (TypeError, ZeroDivisionError):
            return None
    if tag == "bool":
        l, r = const_fold_param(p[2]), const_fold_param(p[3])
        l = l if isinstance(l, bool) else None
        r = r if isinstance(r, bool) else None
        if p[1] == "and":
            if l is False or r is False:
                return False
            if l is True and r is True:
                return True
        else:
            if l is True or r is True:
                return True
            if l is False and r is False:
                return False
        return None
    if tag == "not":
        x = const_fold_param(p[1])
        return (not x) if isinstance(x, bool) else None
    if tag == "isin":
        if len(p[2]) == 0:
            return False
        x = const_fold_param(p[1])
        if x is None:
            return None
        try:
            return any(x == v for v in p[2])
        except TypeError:
            return None
    if tag in ("isnull", "notnull"):
        x = const_fold_param(p[1])
        if x is None:
            return None
        null = (isinstance(x, float) and x != x) or x == _NULL_SENTINEL_INT
        return null if tag == "isnull" else not null
    return None  # col, hlit, hisin: runtime-dependent


# ---------------------------------------------------------------------------
# CohortExpr: cohort-algebra strings with precedence + parentheses
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CohortRef:
    """A named study output used as a cohort operand."""

    name: str


@dataclasses.dataclass(frozen=True)
class CohortCombine:
    """Binary cohort algebra: ``&`` (∩), ``|`` (∪), ``-`` (\\)."""

    op: str
    left: Union["CohortRef", "CohortCombine"]
    right: Union["CohortRef", "CohortCombine"]


class CohortParseError(ValueError):
    """A cohort-algebra syntax error with character position.

    ``offset`` is the 0-based character offset of the offending token in the
    submitted string (the string's end for truncated expressions); the
    message carries a caret snippet so wire-level errors (``SPEC-012``) point
    at the exact character.  Subclasses ``ValueError`` so every pre-existing
    caller's ``except ValueError`` keeps working."""

    def __init__(self, reason: str, expr: str, offset: int) -> None:
        self.reason = reason
        self.expr_text = expr
        self.offset = int(offset)
        caret = "\n  " + expr + "\n  " + " " * self.offset + "^"
        super().__init__(
            f"{reason} at offset {self.offset} in cohort expression{caret}")


def _tokenize_cohort(expr: str):
    """Whitespace-first tokenizer with paren peeling.  Operand names keep
    every non-paren character (so legacy names like ``drug_purchases[cip13]``
    or hyphenated names survive); operators must be whitespace-separated,
    exactly as in the historical flat grammar; parentheses may abut names.
    Returns ``(token, offset)`` pairs — offsets index into ``expr`` so parse
    errors can point at the offending character."""
    toks = []
    k = 0
    for raw in expr.split():
        k = expr.index(raw, k)                   # offset of this word
        i, j = 0, len(raw)
        while i < j and raw[i] == "(":
            toks.append(("(", k + i))
            i += 1
        trail = []
        while j > i and raw[j - 1] == ")":
            j -= 1
            trail.append((")", k + j))
        if i < j:
            toks.append((raw[i:j], k + i))
        toks.extend(reversed(trail))
        k += len(raw)
    return toks


def parse_cohort_expr(expr: str) -> Union[CohortRef, CohortCombine]:
    """Recursive-descent parser for cohort algebra strings.

    Grammar (``&`` binds tighter than ``|`` and ``-``; both levels are
    left-associative, so legacy flat expressions like
    ``"exposed & base - fractured"`` parse to the identical
    ``((exposed ∩ base) \\ fractured)``)::

        expr := term (("|" | "-") term)*
        term := atom ("&" atom)*
        atom := NAME | "(" expr ")"

    Syntax errors raise ``CohortParseError`` (a ``ValueError``) carrying the
    character offset and a caret snippet.
    """
    toks = _tokenize_cohort(expr)
    end = len(expr)
    if not toks:
        raise CohortParseError("empty cohort expression", expr, 0)
    pos = [0]

    def peek():
        return toks[pos[0]][0] if pos[0] < len(toks) else None

    def here():
        return toks[pos[0]][1] if pos[0] < len(toks) else end

    def take():
        t = peek()
        pos[0] += 1
        return t

    def parse_atom():
        at = here()
        t = take()
        if t == "(":
            node = parse_union()
            if peek() != ")":
                raise CohortParseError("unbalanced parentheses", expr, here())
            take()
            return node
        if t is None or t in ("&", "|", "-", ")"):
            raise CohortParseError(
                f"expected cohort name, got {t!r}", expr, at)
        return CohortRef(t)

    def parse_inter():
        node = parse_atom()
        while peek() == "&":
            take()
            node = CohortCombine("&", node, parse_atom())
        return node

    def parse_union():
        node = parse_inter()
        while peek() in ("|", "-"):
            node = CohortCombine(take(), node, parse_inter())
        return node

    node = parse_union()
    if pos[0] != len(toks):
        raise CohortParseError(
            f"unexpected token {peek()!r}", expr, here())
    return node
