"""Seeded-defect plan fixtures: one deliberately-broken plan per analyzer
diagnostic code.

Shared by the test suite (``tests/test_analyze.py`` asserts the exact code
fires on each fixture) and the ``tools/plan_lint.py`` CI gate (which fails
if the shipped analyzer stops detecting any defect class).  Each builder
returns ``(plan, analyze_kwargs)`` — some defects only manifest against a
bound table environment (unknown sources, dtype mismatches, misaligned
capacities), so the kwargs carry the tables/shard context the analyzer
needs.

Also hosts ``golden_studies()`` — the example-pipeline mirrors the plan
goldens pin — so the lint CLI and the smoke ``analyze`` gate exercise the
same plans as ``tests/test_plan_goldens.py`` without importing test code.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, Tuple

import jax.numpy as jnp

from repro.core.columnar import ColumnarTable
from repro.kernels.predicate import MAX_ISIN_VALUES
from repro.study import optimizer as _opt
from repro.study.expr import _NULL_SENTINEL_INT, col, lit
from repro.study.plan import Plan, PlanBuilder

__all__ = ["DEFECTS", "build_defect", "all_defects", "golden_studies"]


def _table(n: int = 64, dtype=jnp.int32, cols=("x",)) -> ColumnarTable:
    return ColumnarTable.from_columns(
        {c: jnp.arange(n, dtype=dtype) for c in cols})


def _scan(b: PlanBuilder, cols=("x",)) -> int:
    return b.scan_star("EV", star="synthetic", columns=tuple(cols))


def _out(b: PlanBuilder, nid: int, name: str = "out") -> Plan:
    b.set_output(name, b.compact(nid))
    return b.build()


def _sp001() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    t = b.scan("MISSING_SOURCE")
    return _out(b, t), {"tables": {"EV": _table()}}


def _sp002() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    t = _scan(b, cols=("a", "b"))
    t = b.select(t, ("a",))                      # drops b ...
    t = b.predicate(t, col("b") > 0)             # ... then reads it
    return _out(b, t), {}


def _sp003() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    t = _scan(b)
    t = b.predicate(t, (col("x") < 3) & (col("x") > 5))
    return _out(b, t), {}


def _sp004() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    t = _scan(b)
    t = b.predicate(t, (col("x") >= 0) & (lit(2) < 3))
    return _out(b, t), {}


def _sp005() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    t = _scan(b)
    t = b.predicate(t, col("x").isin([_NULL_SENTINEL_INT, 5]))
    return _out(b, t), {}


def _sp006() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    left = b.scan("L")
    right = b.scan("R")
    t = b.lookup_join(left, right, left_key="pid", right_key="pid",
                      prefix="r_")
    tables = {"L": _table(cols=("pid", "v")),
              "R": _table(dtype=jnp.float32, cols=("pid", "w"))}
    return _out(b, t), {"tables": tables}


def _sp007() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    left = _scan(b, cols=("pid", "v"))
    right = b.scan_star("DIM", columns=("pid", "w"))
    t = b.expand_join(left, right, left_key="pid", right_key="pid",
                      capacity=100, prefix="d_")     # 100 % 64 != 0
    return _out(b, t), {"n_shards": 2}


def _sp008() -> Tuple[Plan, Dict[str, Any]]:
    # an isin whitelist past the kernel's VMEM operand budget, force-stamped
    # pallas (the optimizer would refuse the stamp): the one shape that
    # still demotes to jnp when served now that hoisted literals are
    # first-class kernel operands
    from repro.study.expr import as_param

    b = PlanBuilder()
    t = _scan(b)
    t = b.add("predicate", (t,),
              expr=as_param(col("x").isin(range(MAX_ISIN_VALUES + 1))),
              engine="pallas", bitset_block=1024, bitset_word="uint32")
    return _out(b, t), {}


def _sp009() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    t = _scan(b)
    t = b.predicate(t, col("x") > 5)
    plan = _out(b, t)
    # stamp the pallas engine the way the optimizer does; the inline
    # literal 5 is what normalize() hoists into a traced slot that rides
    # as a kernel operand (the node keeps pallas when served)
    return _opt.assign_engines(plan, predicate_engine="pallas"), {}


def _sp010() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    a = b.scan("A")
    c = b.scan("B")
    t = b.concat((a, c))
    tables = {"A": _table(n=50), "B": _table(n=50)}  # 50 % 32 != 0
    return _out(b, t), {"tables": tables}


def _sp011() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    left = _scan(b, cols=("pid", "v"))
    right = b.scan_star("DIM", columns=("pid", "w"))
    t = b.expand_join(left, right, left_key="pid", right_key="pid",
                      capacity=None, prefix="d_")
    return _out(b, t), {}


def _sp012() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    a = _scan(b)
    c = _scan(b, cols=("y",))
    t = b.cohort_op("&", a, c, name="bad")           # tables are not cohorts
    b.set_output("bad", t)
    return b.build(), {}


def _sp013() -> Tuple[Plan, Dict[str, Any]]:
    b = PlanBuilder()
    t = _scan(b)
    t = b.add("frobnicate", (t,))
    return _out(b, t), {}


def _sp014() -> Tuple[Plan, Dict[str, Any]]:
    plan, kwargs = _sp003()                          # contradictory mask ...
    return plan, kwargs                              # ... named output rides it


def _sp015() -> Tuple[Plan, Dict[str, Any]]:
    # a fine plan over a manifest whose chunk capacity splits validity words
    b = PlanBuilder()
    t = _scan(b)
    return _out(b, t), {"chunk_capacity": 100}       # 100 % 32 != 0


DEFECTS: Mapping[str, Callable[[], Tuple[Plan, Dict[str, Any]]]] = {
    "SP001": _sp001, "SP002": _sp002, "SP003": _sp003, "SP004": _sp004,
    "SP005": _sp005, "SP006": _sp006, "SP007": _sp007, "SP008": _sp008,
    "SP009": _sp009, "SP010": _sp010, "SP011": _sp011, "SP012": _sp012,
    "SP013": _sp013, "SP014": _sp014, "SP015": _sp015,
}


def build_defect(code: str) -> Tuple[Plan, Dict[str, Any]]:
    """The seeded-defect plan (and analyzer kwargs) for one diagnostic
    code."""
    return DEFECTS[code]()


def all_defects():
    """Yield ``(code, plan, analyze_kwargs)`` for every seeded defect."""
    for code, mk in DEFECTS.items():
        plan, kwargs = mk()
        yield code, plan, kwargs


# ---------------------------------------------------------------------------
# golden example studies (mirrors of examples/quickstart.py and
# examples/cohort_study.py, same shapes the plan goldens pin)
# ---------------------------------------------------------------------------
def golden_studies() -> Dict[str, Any]:
    from repro.core import DCIR_SCHEMA, diagnoses, drug_dispenses, \
        hospital_stays, medical_acts_dcir, medical_acts_pmsi
    from repro.study.api import Study

    quickstart = (Study(n_patients=1_000)
                  .flatten(DCIR_SCHEMA)
                  .extract(drug_dispenses(), name="drug_purchases")
                  .extract(medical_acts_dcir(codes=list(range(30))),
                           name="acts")
                  .patients("IR_BEN")
                  .cohort("base", "extract_patients")
                  .cohort("drugged", "drug_purchases")
                  .cohort("final", "drugged & base - acts")
                  .flow("base", "drugged", "final"))

    study_end = 14_600 + 3 * 365
    cohort_study = (Study(n_patients=2_000, window=(14_600, study_end))
                    .patients("IR_BEN")
                    .extract(drug_dispenses(), name="drug_purchases")
                    .extract(drug_dispenses()
                             .filtered(col("cip13").isin(range(65))
                                       & col("execution_date")
                                       .between(14_600, study_end)),
                             name="prevalent_drugs")
                    .extract(medical_acts_dcir(), name="acts")
                    .extract(medical_acts_pmsi(), name="hospital_acts")
                    .extract(diagnoses(), name="diagnoses")
                    .extract(hospital_stays(), name="stays")
                    .transform("exposures", "drug_purchases",
                               name="exposures", purview_days=60)
                    .concat("all_acts", "acts", "hospital_acts")
                    .transform("fractures", "all_acts", "diagnoses",
                               name="fractures",
                               fracture_act_codes=list(range(30)),
                               fracture_diag_codes=list(range(40)))
                    .transform("follow_up", "extract_patients",
                               "drug_purchases", name="follow_up",
                               study_end=study_end)
                    .cohort("base", "extract_patients")
                    .cohort("exposed", "exposures")
                    .cohort("fractured", "fractures")
                    .cohort("final", "(exposed & base) - fractured")
                    .flow("base", "exposed", "final")
                    .featurize("X", cohort="final", kind="dense",
                               n_buckets=36, bucket_days=31, n_features=128)
                    .featurize("tokens", cohort="final", kind="tokens",
                               seq_len=256))
    return {"quickstart": quickstart, "cohort_study": cohort_study}
