"""Multi-tenant cohort-query service: one resident star schema, many
concurrent Study plans.

SCALPEL3's end state is interactive cohort analysis over a population-scale
claims database — many analysts (tenants) issuing structured cohort queries
against one dataset that stays resident on the accelerator.  The PR 1–5
stack stops at "one Study, one process"; ``CohortQueryService`` adds the
serving layer in three tiers:

1. **Admission + batching** — a ``serving.batching.SlotScheduler``: bounded
   in-flight window (``n_slots``), FIFO-with-priority queueing, per-tenant
   in-flight quotas, bounded queue depth (over-depth submissions are
   *rejected*, not silently dropped).
2. **Plan normalization** (``study.normalize``) — every admitted study's
   optimized plan is canonicalized (stable order, labels stripped, literals
   hoisted into a params vector), so structurally-equal queries from
   different tenants share ONE compiled executable; the literals enter as
   traced arguments.
3. **Cross-tenant subgraph result cache** — each cacheable plan prefix
   (scan/predicate/join subtrees, ``normalize.cut_points``) is
   content-hashed with its literal values resolved back in and keyed by
   table version; a shared scan or predicate bitset is computed once and
   served from the cache for every later query, with LRU eviction under a
   device-byte budget and wholesale invalidation on table-version bump.

Cache injection without recompiles: the compiled program's structure must
not depend on *which* cut nodes hit (that would fork executables per hit
pattern), so each cut node's evaluation is wrapped in ``jax.lax.cond`` over
a traced hit flag — on hit the provided cached table flows through, on miss
the node computes in place.  XLA executes only the taken branch at runtime,
and the flag is a traced scalar, so the hit pattern never retraces.

Results are realized through ``Study._finish_result`` — the exact code path
``Study.run`` uses — so every admitted query's events, cohorts, flowcharts
and features are bit-identical to a solo run of the same study (the
acceptance bar ``benchmarks/serving_bench.py`` gates on).

Sharded residency: with ``mesh=`` the resident tables are pre-padded to the
mesh word quantum (``distributed.pipeline.pad_tables_for_mesh``) and queries
run through ``execute_plan_sharded``; normalization sharing and the subgraph
cache currently apply to the local path only (the sharded plan cache already
dedupes by structure).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.columnar import ColumnarTable
from repro.core.metadata import OperationLog
from repro.kernels import predicate as _pk
from repro.serving.batching import SlotScheduler
from repro.study import executor as _executor
# member imports, not `from repro.study import normalize`: the package
# re-exports the normalize() function, shadowing the submodule attribute
from repro.study.normalize import (
    NormalPlan, cut_points, device_params, normalize, params_signature,
    subgraph_hashes,
)
from repro.study.analyze import PlanValidationError, analyze as _analyze_plan
from repro.study.api import Study, StudyResult
from repro.study.expr import bound_params
from repro.study.optimizer import OPTIMIZER_VERSION
from repro.study.plan import Plan, STATS_OPS

__all__ = ["CohortQueryService", "ServiceConfig", "ServiceStats",
           "TenantStats", "QueryTicket"]


# ---------------------------------------------------------------------------
# config / audit surface
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServiceConfig:
    n_slots: int = 8                      # in-flight admission window
    per_tenant_inflight: int = 2          # per-tenant quota within the window
    max_queue: int = 256                  # queue depth; beyond this: reject
    cache_budget_bytes: int = 256 << 20   # subgraph-cache LRU budget
    engine: str = "xla"
    predicate_engine: Optional[str] = None  # None/"auto" resolve by backend


@dataclasses.dataclass
class TenantStats:
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    rejected: int = 0
    failed: int = 0
    invalid: int = 0     # plans rejected by admission-time static analysis
    demoted: int = 0     # predicate nodes normalization demoted pallas->jnp


@dataclasses.dataclass
class ServiceStats:
    """The audit surface: per-tenant admission counts plus cache/compile
    counters.  Mirrored into the service ``OperationLog`` per event."""

    tenants: Dict[str, TenantStats] = dataclasses.field(default_factory=dict)
    queries: int = 0
    compile_count: int = 0            # distinct compiled executables built
    cache_hits: int = 0               # cut subgraphs served from cache
    cache_misses: int = 0             # cut subgraphs computed + inserted
    cache_evictions: int = 0
    cache_entries: int = 0
    cache_bytes: int = 0
    table_version: int = 0
    plans_rejected: int = 0           # error-level static analysis findings
    demotions: int = 0                # pallas->jnp normalization demotions

    def tenant(self, name: str) -> TenantStats:
        return self.tenants.setdefault(name, TenantStats())

    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def snapshot(self) -> Dict[str, Any]:
        return {
            "tenants": {k: dataclasses.asdict(v)
                        for k, v in sorted(self.tenants.items())},
            "queries": self.queries,
            "compile_count": self.compile_count,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": round(self.hit_rate(), 4),
            "cache_evictions": self.cache_evictions,
            "cache_entries": self.cache_entries,
            "cache_bytes": self.cache_bytes,
            "table_version": self.table_version,
            "plans_rejected": self.plans_rejected,
            "demotions": self.demotions,
        }


@dataclasses.dataclass
class QueryTicket:
    """One submitted study: filled in as it moves queued -> done/failed."""

    tenant: str
    study: Study
    priority: int = 0
    seq: int = -1
    status: str = "queued"    # queued | rejected | invalid | done | failed
    result: Optional[StudyResult] = None
    error: Optional[BaseException] = None
    cache_hits: int = 0
    cache_misses: int = 0
    compiled: bool = False            # this query built a new executable
    latency_s: float = 0.0


class _Count:
    def __init__(self, c: int) -> None:
        self.count = int(c)


# ---------------------------------------------------------------------------
# compiled shape programs + cache entries
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Program:
    fn: Callable                       # jit(env, lits, vecs, cut_tabs, flags)
    cut_ids: Tuple[int, ...]
    zeros: Dict[int, Any]              # per-cut miss placeholder pytrees


@dataclasses.dataclass
class _CacheEntry:
    value: Any                         # device ColumnarTable
    stats: Optional[Dict[str, int]]    # host FlatteningStats (STATS_OPS cuts)
    nbytes: int


def _table_nbytes(t: ColumnarTable) -> int:
    return int(sum(np.dtype(c.dtype).itemsize * int(np.prod(c.shape))
                   for c in t.columns.values())
               + np.dtype(t.valid.dtype).itemsize * int(np.prod(t.valid.shape))
               + 4)


def _zeros_like_struct(struct):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------
class CohortQueryService:
    """Admit many tenants' Study plans against one resident table set.

    Synchronous reference implementation: ``submit`` queues, ``step`` admits
    one window and runs it, ``drain`` runs to empty.  See the module
    docstring for the three-layer architecture.
    """

    def __init__(self, tables: Dict[str, ColumnarTable],
                 table_version: int = 0,
                 config: Optional[ServiceConfig] = None,
                 mesh=None, axis_name: str = "data",
                 log: Optional[OperationLog] = None):
        self.config = config or ServiceConfig()
        self.mesh = mesh
        self.axis_name = axis_name
        self.log = log if log is not None else OperationLog()
        self.stats = ServiceStats(table_version=int(table_version))
        self._version = int(table_version)
        self._env: Dict[str, ColumnarTable] = {}
        self._load_tables(tables)
        self._sched = SlotScheduler(
            self.config.n_slots,
            per_key_quota=self.config.per_tenant_inflight,
            max_queue=self.config.max_queue)
        self._seq = 0
        self._programs: Dict[Tuple, _Program] = {}
        self._cache: "OrderedDict[str, _CacheEntry]" = OrderedDict()
        self._cache_bytes = 0

    @classmethod
    def from_npz_dir(cls, dirpath: str, **kwargs) -> "CohortQueryService":
        """Resident service over a star schema persisted by
        ``data.io.save_star`` (one load per table version)."""
        from repro.data.io import load_star

        return cls(load_star(dirpath), **kwargs)

    # -- residency -----------------------------------------------------------
    def _load_tables(self, tables: Dict[str, ColumnarTable]) -> None:
        if self.mesh is not None:
            from repro.distributed.pipeline import pad_tables_for_mesh

            tables = pad_tables_for_mesh(tables,
                                         self.mesh.shape[self.axis_name])
        # loaded ONCE per table version: device residency is the service's
        # contract — queries never re-upload sources (leaf-wise device_put:
        # ColumnarTable's pytree round-trip re-packs validity on unflatten)
        self._env = {k: jax.tree.map(jax.device_put, t)
                     for k, t in tables.items()}
        self.log.record(
            op="service:load_tables", inputs={},
            outputs={k: _Count(int(t.count)) for k, t in tables.items()},
            params={"version": self._version,
                    "resident_bytes": sum(_table_nbytes(t)
                                          for t in self._env.values())})

    def update_tables(self, tables: Dict[str, ColumnarTable],
                      version: Optional[int] = None) -> None:
        """Install a new table version: re-residents the star schema, bumps
        the version (invalidating every subgraph-cache entry — the version
        salts the content hashes — and dropping the cached entries' bytes),
        and discards shape programs (table capacities may have changed)."""
        self._version = int(version) if version is not None \
            else self._version + 1
        self.stats.table_version = self._version
        dropped = len(self._cache)
        self._cache.clear()
        self._cache_bytes = 0
        self.stats.cache_entries = 0
        self.stats.cache_bytes = 0
        self._programs.clear()
        self._load_tables(tables)
        self.log.record(op="service:update_tables", inputs={}, outputs={},
                        params={"version": self._version,
                                "cache_dropped": dropped})

    # -- admission -----------------------------------------------------------
    def submit(self, study: Study, tenant: str = "default",
               priority: int = 0) -> QueryTicket:
        """Queue a study for ``tenant``.  Returns its ticket immediately;
        the ticket resolves during ``step``/``drain``.  Over-depth queues
        reject (``status == "rejected"``)."""
        t = QueryTicket(tenant=tenant, study=study, priority=int(priority),
                        seq=self._seq)
        self._seq += 1
        ts = self.stats.tenant(tenant)
        ts.submitted += 1
        if not self._sched.submit(t, key=tenant, priority=priority):
            t.status = "rejected"
            ts.rejected += 1
            self.log.record(op=f"service:reject:{tenant}", inputs={},
                            outputs={}, params={"queued": self._sched.queued()})
        return t

    def step(self) -> int:
        """Admit one window of queued tickets (priority order, per-tenant
        quotas) and run them; returns the number admitted."""
        admitted = self._sched.admit()
        for ticket, tenant in admitted:
            ts = self.stats.tenant(tenant)
            ts.admitted += 1
            try:
                self._run_ticket(ticket)
                ticket.status = "done"
                ts.completed += 1
            except PlanValidationError as e:
                # static analysis rejected the plan at admission — it never
                # touched the compile cache; distinct from runtime failures
                ticket.status = "invalid"
                ticket.error = e
                ts.invalid += 1
                self.stats.plans_rejected += 1
                self.log.record(
                    op=f"service:invalid:{tenant}", inputs={}, outputs={},
                    params={"diagnostics": [str(d) for d in e.diagnostics
                                            if d.severity == "error"][:8]})
            except Exception as e:  # noqa: BLE001 — isolate tenant failures
                ticket.status = "failed"
                ticket.error = e
                ts.failed += 1
                self.log.record(op=f"service:failed:{tenant}", inputs={},
                                outputs={}, params={"error": repr(e)})
            finally:
                self._sched.release(tenant)
        return len(admitted)

    def drain(self) -> None:
        """Run until the queue is empty."""
        while self._sched.queued():
            if not self.step():
                break

    def query(self, study: Study, tenant: str = "default",
              priority: int = 0) -> StudyResult:
        """Submit + drain convenience for single-query callers."""
        t = self.submit(study, tenant=tenant, priority=priority)
        self.drain()
        if t.status == "rejected":
            raise RuntimeError("query rejected: service queue is full")
        if t.error is not None:
            raise t.error
        assert t.result is not None
        return t.result

    # -- execution -----------------------------------------------------------
    def _run_ticket(self, ticket: QueryTicket) -> None:
        t0 = time.perf_counter()
        study = ticket.study
        peng_arg = self.config.predicate_engine
        plan = study.optimized_plan(tables=self._env,
                                    predicate_engine=peng_arg or "auto",
                                    engine=self.config.engine)
        # admission-time static analysis: error-level plans (unknown
        # sources, dropped-column reads, provably-empty masks, kind
        # mismatches) are rejected BEFORE they reach normalization or the
        # compile cache — a broken tenant plan must not cost a compile slot
        # or poison shared executables
        n_shards = (self.mesh.shape[self.axis_name]
                    if self.mesh is not None else 1)
        diags = _analyze_plan(plan, tables=self._env, n_shards=n_shards,
                              n_patients=study.n_patients)
        if any(d.severity == "error" for d in diags):
            raise PlanValidationError(diags)
        req_log = OperationLog()
        if self.mesh is not None:
            # sharded passthrough: the mesh plan cache dedupes by structure;
            # normalization sharing + subgraph caching are local-path only
            from repro.distributed.pipeline import execute_plan_sharded

            vals, counts, join_stats = execute_plan_sharded(
                plan, self._env, study.n_patients, self.mesh,
                axis_name=self.axis_name, engine=self.config.engine,
                predicate_engine=peng_arg)
            _executor.record_plan(plan, counts, req_log, self.config.engine,
                                  stats=join_stats, predicate_engine=peng_arg)
        else:
            vals, join_stats = self._run_local(ticket, study, plan)
        for i, d in join_stats.items():
            d.setdefault("stage", plan.nodes[i].label())
        ticket.result = study._finish_result(plan, vals, join_stats, req_log)
        ticket.latency_s = time.perf_counter() - t0
        self.stats.queries += 1
        self.log.record(
            op=f"service:query:{ticket.tenant}", inputs={},
            outputs={name: _Count(t.count)
                     for name, t in ticket.result.events.items()},
            params={"plan_nodes": len(plan.nodes),
                    "cache_hits": ticket.cache_hits,
                    "cache_misses": ticket.cache_misses,
                    "compiled": ticket.compiled,
                    "latency_us": round(ticket.latency_s * 1e6, 1)})

    def _run_local(self, ticket: QueryTicket, study: Study, plan: Plan):
        """Normalize -> shared executable -> subgraph cache -> canonical
        values mapped back to the original plan's node ids."""
        peng = _pk.resolve_engine(self.config.predicate_engine,
                                  self.config.engine)
        nplan = normalize(plan)
        if nplan.demoted:
            # satellite of the engine-feasibility analysis (SP009): the
            # silent pallas->jnp demotion is now auditable — logged per
            # query and counted per tenant
            ts = self.stats.tenant(ticket.tenant)
            ts.demoted += len(nplan.demoted)
            self.stats.demotions += len(nplan.demoted)
            self.log.record(
                op=f"service:demote:{ticket.tenant}", inputs={}, outputs={},
                params={"nodes": list(nplan.demoted),
                        "engine": "pallas->jnp",
                        "reason": "hoisted-literal predicates run the "
                                  "value-generic jnp engine"})
        lits, vecs = device_params(nplan)
        env = {s: self._env[s] for s in nplan.plan.sources()}
        prog = self._program(ticket, nplan, study.n_patients, peng, env,
                             lits, vecs)

        salt = (self._version, study.n_patients, self.config.engine, peng,
                OPTIMIZER_VERSION)
        hashes = subgraph_hashes(nplan, salt=salt)
        flags: Dict[int, Any] = {}
        cut_tabs: Dict[int, Any] = {}
        # entries pinned at lookup time: a later miss's insert may LRU-evict
        # a hit of this very query, but its device value stays referenced
        hit_entries: Dict[int, _CacheEntry] = {}
        for i in prog.cut_ids:
            entry = self._cache.get(hashes[i])
            if entry is not None:
                self._cache.move_to_end(hashes[i])
                flags[i] = jnp.asarray(True)
                cut_tabs[i] = entry.value
                hit_entries[i] = entry
            else:
                flags[i] = jnp.asarray(False)
                cut_tabs[i] = prog.zeros[i]

        keep_vals, cut_vals, stats = prog.fn(env, lits, vecs, cut_tabs, flags)

        host_stats = _executor._host_stats(stats)
        for i in prog.cut_ids:
            if i in hit_entries:
                ticket.cache_hits += 1
                self.stats.cache_hits += 1
                if hit_entries[i].stats is not None:
                    host_stats[i] = dict(hit_entries[i].stats)
            else:
                ticket.cache_misses += 1
                self.stats.cache_misses += 1
                self._insert(hashes[i], cut_vals[i], host_stats.get(i))

        # canonical ids -> original ids (many-to-one on the canonical side)
        vals = {}
        stats_orig: Dict[int, Dict[str, int]] = {}
        canon_of = nplan.orig_to_canon()
        keep_orig = _executor.keep_ids(plan)
        for oi in range(len(plan.nodes)):
            ci = canon_of.get(oi)
            if ci is None:
                continue
            if oi in keep_orig and ci in keep_vals:
                vals[oi] = keep_vals[ci]
            if ci in host_stats:
                stats_orig[oi] = dict(host_stats[ci])
        return vals, stats_orig

    def _program(self, ticket: QueryTicket, nplan: NormalPlan,
                 n_patients: int, peng: str, env, lits, vecs) -> _Program:
        skey = (nplan.plan.key(), n_patients, self.config.engine, peng,
                params_signature(lits, vecs))
        prog = self._programs.get(skey)
        if prog is not None:
            return prog
        plan = nplan.plan
        engine = self.config.engine
        cut_ids = cut_points(plan)
        cut_set = frozenset(cut_ids)
        keep = _executor.keep_ids(plan)
        traced = _executor.traced_ids(plan)

        def _cut_structs(env, lits, vecs):
            with bound_params(lits, vecs):
                vals, _, stats = _executor.run_plan_body(
                    plan, env, n_patients, engine, predicate_engine=peng)
            return {i: (vals[i], stats.get(i)) for i in cut_ids}

        struct = jax.eval_shape(_cut_structs, env, lits, vecs)

        def body(env, lits, vecs, cut_tabs, flags):
            with bound_params(lits, vecs):
                vals: Dict[int, Any] = {}
                stats: Dict[int, Any] = {}
                for i in traced:
                    node = plan.nodes[i]
                    ins = [vals[j] for j in node.inputs]
                    if i in cut_set:
                        # structure-stable cache injection: the cond picks
                        # between the cached table and computing in place,
                        # so the executable is identical whatever hits
                        def _compute(node=node, ins=ins):
                            out = _executor._eval_node(
                                node, ins, env, n_patients, engine,
                                predicate_engine=peng)
                            if node.op in STATS_OPS:
                                return out
                            return (out, None)

                        def _cached(i=i):
                            st = struct[i][1]
                            return (cut_tabs[i],
                                    None if st is None
                                    else _zeros_like_struct(st))

                        out, st = jax.lax.cond(flags[i], _cached, _compute)
                        if st is not None:
                            stats[i] = st
                    else:
                        out = _executor._eval_node(
                            node, ins, env, n_patients, engine,
                            predicate_engine=peng)
                        if node.op in STATS_OPS:
                            out, stats[i] = out
                    vals[i] = out
                return ({i: vals[i] for i in keep},
                        {i: vals[i] for i in cut_ids},
                        stats)

        prog = _Program(fn=jax.jit(body), cut_ids=cut_ids,
                        zeros={i: _zeros_like_struct(struct[i][0])
                               for i in cut_ids})
        self._programs[skey] = prog
        self.stats.compile_count += 1
        ticket.compiled = True
        self.log.record(op="service:compile", inputs={}, outputs={},
                        params={"plan_nodes": len(plan.nodes),
                                "cut_points": len(cut_ids),
                                "executables": self.stats.compile_count})
        return prog

    # -- subgraph cache ------------------------------------------------------
    def _insert(self, h: str, value: Any,
                stats: Optional[Dict[str, int]]) -> None:
        nbytes = _table_nbytes(value)
        if nbytes > self.config.cache_budget_bytes:
            return                      # larger than the whole budget: skip
        self._cache[h] = _CacheEntry(value=value, stats=stats, nbytes=nbytes)
        self._cache_bytes += nbytes
        while self._cache_bytes > self.config.cache_budget_bytes:
            _, old = self._cache.popitem(last=False)   # LRU eviction
            self._cache_bytes -= old.nbytes
            self.stats.cache_evictions += 1
            self.log.record(op="service:evict", inputs={}, outputs={},
                            params={"freed_bytes": old.nbytes,
                                    "cache_bytes": self._cache_bytes})
        self.stats.cache_entries = len(self._cache)
        self.stats.cache_bytes = self._cache_bytes
