"""Multi-tenant cohort-query service: one resident star schema, many
concurrent Study plans.

SCALPEL3's end state is interactive cohort analysis over a population-scale
claims database — many analysts (tenants) issuing structured cohort queries
against one dataset that stays resident on the accelerator.  The PR 1–5
stack stops at "one Study, one process"; ``CohortQueryService`` adds the
serving layer in three tiers:

1. **Admission + batching** — a ``serving.batching.SlotScheduler``: bounded
   in-flight window (``n_slots``), FIFO-with-priority queueing, per-tenant
   in-flight quotas, bounded queue depth (over-depth submissions are
   *rejected*, not silently dropped).
2. **Plan normalization** (``study.normalize``) — every admitted study's
   optimized plan is canonicalized (stable order, labels stripped, literals
   hoisted into a params vector), so structurally-equal queries from
   different tenants share ONE compiled executable; the literals enter as
   traced arguments.
3. **Cross-tenant subgraph result cache** — each cacheable plan prefix
   (scan/predicate/join subtrees, ``normalize.cut_points``) is
   content-hashed with its literal values resolved back in and keyed by
   table version; a shared scan or predicate bitset is computed once and
   served from the cache for every later query, with LRU eviction under a
   device-byte budget and wholesale invalidation on table-version bump.

Cache injection without recompiles: the compiled program's structure must
not depend on *which* cut nodes hit (that would fork executables per hit
pattern), so each cut node's evaluation is wrapped in ``jax.lax.cond`` over
a traced hit flag — on hit the provided cached table flows through, on miss
the node computes in place.  XLA executes only the taken branch at runtime,
and the flag is a traced scalar, so the hit pattern never retraces.

Async step pipeline: each admitted ticket runs in two stages.  The
*device-submit* stage (optimize, analyze, normalize, program lookup, cache
lookup, dispatch of the compiled program) runs on the calling thread; the
*host-realize* stage (stats transfer, cache insert, ``_finish_result``
replay) runs on a single realization worker, double-buffer style (the same
overlap idiom as ``study.chunked``), so device execution of the next
admitted ticket overlaps host materialization of the previous one.
Scheduler slots release when realization *finishes* — the in-flight window
bounds work actually in flight, not just dispatches.  A submit-stage cache miss
publishes its cut hash in an in-flight registry; a later admission wanting
the same subgraph waits for that realization's insert instead of
recomputing, so pipelined hit/miss accounting matches the synchronous
mode (``ServiceConfig.pipeline=False``) exactly.

Sharded residency: with ``mesh=`` the resident tables are pre-padded to the
mesh word quantum (``distributed.pipeline.pad_tables_for_mesh``) and the
*same* normalization sharing + subgraph cache apply: the compiled program is
a ``shard_map`` body (mirroring ``execute_plan_sharded``'s conventions —
patient-partitioned tables in, psum'd bitsets/counts/stats out) with the
``lax.cond`` hit injection inside, cached cut tables crossing as global
``P(axis)``-sharded operands.  Cache keys and program keys are salted with
the mesh shape + axis so local and sharded entries never collide.  Cut
nodes whose shard-local capacity is not 32-aligned are not injected (their
validity words would straddle shard boundaries); they compute in place.

Results are realized through ``Study._finish_result`` — the exact code path
``Study.run`` uses — so every admitted query's events, cohorts, flowcharts
and features are bit-identical to a solo run of the same study (the
acceptance bar ``benchmarks/serving_bench.py`` gates on).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.columnar import ColumnarTable
from repro.core.metadata import OperationLog
from repro.kernels import predicate as _pk
from repro.serving.batching import SlotScheduler
from repro.study import executor as _executor
# member imports, not `from repro.study import normalize`: the package
# re-exports the normalize() function, shadowing the submodule attribute
from repro.study.normalize import (
    NormalPlan, cut_points, device_params, normalize, params_signature,
    subgraph_hashes,
)
from repro.study.analyze import PlanValidationError, analyze as _analyze_plan
from repro.study.api import Study, StudyResult
from repro.study.expr import bound_params
from repro.study.optimizer import OPTIMIZER_VERSION
from repro.study.plan import COHORT_OPS, Plan, STATS_OPS, TABLE_OPS

__all__ = ["CohortQueryService", "ServiceConfig", "ServiceStats",
           "TenantStats", "QueryTicket"]


# ---------------------------------------------------------------------------
# config / audit surface
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServiceConfig:
    n_slots: int = 8                      # in-flight admission window
    per_tenant_inflight: int = 2          # per-tenant quota within the window
    max_queue: int = 256                  # queue depth; beyond this: reject
    cache_budget_bytes: int = 256 << 20   # subgraph-cache LRU budget
    engine: str = "xla"
    predicate_engine: Optional[str] = None  # None/"auto" resolve by backend
    pipeline: bool = True                 # overlap realize with next submit


@dataclasses.dataclass
class TenantStats:
    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    rejected: int = 0
    failed: int = 0
    invalid: int = 0     # plans rejected by admission-time static analysis
    demoted: int = 0     # predicate nodes normalization demoted pallas->jnp


@dataclasses.dataclass
class ServiceStats:
    """The audit surface: per-tenant admission counts plus cache/compile
    counters.  Mirrored into the service ``OperationLog`` per event."""

    tenants: Dict[str, TenantStats] = dataclasses.field(default_factory=dict)
    queries: int = 0
    compile_count: int = 0            # distinct compiled executables built
    cache_hits: int = 0               # cut subgraphs served from cache
    cache_misses: int = 0             # cut subgraphs computed + inserted
    cache_evictions: int = 0
    cache_entries: int = 0
    cache_bytes: int = 0
    table_version: int = 0
    plans_rejected: int = 0           # error-level static analysis findings
    demotions: int = 0                # pallas->jnp normalization demotions
    submit_s: float = 0.0             # summed device-submit stage time
    realize_s: float = 0.0            # summed host-realize stage time
    wall_s: float = 0.0               # summed drain() wall time

    def tenant(self, name: str) -> TenantStats:
        return self.tenants.setdefault(name, TenantStats())

    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    def overlap_s(self) -> float:
        """Wall time saved by the submit/realize pipeline: the summed stage
        times minus the drain wall they actually took (0 when the service
        has only been stepped outside ``drain``)."""
        if not self.wall_s:
            return 0.0
        return max(0.0, self.submit_s + self.realize_s - self.wall_s)

    def snapshot(self) -> Dict[str, Any]:
        return {
            "tenants": {k: dataclasses.asdict(v)
                        for k, v in sorted(self.tenants.items())},
            "queries": self.queries,
            "compile_count": self.compile_count,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "cache_hit_rate": round(self.hit_rate(), 4),
            "cache_evictions": self.cache_evictions,
            "cache_entries": self.cache_entries,
            "cache_bytes": self.cache_bytes,
            "table_version": self.table_version,
            "plans_rejected": self.plans_rejected,
            "demotions": self.demotions,
            "submit_s": round(self.submit_s, 6),
            "realize_s": round(self.realize_s, 6),
            "wall_s": round(self.wall_s, 6),
            "overlap_s": round(self.overlap_s(), 6),
        }


@dataclasses.dataclass
class QueryTicket:
    """One submitted study: filled in as it moves queued -> done/failed.

    ``wire=True`` marks tickets that entered through the declarative wire
    path (``submit_spec``): their failures are always *structured* — any
    exception class maps to ``status == "invalid"`` with ``SPEC-nnn``/
    ``SPnnn`` error codes, and ``wire_payload()`` renders the ticket as the
    service's JSON response (a traceback never reaches a tenant)."""

    tenant: str
    study: Optional[Study]
    priority: int = 0
    seq: int = -1
    status: str = "queued"    # queued | rejected | invalid | done | failed
    result: Optional[StudyResult] = None
    error: Optional[BaseException] = None
    wire: bool = False                # submitted as a spec via the wire path
    cache_hits: int = 0
    cache_misses: int = 0
    compiled: bool = False            # this query built a new executable
    latency_s: float = 0.0
    submit_s: float = 0.0             # device-submit stage time
    realize_s: float = 0.0            # host-realize stage time
    # in-flight cut registration (see _cut_lookup / _release_cuts)
    _cut_evt: Optional[threading.Event] = dataclasses.field(
        default=None, repr=False, compare=False)
    _cut_hashes: List[str] = dataclasses.field(
        default_factory=list, repr=False, compare=False)

    def wire_payload(self) -> Dict[str, Any]:
        """The ticket as a structured wire response.

        ``done`` -> result summary (event/cohort counts, flow stages, cache
        accounting); ``rejected``/``invalid``/``failed`` -> an ``errors``
        list of ``{code, path|node, message, hint}`` entries
        (``spec.error_payload``).  Exception *types* are mapped to stable
        codes; messages of unexpected exceptions and tracebacks are never
        included."""
        if self.status == "queued":
            return {"status": "queued", "seq": self.seq}
        if self.status == "rejected":
            return {"status": "rejected", "errors": [{
                "code": "SPEC-429",
                "message": "service queue is full; the query was not "
                           "admitted",
                "hint": "resubmit once in-flight queries drain"}]}
        if self.status == "done" and self.result is not None:
            r = self.result
            payload: Dict[str, Any] = {
                "status": "done",
                "events": {k: int(t.count) for k, t in r.events.items()},
                "cohorts": {k: int(c.subject_count())
                            for k, c in r.cohorts.items()},
                "cache_hits": self.cache_hits,
                "cache_misses": self.cache_misses,
                "compiled": self.compiled,
            }
            if r.flow is not None:
                payload["flow"] = [int(c.subject_count())
                                   for c in r.flow.steps]
            if r.features:
                payload["features"] = sorted(r.features)
            return payload
        from repro.study.spec import error_payload
        err = self.error if self.error is not None \
            else RuntimeError("unresolved ticket")
        return {"status": self.status, "errors": error_payload(err)}


class _Count:
    def __init__(self, c: int) -> None:
        self.count = int(c)


# ---------------------------------------------------------------------------
# compiled shape programs + cache entries
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Program:
    fn: Callable                       # jit(env, lits, vecs, cut_tabs, flags)
    cut_ids: Tuple[int, ...]
    zeros: Dict[int, Any]              # per-cut miss placeholder pytrees


@dataclasses.dataclass
class _CacheEntry:
    value: Any                         # device ColumnarTable (global rows)
    stats: Optional[Dict[str, int]]    # host FlatteningStats (STATS_OPS cuts)
    nbytes: int


def _table_nbytes(t: ColumnarTable) -> int:
    return int(sum(np.dtype(c.dtype).itemsize * int(np.prod(c.shape))
                   for c in t.columns.values())
               + np.dtype(t.valid.dtype).itemsize * int(np.prod(t.valid.shape))
               + 4)


def _zeros_like_struct(struct):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)


# ---------------------------------------------------------------------------
# the service
# ---------------------------------------------------------------------------
class CohortQueryService:
    """Admit many tenants' Study plans against one resident table set.

    ``submit`` queues, ``step`` admits one window and dispatches it,
    ``drain`` runs to empty (blocking on in-flight realizations).  With
    ``config.pipeline`` (the default) realization runs on a worker thread so
    the next admission's device work overlaps it; ``pipeline=False`` is the
    synchronous reference mode.  See the module docstring for the
    three-layer architecture.
    """

    def __init__(self, tables: Dict[str, ColumnarTable],
                 table_version: int = 0,
                 config: Optional[ServiceConfig] = None,
                 mesh=None, axis_name: str = "data",
                 log: Optional[OperationLog] = None):
        self.config = config or ServiceConfig()
        self.mesh = mesh
        self.axis_name = axis_name
        self.log = log if log is not None else OperationLog()
        self.stats = ServiceStats(table_version=int(table_version))
        self._version = int(table_version)
        self._env: Dict[str, ColumnarTable] = {}
        self._load_tables(tables)
        self._sched = SlotScheduler(
            self.config.n_slots,
            per_key_quota=self.config.per_tenant_inflight,
            max_queue=self.config.max_queue)
        self._seq = 0
        self._programs: Dict[Tuple, _Program] = {}
        self._cache: "OrderedDict[str, _CacheEntry]" = OrderedDict()
        self._cache_bytes = 0
        # shared mutable state (stats, log, cache, in-flight registry) is
        # touched from the main thread and the realization worker
        self._lock = threading.RLock()
        self._realizer: Optional[ThreadPoolExecutor] = None
        self._pending: "deque[Tuple[QueryTicket, Future]]" = deque()
        self._inflight_cuts: Dict[str, threading.Event] = {}

    @classmethod
    def from_npz_dir(cls, dirpath: str, **kwargs) -> "CohortQueryService":
        """Resident service over a star schema persisted by
        ``data.io.save_star`` (one load per table version)."""
        from repro.data.io import load_star

        return cls(load_star(dirpath), **kwargs)

    # -- residency -----------------------------------------------------------
    def _load_tables(self, tables: Dict[str, ColumnarTable]) -> None:
        if self.mesh is not None:
            from repro.distributed.pipeline import pad_tables_for_mesh

            tables = pad_tables_for_mesh(tables,
                                         self.mesh.shape[self.axis_name])
        # loaded ONCE per table version: device residency is the service's
        # contract — queries never re-upload sources (leaf-wise device_put:
        # ColumnarTable's pytree round-trip re-packs validity on unflatten)
        self._env = {k: jax.tree.map(jax.device_put, t)
                     for k, t in tables.items()}
        self.log.record(
            op="service:load_tables", inputs={},
            outputs={k: _Count(int(t.count)) for k, t in tables.items()},
            params={"version": self._version,
                    "resident_bytes": sum(_table_nbytes(t)
                                          for t in self._env.values())})

    def update_tables(self, tables: Dict[str, ColumnarTable],
                      version: Optional[int] = None) -> None:
        """Install a new table version: re-residents the star schema, bumps
        the version (invalidating every subgraph-cache entry — the version
        salts the content hashes — and dropping the cached entries' bytes),
        and discards shape programs (table capacities may have changed).
        Quiesces in-flight realizations first: they hold references into the
        outgoing table set."""
        self._quiesce()
        with self._lock:
            self._version = int(version) if version is not None \
                else self._version + 1
            self.stats.table_version = self._version
            dropped = len(self._cache)
            self._cache.clear()
            self._cache_bytes = 0
            self.stats.cache_entries = 0
            self.stats.cache_bytes = 0
            self._programs.clear()
            self._load_tables(tables)
            self.log.record(op="service:update_tables", inputs={},
                            outputs={},
                            params={"version": self._version,
                                    "cache_dropped": dropped})

    # -- admission -----------------------------------------------------------
    def submit(self, study: Study, tenant: str = "default",
               priority: int = 0, wire: bool = False) -> QueryTicket:
        """Queue a study for ``tenant``.  Returns its ticket immediately;
        the ticket resolves during ``step``/``drain``.  Over-depth queues
        reject (``status == "rejected"``)."""
        t = QueryTicket(tenant=tenant, study=study, priority=int(priority),
                        seq=self._seq, wire=wire)
        self._seq += 1
        with self._lock:
            self.stats.tenant(tenant).submitted += 1
        if not self._sched.submit(t, key=tenant, priority=priority):
            t.status = "rejected"
            with self._lock:
                self.stats.tenant(tenant).rejected += 1
                self.log.record(op=f"service:reject:{tenant}", inputs={},
                                outputs={},
                                params={"queued": self._sched.queued()})
        return t

    def submit_spec(self, spec: Any, tenant: str = "default",
                    priority: int = 0) -> QueryTicket:
        """Queue a declarative wire-format study spec (``study.spec``).

        The spec validates and compiles *before* admission: a malformed
        payload comes back immediately as an ``"invalid"`` ticket carrying
        every ``SPEC-nnn`` finding (and counts into
        ``stats.plans_rejected``), without consuming a queue slot.  A
        compiling spec queues exactly like the equivalent Python-built
        ``Study`` — same optimize -> analyze -> normalize admission, same
        compiled-executable sharing, same subgraph cache, bit-identical
        results — but its ticket is marked ``wire``: every later failure,
        including ``SPnnn`` analyzer rejections and runtime surprises, is
        rendered structurally by ``QueryTicket.wire_payload()``; no
        exception class leaks a traceback to the tenant."""
        from repro.study.spec import compile_spec, error_payload

        try:
            study = compile_spec(spec)
        except Exception as e:  # noqa: BLE001 — wire admission never raises:
            # SpecValidationError carries its SPEC-nnn issues; anything else
            # renders as a single SPEC-900 entry via error_payload.
            t = QueryTicket(tenant=tenant, study=None,
                            priority=int(priority), seq=self._seq, wire=True)
            self._seq += 1
            t.status = "invalid"
            t.error = e
            with self._lock:
                ts = self.stats.tenant(tenant)
                ts.submitted += 1
                ts.invalid += 1
                self.stats.plans_rejected += 1
                self.log.record(
                    op=f"service:invalid:{tenant}", inputs={}, outputs={},
                    params={"errors": [
                        " ".join(str(d.get(k)) for k in
                                 ("code", "node", "path", "message")
                                 if d.get(k) is not None)
                        for d in error_payload(e)][:8]})
            return t
        return self.submit(study, tenant=tenant, priority=priority,
                           wire=True)

    def step(self) -> int:
        """Admit one window of queued tickets (priority order, per-tenant
        quotas) and run their device-submit stage; returns the number
        admitted.  With ``config.pipeline`` the host-realize stage is handed
        to the realization worker and the slot releases when it completes;
        otherwise it runs inline."""
        self._reap(block=False)
        admitted = self._sched.admit()
        for ticket, tenant in admitted:
            with self._lock:
                self.stats.tenant(tenant).admitted += 1
            try:
                realize = self._submit_ticket(ticket)
            except Exception as e:  # noqa: BLE001 — isolate tenant failures
                self._resolve_failure(ticket, e)
                self._release_cuts(ticket)
                self._sched.release(tenant)
            else:
                if self.config.pipeline:
                    self._pending.append(
                        (ticket,
                         self._pool().submit(self._realize_ticket, ticket,
                                             realize)))
                else:
                    self._realize_ticket(ticket, realize)
        return len(admitted)

    def drain(self) -> None:
        """Run until the queue is empty and every in-flight realization has
        resolved.  The elapsed wall accrues into ``stats.wall_s`` — the
        baseline the pipeline's ``overlap_s`` accounting is measured
        against."""
        t0 = time.perf_counter()
        while True:
            if self.step():
                continue
            if self._pending:
                # nothing admittable: a finishing realization frees slots
                self._reap(block=True)
                continue
            break
        with self._lock:
            self.stats.wall_s += time.perf_counter() - t0

    def query(self, study: Study, tenant: str = "default",
              priority: int = 0) -> StudyResult:
        """Submit + drain convenience for single-query callers."""
        t = self.submit(study, tenant=tenant, priority=priority)
        self.drain()
        if t.status == "rejected":
            raise RuntimeError("query rejected: service queue is full")
        if t.error is not None:
            raise t.error
        assert t.result is not None
        return t.result

    # -- pipeline machinery --------------------------------------------------
    def _pool(self) -> ThreadPoolExecutor:
        if self._realizer is None:
            self._realizer = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="svc-realize")
        return self._realizer

    def _reap(self, block: bool) -> int:
        """Pop finished realizations off the pending deque (FIFO — the
        single worker realizes in submission order).  ``block`` waits for
        the oldest one.  Main-thread only."""
        done = 0
        while self._pending and self._pending[0][1].done():
            self._pending.popleft()
            done += 1
        if block and self._pending:
            self._pending[0][1].result()   # _realize_ticket never raises
            self._pending.popleft()
            done += 1
            while self._pending and self._pending[0][1].done():
                self._pending.popleft()
                done += 1
        return done

    def _quiesce(self) -> None:
        while self._pending:
            self._reap(block=True)

    def _realize_ticket(self, ticket: QueryTicket,
                        realize: Callable[[], None]) -> None:
        try:
            realize()
            with self._lock:
                ticket.status = "done"
                self.stats.tenant(ticket.tenant).completed += 1
        except Exception as e:  # noqa: BLE001 — isolate tenant failures
            self._resolve_failure(ticket, e)
        finally:
            self._release_cuts(ticket)
            self._sched.release(ticket.tenant)

    def _resolve_failure(self, ticket: QueryTicket,
                         e: BaseException) -> None:
        """Resolve a ticket whose submit or realize stage threw.

        ``PlanValidationError`` (admission-time static analysis) always maps
        to ``"invalid"`` — it never touched the compile cache, distinct from
        runtime failures.  Wire tickets map *every* exception to
        ``"invalid"`` too: the wire contract is structured rejection with
        stable codes (``QueryTicket.wire_payload``), never a leaked
        traceback, and each counts into ``stats.plans_rejected``.  Python
        tickets keep the legacy ``"failed"`` status with the exception
        re-raisable from ``ticket.error``."""
        invalid = ticket.wire or isinstance(e, PlanValidationError)
        with self._lock:
            ticket.error = e
            ts = self.stats.tenant(ticket.tenant)
            if invalid:
                from repro.study.spec import error_payload

                ticket.status = "invalid"
                ts.invalid += 1
                self.stats.plans_rejected += 1
                self.log.record(
                    op=f"service:invalid:{ticket.tenant}", inputs={},
                    outputs={},
                    params={"errors": [
                        " ".join(str(d.get(k)) for k in
                                 ("code", "node", "path", "message")
                                 if d.get(k) is not None)
                        for d in error_payload(e)][:8]})
            else:
                ticket.status = "failed"
                ts.failed += 1
                self.log.record(op=f"service:failed:{ticket.tenant}",
                                inputs={}, outputs={},
                                params={"error": repr(e)})

    def _release_cuts(self, ticket: QueryTicket) -> None:
        """Retire the ticket's in-flight cut registrations and wake waiters
        (who re-check the cache — on a failed realization the entry is
        absent and the waiter becomes the computer)."""
        evt = ticket._cut_evt
        if evt is None:
            return
        with self._lock:
            for h in ticket._cut_hashes:
                if self._inflight_cuts.get(h) is evt:
                    del self._inflight_cuts[h]
        evt.set()

    # -- execution -----------------------------------------------------------
    def _submit_ticket(self, ticket: QueryTicket) -> Callable[[], None]:
        """Device-submit stage: optimize, admission analysis, normalize,
        program + cache lookup, dispatch.  Returns the host-realize closure
        (run by ``_realize_ticket``, possibly on the worker)."""
        t0 = time.perf_counter()
        study = ticket.study
        peng_arg = self.config.predicate_engine
        plan = study.optimized_plan(tables=self._env,
                                    predicate_engine=peng_arg or "auto",
                                    engine=self.config.engine)
        # admission-time static analysis: error-level plans (unknown
        # sources, dropped-column reads, provably-empty masks, kind
        # mismatches) are rejected BEFORE they reach normalization or the
        # compile cache — a broken tenant plan must not cost a compile slot
        # or poison shared executables
        n_shards = (self.mesh.shape[self.axis_name]
                    if self.mesh is not None else 1)
        diags = _analyze_plan(plan, tables=self._env, n_shards=n_shards,
                              n_patients=study.n_patients)
        if any(d.severity == "error" for d in diags):
            raise PlanValidationError(diags)
        if self.mesh is not None:
            realize_vals = self._run_sharded(ticket, study, plan)
        else:
            realize_vals = self._run_local(ticket, study, plan)
        ticket.submit_s = time.perf_counter() - t0
        with self._lock:
            self.stats.submit_s += ticket.submit_s

        def realize() -> None:
            t1 = time.perf_counter()
            vals, stats_orig, req_log = realize_vals()
            for i, d in stats_orig.items():
                d.setdefault("stage", plan.nodes[i].label())
            ticket.result = study._finish_result(plan, vals, stats_orig,
                                                 req_log)
            now = time.perf_counter()
            ticket.realize_s = now - t1
            ticket.latency_s = now - t0
            with self._lock:
                self.stats.realize_s += ticket.realize_s
                self.stats.queries += 1
                self.log.record(
                    op=f"service:query:{ticket.tenant}", inputs={},
                    outputs={name: _Count(t.count)
                             for name, t in ticket.result.events.items()},
                    params={"plan_nodes": len(plan.nodes),
                            "cache_hits": ticket.cache_hits,
                            "cache_misses": ticket.cache_misses,
                            "compiled": ticket.compiled,
                            "submit_us": round(ticket.submit_s * 1e6, 1),
                            "realize_us": round(ticket.realize_s * 1e6, 1),
                            "latency_us": round(ticket.latency_s * 1e6, 1)})

        return realize

    def _audit_demotions(self, ticket: QueryTicket,
                         nplan: NormalPlan) -> None:
        if not nplan.demoted:
            return
        # satellite of the engine-feasibility analysis (SP008/SP009): the
        # silent pallas->jnp demotion is auditable — logged per query and
        # counted per tenant.  With hoisted literals now first-class kernel
        # operands this fires only for kernel-infeasible stamps (oversized
        # isin whitelists, non-boolean roots).
        with self._lock:
            self.stats.tenant(ticket.tenant).demoted += len(nplan.demoted)
            self.stats.demotions += len(nplan.demoted)
            self.log.record(
                op=f"service:demote:{ticket.tenant}", inputs={}, outputs={},
                params={"nodes": list(nplan.demoted),
                        "engine": "pallas->jnp",
                        "reason": "kernel-infeasible predicate (oversized "
                                  "isin whitelist or non-boolean root)"})

    def _cut_lookup(self, prog: _Program, hashes: Dict[int, str],
                    ticket: QueryTicket,
                    as_payload: Callable[[_CacheEntry], Any]):
        """Per-cut cache lookup building the injection flags/operands.
        Misses are published in the in-flight registry; a hash another
        ticket is currently realizing is *waited on* (outside the lock) so
        pipelined admissions hit exactly like synchronous ones."""
        if ticket._cut_evt is None:
            ticket._cut_evt = threading.Event()
        flags: Dict[int, Any] = {}
        cut_tabs: Dict[int, Any] = {}
        # entries pinned at lookup time: a later miss's insert may LRU-evict
        # a hit of this very query, but its device value stays referenced
        hit_entries: Dict[int, _CacheEntry] = {}
        for i in prog.cut_ids:
            h = hashes[i]
            while True:
                with self._lock:
                    entry = self._cache.get(h)
                    if entry is not None:
                        self._cache.move_to_end(h)
                        flags[i] = jnp.asarray(True)
                        cut_tabs[i] = as_payload(entry)
                        hit_entries[i] = entry
                        break
                    evt = self._inflight_cuts.get(h)
                    if evt is None or evt is ticket._cut_evt:
                        # we compute it; publish intent for later admissions
                        self._inflight_cuts[h] = ticket._cut_evt
                        if h not in ticket._cut_hashes:
                            ticket._cut_hashes.append(h)
                        flags[i] = jnp.asarray(False)
                        cut_tabs[i] = prog.zeros[i]
                        break
                # an earlier ticket is realizing this subgraph: wait for its
                # insert, then re-check (it may have failed -> we compute)
                evt.wait()
        return flags, cut_tabs, hit_entries

    def _run_local(self, ticket: QueryTicket, study: Study, plan: Plan):
        """Normalize -> shared executable -> subgraph cache; returns the
        realize closure mapping canonical values back to the original
        plan's node ids."""
        peng = _pk.resolve_engine(self.config.predicate_engine,
                                  self.config.engine)
        nplan = normalize(plan)
        self._audit_demotions(ticket, nplan)
        lits, vecs = device_params(nplan)
        env = {s: self._env[s] for s in nplan.plan.sources()}
        prog = self._program(ticket, nplan, study.n_patients, peng, env,
                             lits, vecs)

        salt = (self._version, study.n_patients, self.config.engine, peng,
                OPTIMIZER_VERSION)
        hashes = subgraph_hashes(nplan, salt=salt)
        flags, cut_tabs, hit_entries = self._cut_lookup(
            prog, hashes, ticket, lambda e: e.value)

        keep_vals, cut_vals, stats = prog.fn(env, lits, vecs, cut_tabs, flags)

        def realize_vals():
            host_stats = _executor._host_stats(stats)
            with self._lock:
                for i in prog.cut_ids:
                    if i in hit_entries:
                        ticket.cache_hits += 1
                        self.stats.cache_hits += 1
                        if hit_entries[i].stats is not None:
                            host_stats[i] = dict(hit_entries[i].stats)
                    else:
                        ticket.cache_misses += 1
                        self.stats.cache_misses += 1
                        self._insert(hashes[i], cut_vals[i],
                                     host_stats.get(i))

            # canonical ids -> original ids (many-to-one, canonical side)
            vals = {}
            stats_orig: Dict[int, Dict[str, int]] = {}
            canon_of = nplan.orig_to_canon()
            keep_orig = _executor.keep_ids(plan)
            for oi in range(len(plan.nodes)):
                ci = canon_of.get(oi)
                if ci is None:
                    continue
                if oi in keep_orig and ci in keep_vals:
                    vals[oi] = keep_vals[ci]
                if ci in host_stats:
                    stats_orig[oi] = dict(host_stats[ci])
            return vals, stats_orig, OperationLog()

        return realize_vals

    def _run_sharded(self, ticket: QueryTicket, study: Study, plan: Plan):
        """The sharded twin of ``_run_local``: same normalization sharing
        and subgraph cache, program body under ``shard_map`` (conventions
        mirrored from ``distributed.pipeline.execute_plan_sharded``)."""
        peng = _pk.resolve_engine(self.config.predicate_engine,
                                  self.config.engine)
        nplan = normalize(plan)
        self._audit_demotions(ticket, nplan)
        lits, vecs = device_params(nplan)
        env = {s: self._env[s] for s in nplan.plan.sources()}
        prog = self._program(ticket, nplan, study.n_patients, peng, env,
                             lits, vecs)

        salt = (self._version, study.n_patients, self.config.engine, peng,
                OPTIMIZER_VERSION, self._mesh_key(), self.axis_name)
        hashes = subgraph_hashes(nplan, salt=salt)
        flags, cut_tabs, hit_entries = self._cut_lookup(
            prog, hashes, ticket,
            lambda e: (dict(e.value.columns), e.value.valid))

        cols_in = {s: dict(t.columns) for s, t in env.items()}
        valid_in = {s: t.valid for s, t in env.items()}
        t_out, b_out, counts_vec, s_out, cut_out = prog.fn(
            cols_in, valid_in, lits, vecs, cut_tabs, flags)
        cplan = nplan.plan

        def realize_vals():
            counts_c = {i: int(c) for i, c in
                        zip(_executor.traced_ids(cplan),
                            np.asarray(counts_vec))}
            host_stats = _executor._host_stats(s_out)
            with self._lock:
                for i in prog.cut_ids:
                    if i in hit_entries:
                        ticket.cache_hits += 1
                        self.stats.cache_hits += 1
                        if hit_entries[i].stats is not None:
                            host_stats[i] = dict(hit_entries[i].stats)
                    else:
                        ticket.cache_misses += 1
                        self.stats.cache_misses += 1
                        c, v = cut_out[i]
                        self._insert(
                            hashes[i],
                            ColumnarTable(c, v, jnp.int32(counts_c[i])),
                            host_stats.get(i))

            vals_c: Dict[int, Any] = {
                i: ColumnarTable(c, v, jnp.int32(counts_c[i]))
                for i, (c, v) in t_out.items()}
            vals_c.update(b_out)
            canon_of = nplan.orig_to_canon()
            vals: Dict[int, Any] = {}
            counts: Dict[int, int] = {}
            stats_orig: Dict[int, Dict[str, int]] = {}
            for oi in range(len(plan.nodes)):
                ci = canon_of.get(oi)
                if ci is None:
                    continue
                if ci in vals_c:
                    vals[oi] = vals_c[ci]
                if ci in counts_c:
                    counts[oi] = counts_c[ci]
                if ci in host_stats:
                    stats_orig[oi] = dict(host_stats[ci])
            req_log = OperationLog()
            _executor.record_plan(
                plan, counts, req_log, self.config.engine, stats=stats_orig,
                predicate_engine=self.config.predicate_engine)
            return vals, stats_orig, req_log

        return realize_vals

    # -- compiled shape programs --------------------------------------------
    def _mesh_key(self) -> Tuple:
        m = self.mesh
        return (tuple(m.axis_names),
                tuple(m.shape[a] for a in m.axis_names),
                tuple(d.id for d in np.ravel(m.devices)))

    def _program(self, ticket: QueryTicket, nplan: NormalPlan,
                 n_patients: int, peng: str, env, lits, vecs) -> _Program:
        skey = (nplan.plan.key(), n_patients, self.config.engine, peng,
                params_signature(lits, vecs))
        if self.mesh is not None:
            skey += (self._mesh_key(), self.axis_name)
        prog = self._programs.get(skey)
        if prog is not None:
            return prog
        if self.mesh is not None:
            prog = self._build_sharded_program(nplan, n_patients, peng, env,
                                               lits, vecs)
        else:
            prog = self._build_local_program(nplan, n_patients, peng, env,
                                             lits, vecs)
        self._programs[skey] = prog
        with self._lock:
            self.stats.compile_count += 1
            ticket.compiled = True
            self.log.record(op="service:compile", inputs={}, outputs={},
                            params={"plan_nodes": len(nplan.plan.nodes),
                                    "cut_points": len(prog.cut_ids),
                                    "sharded": self.mesh is not None,
                                    "executables": self.stats.compile_count})
        return prog

    def _build_local_program(self, nplan: NormalPlan, n_patients: int,
                             peng: str, env, lits, vecs) -> _Program:
        plan = nplan.plan
        engine = self.config.engine
        cut_ids = cut_points(plan)
        cut_set = frozenset(cut_ids)
        keep = _executor.keep_ids(plan)
        traced = _executor.traced_ids(plan)

        def _cut_structs(env, lits, vecs):
            with bound_params(lits, vecs):
                vals, _, stats = _executor.run_plan_body(
                    plan, env, n_patients, engine, predicate_engine=peng)
            return {i: (vals[i], stats.get(i)) for i in cut_ids}

        struct = jax.eval_shape(_cut_structs, env, lits, vecs)

        def body(env, lits, vecs, cut_tabs, flags):
            with bound_params(lits, vecs):
                vals: Dict[int, Any] = {}
                stats: Dict[int, Any] = {}
                for i in traced:
                    node = plan.nodes[i]
                    ins = [vals[j] for j in node.inputs]
                    if i in cut_set:
                        # structure-stable cache injection: the cond picks
                        # between the cached table and computing in place,
                        # so the executable is identical whatever hits
                        def _compute(node=node, ins=ins):
                            out = _executor._eval_node(
                                node, ins, env, n_patients, engine,
                                predicate_engine=peng)
                            if node.op in STATS_OPS:
                                return out
                            return (out, None)

                        def _cached(i=i):
                            st = struct[i][1]
                            return (cut_tabs[i],
                                    None if st is None
                                    else _zeros_like_struct(st))

                        out, st = jax.lax.cond(flags[i], _cached, _compute)
                        if st is not None:
                            stats[i] = st
                    else:
                        out = _executor._eval_node(
                            node, ins, env, n_patients, engine,
                            predicate_engine=peng)
                        if node.op in STATS_OPS:
                            out, stats[i] = out
                    vals[i] = out
                return ({i: vals[i] for i in keep},
                        {i: vals[i] for i in cut_ids},
                        stats)

        return _Program(fn=jax.jit(body), cut_ids=cut_ids,
                        zeros={i: _zeros_like_struct(struct[i][0])
                               for i in cut_ids})

    def _build_sharded_program(self, nplan: NormalPlan, n_patients: int,
                               peng: str, env, lits, vecs) -> _Program:
        """Compile the normalized plan as ONE shard_map body with the
        lax.cond cache injection inside.  Export conventions mirror
        ``execute_plan_sharded``: tables cross the boundary as
        ``(columns, valid)`` tuples under ``P(axis)``, cohort bitsets /
        stacked counts / join stats psum out replicated.  Injection-eligible
        cut nodes are those whose shard-local capacity is 32-aligned (the
        cached global words then split on shard row boundaries); the rest
        compute in place, uncached."""
        from jax.sharding import PartitionSpec as P

        from repro.core.bitset import count as _bits_count
        from repro.distributed.pipeline import compat_shard_map

        plan = nplan.plan
        mesh, axis = self.mesh, self.axis_name
        n = mesh.shape[axis]
        engine = self.config.engine
        out_ids = {i for _, i in plan.outputs}
        table_ids = tuple(i for i in sorted(out_ids)
                          if plan.nodes[i].op in TABLE_OPS)
        cohort_ids = tuple(i for i, nd in enumerate(plan.nodes)
                           if nd.op == "cohort_from_events"
                           or (nd.op in COHORT_OPS and i in out_ids))
        ev_ids = tuple(sorted(set(table_ids) | {
            nd.inputs[0] for nd in plan.nodes
            if nd.op == "cohort_from_events"}))
        candidates = cut_points(plan)
        traced = _executor.traced_ids(plan)
        cols_in = {s: dict(t.columns) for s, t in env.items()}
        valid_in = {s: t.valid for s, t in env.items()}

        def _aligned(t):
            # 32-align the local capacity so the shard-concatenated
            # validity words stay row-exact on the host side
            cap = -(-t.capacity // 32) * 32
            return t if cap == t.capacity else t.pad_to(cap)

        def probe(cols, valids, lits, vecs):
            local = {s: ColumnarTable(c, valids[s], _bits_count(valids[s]))
                     for s, c in cols.items()}
            with bound_params(lits, vecs):
                vals, _, stats = _executor.run_plan_body(
                    plan, local, n_patients, engine, axis_name=axis,
                    n_shards=n, predicate_engine=peng)
            return ({i: (dict(vals[i].columns), vals[i].valid)
                     for i in candidates},
                    {i: vals[i].count for i in candidates},
                    {i: stats.get(i) for i in candidates})

        probe_fn = compat_shard_map(
            probe, mesh,
            in_specs=(P(axis), P(axis), P(), P()),
            out_specs=(P(axis), P(), P()))
        cut_struct, cnt_struct, stats_struct = jax.eval_shape(
            probe_fn, cols_in, valid_in, lits, vecs)

        def _eligible(i) -> bool:
            cs, valid = cut_struct[i]
            if not cs:
                return False       # no column to read the capacity from
            rows = next(iter(cs.values())).shape[0]
            return (rows // n) % 32 == 0 and valid.shape[0] * 32 == rows

        cut_ids = tuple(i for i in candidates if _eligible(i))
        cut_set = frozenset(cut_ids)
        zeros = {i: _zeros_like_struct(cut_struct[i]) for i in cut_ids}

        def body(cols, valids, lits, vecs, cut_tabs, flags):
            local = {s: ColumnarTable(c, valids[s], _bits_count(valids[s]))
                     for s, c in cols.items()}
            with bound_params(lits, vecs):
                vals: Dict[int, Any] = {}
                counts: Dict[int, Any] = {}
                stats: Dict[int, Any] = {}
                for i in traced:
                    node = plan.nodes[i]
                    ins = [vals[j] for j in node.inputs]
                    if i in cut_set:
                        def _compute(node=node, ins=ins):
                            out = _executor._eval_node(
                                node, ins, local, n_patients, engine, axis,
                                n, predicate_engine=peng)
                            if node.op in STATS_OPS:
                                return out
                            return (out, None)

                        def _cached(i=i):
                            c, v = cut_tabs[i]
                            cnt = _bits_count(v).astype(cnt_struct[i].dtype)
                            st = stats_struct[i]
                            return (ColumnarTable(c, v, cnt),
                                    None if st is None
                                    else _zeros_like_struct(st))

                        out, st = jax.lax.cond(flags[i], _cached, _compute)
                        if st is not None:
                            stats[i] = st
                    else:
                        out = _executor._eval_node(
                            node, ins, local, n_patients, engine, axis, n,
                            predicate_engine=peng)
                        if node.op in STATS_OPS:
                            out, stats[i] = out
                    vals[i] = out
                    counts[i] = _executor._node_count(node, out)
            t_out = {}
            for i in ev_ids:
                t = _aligned(vals[i])
                t_out[i] = (dict(t.columns), t.valid)
            # eligible cuts are already 32-aligned: export as computed
            cut_out = {i: (dict(vals[i].columns), vals[i].valid)
                       for i in cut_ids}
            b_out = {i: jax.lax.psum(vals[i], axis) for i in cohort_ids}
            ids = tuple(sorted(counts))
            c_out = jax.lax.psum(jnp.stack([counts[i] for i in ids]), axis)
            s_out = jax.lax.psum(stats, axis) if stats else {}
            return t_out, b_out, c_out, s_out, cut_out

        fn = jax.jit(compat_shard_map(
            body, mesh,
            in_specs=(P(axis), P(axis), P(), P(), P(axis), P()),
            out_specs=(P(axis), P(), P(), P(), P(axis))))
        return _Program(fn=fn, cut_ids=cut_ids, zeros=zeros)

    # -- subgraph cache ------------------------------------------------------
    def _insert(self, h: str, value: Any,
                stats: Optional[Dict[str, int]]) -> None:
        """Insert under the service lock (callers hold it).  Idempotent: a
        duplicate hash replaces the old entry without double-counting."""
        nbytes = _table_nbytes(value)
        if nbytes > self.config.cache_budget_bytes:
            return                      # larger than the whole budget: skip
        old = self._cache.pop(h, None)
        if old is not None:
            self._cache_bytes -= old.nbytes
        self._cache[h] = _CacheEntry(value=value, stats=stats, nbytes=nbytes)
        self._cache_bytes += nbytes
        while self._cache_bytes > self.config.cache_budget_bytes:
            _, old = self._cache.popitem(last=False)   # LRU eviction
            self._cache_bytes -= old.nbytes
            self.stats.cache_evictions += 1
            self.log.record(op="service:evict", inputs={}, outputs={},
                            params={"freed_bytes": old.nbytes,
                                    "cache_bytes": self._cache_bytes})
        self.stats.cache_entries = len(self._cache)
        self.stats.cache_bytes = self._cache_bytes
