"""Plan normalization: canonical form for cross-tenant executable sharing.

The executor's jit cache keys on ``Plan.key()`` — the full node tuple — so
two tenants asking the *same question with different constants* ("dispenses
of drug 17" vs "drug 23") compile two executables: the literals are baked
into the node params.  ``normalize`` rewrites an optimized plan into a
canonical form where that no longer happens:

  * **literal hoisting** — every ``("lit", v)`` leaf and every ``("isin", x,
    values)`` whitelist inside predicate exprs is replaced by a slot
    reference (``("hlit", i)`` / ``("hisin", x, j, n, isfloat)``); the values
    move into a params vector (``NormalPlan.lits`` / ``.vecs``) passed to the
    compiled program as *traced arguments* (``expr.bound_params``).  Only
    shape-bearing constants stay structural: whitelist sizes, ``slice_time``
    bounds (they feed the capacity planner) and planned capacities.
  * **alpha-renaming** — tenant-chosen labels are stripped (node ``name``
    params dropped, output names rewritten ``o0, o1, ...`` in canonical
    order).  Column refs are *not* renamed: every tenant queries the same
    resident star schema, so column names are shared vocabulary, not
    tenant-local naming.
  * **stable node ordering** — nodes re-emit in a deterministic order
    (post-order DFS from the outputs, outputs visited by structural hash),
    so builder-order differences between equivalent studies disappear.
  * **conjunct canonicalization** — a ``fused_mask``'s legacy ``null_cols``/
    ``filters`` conjuncts are folded into its ``exprs`` list (in the exact
    order ``expr.fused_predicate`` evaluates them), so equal predicates
    serialize equally regardless of how they were built.

Hoisted predicates keep the Pallas engine: the Expr->bitset codegen takes
hoisted literals as kernel *operands* (SMEM scalars, sorted VMEM whitelist
vectors), so a normalized plan gets cross-tenant compile sharing AND the
fused kernel.  Demotion to ``"jnp"`` is now the exception — it happens only
when the hoisted form is not kernel-compilable (oversized ``isin``
whitelist, non-boolean root), and ``NormalPlan.demoted`` records exactly
those nodes.

The module also provides the service's subgraph identity: ``cut_points``
picks the structurally cacheable nodes (scan/predicate/join prefixes) and
``subgraph_hashes`` content-hashes each node's subtree *with the literal
values resolved back in*, so a cache hit means "this exact computation over
this exact table version".
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.study.plan import Node, Plan, PlanBuilder, PREDICATE_OPS

__all__ = ["NormalPlan", "normalize", "device_params", "params_signature",
           "cut_points", "subgraph_hashes", "CACHEABLE_OPS", "CUT_OPS"]


# ---------------------------------------------------------------------------
# expr-param rewriting helpers
# ---------------------------------------------------------------------------
_EXPR_KEYS = ("expr",)        # params holding ONE serialized Expr
_EXPRS_KEYS = ("exprs",)      # params holding a tuple of serialized Exprs


def _isfloat(values: Sequence) -> bool:
    return any(isinstance(c, float) for c in values)


def _scrub_expr(p: Tuple) -> Tuple:
    """Literal-free view of an expr param (for structural hashing): values
    are dropped, shape-bearing facts (whitelist size/kind) kept."""
    tag = p[0]
    if tag == "lit":
        return ("lit?",)
    if tag == "isin":
        return ("isin?", _scrub_expr(p[1]), len(p[2]), _isfloat(p[2]))
    if tag in ("cmp", "arith", "bool"):
        return (tag, p[1], _scrub_expr(p[2]), _scrub_expr(p[3]))
    if tag in ("not", "isnull", "notnull"):
        return (tag, _scrub_expr(p[1]))
    if tag == "hisin":
        return ("hisin", _scrub_expr(p[1]), p[2], p[3], p[4])
    return p  # col / hlit — already value-free


def _hoist_expr(p: Tuple, lits: List, vecs: List) -> Tuple:
    """Rewrite an expr param: literals -> slot refs, values appended to the
    growing ``lits``/``vecs`` vectors (depth-first, left-to-right — the slot
    order is part of the canonical form)."""
    tag = p[0]
    if tag == "lit":
        lits.append(p[1])
        return ("hlit", len(lits) - 1)
    if tag == "isin":
        inner = _hoist_expr(p[1], lits, vecs)
        vecs.append(tuple(p[2]))
        return ("hisin", inner, len(vecs) - 1, len(p[2]), _isfloat(p[2]))
    if tag in ("cmp", "arith", "bool"):
        return (tag, p[1], _hoist_expr(p[2], lits, vecs),
                _hoist_expr(p[3], lits, vecs))
    if tag in ("not", "isnull", "notnull"):
        return (tag, _hoist_expr(p[1], lits, vecs))
    return p  # col — nothing to hoist; hlit/hisin pass through untouched


def _has_hoisted(p: Tuple) -> bool:
    if not isinstance(p, tuple):
        return False
    if p and p[0] in ("hlit", "hisin"):
        return True
    return any(_has_hoisted(x) for x in p)


class _ParamView:
    """Minimal Node stand-in (``.op`` + ``.get``) so ``expr.node_predicate``
    can re-express a *candidate* hoisted node before it is emitted."""

    def __init__(self, op: str, params: Dict[str, Any]):
        self.op = op
        self._p = params

    def get(self, k: str, default=None):
        return self._p.get(k, default)


def _kernel_compilable(op: str, params: Dict[str, Any]) -> bool:
    """Post-hoisting engine feasibility: hoisted literals are Pallas kernel
    operands, so a hoisted predicate stays on the pallas engine whenever its
    combined Expr still compiles (boolean root, membership budget — hoisted
    whitelists count their structural ``n``)."""
    from repro.kernels import predicate as _pk
    from repro.study.expr import node_predicate

    e = node_predicate(_ParamView(op, params))
    return e is not None and _pk.compilable(e.to_param())


def _resolve_expr(p: Tuple, lits: Sequence, vecs: Sequence) -> Tuple:
    """Inverse of hoisting (for content hashing): slot refs -> concrete
    values."""
    tag = p[0]
    if tag == "hlit":
        return ("lit", lits[p[1]])
    if tag == "hisin":
        return ("isin", _resolve_expr(p[1], lits, vecs), tuple(vecs[p[2]]))
    if tag == "isin":
        return ("isin", _resolve_expr(p[1], lits, vecs), p[2])
    if tag in ("cmp", "arith", "bool"):
        return (tag, p[1], _resolve_expr(p[2], lits, vecs),
                _resolve_expr(p[3], lits, vecs))
    if tag in ("not", "isnull", "notnull"):
        return (tag, _resolve_expr(p[1], lits, vecs))
    return p


def _canonical_param_items(node: Node) -> List[Tuple[str, Any]]:
    """Node params with tenant labels removed and fused_mask conjuncts folded
    into ``exprs`` (mirroring ``expr.fused_predicate``'s evaluation order:
    null tests, whitelist filters, then exprs)."""
    items = [(k, v) for k, v in node.params if k != "name"]
    if node.op == "fused_mask":
        d = dict(items)
        exprs = []
        exprs += [("notnull", ("col", c)) for c in (d.get("null_cols") or ())]
        exprs += [("isin", ("col", c), tuple(codes))
                  for c, codes in (d.get("filters") or ())]
        exprs += list(d.get("exprs") or ())
        d["exprs"] = tuple(exprs)
        d["null_cols"] = ()
        d["filters"] = ()
        items = sorted(d.items())
    return items


# ---------------------------------------------------------------------------
# normal form
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NormalPlan:
    """A canonicalized plan plus the values normalization hoisted out of it.

    ``plan.key()`` is the sharing unit: every structurally-equal query maps
    to the same canonical plan, whatever its literals or labels.  ``lits``/
    ``vecs`` carry this query's concrete values in slot order; ``node_map``
    links original node ids to canonical ones (many-to-one — label stripping
    can hash-cons formerly distinct nodes together) and ``out_map`` links
    original output names to their ``oN`` aliases."""

    plan: Plan
    lits: Tuple
    vecs: Tuple[Tuple, ...]
    node_map: Tuple[Tuple[int, int], ...]
    out_map: Tuple[Tuple[str, str], ...]
    # canonical node ids whose predicate engine normalization demoted
    # pallas -> jnp.  Hoisted literals ride the kernel as operands, so this
    # is the EXCEPTION: only hoisted predicates the kernel cannot take
    # (oversized whitelist / non-boolean root) appear here.  The service
    # audits these into the OperationLog + per-tenant ServiceStats, and the
    # analyzer's SP009 diagnostic predicts them.
    demoted: Tuple[int, ...] = ()

    def orig_to_canon(self) -> Dict[int, int]:
        return dict(self.node_map)


def _structural_hashes(plan: Plan) -> List[str]:
    hs: List[str] = []
    for node in plan.nodes:
        items = []
        for k, v in _canonical_param_items(node):
            if k in _EXPR_KEYS and v is not None:
                v = _scrub_expr(v)
            elif k in _EXPRS_KEYS and v is not None:
                v = tuple(_scrub_expr(e) for e in v)
            items.append((k, v))
        blob = repr((node.op, tuple(items), tuple(hs[j] for j in node.inputs)))
        hs.append(hashlib.sha1(blob.encode()).hexdigest())
    return hs


def normalize(plan: Plan) -> NormalPlan:
    """Canonicalize an (optimized) plan for executable sharing.

    Expects concrete literals (plans from ``Study.optimized_plan``); already-
    hoisted slot refs pass through untouched, so feeding a canonical plan
    back in is harmless but not a supported identity."""
    hs = _structural_hashes(plan)
    b = PlanBuilder()
    lits: List = []
    vecs: List[Tuple] = []
    new_id: Dict[int, int] = {}
    demoted: set = set()

    def emit(i: int) -> int:
        if i in new_id:
            return new_id[i]
        node = plan.nodes[i]
        ins = [emit(j) for j in node.inputs]
        params: Dict[str, Any] = {}
        for k, v in _canonical_param_items(node):
            if k in _EXPR_KEYS and v is not None:
                v = _hoist_expr(v, lits, vecs)
            elif k in _EXPRS_KEYS and v is not None:
                v = tuple(_hoist_expr(e, lits, vecs) for e in v)
            params[k] = v
        hoisted = (node.op in PREDICATE_OPS
                   and params.get("engine") == "pallas"
                   and any(_has_hoisted(v) for k, v in params.items()
                           if k in _EXPR_KEYS + _EXPRS_KEYS
                           and v is not None))
        demote = hoisted and not _kernel_compilable(node.op, params)
        if demote:
            # hoisted literals are kernel operands now, so demotion is the
            # exception: only hoisted predicates the kernel still cannot
            # take (oversized whitelist, non-boolean root) fall back to the
            # value-generic jnp engine
            params["engine"] = "jnp"
            params.pop("bitset_block", None)
            params.pop("bitset_word", None)
        nid = b.add(node.op, ins, **params)
        if demote:
            demoted.add(nid)
        new_id[i] = nid
        return nid

    # visit outputs in structural order (orig name only tie-breaks between
    # scrub-identical subtrees, where either order yields the same structure)
    out_map: List[Tuple[str, str]] = []
    for k, (name, i) in enumerate(
            sorted(plan.outputs, key=lambda o: (hs[o[1]], o[0]))):
        canon_name = f"o{k}"
        b.set_output(canon_name, emit(i))
        out_map.append((name, canon_name))
    return NormalPlan(plan=b.build(), lits=tuple(lits), vecs=tuple(vecs),
                      node_map=tuple(sorted(new_id.items())),
                      out_map=tuple(sorted(out_map)),
                      demoted=tuple(sorted(demoted)))


# ---------------------------------------------------------------------------
# device binding
# ---------------------------------------------------------------------------
def _lit_dtype(v):
    if isinstance(v, bool):
        return jnp.bool_
    if isinstance(v, float):
        return jnp.float32
    return jnp.int32


def device_params(nplan: NormalPlan) -> Tuple[Tuple, Tuple]:
    """The ``(lits, vecs)`` traced-argument pytrees for a normalized plan,
    in canonical dtypes (int32/float32/bool — matching what ``Lit``/``IsIn``
    evaluation promotes to, so normalized results stay bit-identical)."""
    lits = tuple(jnp.asarray(v, _lit_dtype(v)) for v in nplan.lits)
    vecs = tuple(
        jnp.asarray(np.asarray(v, np.float32 if _isfloat(v) else np.int32))
        for v in nplan.vecs)
    return lits, vecs


def params_signature(lits: Sequence, vecs: Sequence) -> Tuple:
    """Shape/dtype fingerprint of bound params — part of the executor's jit
    key, so changing a literal *value* never recompiles but changing the
    params *spec* (different slot count/kind) does."""
    return (tuple(str(jnp.asarray(x).dtype) for x in lits),
            tuple((int(np.shape(v)[0]), str(jnp.asarray(v).dtype))
                  for v in vecs))


# ---------------------------------------------------------------------------
# subgraph identity (the service's result cache)
# ---------------------------------------------------------------------------
# ops whose value is a pure function of resident tables + the node subtree —
# safe to serve from a cross-tenant cache.  transform/conform/compact/concat
# stay out: cheap, or carrying realization-facing params not worth hashing.
CACHEABLE_OPS = frozenset({
    "scan", "scan_star", "select", "predicate", "drop_nulls", "value_filter",
    "fused_mask", "lookup_join", "expand_join", "exchange", "slice_time",
    "key_count", "dedupe",
})
# boundary ops worth materializing a cache entry at (heavy compute whose
# output many tenants share: predicate bitsets, join results, dedupes)
CUT_OPS = frozenset({
    "predicate", "fused_mask", "lookup_join", "expand_join", "slice_time",
    "key_count", "dedupe",
})


def cut_points(plan: Plan) -> Tuple[int, ...]:
    """Node ids eligible for subgraph caching: every node whose transitive
    subtree is cacheable and whose own op is a cut boundary.  Purely
    structural — all queries sharing a canonical plan share cut points."""
    ok: List[bool] = []
    for node in plan.nodes:
        ok.append(node.op in CACHEABLE_OPS and all(ok[j] for j in node.inputs))
    return tuple(i for i, node in enumerate(plan.nodes)
                 if ok[i] and node.op in CUT_OPS)


def subgraph_hashes(nplan: NormalPlan, salt: Tuple = ()) -> Tuple[str, ...]:
    """Content hash of every node's subtree with literal values resolved
    back in — equal hash ⇒ identical computation over the same sources.
    ``salt`` carries run-scoped identity (table version, engines,
    n_patients, optimizer version)."""
    hs: List[str] = []
    for node in nplan.plan.nodes:
        items = []
        for k, v in node.params:
            if k in _EXPR_KEYS and v is not None:
                v = _resolve_expr(v, nplan.lits, nplan.vecs)
            elif k in _EXPRS_KEYS and v is not None:
                v = tuple(_resolve_expr(e, nplan.lits, nplan.vecs) for e in v)
            items.append((k, v))
        blob = repr((salt, node.op, tuple(items),
                     tuple(hs[j] for j in node.inputs)))
        hs.append(hashlib.sha256(blob.encode()).hexdigest())
    return tuple(hs)
