"""Differential spec fuzzer: random wire specs vs. the three engines.

Two generators share one seeded ``random.Random``:

  * ``gen_valid_spec`` — specs that are valid **by construction**: a DCIR
    flatten, 1-3 extractors with random code whitelists / ``where``
    predicate trees, optional filters, random cohort algebra (with
    parentheses), optional flow and feature exports.  Every generated spec
    avoids chunk-unsafe ops (transforms, distinct extractors) so the same
    spec can execute resident AND out-of-core.
  * ``MUTATIONS`` — one targeted corruption per ``SPEC-nnn`` validation
    code; each asserts the validator rejects with that code (never a
    traceback) and that ``compile_spec`` refuses to build a plan.

``run_spec_differential`` is the oracle: one spec, three executions —
``predicate_engine="jnp"``, ``predicate_engine="pallas"``, and chunked over
a partitioned store — must agree bit-identically (the resident pair down to
raw column/validity-word layout; the chunked run on valid-row contents,
masks and features, the same contract ``tests/test_chunked.py`` pins).  The
static analyzer is cross-checked against reality on every run: an ``SP014``
("output provably empty") verdict must coincide with an executed count of
zero, and a plan carrying an ``SP003`` contradiction must be *refused* by
the chunked executor's analyzer preflight.

``run_corpus`` drives n specs (half valid+executed, half mutated+rejected)
and returns a ``FuzzReport``; ``tools/spec_fuzz.py`` is the CLI and CI
gate.
"""
from __future__ import annotations

import copy
import dataclasses
import random
import tempfile
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, \
    Tuple

import numpy as np

from repro.data import SyntheticConfig, generate_dcir, partition_star
from repro.study.analyze import PlanValidationError, analyze
from repro.study.spec import SpecValidationError, compile_spec, validate_spec

__all__ = [
    "FuzzFailure", "FuzzReport", "MUTATIONS",
    "gen_valid_spec", "mutate_spec", "results_equal",
    "run_spec_differential", "run_corpus",
]

# column -> (lo, hi) sampling ranges matching data.synthetic's generator, so
# random predicates are sometimes-true/sometimes-false instead of degenerate
_FLAT_COLUMNS: Dict[str, Tuple[int, int]] = {
    "prestation_code": (1000, 1100),
    "execution_date": (14_600, 14_600 + 3 * 365),
    "cip13": (0, 600),
    "atc_class": (0, 65),
    "quantity": (1, 4),
    "ccam_code": (0, 300),
    "gender": (1, 3),
}

# conformed-events layout (post conform_events) for filter predicates
_EVENT_COLUMNS: Dict[str, Tuple[int, int]] = {
    "patient_id": (0, 200),
    "start": (14_600, 14_600 + 3 * 365),
    "value": (0, 300),
}

_CMP_OPS = ("==", "!=", "<", "<=", ">", ">=")


# ---------------------------------------------------------------------------
# valid-by-construction generator
# ---------------------------------------------------------------------------
def _gen_leaf(rng: random.Random, cols: Mapping[str, Tuple[int, int]],
              contradiction: bool = False) -> Dict[str, Any]:
    """One predicate leaf; ``contradiction`` forces a provably-false
    conjunction ((c < lo) & (c > hi), hi > lo) to give SP003/SP014 teeth."""
    name = rng.choice(sorted(cols))
    lo, hi = cols[name]
    col = {"op": "col", "name": name}
    if contradiction:
        a, b = sorted((rng.randrange(lo, hi), rng.randrange(lo, hi + 10)))
        return {"op": "and",
                "lhs": {"op": "cmp", "cmp": "<", "lhs": col,
                        "rhs": {"op": "lit", "value": a}},
                "rhs": {"op": "cmp", "cmp": ">", "lhs": col,
                        "rhs": {"op": "lit", "value": b + 1}}}
    if rng.random() < 0.25:
        k = rng.randrange(1, 6)
        return {"op": "isin", "x": col,
                "values": sorted(rng.sample(range(lo, hi), min(k, hi - lo)))}
    return {"op": "cmp", "cmp": rng.choice(_CMP_OPS), "lhs": col,
            "rhs": {"op": "lit", "value": rng.randrange(lo, hi)}}


def _gen_expr(rng: random.Random, cols: Mapping[str, Tuple[int, int]],
              depth: int = 2, contradiction: bool = False) -> Dict[str, Any]:
    if contradiction:
        return _gen_leaf(rng, cols, contradiction=True)
    if depth <= 0 or rng.random() < 0.45:
        return _gen_leaf(rng, cols)
    if rng.random() < 0.15:
        return {"op": "not", "x": _gen_expr(rng, cols, depth - 1)}
    return {"op": rng.choice(("and", "or")),
            "lhs": _gen_expr(rng, cols, depth - 1),
            "rhs": _gen_expr(rng, cols, depth - 1)}


_EXTRACT_TEMPLATES = (
    # (value_col, category, null_cols) — DRUG_DISPENSE / MEDICAL_ACT lineages
    ("cip13", 1, ("cip13",)),
    ("atc_class", 1, ("cip13",)),
    ("ccam_code", 2, ("ccam_code",)),
)


def _gen_extractor(rng: random.Random, name: str, flat: str,
                   contradiction: bool) -> Dict[str, Any]:
    value_col, category, null_cols = rng.choice(_EXTRACT_TEMPLATES)
    d: Dict[str, Any] = {
        "name": name, "source": flat, "category": category,
        "value_col": value_col, "start_col": "execution_date",
        "null_cols": list(null_cols),
    }
    if rng.random() < 0.5:
        lo, hi = _FLAT_COLUMNS[value_col]
        d["codes"] = sorted(rng.sample(range(lo, hi), rng.randrange(2, 12)))
    if contradiction or rng.random() < 0.4:
        d["where"] = _gen_expr(rng, _FLAT_COLUMNS, contradiction=contradiction)
    return d


def _gen_algebra(rng: random.Random, first_pool: Sequence[str],
                 rest_pool: Sequence[str]) -> str:
    """Random cohort algebra with parentheses.  The leftmost leaf comes
    from ``first_pool``: cohort combination keeps the *left* operand's
    events (``core.cohort._combine``), so algebra rooted at an
    events-derived cohort stays featurizable."""
    expr = rng.choice(list(first_pool))
    for _ in range(rng.randrange(0, min(2, len(rest_pool)) + 1)):
        op = rng.choice(("&", "|", "-"))
        t = rng.choice(list(rest_pool))
        expr = (f"({expr}) {op} {t}" if rng.random() < 0.4
                else f"{expr} {op} {t}")
    return expr


def gen_valid_spec(rng: random.Random, n_patients: int = 200) -> Dict[str, Any]:
    """One random wire spec, valid by construction and chunk-safe.

    ~10% of specs carry a provably-false predicate so the corpus exercises
    the SP003/SP014 emptiness verdicts, not just the happy path.  No
    transforms, no distinct extractors: everything generated must also run
    out-of-core (see ``chunked.chunk_unsafe_ops``).
    """
    spec: Dict[str, Any] = {"spec_version": 1, "n_patients": n_patients}
    if rng.random() < 0.3:
        t0 = 14_600 + rng.randrange(0, 200)
        spec["window"] = [t0, t0 + rng.randrange(365, 3 * 365)]
    flat = rng.choice(("DCIR", "flat"))
    directive: Dict[str, Any] = {"star": "DCIR"}
    if flat != "DCIR":
        directive["name"] = flat
    spec["schema"] = [directive]

    contradiction_at = (rng.randrange(3) if rng.random() < 0.1 else None)
    concepts: List[Dict[str, Any]] = []
    event_names: List[str] = []
    for i in range(rng.randrange(1, 4)):
        nm = f"ev{i}"
        concepts.append({"kind": "extract",
                         "extractor": _gen_extractor(
                             rng, nm, flat, contradiction=(
                                 contradiction_at == i))})
        event_names.append(nm)
    concepts.append({"kind": "patients"})
    if rng.random() < 0.3:
        src = rng.choice(event_names)
        concepts.append({"kind": "filter", "source": src,
                         "where": _gen_expr(rng, _EVENT_COLUMNS, depth=1),
                         "name": f"{src}_narrow"})
        event_names.append(f"{src}_narrow")
    if len(event_names) >= 2 and rng.random() < 0.25:
        concepts.append({"kind": "concat", "name": "both",
                         "inputs": rng.sample(event_names, 2)})
        event_names.append("both")
    spec["concepts"] = concepts

    cohorts: Dict[str, str] = {"base": "extract_patients"}
    event_pool: List[str] = []          # events-rooted => featurizable
    for k, nm in enumerate(event_names):
        if k == 0 or rng.random() < 0.8:
            cohorts[f"c_{nm}"] = nm
            event_pool.append(f"c_{nm}")
    pool = list(cohorts)
    for j in range(rng.randrange(1, 3)):
        cohorts[f"mix{j}"] = _gen_algebra(rng, event_pool, pool)
        event_pool.append(f"mix{j}")
        pool.append(f"mix{j}")
    spec["cohorts"] = cohorts

    if rng.random() < 0.5:
        spec["flow"] = rng.sample(pool, min(len(pool), rng.randrange(2, 4)))
    if rng.random() < 0.25:
        fk = rng.choice(("dense", "tokens"))
        spec["outputs"] = [{"kind": "featurize", "name": "X",
                            "cohort": rng.choice(event_pool),
                            "feature_kind": fk,
                            "kwargs": ({"seq_len": 64} if fk == "tokens"
                                       else {"n_buckets": 12,
                                             "bucket_days": 31,
                                             "n_features": 64})}]
    return spec


# ---------------------------------------------------------------------------
# mutation catalog: one corruption per SPEC validation code
# ---------------------------------------------------------------------------
def _first_extractor(spec: Dict[str, Any]) -> Dict[str, Any]:
    for c in spec["concepts"]:
        if c.get("kind") == "extract":
            return c["extractor"]
    raise AssertionError("generated spec always has an extractor")


def _mut_root(spec, rng):
    return [spec]                                        # list, not object


def _mut_version(spec, rng):
    spec["spec_version"] = 99
    return spec


def _mut_unknown_field(spec, rng):
    spec["frobnicate"] = True
    return spec


def _mut_missing_required(spec, rng):
    del spec["n_patients"]
    return spec


def _mut_bad_type(spec, rng):
    spec["n_patients"] = -3
    return spec


def _mut_unknown_star(spec, rng):
    spec["schema"][0]["star"] = "SNIIRAM_CLASSIC"
    return spec


def _mut_unknown_transform(spec, rng):
    spec["concepts"].append({"kind": "transform", "fn": "no_such_fn",
                             "inputs": ["ev0"], "name": "zz"})
    return spec


def _mut_duplicate_name(spec, rng):
    spec["concepts"].append({"kind": "concat", "name": "ev0",
                             "inputs": ["ev0"]})
    return spec


def _mut_undefined_ref(spec, rng):
    spec["cohorts"]["mutant"] = "no_such_output"
    return spec


def _mut_malformed_expr(spec, rng):
    _first_extractor(spec)["where"] = {"op": "frobnicate"}
    return spec


def _mut_bad_literal(spec, rng):
    _first_extractor(spec)["where"] = {"op": "lit", "value": "a string"}
    return spec


def _mut_cohort_syntax(spec, rng):
    spec["cohorts"]["mutant"] = "base & ( base"
    return spec


def _mut_bad_enum(spec, rng):
    spec["concepts"][0] = dict(spec["concepts"][0], kind="explode")
    return spec


def _mut_bad_time_slice(spec, rng):
    spec["schema"][0]["time_slices"] = 4                 # no time_column/t0/t1
    return spec


# (code, mutation) — every SPEC validation code has a dedicated corruption;
# the fuzzer asserts the validator reports *that* code on the mutated spec.
MUTATIONS: Tuple[Tuple[str, Callable], ...] = (
    ("SPEC-001", _mut_root),
    ("SPEC-002", _mut_version),
    ("SPEC-003", _mut_unknown_field),
    ("SPEC-004", _mut_missing_required),
    ("SPEC-005", _mut_bad_type),
    ("SPEC-006", _mut_unknown_star),
    ("SPEC-007", _mut_unknown_transform),
    ("SPEC-008", _mut_duplicate_name),
    ("SPEC-009", _mut_undefined_ref),
    ("SPEC-010", _mut_malformed_expr),
    ("SPEC-011", _mut_bad_literal),
    ("SPEC-012", _mut_cohort_syntax),
    ("SPEC-013", _mut_bad_enum),
    ("SPEC-014", _mut_bad_time_slice),
)


def mutate_spec(spec: Dict[str, Any], index: int,
                rng: random.Random) -> Tuple[str, Any]:
    """Apply the ``index``-th catalog corruption to a deep copy of ``spec``;
    returns (expected SPEC code, mutated spec)."""
    code, fn = MUTATIONS[index % len(MUTATIONS)]
    return code, fn(copy.deepcopy(spec), rng)


# ---------------------------------------------------------------------------
# differential oracle
# ---------------------------------------------------------------------------
def _table_delta(name: str, a, b, layout: bool) -> Optional[str]:
    if int(a.count) != int(b.count):
        return f"{name}: count {int(a.count)} != {int(b.count)}"
    if sorted(a.columns) != sorted(b.columns):
        return f"{name}: columns {sorted(a.columns)} != {sorted(b.columns)}"
    if layout:
        if not np.array_equal(np.asarray(a.valid), np.asarray(b.valid)):
            return f"{name}: validity words differ"
        for c in a.columns:
            if not np.array_equal(np.asarray(a.columns[c]),
                                  np.asarray(b.columns[c])):
                return f"{name}.{c}: values differ"
    else:
        av, bv = a.to_numpy(), b.to_numpy()
        for c in av:
            if not np.array_equal(av[c], bv[c]):
                return f"{name}.{c}: valid-row values differ"
    return None


def _feature_delta(name: str, a, b) -> Optional[str]:
    if isinstance(a, dict) and isinstance(b, dict):
        if sorted(a) != sorted(b):
            return f"{name}: keys differ"
        for k in a:
            d = _feature_delta(f"{name}.{k}", a[k], b[k])
            if d:
                return d
        return None
    if isinstance(a, (tuple, list)) and isinstance(b, (tuple, list)):
        if len(a) != len(b):
            return f"{name}: length {len(a)} != {len(b)}"
        for i, (x, y) in enumerate(zip(a, b)):
            d = _feature_delta(f"{name}[{i}]", x, y)
            if d:
                return d
        return None
    if hasattr(a, "shape") or hasattr(b, "shape"):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            return f"{name}: arrays differ"
        return None
    return None if a == b else f"{name}: {a!r} != {b!r}"


def results_equal(a, b, layout: bool = True) -> Optional[str]:
    """None when two StudyResults agree bit-for-bit; else a one-line delta.

    ``layout=True`` also compares raw column arrays and packed validity
    words (same-engine-family runs); ``layout=False`` compares valid-row
    contents (the resident-vs-chunked contract: identical rows, possibly
    different padding capacity)."""
    if sorted(a.events) != sorted(b.events):
        return f"event outputs {sorted(a.events)} != {sorted(b.events)}"
    for nm in a.events:
        d = _table_delta(f"events.{nm}", a.events[nm], b.events[nm], layout)
        if d:
            return d
    if sorted(a.cohorts) != sorted(b.cohorts):
        return f"cohorts {sorted(a.cohorts)} != {sorted(b.cohorts)}"
    for nm in a.cohorts:
        ca, cb = a.cohorts[nm], b.cohorts[nm]
        if ca.subject_count() != cb.subject_count():
            return (f"cohort {nm}: {ca.subject_count()} != "
                    f"{cb.subject_count()} subjects")
        if not np.array_equal(np.asarray(ca.subjects),
                              np.asarray(cb.subjects)):
            return f"cohort {nm}: subject bitsets differ"
    if (a.flow is None) != (b.flow is None):
        return "flow presence differs"
    if a.flow is not None:
        fa = [r["subjects"] for r in a.flow.flowchart()]
        fb = [r["subjects"] for r in b.flow.flowchart()]
        if fa != fb:
            return f"flow counts {fa} != {fb}"
    if sorted(a.features) != sorted(b.features):
        return f"features {sorted(a.features)} != {sorted(b.features)}"
    for nm in a.features:
        d = _feature_delta(f"features.{nm}", a.features[nm], b.features[nm])
        if d:
            return d
    return None


@dataclasses.dataclass
class DifferentialStats:
    sp003: int = 0                 # always-false predicate verdicts
    sp014: int = 0                 # provably-empty output verdicts
    chunk_gated: bool = False      # chunked preflight refused (SP003 plan)


def _emptiness_delta(result, diags) -> Optional[str]:
    """SP014 ("named output is provably empty") must imply an executed count
    of exactly zero — the analyzer is sound, so a non-zero count means the
    abstract interpretation lost touch with the engines."""
    by_node: Dict[int, List[str]] = {}
    for nm, i in result.plan.outputs:
        by_node.setdefault(i, []).append(nm)
    for d in diags:
        if d.code != "SP014":
            continue
        for nm in by_node.get(d.node, ()):
            if nm in result.events:
                got = int(result.events[nm].count)
            elif nm in result.cohorts:
                got = result.cohorts[nm].subject_count()
            else:
                continue
            if got != 0:
                return (f"SP014 claims {nm!r} empty but executed count "
                        f"is {got}")
    return None


def run_spec_differential(spec: Dict[str, Any], tables, store,
                          n_patients: int
                          ) -> Tuple[Optional[str], DifferentialStats]:
    """One spec, three engines; returns (first delta or None, stats).

    Each execution compiles the spec **fresh** — three independent Studies,
    three plans — so agreement also certifies compile determinism, not just
    executor parity.  Plans the analyzer proves contradictory (SP003) still
    execute resident (zero rows); the chunked executor's preflight must
    *refuse* them, which this harness asserts instead of the third run."""
    stats = DifferentialStats()
    jnp_res = compile_spec(spec).run(tables, predicate_engine="jnp")
    pal_res = compile_spec(spec).run(tables, predicate_engine="pallas")
    d = results_equal(jnp_res, pal_res, layout=True)
    if d:
        return f"jnp vs pallas: {d}", stats

    diags = analyze(jnp_res.plan, tables=tables, n_patients=n_patients)
    stats.sp003 = sum(1 for g in diags if g.code == "SP003")
    stats.sp014 = sum(1 for g in diags if g.code == "SP014")
    d = _emptiness_delta(jnp_res, diags)
    if d:
        return d, stats

    if stats.sp003:
        stats.chunk_gated = True
        try:
            compile_spec(spec).run_chunked(store)
        except PlanValidationError as e:
            if not any(g.code == "SP003" for g in e.diagnostics):
                return ("chunked preflight rejected an SP003 plan without "
                        "reporting SP003", stats)
        else:
            return ("chunked preflight executed a plan the analyzer "
                    "proves contradictory (SP003)", stats)
        return None, stats

    chunk_res = compile_spec(spec).run_chunked(store)
    d = results_equal(jnp_res, chunk_res, layout=False)
    if d:
        return f"resident vs chunked: {d}", stats
    return None, stats


# ---------------------------------------------------------------------------
# corpus driver
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class FuzzFailure:
    kind: str                  # "differential" | "rejection" | "crash"
    seed_index: int
    detail: str
    spec: Any


@dataclasses.dataclass
class FuzzReport:
    n: int
    seed: int
    n_valid: int = 0
    n_mutated: int = 0
    n_sp003: int = 0
    n_sp014: int = 0
    n_chunk_gated: int = 0
    failures: List[FuzzFailure] = dataclasses.field(default_factory=list)
    rejected_by_code: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.failures

    def to_json(self) -> Dict[str, Any]:
        return {"n": self.n, "seed": self.seed, "ok": self.ok,
                "n_valid": self.n_valid, "n_mutated": self.n_mutated,
                "n_sp003": self.n_sp003, "n_sp014": self.n_sp014,
                "n_chunk_gated": self.n_chunk_gated,
                "rejected_by_code": dict(self.rejected_by_code),
                "failures": [{"kind": f.kind, "spec": f.seed_index,
                              "detail": f.detail} for f in self.failures]}

    def summary(self) -> str:
        lines = [
            f"spec fuzz: {self.n} specs (seed={self.seed}) — "
            f"{self.n_valid} valid executed differentially, "
            f"{self.n_mutated} mutated rejected",
            f"  emptiness verdicts: {self.n_sp003} SP003, "
            f"{self.n_sp014} SP014 cross-checked; "
            f"{self.n_chunk_gated} plans gated at chunked preflight",
            f"  rejections by code: "
            + (", ".join(f"{c}×{k}" for c, k in
                         sorted(self.rejected_by_code.items())) or "(none)"),
        ]
        for f in self.failures[:10]:
            lines.append(f"  FAIL [{f.kind}] spec #{f.seed_index}: {f.detail}")
        if len(self.failures) > 10:
            lines.append(f"  ... and {len(self.failures) - 10} more failures")
        lines.append("PASS" if self.ok else
                     f"FAIL ({len(self.failures)} failures)")
        return "\n".join(lines)


def run_corpus(n: int = 200, seed: int = 0, n_patients: int = 200,
               store_dir: Optional[str] = None,
               execute: bool = True) -> FuzzReport:
    """Drive the fuzzer: ``n - n//2`` valid specs (each executed
    differentially and emptiness-cross-checked) plus ``n//2`` mutated specs
    (each asserted to be rejected with its catalog code).
    ``execute=False`` restricts the valid half to validate+compile+plan
    (fast structural smoke, no engine runs)."""
    rng = random.Random(seed)
    report = FuzzReport(n=n, seed=seed)
    n_mut = n // 2
    n_ok = n - n_mut

    tables = store = tmp = None
    if execute:
        tables = generate_dcir(SyntheticConfig(n_patients=n_patients,
                                               seed=seed))
        n_flows = int(tables["ER_PRS"].count)
        cap = max(32, ((n_flows // 3) // 32 + 1) * 32)
        if store_dir is None:
            tmp = tempfile.TemporaryDirectory(prefix="spec_fuzz_")
            store_dir = tmp.name
        store = partition_star(tables, f"{store_dir}/store", source="ER_PRS",
                               chunk_capacity=cap)
    try:
        for i in range(n_ok):
            spec = gen_valid_spec(rng, n_patients=n_patients)
            issues = validate_spec(spec)
            if issues:
                report.failures.append(FuzzFailure(
                    "rejection", i,
                    f"valid-by-construction spec rejected: {issues[0]}",
                    spec))
                continue
            try:
                if execute:
                    delta, st = run_spec_differential(
                        spec, tables, store, n_patients)
                    report.n_sp003 += st.sp003
                    report.n_sp014 += st.sp014
                    report.n_chunk_gated += int(st.chunk_gated)
                    if delta:
                        report.failures.append(FuzzFailure(
                            "differential", i, delta, spec))
                        continue
                else:
                    compile_spec(spec).plan()
            except Exception as e:               # any traceback is a finding
                report.failures.append(FuzzFailure(
                    "crash", i, f"{type(e).__name__}: {e}", spec))
                continue
            report.n_valid += 1

        for j in range(n_mut):
            base = gen_valid_spec(rng, n_patients=n_patients)
            code, mutated = mutate_spec(base, j, rng)
            idx = n_ok + j
            try:
                issues = validate_spec(mutated)
            except Exception as e:               # validator must never raise
                report.failures.append(FuzzFailure(
                    "crash", idx,
                    f"validator raised {type(e).__name__}: {e}", mutated))
                continue
            if not any(i.code == code for i in issues):
                report.failures.append(FuzzFailure(
                    "rejection", idx,
                    f"expected {code}, got "
                    f"{sorted({i.code for i in issues}) or 'no issues'}",
                    mutated))
                continue
            try:
                compile_spec(mutated)
            except SpecValidationError:
                report.n_mutated += 1
                report.rejected_by_code[code] = \
                    report.rejected_by_code.get(code, 0) + 1
            except Exception as e:
                report.failures.append(FuzzFailure(
                    "crash", idx,
                    f"compile raised {type(e).__name__} instead of "
                    f"SpecValidationError: {e}", mutated))
            else:
                report.failures.append(FuzzFailure(
                    "rejection", idx,
                    f"compile_spec accepted a spec the validator rejects "
                    f"({code})", mutated))
    finally:
        if tmp is not None:
            tmp.cleanup()
    return report
