"""Static plan verifier: abstract interpretation over the Study plan IR.

``analyze(plan)`` walks a (raw or optimized) plan WITHOUT executing it and
computes per-node facts — inferred schema (columns + dtypes), capacity
bounds, value kinds (table/cohort/host), predicate semantics (per-column
interval + whitelist + nullness constraints), validity-layout alignment, and
predicate-engine feasibility — then reports everything inconsistent as a
``Diagnostic`` with a stable ``SPnnn`` code, a severity, the offending node
id, and a fix hint.

Why this exists (paper §2: "sharp interactive control ... through legible
code"): today an ill-typed or self-contradictory tenant plan is only caught
when XLA traces it — or worse, a 49-minute extraction silently returns zero
rows because two conjuncts of one predicate contradict each other.  The
verifier runs in microseconds on the host and is surfaced three ways:

  * ``Study.check()``            — interactive, returns the diagnostic list
  * ``CohortQueryService``       — admission-time: error-level plans are
                                   rejected before they touch the compile
                                   cache (counted in ``ServiceStats``)
  * ``tools/plan_lint.py``       — CLI/CI gate over plan goldens + the
                                   seeded-defect fixtures in ``defects.py``

The analysis is deliberately *sound-for-errors*: an ``error``-level finding
means the plan cannot produce the rows the author intended (unknown source,
read of a never-produced column, provably-empty mask, kind-mismatched
wiring), never a heuristic style opinion.  Heuristics live at warn/info.

Diagnostic codes (stable; the README table and the seeded-defect fixtures
mirror this registry):

  SP001 error  scan of a source absent from the bound table environment
  SP002 error  column read is never produced upstream
  SP003 error  predicate is provably always-false (contradictory conjuncts,
               empty whitelist)
  SP004 warn   predicate conjunct is provably always-true (no-op filter)
  SP005 warn   isin whitelist contains the NULL sentinel
  SP006 error  join key dtype mismatch between left and right inputs
  SP007 error/warn  planned capacity misaligned to the 32-bit validity word
               (error when it also breaks the n_shards split quantum)
  SP008 warn   predicate not pallas-compilable (oversized isin whitelist /
               non-boolean root) — executor falls back to the jnp engine
  SP009 info   pallas predicate carries literals; ``normalize()`` hoists
               them into traced slots that ride as kernel operands (the
               node keeps the pallas engine when served)
  SP010 info   concat of non-word-aligned capacities expands validity to a
               bool mask (loses the packed-bitset fast path)
  SP011 warn   expand_join without a planned capacity (trace-time
               ``(L+R)*slack`` heuristic; overflow risk)
  SP012 error  op wired to inputs of the wrong kind (table vs cohort)
  SP013 error  op not registered in the plan-IR op tables
  SP014 warn   named output is provably empty
  SP015 error  chunked-execution capacity misaligned to the validity word
               quantum (chunk boundaries would split packed words)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, FrozenSet, List, Mapping, Optional, Tuple

from repro.kernels import predicate as _pk
from repro.study import optimizer as _opt
from repro.study.expr import _NULL_SENTINEL_INT, const_fold_param, \
    expr_from_param, node_predicate, param_conjuncts, render_param
from repro.study.plan import JOIN_OPS, OP_KINDS, PREDICATE_OPS, Plan

__all__ = [
    "Diagnostic", "DIAGNOSTIC_CODES", "PlanValidationError", "analyze",
    "errors", "format_diagnostics",
]

WORD = 32  # validity word quantum (bitset.WORD_BITS; kept host-side)

# code -> (default severity, one-line summary) — the README table renders
# from this registry and tools/plan_lint.py cross-checks fixture coverage
DIAGNOSTIC_CODES: Mapping[str, Tuple[str, str]] = {
    "SP001": ("error", "scan source not in the bound table environment"),
    "SP002": ("error", "column read is never produced upstream"),
    "SP003": ("error", "predicate is provably always-false"),
    "SP004": ("warn", "predicate conjunct is provably always-true"),
    "SP005": ("warn", "isin whitelist contains the NULL sentinel"),
    "SP006": ("error", "join key dtype mismatch"),
    "SP007": ("warn", "capacity misaligned to the 32-bit validity word"),
    "SP008": ("warn", "predicate not pallas-compilable; jnp fallback"),
    "SP009": ("info", "literals hoist into pallas kernel operands"),
    "SP010": ("info", "concat misalignment expands validity to bool"),
    "SP011": ("warn", "expand_join capacity left to trace-time slack"),
    "SP012": ("error", "op wired to inputs of the wrong kind"),
    "SP013": ("error", "op not registered in the plan-IR op tables"),
    "SP014": ("warn", "named output is provably empty"),
    "SP015": ("error", "chunk capacity misaligned to the validity word "
                       "quantum"),
}

SEVERITIES = ("info", "warn", "error")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One analyzer finding, anchored to a plan node."""

    code: str         # stable "SPnnn" identifier
    severity: str     # "error" | "warn" | "info"
    node: int         # offending node id in the analyzed plan
    message: str      # what is wrong, with the concrete evidence
    hint: str = ""    # how to fix it

    def __str__(self) -> str:
        tail = f"  ({self.hint})" if self.hint else ""
        return f"{self.code} {self.severity} @node{self.node}: " \
               f"{self.message}{tail}"


class PlanValidationError(ValueError):
    """Raised by admission-time validation when a plan carries error-level
    diagnostics.  Carries the full diagnostic list for auditing."""

    def __init__(self, diagnostics: List[Diagnostic]):
        self.diagnostics = tuple(diagnostics)
        super().__init__(format_diagnostics(
            [d for d in diagnostics if d.severity == "error"]))


def errors(diagnostics) -> List[Diagnostic]:
    return [d for d in diagnostics if d.severity == "error"]


def format_diagnostics(diagnostics) -> str:
    if not diagnostics:
        return "no diagnostics"
    return "\n".join(str(d) for d in diagnostics)


# ---------------------------------------------------------------------------
# abstract domain
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NodeFact:
    """Per-node abstract state.  ``None`` fields mean statically unknown —
    every check degrades to silence on unknown, never to a false alarm."""

    kind: str = "table"                                # table | cohort | host
    columns: Optional[FrozenSet[str]] = None
    dtypes: Optional[Dict[str, str]] = None            # partial: known cols
    capacity: Optional[int] = None
    empty: bool = False                                # provably zero rows


@dataclasses.dataclass
class _ColState:
    """Conjunction state for one column inside one predicate node: the
    interval / whitelist / nullness constraints accumulated over the
    conjuncts.  A contradiction here is an always-false mask (SP003)."""

    lo: float = -math.inf
    lo_open: bool = False
    hi: float = math.inf
    hi_open: bool = False
    allowed: Optional[FrozenSet] = None                # isin intersection
    must_null: bool = False
    must_not_null: bool = False

    def narrow_cmp(self, op: str, v: float) -> None:
        if op == "==":
            self.narrow_cmp(">=", v)
            self.narrow_cmp("<=", v)
        elif op == "<":
            if v < self.hi or (v == self.hi and not self.hi_open):
                self.hi, self.hi_open = v, True
        elif op == "<=":
            if v < self.hi:
                self.hi, self.hi_open = v, False
        elif op == ">":
            if v > self.lo or (v == self.lo and not self.lo_open):
                self.lo, self.lo_open = v, True
        elif op == ">=":
            if v > self.lo:
                self.lo, self.lo_open = v, False
        # "!=" carries no interval information

    def narrow_isin(self, values) -> None:
        vals = frozenset(v for v in values
                         if not (isinstance(v, float) and math.isnan(v)))
        self.allowed = vals if self.allowed is None else self.allowed & vals

    def _in_interval(self, v) -> bool:
        if v < self.lo or (v == self.lo and self.lo_open):
            return False
        if v > self.hi or (v == self.hi and self.hi_open):
            return False
        return True

    def contradiction(self) -> Optional[str]:
        """A human-readable reason this conjunction can never hold."""
        if self.must_null and self.must_not_null:
            return "required both null and not-null"
        if self.lo > self.hi or (self.lo == self.hi
                                 and (self.lo_open or self.hi_open)):
            lo = f"{'(' if self.lo_open else '['}{self.lo:g}"
            hi = f"{self.hi:g}{')' if self.hi_open else ']'}"
            return f"interval {lo}, {hi} is empty"
        if self.allowed is not None:
            if not self.allowed:
                return "whitelist intersection is empty"
            if not any(self._in_interval(v) for v in self.allowed):
                return "no whitelist value satisfies the interval bounds"
        return None


def _lit_value(p) -> Optional[float]:
    """Numeric value of a ("lit", v) param, else None."""
    if isinstance(p, tuple) and p and p[0] == "lit" \
            and isinstance(p[1], (int, float)) \
            and not isinstance(p[1], bool):
        return p[1]
    return None


def _col_name(p) -> Optional[str]:
    if isinstance(p, tuple) and p and p[0] == "col":
        return p[1]
    return None


_MIRROR = {"<": ">", "<=": ">=", ">": "<", ">=": "<=", "==": "==", "!=": "!="}


def _isin_whitelists(p, out: List[Tuple[Tuple, int]]) -> None:
    """Collect (values-or-None, size) for every isin/hisin in a param tree.
    Hoisted whitelists keep their size (it is shape, hence static) but lose
    their values."""
    if not isinstance(p, tuple) or not p:
        return
    if p[0] == "isin":
        out.append((p[2], len(p[2])))
        _isin_whitelists(p[1], out)
        return
    if p[0] == "hisin":
        out.append((None, p[3]))
        _isin_whitelists(p[1], out)
        return
    for x in p[1:]:
        _isin_whitelists(x, out)


def _has_concrete_literal(p) -> bool:
    """True when the param tree carries inline literal values that
    ``normalize()`` hoists into traced slots (lit / isin whitelists)."""
    if not isinstance(p, tuple) or not p:
        return False
    if p[0] in ("lit", "isin"):
        return True
    return any(_has_concrete_literal(x) for x in p[1:])


# ---------------------------------------------------------------------------
# kind checking against plan.OP_KINDS
# ---------------------------------------------------------------------------
def _kinds_match(spec: Tuple[str, ...], got: List[Optional[str]]) -> bool:
    i = 0
    for s in spec:
        if s.endswith("*"):
            k = s[:-1]
            return all(g in (k, None, "unknown") for g in got[i:])
        if s.endswith("?"):
            k = s[:-1]
            if i < len(got):
                if got[i] not in (k, None, "unknown"):
                    return False
                i += 1
            continue
        if i >= len(got) or got[i] not in (s, None, "unknown"):
            return False
        i += 1
    return i == len(got)


# ---------------------------------------------------------------------------
# the analysis
# ---------------------------------------------------------------------------
def analyze(plan: Plan, tables: Optional[Mapping[str, Any]] = None,
            n_shards: int = 1, n_patients: Optional[int] = None,
            chunk_capacity: Optional[int] = None) -> List[Diagnostic]:
    """Abstract-interpret ``plan`` and return its diagnostics.

    ``tables`` (optional name -> ColumnarTable environment — e.g. the
    service's resident star schema) grounds scans in real schemas, dtypes
    and capacities; without it, schema facts start from ``scan_star``
    ``columns`` declarations and the content-dependent checks stay silent.
    ``n_shards`` tightens the capacity-alignment check to the mesh split
    quantum.  ``n_patients`` is accepted for symmetry with execution entry
    points (cohort capacities) but no current check consumes it.
    ``chunk_capacity`` (the out-of-core executor's per-chunk row capacity)
    enables SP015: chunk boundaries must fall on packed-validity word
    boundaries — and, sharded, on the 32*n_shards mesh quantum — or the
    per-chunk word slices are not the bitsets of their rows."""
    diags: List[Diagnostic] = []
    facts: Dict[int, NodeFact] = {}

    def emit(code: str, node: int, message: str, hint: str = "",
             severity: Optional[str] = None) -> None:
        diags.append(Diagnostic(code, severity or DIAGNOSTIC_CODES[code][0],
                                node, message, hint))

    if chunk_capacity is not None:
        _check_chunk_capacity(int(chunk_capacity), plan, n_shards, emit)

    for i, node in enumerate(plan.nodes):
        spec = OP_KINDS.get(node.op)
        if spec is None:
            emit("SP013", i, f"op {node.op!r} is not registered in "
                 "plan.OP_KINDS / the op tables",
                 hint="register the op in study/plan.py before executing it")
            facts[i] = NodeFact(kind="unknown")
            continue
        in_spec, out_kind = spec
        in_kinds = [facts[j].kind if j in facts else None
                    for j in node.inputs]
        if not _kinds_match(in_spec, in_kinds):
            emit("SP012", i,
                 f"{node.op} expects input kinds {in_spec}, got "
                 f"{tuple(in_kinds)}",
                 hint="rewire the plan: table ops consume tables, cohort "
                      "algebra consumes cohorts")
        fact = _transfer(node, i, [facts.get(j) for j in node.inputs],
                         tables, n_shards, emit)
        fact.kind = out_kind
        facts[i] = fact

    # SP014: provably-empty named outputs — the "silent zero rows" case the
    # verifier exists to catch, anchored where the user will look (the
    # output node), with the upstream contradiction already reported
    for name, i in plan.outputs:
        f = facts.get(i)
        if f is not None and f.empty and f.kind in ("table", "cohort"):
            emit("SP014", i,
                 f"output {name!r} is provably empty (an upstream predicate "
                 "can never hold)",
                 hint="see the SP003 diagnostics upstream of this node")
    return diags


def _transfer(node, i: int, in_facts: List[Optional[NodeFact]], tables,
              n_shards: int, emit) -> NodeFact:
    """Per-op transfer function: fold input facts into the node's fact,
    emitting diagnostics along the way.  Mirrors ``executor._eval_node``
    semantics (and ``optimizer.available_columns`` for schema flow)."""
    op = node.op
    left = in_facts[0] if in_facts else None
    empty = bool(left and left.empty)

    if op in ("scan", "scan_star"):
        source = node.get("source")
        declared = node.get("columns")
        t = (tables or {}).get(source) if tables is not None else None
        if tables is not None and t is None:
            emit("SP001", i, f"scan of {source!r}, which is not among the "
                 f"bound tables {sorted(tables)[:8]}",
                 hint="bind the table or fix the source name")
            return NodeFact(columns=frozenset(declared) if declared else None)
        if t is not None:
            actual = frozenset(t.columns)
            for c in sorted(frozenset(declared or ()) - actual):
                emit("SP002", i, f"scan of {source!r} declares column "
                     f"{c!r} absent from the bound table",
                     hint="the declared schema drifted from the data")
            return NodeFact(columns=actual,
                            dtypes={c: str(v.dtype)
                                    for c, v in t.columns.items()},
                            capacity=int(t.capacity))
        return NodeFact(columns=frozenset(declared) if declared else None)

    if op == "select":
        cols = frozenset(node.get("cols"))
        fact = NodeFact(columns=cols, capacity=left.capacity if left else None,
                        empty=empty)
        if left and left.columns is not None:
            for c in sorted(cols - left.columns):
                emit("SP002", i, f"select reads column {c!r}, never produced "
                     "upstream", hint="it was dropped by an upstream "
                     "projection or misspelled")
            if left.dtypes:
                fact.dtypes = {c: left.dtypes[c] for c in cols
                               if c in left.dtypes}
        return fact

    if op in PREDICATE_OPS or op == "slice_time":
        fact = NodeFact(columns=left.columns if left else None,
                        dtypes=left.dtypes if left else None,
                        capacity=left.capacity if left else None, empty=empty)
        _check_predicate(node, i, left, emit, fact)
        if op == "slice_time":
            cap = node.get("capacity")
            if cap is not None:
                _check_alignment(int(cap), i, op, n_shards, emit)
                if fact.capacity is None or cap < fact.capacity:
                    fact.capacity = int(cap)
        return fact

    if op in ("dedupe", "compact"):
        fact = NodeFact(columns=left.columns if left else None,
                        dtypes=left.dtypes if left else None,
                        capacity=left.capacity if left else None, empty=empty)
        if op == "dedupe" and left and left.columns is not None:
            for c in sorted(frozenset(node.get("keys")) - left.columns):
                emit("SP002", i, f"dedupe keys on column {c!r}, never "
                     "produced upstream")
        return fact

    if op == "conform_events":
        if left and left.columns is not None:
            read = [node.get(k) for k in ("value_col", "start_col", "end_col",
                                          "group_col", "weight_col")]
            for c in sorted({c for c in read + ["patient_id"] if c}
                            - left.columns):
                emit("SP002", i, f"conform_events reads column {c!r}, never "
                     "produced upstream")
        return NodeFact(columns=frozenset(_opt._EVENT_COLS),
                        capacity=left.capacity if left else None, empty=empty)

    if op == "exchange":
        fact = NodeFact(columns=left.columns if left else None,
                        dtypes=left.dtypes if left else None,
                        capacity=left.capacity if left else None, empty=empty)
        if left and left.columns is not None \
                and node.get("key") not in left.columns:
            emit("SP002", i, f"exchange partitions on column "
                 f"{node.get('key')!r}, never produced upstream")
        per = node.get("per_dest_capacity")
        if per is not None:
            _check_alignment(int(per), i, "exchange per_dest_capacity",
                             n_shards, emit)
        return fact

    if op in JOIN_OPS or op == "key_count":
        right = in_facts[1] if len(in_facts) > 1 else None
        lk, rk = node.get("left_key"), node.get("right_key")
        if left and left.columns is not None and lk not in left.columns:
            emit("SP002", i, f"{op} left key {lk!r} is never produced "
                 "upstream")
        if right and right.columns is not None and rk not in right.columns:
            emit("SP002", i, f"{op} right key {rk!r} is never produced "
                 "upstream")
        if left and right and left.dtypes and right.dtypes:
            lt, rt = left.dtypes.get(lk), right.dtypes.get(rk)
            if lt and rt and lt != rt:
                emit("SP006", i, f"{op} key dtypes differ: left {lk!r} is "
                     f"{lt}, right {rk!r} is {rt}",
                     hint="searchsorted key fills compare raw lanes; cast "
                          "one side at ingestion")
        if op == "key_count":     # value = the left table unchanged
            return NodeFact(columns=left.columns if left else None,
                            dtypes=left.dtypes if left else None,
                            capacity=left.capacity if left else None,
                            empty=empty)
        cols = dtypes = None
        if left and right and left.columns is not None \
                and right.columns is not None:
            named = _opt.join_right_cols(node, right.columns)
            cols = left.columns | frozenset(named)
            if left.dtypes and right.dtypes:
                dtypes = dict(left.dtypes)
                dtypes.update({out: right.dtypes[src]
                               for out, src in named.items()
                               if src in right.dtypes})
        if op == "lookup_join":
            return NodeFact(columns=cols, dtypes=dtypes,
                            capacity=left.capacity if left else None,
                            empty=empty)
        # expand_join
        cap = node.get("capacity")
        if cap is not None:
            _check_alignment(int(cap), i, op, n_shards, emit)
            out_cap = int(cap)
        else:
            emit("SP011", i, "expand_join has no planned capacity; the "
                 "executor will size it from the trace-time (L+R)*slack "
                 "heuristic",
                 hint="optimize with tables= so plan_capacities can size it "
                      "exactly")
            out_cap = None
            if left and right and left.capacity is not None \
                    and right.capacity is not None:
                out_cap = int((left.capacity + right.capacity)
                              * (node.get("slack") or 1.5))
        return NodeFact(columns=cols, dtypes=dtypes, capacity=out_cap,
                        empty=empty)

    if op == "concat":
        known = [f for f in in_facts if f is not None]
        colsets = [f.columns for f in known]
        cols = colsets[0] if colsets and all(c == colsets[0]
                                             for c in colsets) else None
        if colsets and all(c is not None for c in colsets) and cols is None:
            diff = frozenset().union(*colsets) - frozenset.intersection(
                *colsets)
            emit("SP002", i, "concat inputs disagree on schema: "
                 f"{sorted(diff)} not produced by every input",
                 hint="ColumnarTable.concat requires identical column sets")
        caps = [f.capacity for f in known]
        cap = sum(caps) if caps and all(c is not None for c in caps) else None
        misaligned = [c for c in caps[:-1] if c is not None and c % WORD]
        if misaligned:
            emit("SP010", i, "concat input capacities "
                 f"{misaligned} are not 32-aligned: validity falls off the "
                 "packed-word fast path and round-trips through a bool mask",
                 hint="pad inputs to a 32-row quantum to keep the bitset "
                      "layout end-to-end")
        return NodeFact(columns=cols, capacity=cap,
                        empty=bool(known) and all(f.empty for f in known))

    if op == "transform":
        return NodeFact()  # opaque host fn: schema/capacity unknown

    if op == "cohort_from_events":
        if left and left.columns is not None \
                and "patient_id" not in left.columns:
            emit("SP002", i, "cohort_from_events needs column 'patient_id', "
                 "never produced upstream")
        return NodeFact(kind="cohort", empty=empty)

    if op == "cohort_op":
        right = in_facts[1] if len(in_facts) > 1 else None
        kind = node.get("kind")
        l_empty = bool(left and left.empty)
        r_empty = bool(right and right.empty)
        out_empty = {"&": l_empty or r_empty, "|": l_empty and r_empty,
                     "-": l_empty}.get(kind, False)
        return NodeFact(kind="cohort", empty=out_empty)

    # host ops (featurize, flow) and anything kind-checked above
    return NodeFact(kind="host")


def _check_chunk_capacity(cap: int, plan: Plan, n_shards: int, emit) -> None:
    """SP015: a chunked manifest whose per-chunk capacity is off the packed
    validity word (or, sharded, the 32*n_shards mesh quantum) cannot slice
    the source bitset on chunk boundaries — reject before any chunk IO.
    Anchored at the plan's scan nodes (the boundary the chunks feed)."""
    quantum = WORD * max(int(n_shards), 1)
    anchor = next((i for i, n in enumerate(plan.nodes)
                   if n.op in ("scan", "scan_star")), 0)
    if cap <= 0:
        emit("SP015", anchor, f"chunk capacity {cap} is not positive",
             hint="partition with a positive multiple of 32 rows per chunk")
    elif cap % quantum:
        what = (f"the sharded validity quantum {quantum} (32*{n_shards} "
                "shards)" if n_shards > 1 else "the 32-bit validity word")
        emit("SP015", anchor, f"chunk capacity {cap} is not a multiple of "
             f"{what}, so chunk boundaries split validity words",
             hint="re-partition the store with a 32-aligned (sharded: "
                  "32*n_shards-aligned) chunk_capacity")


def _check_alignment(cap: int, i: int, what: str, n_shards: int,
                     emit) -> None:
    """SP007: planned capacities must respect the packed-validity word (and,
    sharded, the mesh split quantum 32*n_shards — ``pad_tables_for_mesh``
    pads *inputs*, but a misaligned planned capacity re-breaks alignment
    mid-plan)."""
    quantum = WORD * max(int(n_shards), 1)
    if n_shards > 1 and cap % quantum:
        emit("SP007", i, f"{what} capacity {cap} is not a multiple of the "
             f"sharded validity quantum {quantum} (32*{n_shards} shards)",
             hint="round capacities up to 32*n_shards (plan_capacities "
                  "rounds to 64)", severity="error")
    elif cap % WORD:
        emit("SP007", i, f"{what} capacity {cap} is not a multiple of the "
             "32-bit validity word",
             hint="round capacities up to a 32-row quantum")


def _check_predicate(node, i: int, left: Optional[NodeFact], emit,
                     fact: NodeFact) -> None:
    """Predicate semantics + engine feasibility for one mask-evaluating
    node."""
    e = node_predicate(node)
    if e is None:
        return
    param = e.to_param()

    # SP002: columns the mask reads but no upstream node produces
    if left is not None and left.columns is not None:
        for c in sorted(e.required_columns() - left.columns):
            emit("SP002", i, f"{node.op} reads column {c!r}, never produced "
                 "upstream",
                 hint="it was pruned/dropped upstream or misspelled")

    # conjunct-level semantics: constant folds + per-column interval algebra
    states: Dict[str, _ColState] = {}
    contradicted = False
    for conj in param_conjuncts(param):
        folded = const_fold_param(conj)
        if folded is False:
            emit("SP003", i, f"conjunct {render_param(conj)} is always "
                 "false: the mask keeps zero rows",
                 hint="empty whitelists / literal-only comparisons never "
                      "hold")
            contradicted = True
            continue
        if folded is True:
            emit("SP004", i, f"conjunct {render_param(conj)} is always "
                 "true: the filter is a no-op",
                 hint="drop the tautological conjunct")
            continue
        _narrow(conj, states)
    for c, st in states.items():
        reason = st.contradiction()
        if reason is not None:
            emit("SP003", i, f"constraints on column {c!r} contradict: "
                 f"{reason} — the mask keeps zero rows",
                 hint="two conjuncts of this predicate exclude each other")
            contradicted = True
            break
    if contradicted:
        fact.empty = True

    # SP005: whitelists that name the NULL sentinel (never matches the
    # author's intent — null tests go through is_null, and float NULL is
    # NaN, which isin can never match)
    wls: List[Tuple[Tuple, int]] = []
    _isin_whitelists(param, wls)
    for values, size in wls:
        if values is None:
            continue
        if any(v == _NULL_SENTINEL_INT
               or (isinstance(v, float) and math.isnan(v)) for v in values):
            emit("SP005", i, "isin whitelist contains the NULL sentinel "
                 f"({_NULL_SENTINEL_INT} / NaN)",
                 hint="nulls never match a whitelist; use is_null()/"
                      "drop_nulls instead")
            break

    # engine feasibility
    oversized = [s for _, s in wls if s > _pk.MAX_ISIN_VALUES]
    if oversized:
        vmem = _pk.isin_vmem_bytes(max(oversized))
        emit("SP008", i, f"isin whitelist of {max(oversized)} values "
             f"exceeds the pallas membership budget "
             f"({_pk.MAX_ISIN_VALUES}); the broadcast intermediate alone "
             f"needs ~{vmem / 2**20:.1f} MiB of VMEM — the executor falls "
             "back to the jnp engine",
             hint="split the whitelist or pre-join a code dimension")
    if node.get("engine") == "pallas":
        if not _pk.compilable(param) and not oversized:
            emit("SP008", i, "node is stamped engine=pallas but its expr is "
                 "not kernel-compilable (non-boolean root); the executor "
                 "falls back to the jnp engine",
                 hint="the mask root must be a comparison/boolean op")
        if _has_concrete_literal(param) and _pk.compilable(param):
            emit("SP009", i, "pallas-stamped mask carries inline literals; "
                 "normalize() hoists them into traced slots that enter the "
                 "kernel as operands (scalar literals via SMEM, sorted "
                 "isin whitelists as padded VMEM vectors) — the node keeps "
                 "the pallas engine when served",
                 hint="structurally-equal plans with different literal "
                      "values share one compiled executable; only "
                      "kernel-infeasible stamps (SP008) demote to jnp")


def _narrow(conj, states: Dict[str, _ColState]) -> None:
    """Fold one conjunct into the per-column constraint states.  Only
    directly-grounded shapes (col vs literal) narrow; anything else is
    conservatively ignored."""
    tag = conj[0] if isinstance(conj, tuple) and conj else None
    if tag == "cmp":
        c, v = _col_name(conj[2]), _lit_value(conj[3])
        op = conj[1]
        if c is None or v is None:
            c, v = _col_name(conj[3]), _lit_value(conj[2])
            op = _MIRROR[conj[1]]
        # NOTE: a satisfied comparison does NOT imply non-null — the int32
        # NULL sentinel compares as an ordinary lane value at runtime, so
        # nullness only narrows through explicit isnull/notnull conjuncts.
        if c is not None and v is not None:
            states.setdefault(c, _ColState()).narrow_cmp(op, v)
    elif tag == "isin":
        c = _col_name(conj[1])
        if c is not None:
            states.setdefault(c, _ColState()).narrow_isin(conj[2])
    elif tag == "isnull":
        c = _col_name(conj[1])
        if c is not None:
            states.setdefault(c, _ColState()).must_null = True
    elif tag == "notnull":
        c = _col_name(conj[1])
        if c is not None:
            states.setdefault(c, _ColState()).must_not_null = True
