# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
import jax


def default_interpret() -> bool:
    """Single source of truth for the kernels' interpret default: the Pallas
    kernels are TPU-targeted and run in interpret mode on any other backend
    (the container-CI case).  Every kernel module resolves ``interpret=None``
    through this helper so the fleet can never disagree."""
    return jax.default_backend() != "tpu"
