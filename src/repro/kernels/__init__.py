# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
import jax
import jax.numpy as jnp


def unpack_words_block(words):
    """In-VMEM expansion of a packed uint32 validity block to a bool row
    vector (``core.bitset`` layout: bit i%32 of word i//32).  Shared by every
    kernel that streams validity packed — ONE definition so the kernels can
    never disagree with the host-side layout.  Deliberately distinct from
    ``core.bitset.unpack`` (the HBM-level expansion the no-unpack tests
    instrument): this runs on an already-loaded VMEM block."""
    lanes = jax.lax.broadcasted_iota(jnp.uint32, (words.shape[0], 32), 1)
    return ((words[:, None] >> lanes) & 1).astype(bool).reshape(-1)


def default_interpret() -> bool:
    """Single source of truth for the kernels' interpret default: the Pallas
    kernels are TPU-targeted and run in interpret mode on any other backend
    (the container-CI case).  Every kernel module resolves ``interpret=None``
    through this helper so the fleet can never disagree."""
    return jax.default_backend() != "tpu"
