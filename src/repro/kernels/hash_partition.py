"""Pallas TPU kernel: radix histogram + in-block rank for the shuffle.

The flattening exchange (Spark shuffle analogue, DESIGN.md §2) needs, per
row: a destination shard ``hash(key) % n`` and a *rank* — the row's position
among same-destination rows of its block — plus per-(block, dest) histograms
so the wrapper can compute global send offsets with one small cumsum.

TPU-native: the rank is an exclusive prefix sum over the (B × n_dest) one-hot
destination matrix — a log-step scan over VPU lanes; histograms are the
column sums of the same matrix.  No scatters in-kernel; the actual permutation
is one XLA gather in the wrapper, fed by (dest, rank, offsets).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 512
_MUL = 0x9E3779B1


def _kernel(keys_ref, valid_ref, dest_ref, rank_ref, hist_ref, *, n_dest: int):
    k = keys_ref[...].astype(jnp.uint32)
    v = valid_ref[...] != 0
    B = k.shape[0]

    h = k * jnp.uint32(_MUL)
    h = h ^ (h >> 16)
    dest = jnp.where(v, (h % jnp.uint32(n_dest)).astype(jnp.int32), jnp.int32(n_dest))

    onehot = (
        dest[:, None] == jax.lax.broadcasted_iota(jnp.int32, (B, n_dest), 1)
    ).astype(jnp.int32)
    excl = jnp.cumsum(onehot, axis=0) - onehot      # exclusive per-dest prefix
    rank = jnp.where(v, (excl * onehot).sum(axis=1), 0)

    dest_ref[...] = dest
    rank_ref[...] = rank
    hist_ref[...] = onehot.sum(axis=0)[None, :]


def hash_partition_plan(keys: jax.Array, valid: jax.Array, n_dest: int,
                        block: int = DEFAULT_BLOCK, interpret: bool = True):
    """Per-row (dest, in-block rank) + per-block histograms.

    Returns ``(dest (N,), rank (N,), hist (n_blocks, n_dest))``.
    ``N % block == 0`` (wrapper pads with invalid rows).
    """
    n = keys.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    import functools
    return pl.pallas_call(
        functools.partial(_kernel, n_dest=n_dest),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((block,), lambda g: (g,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((1, n_dest), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((grid[0], n_dest), jnp.int32),
        ],
        interpret=interpret,
    )(keys, valid.astype(jnp.int8))
