"""Pallas TPU kernel: flash attention with sliding-window + causal masking.

Serving hot-spot for the SWA/local-attention architectures (h2o-danube,
gemma3 local layers, recurrentgemma's local-attn blocks) and the prefill path
generally.  FlashAttention's GPU formulation (shared-memory tiles, warp
reductions) is re-blocked for TPU:

  * KV is streamed block-by-block through VMEM along the innermost
    (sequential) grid dimension; running max / denominator / accumulator live
    in VMEM scratch — the online-softmax recurrence maps to VPU ops, the
    (bq × d)·(d × bk) score product and the (bq × bk)·(bk × d) value product
    hit the MXU at hardware-aligned tile sizes (multiples of 128);
  * GQA is handled in the BlockSpec index maps (q-head -> kv-head integer
    division), so grouped heads share KV traffic;
  * sliding-window blocks fully outside ``[q_pos - window, q_pos]`` are
    skipped with ``pl.when`` — for window ≪ seq this drops compute from
    O(S²) to O(S·W), which is what makes `long_500k` decoding viable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams (~0.5); accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, window: int, causal: bool, q_offset: int,
            bq: int, bk: int, n_kv_blocks: int, kv_len: int):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    q_lo = iq * bq + q_offset          # first query position of this block
    q_hi = q_lo + bq - 1
    k_lo = ik * bk

    # window/causal/kv-length block-level cull (traced per grid step):
    #   need k_lo <= q_hi (causal), k_lo < kv_len (padding), and
    #   k_lo + bk - 1 >= q_lo - window + 1 (window)
    relevant = k_lo < kv_len
    if causal:
        relevant = relevant & (k_lo <= q_hi)
    if window > 0:
        relevant = relevant & (k_lo + bk - 1 >= q_lo - window + 1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(relevant)
    def _block():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                  # (bq, bk)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len          # padded KV rows are never attended
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                        # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _fin():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_swa_attention(
    q: jax.Array,            # (B, Hq, Sq, D)
    k: jax.Array,            # (B, Hkv, Skv, D)
    v: jax.Array,            # (B, Hkv, Skv, D)
    *,
    causal: bool = True,
    window: int = 0,         # 0 = no window (full causal)
    q_offset: int | None = None,   # first q position in kv coords (decode)
    kv_len: int | None = None,     # true (unpadded) KV length
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """Blocked flash attention; see module docstring.  Sq, Skv must divide by
    (bq, bk) — wrapper in ``ops.py`` pads and unpads."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if kv_len is None:
        kv_len = Skv
    if q_offset is None:
        q_offset = kv_len - Sq  # decode: queries sit at the end of the cache
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    n_q, n_kv = Sq // bq, Skv // bk
    scale = 1.0 / (D ** 0.5)

    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Skv, D)
    vr = v.reshape(B * Hkv, Skv, D)

    def kv_head(bh):
        return (bh // Hq) * Hkv + (bh % Hq) // group

    kern = functools.partial(
        _kernel, scale=scale, window=window, causal=causal,
        q_offset=q_offset, bq=bq, bk=bk, n_kv_blocks=n_kv, kv_len=kv_len,
    )
    out = pl.pallas_call(
        kern,
        grid=(B * Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, iq, ik: (kv_head(bh), ik, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, iq, ik: (kv_head(bh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)
