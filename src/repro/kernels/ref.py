"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` mirrors its kernel's semantics exactly; tests sweep shapes and
dtypes asserting ``assert_allclose(kernel(interpret=True), ref)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_BIG = jnp.int32(2_000_000_000)


# -- filter_compact -----------------------------------------------------------
def filter_compact_ref(vals: jax.Array, mask: jax.Array):
    """(compacted values padded with 0, count)."""
    idx = jnp.argsort(~mask, stable=True)
    cnt = mask.sum().astype(jnp.int32)
    lane = jnp.arange(vals.shape[0])
    out = jnp.where(lane < cnt, vals[idx], jnp.asarray(0, vals.dtype))
    return out, cnt


# -- segmented scan -----------------------------------------------------------
def segmented_scan_ref(flags: jax.Array, vals: jax.Array):
    """Inclusive running (min, max, count) with reset where flags is True.

    Sequential oracle via lax.scan (ground truth for the log-step kernel).
    """

    def body(carry, x):
        cmin, cmax, ccnt = carry
        f, v = x
        nmin = jnp.where(f, v, jnp.minimum(cmin, v))
        nmax = jnp.where(f, v, jnp.maximum(cmax, v))
        ncnt = jnp.where(f, 1, ccnt + 1)
        return (nmin, nmax, ncnt), (nmin, nmax, ncnt)

    init = (_BIG.astype(vals.dtype), (-_BIG).astype(vals.dtype), jnp.int32(0))
    _, (mn, mx, ct) = jax.lax.scan(body, init, (flags.astype(bool), vals))
    return mn, mx, ct


# -- bitset ops ----------------------------------------------------------------
def bitset_op_ref(a: jax.Array, b: jax.Array, op: str):
    r = {"and": a & b, "or": a | b, "andnot": a & ~b, "xor": a ^ b}[op]
    return r, jax.lax.population_count(r).astype(jnp.int32).sum()


# -- hash partition --------------------------------------------------------------
def hash_partition_plan_ref(keys: jax.Array, valid: jax.Array, n_dest: int, block: int):
    """(dest, in-block rank, per-block histogram) with the same fixture hash."""
    k = keys.astype(jnp.uint32)
    h = k * jnp.uint32(0x9E3779B1)
    h = h ^ (h >> 16)
    dest = jnp.where(valid, (h % jnp.uint32(n_dest)).astype(jnp.int32), jnp.int32(n_dest))

    n = keys.shape[0]
    g = n // block
    d2 = dest.reshape(g, block)
    onehot = (d2[:, :, None] == jnp.arange(n_dest)[None, None, :]).astype(jnp.int32)
    excl = jnp.cumsum(onehot, axis=1) - onehot
    rank = jnp.where(valid.reshape(g, block), (excl * onehot).sum(-1), 0).reshape(-1)
    hist = onehot.sum(axis=1)
    return dest, rank, hist


# -- attention ---------------------------------------------------------------------
def attention_ref(q, k, v, *, causal=True, window=0, q_offset=None):
    """Dense masked attention oracle (GQA, causal, sliding window)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    if q_offset is None:
        q_offset = Skv - Sq
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    s = s / (D ** 0.5)
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no visible kv -> zero output (kernel convention)
    any_vis = mask.any(axis=1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32))
    out = jnp.where(any_vis[None, None, :, None], out, 0.0)
    return out.astype(q.dtype)
