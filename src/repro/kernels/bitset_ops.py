"""Pallas TPU kernel: fused cohort-bitset algebra + popcount.

Cohort set operations (paper §3.5: intersection/union/difference + subject
counts) over packed uint32 bitsets.  Fusing the bitwise op with the popcount
reduction halves HBM traffic vs. two XLA passes — on multi-million-patient
universes (SNDS: 66M patients -> 2M words) the op is bandwidth-bound, so this
is a straight 2x.

Grid blocks are independent; per-block partial popcounts are summed by the
wrapper (one tiny reduction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 1024

OPS = {"and": 0, "or": 1, "andnot": 2, "xor": 3}


def _make_kernel(op: int):
    def _kernel(a_ref, b_ref, out_ref, pc_ref):
        a = a_ref[...]
        b = b_ref[...]
        if op == 0:
            r = a & b
        elif op == 1:
            r = a | b
        elif op == 2:
            r = a & ~b
        else:
            r = a ^ b
        out_ref[...] = r
        pc_ref[0] = jax.lax.population_count(r).astype(jnp.int32).sum()

    return _kernel


def bitset_op_popcount(a: jax.Array, b: jax.Array, op: str, block: int = DEFAULT_BLOCK,
                       interpret: bool | None = None):
    """Fused ``(a OP b, popcount(a OP b) per block)``.

    Ragged tails are zero-padded to the block quantum (zero words contribute
    no population, and every OPS entry maps 0 OP 0 -> 0, so padded words
    never leak into counts); the padded tail is returned — callers slice.
    ``interpret`` defaults by backend (interpret mode off-TPU).
    """
    from repro.kernels import default_interpret

    interpret = default_interpret() if interpret is None else interpret
    n = a.shape[0]
    if n == 0:
        return jnp.zeros((0,), a.dtype), jnp.zeros((0,), jnp.int32)
    pad = (-n) % block
    if pad:
        a = jnp.concatenate([a, jnp.zeros((pad,), a.dtype)])
        b = jnp.concatenate([b, jnp.zeros((pad,), b.dtype)])
        n += pad
    grid = (n // block,)
    return pl.pallas_call(
        _make_kernel(OPS[op]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((block,), lambda g: (g,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((1,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), a.dtype),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)
