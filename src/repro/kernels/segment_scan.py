"""Pallas TPU kernel: segmented scan (min/max/count) with cross-block carry.

The Transformer hot path (paper §3.4): per-patient folds over time-sorted
events — exposure merging, observation periods — are *segmented scans* where a
boundary flag marks the start of each (patient, drug) run.

TPU-native formulation:
  * within a block: log-step Hillis–Steele segmented scan (``log2(B)`` shifted
    ``where``-combines, pure VPU, no data-dependent control flow);
  * across blocks: the TPU grid executes sequentially (``arbitrary``
    dimension semantics), so the inter-block carry lives in SMEM scratch and
    flows left-to-right — the Pallas analogue of a decoupled-lookback scan,
    with determinism for free.

Outputs are *inclusive* running (min, max, count) per element with reset at
flags; run-aggregates are read at the last element of each run.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams (~0.5); accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

DEFAULT_BLOCK = 512
_BIG = 2_000_000_000


def _shift1(x, d, fill):
    """x[i-d] with `fill` for i<d (static d) — a pad+slice the VPU loves."""
    return jnp.concatenate([jnp.full((d,), fill, x.dtype), x[:-d]])


def _kernel(flags_ref, vals_ref, omin_ref, omax_ref, ocnt_ref,
            carry_ref):  # SMEM carry: [boundary_seen, min, max, cnt]
    g = pl.program_id(0)
    f = flags_ref[...] != 0
    v = vals_ref[...]
    B = v.shape[0]

    vmin = v
    vmax = v
    cnt = jnp.ones((B,), jnp.int32)
    fb = f
    d = 1
    while d < B:  # static unroll: log2(B) steps
        pmin = _shift1(vmin, d, _BIG)
        pmax = _shift1(vmax, d, -_BIG)
        pcnt = _shift1(cnt, d, 0)
        # fill=False: positions beyond the block edge carry *no* boundary —
        # the inter-block carry (below) is the sole cross-block mechanism.
        pf = _shift1(fb, d, False)
        vmin = jnp.where(fb, vmin, jnp.minimum(pmin, vmin))
        vmax = jnp.where(fb, vmax, jnp.maximum(pmax, vmax))
        cnt = jnp.where(fb, cnt, pcnt + cnt)
        fb = fb | pf
        d *= 2

    # fold the inter-block carry into the open prefix (elements whose run
    # started in an earlier block, i.e. still no boundary seen).
    @pl.when(g == 0)
    def _init():
        carry_ref[0] = 1          # boundary "seen" before the first block
        carry_ref[1] = _BIG
        carry_ref[2] = -_BIG
        carry_ref[3] = 0

    open_prefix = ~fb             # no boundary in [0, i]: continue prior run
    cmin, cmax, ccnt = carry_ref[1], carry_ref[2], carry_ref[3]
    vmin = jnp.where(open_prefix, jnp.minimum(vmin, cmin), vmin)
    vmax = jnp.where(open_prefix, jnp.maximum(vmax, cmax), vmax)
    cnt = jnp.where(open_prefix, cnt + ccnt, cnt)

    omin_ref[...] = vmin
    omax_ref[...] = vmax
    ocnt_ref[...] = cnt

    # next block's carry = running aggregate at the last element
    carry_ref[1] = vmin[B - 1]
    carry_ref[2] = vmax[B - 1]
    carry_ref[3] = cnt[B - 1]


def segmented_scan(flags: jax.Array, vals: jax.Array, block: int = DEFAULT_BLOCK,
                   interpret: bool = True):
    """Inclusive segmented (min, max, count) scan; `flags[i]` starts a run.

    Length must be a multiple of ``block`` (wrapper pads with flag=True).
    """
    n = vals.shape[0]
    assert n % block == 0, (n, block)
    grid = (n // block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((block,), lambda g: (g,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((block,), lambda g: (g,)),
            pl.BlockSpec((block,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), vals.dtype),
            jax.ShapeDtypeStruct((n,), vals.dtype),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((4,), jnp.int32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),  # sequential: carry dependency
        ),
    )(flags.astype(jnp.int8), vals)
