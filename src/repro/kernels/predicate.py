"""Pallas TPU kernel: fused Expr-predicate evaluation to a packed bitset.

The extractor hot path (paper §4, Fig. 2) is one mask pass per scan branch.
PR 3 fused each branch's predicate chain into a single ``Expr`` conjunction,
but the executor still evaluated it as jnp mask algebra — one HBM round-trip
per column reference plus a materialized bool column (1 byte/row) that every
consumer re-reads.  This module compiles the serialized Expr tree into ONE
Pallas kernel:

  * one grid pass over the projected columns — every leaf op (comparisons,
    arithmetic, ``isin`` via sorted-membership rank compares, sentinel null
    tests, ``&``/``|``/``~``) evaluates entirely in VMEM;
  * the output is a **packed uint32 bitset** (1 bit/row, 8x smaller than a
    bool column) plus per-block popcounts: the mask pass itself never writes
    a bool column, and the words use the shared ``core.bitset`` layout.
    Since the bitset-native validity redesign, ``ColumnarTable.valid`` IS
    this packed form, so the kernel's output becomes the downstream table's
    validity verbatim — no unpack hop — and both the input validity and the
    result cross HBM at 1 bit/row into the cohort algebra
    (``bitset_ops``) and the compaction keep-mask (``filter_compact``).

Codegen is trace-time: ``compile_predicate`` walks the hashable param tree
(``expr.Expr.to_param`` form — the exact object plan nodes carry) and emits a
closure of jnp ops; ``pallas_call`` then lowers that closure per block.  The
``isin`` whitelists are static plan params, so they are sorted host-side and
streamed to every block; membership is the two monotone rank reductions
``rank(<= x) > rank(< x)`` — broadcast compares + sums, the TPU-native
formulation (no gather), exactly equivalent to sorted-array binary search.

**Hoisted literals are kernel operands** (the normalized-plan path): a
``("hlit", slot)`` leaf becomes a ``(1,)`` SMEM scalar parameter and a
``("hisin", x, slot, n, isfloat)`` whitelist becomes a sorted,
lane-padded VMEM vector operand staged *inside* the jit (``jnp.sort`` +
max-duplicate tail, so padding never adds members).  The compiled kernel is
therefore value-generic: two tenants' queries differing only in literals
share one executable, and ``normalize()`` no longer demotes hoisted pallas
predicates to the jnp engine (oversized whitelists and non-boolean roots
remain the only demotion causes).

Grid blocks are independent (`parallel` semantics); the wrapper pads ragged
tails with invalid rows, so any capacity works.
"""
from __future__ import annotations

import functools
import operator as _op
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401 (TPU lowering)

from repro.kernels import default_interpret

__all__ = [
    "DEFAULT_BLOCK", "MAX_ISIN_VALUES", "PREDICATE_ENGINES", "compilable",
    "compile_predicate", "default_interpret", "isin_vmem_bytes",
    "predicate_bitset", "resolve_engine",
]

DEFAULT_BLOCK = 1024           # rows per grid block; must be a multiple of 32

# sorted-membership is a (block x whitelist) broadcast in VMEM: at the
# default block, 1024 values ~ 4 MB of intermediate — comfortably resident;
# bigger whitelists fall back to the jnp engine instead of risking VMEM
# exhaustion on a real TPU (interpret-mode CI would never catch it)
MAX_ISIN_VALUES = 1024

# mirrors columnar.NULL_INT (kernels stay import-light: no repro.core deps,
# same convention as filter_compact's _INT_MIN)
_NULL_INT = -2_147_483_648 + 1

_CMP = {"==": _op.eq, "!=": _op.ne, "<": _op.lt, "<=": _op.le,
        ">": _op.gt, ">=": _op.ge}
_ARITH = {"+": _op.add, "-": _op.sub, "*": _op.mul,
          "//": _op.floordiv, "%": _op.mod}

# param tags whose value is boolean — the kernel packs bits, so the tree ROOT
# must be one of these (interior arithmetic is unrestricted)
_BOOL_TAGS = frozenset({"cmp", "bool", "not", "isin", "hisin",
                        "isnull", "notnull"})

# lane quantum the sorted whitelists are tail-padded to (static AND hoisted)
_ISIN_PAD = 8

# ---------------------------------------------------------------------------
# engine selection
# ---------------------------------------------------------------------------
PREDICATE_ENGINES = ("jnp", "pallas", "auto")


def resolve_engine(predicate_engine: Optional[str] = None,
                   engine: str = "xla") -> str:
    """Resolve the predicate engine for ``fused_mask``/``predicate`` nodes.

    ``"jnp"``/``"pallas"`` are explicit; ``"auto"`` (or ``None``) picks the
    Pallas bitset kernel when the global executor engine is already
    ``"pallas"`` or when running on a real TPU backend — the same
    backend-derived choice ``ops.default_interpret`` makes for compaction —
    and falls back to jnp mask algebra otherwise.
    """
    pe = predicate_engine or "auto"
    if pe not in PREDICATE_ENGINES:
        raise ValueError(f"predicate engine must be one of {PREDICATE_ENGINES}, "
                         f"got {pe!r}")
    if pe != "auto":
        return pe
    if engine == "pallas" or jax.default_backend() == "tpu":
        return "pallas"
    return "jnp"


def _isin_sizes(p, out: list) -> None:
    if not isinstance(p, tuple) or not p:
        return
    if p[0] == "isin":
        out.append(len(p[2]))
        _isin_sizes(p[1], out)
        return
    if p[0] == "hisin":
        out.append(int(p[3]))          # structural size: the hoisted operand
        _isin_sizes(p[1], out)         # carries exactly n values
        return
    for x in p[1:]:
        _isin_sizes(x, out)


def isin_vmem_bytes(n_values: int, block: int = DEFAULT_BLOCK) -> int:
    """VMEM bytes the in-kernel sorted-membership broadcast needs for one
    ``isin`` whitelist of ``n_values`` entries: the (block x whitelist)
    comparison intermediate plus the resident operand, int32 lanes, with the
    whitelist tail-padded to the ``_ISIN_PAD`` lane quantum (the padded form
    is what actually crosses into VMEM — static tables and hoisted operands
    alike).  The static analyzer quotes this in its engine-feasibility
    diagnostics so an oversized whitelist comes with the budget it would
    blow."""
    n = max(int(n_values), 1)
    n_pad = n + (-n) % _ISIN_PAD
    return 4 * (block * n_pad + n_pad)


def compilable(expr_param) -> bool:
    """True when the serialized Expr can compile to the bitset kernel:

      * the root must be boolean-valued (packing bits of an arithmetic value
        would be meaningless), and
      * every ``isin``/``hisin`` whitelist must fit the VMEM membership
        budget (``MAX_ISIN_VALUES``; larger lists would blow the in-kernel
        broadcast on a real TPU).  Hoisted whitelists count their structural
        size ``n`` — the operand carries exactly that many values.

    Hoisted slot refs (``hlit``/``hisin``) are kernel *operands* — SMEM
    scalars and sorted VMEM vectors — so normalized plans compile too.
    Non-compilable exprs stay on the jnp engine (``assign_engines`` stamps
    them back; the executor double-checks; ``normalize`` demotes hoisted
    pallas nodes only when this predicate says no)."""
    if not (isinstance(expr_param, tuple) and len(expr_param) > 0
            and expr_param[0] in _BOOL_TAGS):
        return False
    sizes: list = []
    _isin_sizes(expr_param, sizes)
    return all(s <= MAX_ISIN_VALUES for s in sizes)


# ---------------------------------------------------------------------------
# Expr-param -> kernel-body codegen
# ---------------------------------------------------------------------------
def _is_null(v: jax.Array) -> jax.Array:
    if jnp.issubdtype(v.dtype, jnp.floating):
        return jnp.isnan(v)
    return v == jnp.asarray(_NULL_INT, v.dtype)


def _sorted_member(x: jax.Array, tbl: jax.Array) -> jax.Array:
    """Sorted-membership: x ∈ tbl iff rank(tbl <= x) > rank(tbl < x).

    Two monotone rank reductions over the sorted whitelist — broadcast
    compares + row sums, all VPU work in VMEM (binary search without the
    gathers TPUs lack).  NaN probes compare false both ways -> non-member,
    matching ``jnp.isin``.
    """
    rd = jnp.promote_types(x.dtype, tbl.dtype)
    xb = x.astype(rd)[:, None]
    tb = tbl.astype(rd)[None, :]
    le = (tb <= xb).sum(axis=1)
    lt = (tb < xb).sum(axis=1)
    return le > lt


def compile_predicate(expr_param: Tuple):
    """Compile a serialized Expr (``Expr.to_param`` nested tuples) into
    ``(columns, isin_tables, eval_fn, lit_slots, vec_slots)``.

    ``columns`` is the ordered tuple of column operands (the kernel's
    projected inputs); ``isin_tables`` holds one sorted (tail-padded with its
    own max, so padding can never match) numpy whitelist per ``isin`` leaf;
    ``lit_slots`` is the ordered tuple of ``hlit`` slot ids the expr reads
    (each becomes an SMEM scalar parameter) and ``vec_slots`` the ordered
    ``(slot, n, isfloat)`` triples of its ``hisin`` leaves (each a sorted
    VMEM vector operand).  ``eval_fn(env, tables, lits, vecs)`` maps
    {column: block array} + table blocks + {slot: scalar} + {slot: sorted
    operand} to the boolean mask block — pure jnp, traceable inside a Pallas
    kernel body.
    """
    columns: List[str] = []
    tables: List[np.ndarray] = []
    lit_slots: List[int] = []
    vec_slots: List[Tuple[int, int, bool]] = []

    def walk(p) -> Callable:
        tag = p[0]
        if tag == "col":
            name = p[1]
            if name not in columns:
                columns.append(name)
            return lambda env, tbls, lits, vecs: env[name]
        if tag == "lit":
            v = p[1]
            return lambda env, tbls, lits, vecs: v
        if tag == "hlit":
            slot = int(p[1])
            if slot not in lit_slots:
                lit_slots.append(slot)
            return lambda env, tbls, lits, vecs: lits[slot]
        if tag == "cmp":
            f, l, r = _CMP[p[1]], walk(p[2]), walk(p[3])
            return lambda env, tbls, lits, vecs: f(l(env, tbls, lits, vecs),
                                                   r(env, tbls, lits, vecs))
        if tag == "arith":
            f, l, r = _ARITH[p[1]], walk(p[2]), walk(p[3])
            return lambda env, tbls, lits, vecs: f(l(env, tbls, lits, vecs),
                                                   r(env, tbls, lits, vecs))
        if tag == "bool":
            l, r = walk(p[2]), walk(p[3])
            if p[1] == "and":
                return lambda env, tbls, lits, vecs: (
                    l(env, tbls, lits, vecs) & r(env, tbls, lits, vecs))
            return lambda env, tbls, lits, vecs: (
                l(env, tbls, lits, vecs) | r(env, tbls, lits, vecs))
        if tag == "not":
            x = walk(p[1])
            return lambda env, tbls, lits, vecs: ~x(env, tbls, lits, vecs)
        if tag in ("isnull", "notnull"):
            x = walk(p[1])
            if tag == "notnull":
                return lambda env, tbls, lits, vecs: ~_is_null(
                    jnp.asarray(x(env, tbls, lits, vecs)))
            return lambda env, tbls, lits, vecs: _is_null(
                jnp.asarray(x(env, tbls, lits, vecs)))
        if tag == "isin":
            x = walk(p[1])
            vals = p[2]
            if not vals:   # empty whitelist matches nothing
                return lambda env, tbls, lits, vecs: jnp.zeros(
                    jnp.shape(jnp.asarray(x(env, tbls, lits, vecs))), bool)
            dt = np.float32 if any(isinstance(c, float) for c in vals) \
                else np.int32
            tbl = np.sort(np.asarray(vals, dt))
            pad = (-tbl.size) % _ISIN_PAD
            if pad:        # lane-align; max-duplicate padding never matches new values
                tbl = np.concatenate([tbl, np.full(pad, tbl[-1], dt)])
            ti = len(tables)
            tables.append(tbl)
            return lambda env, tbls, lits, vecs: _sorted_member(
                jnp.asarray(x(env, tbls, lits, vecs)), tbls[ti])
        if tag == "hisin":
            x = walk(p[1])
            slot, n, isfloat = int(p[2]), int(p[3]), bool(p[4])
            if n == 0:     # empty whitelist matches nothing (no operand)
                return lambda env, tbls, lits, vecs: jnp.zeros(
                    jnp.shape(jnp.asarray(x(env, tbls, lits, vecs))), bool)
            if slot not in [s for s, _, _ in vec_slots]:
                vec_slots.append((slot, n, isfloat))
            return lambda env, tbls, lits, vecs: _sorted_member(
                jnp.asarray(x(env, tbls, lits, vecs)), vecs[slot])
        raise ValueError(f"unknown Expr param tag {tag!r}")

    if expr_param[0] not in _BOOL_TAGS:
        raise ValueError(
            f"pallas predicate engine needs a boolean-valued expression root, "
            f"got tag {expr_param[0]!r} (use the jnp engine)")
    eval_fn = walk(expr_param)
    return (tuple(columns), tuple(tables), eval_fn,
            tuple(lit_slots), tuple(vec_slots))


# ---------------------------------------------------------------------------
# kernel + wrapper
# ---------------------------------------------------------------------------
def _make_kernel(eval_fn: Callable, names: Sequence[str], n_tables: int,
                 vec_slot_ids: Sequence[int], lit_slot_ids: Sequence[int],
                 lit_bool: Sequence[bool]):
    """Kernel ref order: [cols...] [static isin tables...] [hoisted isin
    vectors...] [hoisted lit SMEM scalars...] [packed valid] | [words, pc].
    Bool lits are staged as int32 (SMEM-safe) and cast back here."""
    def _kernel(*refs):
        k = len(names)
        col_refs = refs[:k]
        tbl_refs = refs[k:k + n_tables]
        k += n_tables
        vec_refs = refs[k:k + len(vec_slot_ids)]
        k += len(vec_slot_ids)
        lit_refs = refs[k:k + len(lit_slot_ids)]
        valid_ref = refs[k + len(lit_slot_ids)]
        words_ref, pc_ref = refs[-2:]

        from repro.kernels import unpack_words_block

        env = {nm: r[...] for nm, r in zip(names, col_refs)}
        tbls = [r[...] for r in tbl_refs]
        vecs = {s: r[...] for s, r in zip(vec_slot_ids, vec_refs)}
        lits = {s: (r[0] != 0 if b else r[0])
                for s, b, r in zip(lit_slot_ids, lit_bool, lit_refs)}
        # validity arrives PACKED (1 bit/row of HBM); expand in VMEM only
        m = eval_fn(env, tbls, lits, vecs) & unpack_words_block(valid_ref[...])

        B = m.shape[0]
        lanes = jax.lax.broadcasted_iota(jnp.uint32, (B // 32, 32), 1)
        bits = m.reshape(B // 32, 32).astype(jnp.uint32) << lanes
        words_ref[...] = bits.sum(axis=1).astype(jnp.uint32)
        pc_ref[0] = m.astype(jnp.int32).sum()

    return _kernel


def _stage_hoisted(lit_slots: Sequence[int],
                   vec_slots: Sequence[Tuple[int, int, bool]],
                   params: Tuple[Dict[int, jax.Array], Dict[int, jax.Array]]):
    """Stage bound ``{slot: value}`` maps as kernel operands (traced — runs
    inside the jit): each ``hlit`` slot becomes a ``(1,)`` scalar (bools as
    int32, SMEM has no bool lanes) and each ``hisin`` slot a sorted vector
    tail-padded to the lane quantum with its own max (padding duplicates an
    existing member, so membership is unchanged)."""
    b_lits, b_vecs = params
    lit_ops, lit_bool = [], []
    for slot in lit_slots:
        v = jnp.asarray(b_lits[slot])
        isb = v.dtype == jnp.bool_
        lit_bool.append(isb)
        lit_ops.append(v.reshape(1).astype(jnp.int32) if isb
                       else v.reshape(1))
    vec_ops = []
    for slot, n, _ in vec_slots:
        v = jnp.asarray(b_vecs[slot])
        if v.shape != (n,):
            raise ValueError(f"hoisted whitelist slot {slot}: bound value "
                             f"has shape {v.shape}, expr expects ({n},)")
        s = jnp.sort(v)
        pad = (-n) % _ISIN_PAD
        if pad:
            s = jnp.concatenate([s, jnp.full((pad,), s[-1], s.dtype)])
        vec_ops.append(s)
    return lit_ops, tuple(lit_bool), vec_ops


def predicate_bitset_blocks(expr_param: Tuple, cols: Dict[str, jax.Array],
                            valid_words: jax.Array, block: int = DEFAULT_BLOCK,
                            interpret: Optional[bool] = None,
                            params: Tuple[Dict, Dict] = ({}, {})):
    """One fused pass: evaluate ``expr_param`` over ``cols`` AND the packed
    ``valid_words`` bitset (``core.bitset`` layout — validity is streamed at
    1 bit/row, not a bool column).

    Returns ``(words, popcounts)`` — the packed uint32 bitset (n/32 words)
    and the per-block popcounts.  Column length must be a multiple of
    ``block`` (``predicate_bitset`` pads); ``block`` a multiple of 32;
    ``valid_words`` holds exactly n/32 words.  ``params`` is the bound
    ``(lits, vecs)`` pair backing any hoisted slot refs in the expr.
    """
    interpret = default_interpret() if interpret is None else interpret
    assert block % 32 == 0, block
    n = valid_words.shape[0] * 32
    assert n % block == 0, (n, block)
    grid = (n // block,)
    names, tables, eval_fn, lit_slots, vec_slots = compile_predicate(
        expr_param)
    missing = [nm for nm in names if nm not in cols]
    if missing:
        raise KeyError(f"predicate reads absent column(s) {missing}")
    lit_ops, lit_bool, vec_ops = _stage_hoisted(lit_slots, vec_slots, params)

    in_specs = [pl.BlockSpec((block,), lambda g: (g,)) for _ in names]
    in_specs += [pl.BlockSpec((int(t.size),), lambda g: (0,)) for t in tables]
    in_specs += [pl.BlockSpec((int(v.shape[0]),), lambda g: (0,))
                 for v in vec_ops]
    # scalar literal params live in SMEM — one (1,) ref per slot, read
    # whole (no index_map: scalars are grid-invariant)
    in_specs += [pl.BlockSpec(memory_space=pltpu.SMEM) for _ in lit_ops]
    in_specs += [pl.BlockSpec((block // 32,), lambda g: (g,))]
    operands = ([cols[nm] for nm in names]
                + [jnp.asarray(t) for t in tables]
                + vec_ops + lit_ops
                + [valid_words.astype(jnp.uint32)])
    return pl.pallas_call(
        _make_kernel(eval_fn, names, len(tables),
                     [s for s, _, _ in vec_slots], lit_slots, lit_bool),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block // 32,), lambda g: (g,)),
            pl.BlockSpec((1,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n // 32,), jnp.uint32),
            jax.ShapeDtypeStruct((grid[0],), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)


def _pad_to(x: jax.Array, mult: int, fill=0):
    n = x.shape[0]
    p = (-n) % mult
    if p == 0:
        return x
    return jnp.concatenate([x, jnp.full((p,), fill, x.dtype)])


@functools.partial(jax.jit,
                   static_argnames=("expr_param", "block", "interpret", "n"))
def _predicate_bitset_jit(columns: Dict[str, jax.Array], words: jax.Array,
                          params: Tuple[Tuple, Tuple], *,
                          expr_param: Tuple, block: int,
                          interpret: Optional[bool], n: int):
    if n == 0:
        return jnp.zeros((0,), jnp.uint32), jnp.int32(0)
    cols = {nm: _pad_to(c, block) for nm, c in columns.items()}
    wp = _pad_to(words, block // 32)
    out, pc = predicate_bitset_blocks(expr_param, cols, wp, block=block,
                                      interpret=interpret, params=params)
    return out[: (n + 31) // 32], pc.sum().astype(jnp.int32)


def predicate_bitset(columns: Dict[str, jax.Array], valid: jax.Array, *,
                     expr_param: Tuple, block: int = DEFAULT_BLOCK,
                     interpret: Optional[bool] = None,
                     capacity: Optional[int] = None,
                     params: Optional[Tuple[Tuple, Tuple]] = None):
    """Fused predicate -> packed bitset over a table's columns.

    ``valid`` is the table's validity: the canonical packed uint32 word form
    (``ColumnarTable.valid``) or a legacy ``(n,) bool`` row mask, which is
    packed at the boundary.  Returns ``(words, count)``: ``words`` is the
    ceil(n/32)-word uint32 bitset of ``valid & expr`` (row i lives at word
    i//32, bit i%32 — the shared ``core.bitset`` layout, so the result drops
    straight into the table validity and the cohort algebra kernel),
    ``count`` the total surviving rows.  Columns are padded to the block
    quantum with invalid rows.  Only the columns the expression reads are
    passed into the jit boundary — handing in a whole wide table costs
    nothing extra and never retraces on unrelated columns.  ``capacity``
    names the row count when ``valid`` is packed; it defaults to the first
    column's length.  ``params`` is the bound ``(lits, vecs)`` pair backing
    hoisted slot refs (normalized plans); exprs with ``hlit``/``hisin``
    leaves raise without it — the same contract as evaluating a hoisted
    Expr outside ``expr.bound_params``.  Literal *values* are traced
    operands, so they never retrace or recompile this jit.
    """
    names, _, _, lit_slots, vec_slots = compile_predicate(expr_param)
    b_lits, b_vecs = params if params is not None else ((), ())
    want = max(list(lit_slots) + [-1]), max([s for s, _, _ in vec_slots]
                                            + [-1])
    if want[0] >= len(b_lits) or want[1] >= len(b_vecs):
        raise RuntimeError(
            "expr has hoisted slot refs with no bound value; pass "
            "params=(lits, vecs) (see expr.bound_params)")
    # subset to the slots THIS expr reads — other nodes' literals must not
    # become dead operands of (or retrace triggers for) this executable
    used = ({s: b_lits[s] for s in lit_slots},
            {s: b_vecs[s] for s, _, _ in vec_slots})
    missing = [nm for nm in names if nm not in columns]
    if missing:
        raise KeyError(f"predicate reads absent column(s) {missing}")
    if getattr(valid, "dtype", None) == jnp.uint32:
        if capacity is None:
            if not names:
                raise ValueError("packed valid needs an explicit capacity "
                                 "when the predicate reads no columns")
            capacity = int(columns[names[0]].shape[0])
        words = valid
    else:
        valid = jnp.asarray(valid, bool)
        capacity = int(valid.shape[0])
        from repro.core.bitset import pack as _pack

        words = _pack(valid)
    return _predicate_bitset_jit({nm: columns[nm] for nm in names}, words,
                                 used, expr_param=expr_param, block=block,
                                 interpret=interpret, n=capacity)
